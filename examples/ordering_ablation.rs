//! Table 1 driver: Pearson vs reverse-Pearson ordering for CGAVI-IHB+SVM
//! on the six registry datasets.
//!
//! Run: `cargo run --release --example ordering_ablation [scale] [splits]`

use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::data::load_registry_dataset;
use avi_scale::oavi::OaviConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::report::{run_cell, Method, Protocol};
use avi_scale::estimator::EstimatorConfig;

fn main() -> avi_scale::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().and_then(|v| v.parse().ok()).unwrap_or(0.03);
    let splits: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(3);
    let pool = ThreadPool::default_size();

    println!("Table 1 (CGAVI-IHB+SVM; scale {scale}, {splits} splits; paper uses 10 splits)\n");
    println!("{:<10} {:>14} {:>18} {:>8}", "dataset", "Pearson err%", "rev-Pearson err%", "delta");
    for name in ["bank", "credit", "htru", "seeds", "skin", "spam"] {
        let ds = load_registry_dataset(name, scale, 3)?;
        let mut errs = Vec::new();
        for ordering in [FeatureOrdering::Pearson, FeatureOrdering::ReversePearson] {
            let protocol = Protocol {
                n_splits: splits,
                cv_folds: 3,
                psis: &[0.01, 0.005],
                lambdas: &[1e-3],
                ordering,
                ..Default::default()
            };
            let cell = run_cell(
                Method::Estimator(EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.005))),
                &ds,
                &protocol,
                &pool,
            )?;
            errs.push(cell.error_mean * 100.0);
        }
        println!(
            "{name:<10} {:>14.2} {:>18.2} {:>8.2}",
            errs[0],
            errs[1],
            (errs[0] - errs[1]).abs()
        );
    }
    println!("\npaper shape: deltas are small (≤ ~0.2pp) — the ordering choice barely matters");
    Ok(())
}

//! Figures 2/3/4 driver: training time vs number of samples for the
//! solver/IHB/algorithm comparisons, on bank/htru/skin/synthetic.
//!
//! Run: `cargo run --release --example scaling_curves [figure] [scale] [runs]`
//!   figure ∈ {2, 3, 4, all}    (default all)
//!   scale  ∈ (0,1]             (default 0.05 — skin/synthetic get large)
//!   runs   : reps per point    (default 3; paper 10)

use avi_scale::bench::figures::{
    fig2_methods, fig3_methods, fig4_methods, training_time_sweep, SweepSpec,
};
use avi_scale::bench::report_figure;

fn main() -> avi_scale::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let which = args.first().cloned().unwrap_or_else(|| "all".into());
    let scale: f64 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(0.05);
    let runs: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(3);

    let spec = SweepSpec {
        datasets: vec!["bank".into(), "htru".into(), "skin".into(), "synthetic".into()],
        fractions: vec![0.125, 0.25, 0.5, 0.75, 1.0],
        runs,
        psi: 0.005,
        scale,
        seed: 0xF16,
    };

    if which == "2" || which == "all" {
        println!("### Figure 2: PCGAVI vs BPCGAVI");
        for (ds, series) in training_time_sweep(&fig2_methods(), &spec)? {
            report_figure(&format!("fig2_{ds}"), "m", &series);
        }
    }
    if which == "3" || which == "all" {
        println!("### Figure 3: BPCGAVI vs BPCGAVI-WIHB vs CGAVI-IHB");
        for (ds, series) in training_time_sweep(&fig3_methods(), &spec)? {
            report_figure(&format!("fig3_{ds}"), "m", &series);
        }
    }
    if which == "4" || which == "all" {
        println!("### Figure 4: CGAVI-IHB / BPCGAVI-WIHB / AGDAVI-IHB / ABM / VCA");
        for (ds, series) in training_time_sweep(&fig4_methods(), &spec)? {
            report_figure(&format!("fig4_{ds}"), "m", &series);
        }
    }
    Ok(())
}

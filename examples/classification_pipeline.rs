//! END-TO-END DRIVER (Table 3): the full Algorithm-2 system on the real
//! (simulated-registry) workloads — per-class OAVI/ABM/VCA generator
//! construction, (FT) feature transform, ℓ1 linear SVM, 3-fold CV
//! hyperparameter search, 60/40 splits — reporting the paper's headline
//! metrics (test error, hyperopt time, test time, |G|+|O|, degree, SPAR).
//!
//! Run: `cargo run --release --example classification_pipeline [scale] [splits] [--xla]`
//!   scale  ∈ (0,1]: dataset size multiplier (default 0.05)
//!   splits : random 60/40 partitions          (default 3; paper 10)
//!   --xla  : also verify one OAVI fit through the PJRT artifact backend

use avi_scale::baselines::abm::AbmConfig;
use avi_scale::baselines::vca::VcaConfig;
use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::data::load_registry_dataset;
use avi_scale::oavi::OaviConfig;
use avi_scale::pipeline::report::{format_table, run_cell, Method, Protocol};
use avi_scale::estimator::EstimatorConfig;

fn main() -> avi_scale::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().and_then(|v| v.parse().ok()).unwrap_or(0.05);
    let splits: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(3);
    let use_xla = args.iter().any(|a| a == "--xla");

    let methods = [
        Method::Estimator(EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.005))),
        Method::Estimator(EstimatorConfig::Oavi(OaviConfig::agdavi_ihb(0.005))),
        Method::Estimator(EstimatorConfig::Oavi(OaviConfig::bpcgavi_wihb(0.005))),
        Method::Estimator(EstimatorConfig::Abm(AbmConfig::new(0.005))),
        Method::Estimator(EstimatorConfig::Vca(VcaConfig::new(0.005))),
        Method::KernelSvm,
    ];
    let pool = ThreadPool::default_size();
    println!(
        "Table 3 reproduction: scale={scale}, splits={splits}, workers={}\n",
        pool.workers()
    );

    if use_xla {
        verify_xla_path()?;
    }

    let mut cells = Vec::new();
    for name in ["bank", "credit", "htru", "seeds", "skin", "spam"] {
        let ds = load_registry_dataset(name, scale, 9)?;
        println!("--- {name} (m={}, n={}, k={})", ds.len(), ds.n_features(), ds.n_classes);
        let protocol = Protocol {
            n_splits: splits,
            cv_folds: 3,
            psis: &[0.01, 0.005, 0.001],
            lambdas: &[1e-2, 1e-3],
            ..Default::default()
        };
        for method in methods {
            let cell = run_cell(method, &ds, &protocol, &pool)?;
            println!(
                "  {:<22} err {:>6.2}%  hyper {:>8.2}s  test {:>8.4}s  |G|+|O| {:>7.1}",
                cell.method,
                cell.error_mean * 100.0,
                cell.hyper_secs,
                cell.test_secs,
                cell.size
            );
            cells.push(cell);
        }
    }
    println!("\n===== Table 3 =====\n{}", format_table(&cells));
    let rows: Vec<Vec<f64>> = cells
        .iter()
        .map(|c| {
            vec![c.error_mean, c.error_std, c.hyper_secs, c.test_secs, c.size, c.degree, c.spar]
        })
        .collect();
    avi_scale::data::csvio::write_csv(
        std::path::Path::new("target/bench_results/classification_pipeline.csv"),
        &["error_mean", "error_std", "hyper_secs", "test_secs", "size", "degree", "spar"],
        &rows,
    )?;
    println!("[csv] target/bench_results/classification_pipeline.csv");
    Ok(())
}

/// Prove the PJRT path composes with the pipeline: one fit through the
/// AOT Pallas artifacts must reproduce the native generator structure.
fn verify_xla_path() -> avi_scale::Result<()> {
    use avi_scale::oavi::Oavi;
    use avi_scale::runtime::{PjrtRuntime, XlaBackend};
    use std::sync::Arc;

    let rt = Arc::new(PjrtRuntime::load_default()?);
    let backend = XlaBackend::new(rt);
    let ds = load_registry_dataset("bank", 0.3, 9)?;
    let x = ds.class_matrix(0);
    let cfg = OaviConfig::cgavi_ihb(0.005);
    let native = Oavi::new(cfg).fit(&x)?;
    let xla = Oavi::new(cfg).fit_with_backend(&x, &backend)?;
    assert_eq!(native.total_size(), xla.total_size());
    println!(
        "[xla] PJRT artifact path verified: |G|+|O| = {} matches native\n",
        xla.total_size()
    );
    Ok(())
}

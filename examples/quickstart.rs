//! Quickstart: fit OAVI on the paper's synthetic dataset, inspect the
//! generators, transform features, and train the downstream SVM.
//!
//! Run: `cargo run --release --example quickstart`

use avi_scale::data::splits::train_test_split;
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::oavi::{Oavi, OaviConfig};
use avi_scale::ordering::FeatureOrdering;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::pipeline::{train_pipeline, PipelineConfig};
use avi_scale::svm::linear::LinearSvmConfig;

fn main() -> avi_scale::Result<()> {
    // 1. data: the Appendix-C synthetic set (two quadric surfaces + noise)
    let ds = synthetic_dataset(5_000, 42);
    println!("dataset: {} samples, {} features, {} classes", ds.len(), ds.n_features(), ds.n_classes);

    // 2. fit OAVI on one class and look at what it found
    let cfg = OaviConfig::cgavi_ihb(0.005);
    let model = Oavi::new(cfg).fit(&ds.class_matrix(0))?;
    println!("\nCGAVI-IHB on class 0:");
    println!("  |G| = {}, |O| = {}, degree reached = {}", model.generators.len(), model.o_terms.len(), model.stats.degree_reached);
    println!("  oracle calls = {} (= |G|+|O|−1)", model.stats.oracle_calls);
    println!("  IHB closed-form solves = {}", model.stats.ihb_solves);
    for (i, g) in model.generators.iter().take(4).enumerate() {
        println!("  g{i}: leading {} (degree {}), training MSE {:.2e}", g.leading, g.degree(), g.mse);
    }
    println!("\n  as polynomials (coefficients < 1e-3 hidden):");
    for desc in model.generator_set().describe(1e-3).iter().take(3) {
        println!("    {desc} = 0  (approximately)");
    }

    // 3. the full Algorithm-2 pipeline: per-class OAVI → |g(x)| features → ℓ1 SVM
    let split = train_test_split(&ds, 0.6, 7);
    let pipeline_cfg = PipelineConfig {
        estimator: EstimatorConfig::Oavi(cfg),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    let pipeline = train_pipeline(&pipeline_cfg, &split.train)?;
    println!("\npipeline: {} transformed features", pipeline.transformer.n_generators());
    println!("test error: {:.2}%", pipeline.error_on(&split.test) * 100.0);
    Ok(())
}

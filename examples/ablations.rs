//! Ablation study over the design choices DESIGN.md calls out:
//!
//! * **ψ** — the vanishing parameter: |G|+|O|, degree, accuracy, time.
//! * **τ** — the (CCOP) ℓ1 radius: (INF) frequency, IHB viability,
//!   generalization-bound trade-off (paper §4.4.3).
//! * **ε-factor** — solver accuracy: does looser solving hurt?
//! * **IHB / WIHB / no-IHB** — speed vs sparsity (the §4.4 trade-off).
//!
//! Run: `cargo run --release --example ablations [scale]`

use avi_scale::data::load_registry_dataset;
use avi_scale::data::splits::train_test_split;
use avi_scale::oavi::{Oavi, OaviConfig};
use avi_scale::ordering::FeatureOrdering;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::pipeline::{train_pipeline, PipelineConfig};
use avi_scale::svm::linear::LinearSvmConfig;
use avi_scale::util::timer::Timer;

fn main() -> avi_scale::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.05);
    let ds = load_registry_dataset("htru", scale, 17)?;
    let split = train_test_split(&ds, 0.6, 1);
    println!("ablations on htru (m={}, n={})\n", ds.len(), ds.n_features());

    // ---- ψ sweep -------------------------------------------------------
    println!("## ψ sweep (CGAVI-IHB)");
    println!(
        "{:>8} {:>9} {:>7} {:>9} {:>10} {:>8}",
        "psi", "|G|+|O|", "deg", "err %", "fit s", "D bound"
    );
    for psi in [0.1, 0.05, 0.01, 0.005, 0.001, 0.0005] {
        let cfg = OaviConfig::cgavi_ihb(psi);
        let t = Timer::start();
        let pipe = train_pipeline(
            &PipelineConfig {
                estimator: EstimatorConfig::Oavi(cfg),
                svm: LinearSvmConfig::default(),
                ordering: FeatureOrdering::Pearson,
            },
            &split.train,
        )?;
        let secs = t.secs();
        println!(
            "{:>8} {:>9} {:>7.2} {:>9.2} {:>10.4} {:>8}",
            psi,
            pipe.transformer.total_size(),
            pipe.transformer.avg_degree(),
            pipe.error_on(&split.test) * 100.0,
            secs,
            cfg.theorem_degree()
        );
    }

    // ---- τ sweep -------------------------------------------------------
    println!("\n## τ sweep (CGAVI-IHB; (INF) disables IHB when the closed form leaves the ball)");
    println!(
        "{:>8} {:>9} {:>10} {:>12} {:>12}",
        "tau", "|G|+|O|", "max ℓ1", "INF fired", "solver runs"
    );
    for tau in [2.0, 5.0, 20.0, 100.0, 1000.0] {
        let mut cfg = OaviConfig::cgavi_ihb(0.005);
        cfg.tau = tau;
        let x0 = split.train.class_matrix(0);
        let model = Oavi::new(cfg).fit(&x0)?;
        println!(
            "{:>8} {:>9} {:>10.2} {:>12} {:>12}",
            tau,
            model.total_size(),
            model.generator_set().max_coeff_l1(),
            model.stats.inf_disabled_ihb,
            model.stats.solver_runs
        );
    }

    // ---- ε-factor sweep -------------------------------------------------
    println!("\n## solver-accuracy sweep (BPCGAVI, ε = factor·ψ)");
    println!("{:>10} {:>9} {:>10} {:>12}", "factor", "|G|+|O|", "fit s", "solver iters");
    for factor in [1.0, 0.1, 0.01, 0.001] {
        let mut cfg = OaviConfig::bpcgavi(0.005);
        cfg.eps_factor = factor;
        let x0 = split.train.class_matrix(0);
        let t = Timer::start();
        let model = Oavi::new(cfg).fit(&x0)?;
        println!(
            "{:>10} {:>9} {:>10.4} {:>12}",
            factor,
            model.total_size(),
            t.secs(),
            model.stats.solver_iters
        );
    }

    // ---- IHB mode comparison --------------------------------------------
    println!("\n## IHB mode (speed vs sparsity, paper §4.4)");
    println!(
        "{:<14} {:>10} {:>8} {:>9} {:>12} {:>12}",
        "mode", "fit s", "SPAR", "err %", "ihb solves", "solver runs"
    );
    for (name, cfg) in [
        ("CGAVI-IHB", OaviConfig::cgavi_ihb(0.005)),
        ("BPCGAVI-WIHB", OaviConfig::bpcgavi_wihb(0.005)),
        ("BPCGAVI", OaviConfig::bpcgavi(0.005)),
    ] {
        let t = Timer::start();
        let pipe = train_pipeline(
            &PipelineConfig {
                estimator: EstimatorConfig::Oavi(cfg),
                svm: LinearSvmConfig::default(),
                ordering: FeatureOrdering::Pearson,
            },
            &split.train,
        )?;
        let secs = t.secs();
        let x0 = split.train.class_matrix(0);
        let model = Oavi::new(cfg).fit(&x0)?;
        println!(
            "{:<14} {:>10.4} {:>8.2} {:>9.2} {:>12} {:>12}",
            name,
            secs,
            pipe.transformer.sparsity(),
            pipe.error_on(&split.test) * 100.0,
            model.stats.ihb_solves,
            model.stats.solver_runs + model.stats.wihb_resolves
        );
    }
    Ok(())
}

//! Figure 1 driver: Theorem 4.3 bound curves (left) and bound-vs-empirical
//! |G|+|O| on random data (right).  Writes CSVs under target/bench_results.
//!
//! Run: `cargo run --release --example bound_plot [m] [runs]`

use avi_scale::bench::figures::{fig1_bound_curves, fig1_empirical};
use avi_scale::bench::report_figure;

fn main() -> avi_scale::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(10_000);
    let runs: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(10);

    let psis: Vec<f64> = (0..14).map(|i| 10f64.powf(-0.3 * i as f64 - 0.3)).collect();
    let left = fig1_bound_curves(&[1, 10, 50, 100, 250], &psis);
    report_figure("fig1_left", "psi*1e6", &{
        let mut s = left.clone();
        for ser in &mut s {
            for p in &mut ser.points {
                p.0 *= 1e6;
            }
        }
        s
    });

    println!("\nempirical run: m = {m}, runs = {runs}, psi = 0.005 (paper: m = 10,000, 10 runs)");
    let right = fig1_empirical(m, &[1, 2, 3, 4, 5, 6], 0.005, runs, 0xF1)?;
    report_figure("fig1_right", "n", &right);
    Ok(())
}

//! Coordinator demo: train a pipeline, start the batched transform
//! service, fire concurrent clients, report throughput + latency
//! percentiles + batching stats.
//!
//! Run: `cargo run --release --example serve_demo [requests] [clients]`

use std::sync::Arc;

use avi_scale::coordinator::service::{latency_percentiles, ServeConfig, TransformService};
use avi_scale::data::splits::train_test_split;
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::oavi::OaviConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::pipeline::{train_pipeline, PipelineConfig};
use avi_scale::svm::linear::LinearSvmConfig;

fn main() -> avi_scale::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_req: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(20_000);
    let clients: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(8);

    let ds = synthetic_dataset(8_000, 5);
    let split = train_test_split(&ds, 0.6, 1);
    let cfg = PipelineConfig {
        estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.005)),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    let model = Arc::new(train_pipeline(&cfg, &split.train)?);
    println!("model trained: {} features, test rows available: {}", model.transformer.n_generators(), split.test.len());

    let svc = TransformService::start(model, ServeConfig::default());
    let rows: Vec<Vec<f64>> = (0..n_req)
        .map(|i| split.test.x.row(i % split.test.len()).to_vec())
        .collect();

    let t0 = std::time::Instant::now();
    let latencies = std::sync::Mutex::new(Vec::with_capacity(n_req));
    let queue = std::sync::Mutex::new(rows);
    std::thread::scope(|scope| {
        for _ in 0..clients {
            scope.spawn(|| loop {
                let row = queue.lock().unwrap().pop();
                match row {
                    Some(r) => {
                        let resp = svc.predict_blocking(r).expect("predict");
                        let lat = resp.queue_latency + resp.compute_latency;
                        latencies.lock().unwrap().push(lat.as_secs_f64() * 1e6);
                    }
                    None => break,
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let lat = latencies.into_inner().unwrap();
    let (p50, p95, p99) = latency_percentiles(lat);
    println!("requests   = {n_req} from {clients} concurrent clients");
    println!("throughput = {:.0} req/s", n_req as f64 / wall);
    println!("latency    = p50 {p50:.0}us  p95 {p95:.0}us  p99 {p99:.0}us");
    println!(
        "batches    = {} (max batch size {})",
        svc.metrics.batches.load(std::sync::atomic::Ordering::Relaxed),
        svc.metrics.max_batch.load(std::sync::atomic::Ordering::Relaxed),
    );
    svc.shutdown();
    Ok(())
}

//! Offline stub of the `xla` crate (xla_extension 0.5.1 bindings).
//!
//! The real crate links the PJRT C API, which is unavailable in this
//! build environment.  This stub exposes the exact API surface
//! `avi_scale::runtime` consumes; [`PjRtClient::cpu`] fails at runtime
//! with a descriptive error, so `PjrtRuntime::load` errors out, the
//! parity tests print their SKIP message, and the CLI reports
//! `--backend xla` as unavailable — every other code path is pure Rust
//! and unaffected.  Replace the `xla = { path = "xla-stub" }` dependency
//! with the real crate to enable PJRT execution; no call-site changes.

use std::fmt;

/// Error type mirroring the real crate's (Display is all callers use).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("xla stub: PJRT runtime not linked in this build (see rust/xla-stub)".into())
}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (stub carries no data — nothing executes).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text interchange).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle (`Rc`-based in the real crate — deliberately
/// `!Send`, which the `ComputeBackend` design in `backend/mod.rs`
/// documents and preserves).
#[derive(Debug)]
pub struct PjRtClient {
    _not_send: std::rc::Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

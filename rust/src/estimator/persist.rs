//! Unified model persistence: ONE versioned envelope for every
//! estimator, replacing the old parallel `oavi/persist.rs` (generator
//! sets only) and `pipeline/persist.rs` (monomial-aware pipelines only)
//! paths.  VCA's op-DAG serializes like everything else.
//!
//! Documents are hand-rolled JSON (serde is unavailable offline) with a
//! versioned header:
//!
//! ```json
//! { "format": "avi-scale-model", "version": 1,
//!   "estimator": "CGAVI-IHB", "kind": "generator-set",
//!   "payload": { ... } }
//! ```
//!
//! * `format` discriminates single fitted models
//!   ([`FORMAT_MODEL`]) from whole pipelines ([`FORMAT_PIPELINE`]).
//! * `version` gates evolution: unknown versions are rejected loudly
//!   instead of mis-parsed.
//! * `kind` selects the payload codec ([`KIND_GENERATOR_SET`] for the
//!   monomial-aware methods, [`KIND_VCA_DAG`] for VCA) — the one place a
//!   new estimator registers its serialization.
//!
//! Numeric fidelity: floats are emitted with Rust's shortest-round-trip
//! formatting, so a loaded model transforms **bit-identically** to the
//! fitted one (pinned by `rust/tests/estimator_conformance.rs`).
//!
//! The same envelope also travels in a compact binary form — the `AVIB`
//! codec in [`crate::artifact::codec`] (raw little-endian f64 bits, so
//! fidelity is bitwise by construction).  [`model_from_bytes`] /
//! [`pipeline_from_bytes`] are the version gate that makes the two
//! codecs interchangeable: the leading magic byte selects the decoder.

use std::fs;
use std::path::Path;

use crate::baselines::vca::{VcaModel, VcaNode};
use crate::error::{AviError, Result};
use crate::estimator::{FitReport, FittedGeneratorSet, FittedModel, FittedVca};
use crate::pipeline::{FittedTransformer, PipelineModel};
use crate::poly::eval::{Recipe, TermSet};
use crate::poly::poly::{Generator, GeneratorSet};
use crate::svm::linear::{LinearSvm, LinearSvmConfig};

/// Envelope format tag for a single fitted estimator model.
pub const FORMAT_MODEL: &str = "avi-scale-model";
/// Envelope format tag for a whole fitted pipeline.
pub const FORMAT_PIPELINE: &str = "avi-scale-pipeline";
/// Current envelope version (bump on breaking payload changes).
pub const VERSION: u64 = 1;

/// Payload codec tag: monomial-aware generator set (OAVI family, ABM).
pub const KIND_GENERATOR_SET: &str = "generator-set";
/// Payload codec tag: VCA polynomial op-DAG.
pub const KIND_VCA_DAG: &str = "vca-dag";

// ---------------------------------------------------------------------
// Single fitted model
// ---------------------------------------------------------------------

/// Serialize one fitted model inside the versioned envelope.
pub fn model_to_json(model: &dyn FittedModel) -> String {
    format!(
        "{{\n\"format\": \"{FORMAT_MODEL}\",\n\"version\": {VERSION},\n\
         \"estimator\": \"{}\",\n\"kind\": \"{}\",\n\"payload\": {}}}\n",
        model.report().name(),
        model.payload_kind(),
        model.payload_json(),
    )
}

/// Parse a fitted model back from [`model_to_json`] output.
pub fn model_from_json(text: &str) -> Result<Box<dyn FittedModel>> {
    check_header(text, FORMAT_MODEL)?;
    let estimator = extract_str(text, "\"estimator\":")?;
    let kind = extract_str(text, "\"kind\":")?;
    let payload = extract_object(text, "\"payload\":")?;
    decode_payload(&estimator, &kind, &payload)
}

/// Save one fitted model to a file.
pub fn save_model(model: &dyn FittedModel, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    fs::write(path, model_to_json(model))?;
    Ok(())
}

/// Load one fitted model from a file — JSON or binary, sniffed by magic.
pub fn load_model(path: &Path) -> Result<Box<dyn FittedModel>> {
    model_from_bytes(&fs::read(path)?)
}

/// The codec-agnostic version gate for single models: bytes starting
/// with the [`crate::artifact::codec::MAGIC`] route to the binary
/// decoder, anything else must be the UTF-8 JSON envelope.  Both paths
/// produce bit-identical models, so callers never care which codec
/// wrote the artifact.
pub fn model_from_bytes(bytes: &[u8]) -> Result<Box<dyn FittedModel>> {
    if crate::artifact::codec::is_binary(bytes) {
        return crate::artifact::codec::decode_model(bytes);
    }
    let text = std::str::from_utf8(bytes).map_err(|_| {
        AviError::Data("persist: model envelope is neither binary (AVIB) nor UTF-8 JSON".into())
    })?;
    model_from_json(text)
}

fn decode_payload(estimator: &str, kind: &str, payload: &str) -> Result<Box<dyn FittedModel>> {
    match kind {
        KIND_GENERATOR_SET => {
            let set = generator_set_from_json(payload)?;
            let report = loaded_report(estimator, set.generators.len(), set.o_terms.len());
            Ok(Box::new(FittedGeneratorSet { set, report }))
        }
        KIND_VCA_DAG => {
            let model = vca_from_json(payload)?;
            let n_f: usize = model.f_sets.iter().map(|f| f.len()).sum();
            let report = loaded_report(estimator, model.n_generators(), n_f);
            Ok(Box::new(FittedVca { model, report }))
        }
        other => Err(AviError::Data(format!(
            "persist: unknown payload kind '{other}' (known: {KIND_GENERATOR_SET}, {KIND_VCA_DAG})"
        ))),
    }
}

/// Report for a loaded model: name and sizes survive persistence; the
/// fit-time counters and wall-clock do not.  (`pub(crate)` so the
/// binary codec in [`crate::artifact::codec`] builds identical reports.)
pub(crate) fn loaded_report(name: &str, n_generators: usize, n_order_terms: usize) -> FitReport {
    FitReport {
        name: name.to_string(),
        n_generators,
        n_order_terms,
        ..FitReport::default()
    }
}

// ---------------------------------------------------------------------
// Whole pipeline
// ---------------------------------------------------------------------

/// Serialize a trained pipeline (ordering permutation + per-class models
/// + SVM heads) inside the versioned envelope.  Every estimator —
/// including VCA, which the old path rejected — round-trips.
pub fn pipeline_to_json(model: &PipelineModel) -> String {
    let mut out = String::new();
    out.push_str(&format!("{{\n\"format\": \"{FORMAT_PIPELINE}\",\n\"version\": {VERSION},\n"));
    out.push_str(&format!("\"method\": \"{}\",\n", model.transformer.method_name));
    out.push_str(&format!(
        "\"perm\": [{}],\n",
        model.perm.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(",")
    ));
    out.push_str(&format!("\"n_classes\": {},\n", model.n_classes));
    out.push_str("\"classes\": [\n");
    for (i, cm) in model.transformer.per_class.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&model_to_json(cm.as_ref()));
    }
    out.push_str("\n],\n");
    out.push_str("\"svm\": {\n");
    out.push_str(&format!("\"lambda\": {:e},\n", model.svm.config.lambda));
    out.push_str("\"heads\": [\n");
    for (hi, (w, b)) in model.svm.weights.iter().enumerate() {
        if hi > 0 {
            out.push_str(",\n");
        }
        let ws: Vec<String> = w.iter().map(|v| format!("{v:e}")).collect();
        out.push_str(&format!("{{\"bias\": {b:e}, \"w\": [{}]}}", ws.join(",")));
    }
    out.push_str("\n]\n}\n}\n");
    out
}

/// Parse a pipeline back from [`pipeline_to_json`] output.
pub fn pipeline_from_json(text: &str) -> Result<PipelineModel> {
    check_header(text, FORMAT_PIPELINE)?;
    let method_name = extract_str(text, "\"method\":")?;
    let perm: Vec<usize> = parse_num_list(&extract_array(text, "\"perm\":")?)?
        .into_iter()
        .map(|v| v as usize)
        .collect();
    let n_classes = extract_f64(text, "\"n_classes\":")? as usize;

    let classes_src = extract_array(text, "\"classes\":")?;
    let mut per_class: Vec<Box<dyn FittedModel>> = Vec::new();
    for doc in split_objects(&classes_src) {
        per_class.push(model_from_json(doc)?);
    }
    if per_class.len() != n_classes {
        return Err(AviError::Data(format!(
            "persist: {} classes parsed, expected {n_classes}",
            per_class.len()
        )));
    }

    let svm_pos = text
        .find("\"svm\":")
        .ok_or_else(|| AviError::Data("persist: missing svm".into()))?;
    let svm_src = &text[svm_pos..];
    let lambda = extract_f64(svm_src, "\"lambda\":")?;
    let mut weights = Vec::new();
    for head in split_objects(&extract_array(svm_src, "\"heads\":")?) {
        let bias = extract_f64(head, "\"bias\":")?;
        let w = parse_num_list(&extract_array(head, "\"w\":")?)?;
        weights.push((w, bias));
    }
    if weights.is_empty() {
        return Err(AviError::Data("persist: no svm heads".into()));
    }
    let svm = LinearSvm {
        weights,
        n_classes,
        config: LinearSvmConfig { lambda, ..Default::default() },
        iters: vec![],
    };
    Ok(PipelineModel {
        perm,
        transformer: FittedTransformer { method_name, per_class },
        svm,
        n_classes,
    })
}

/// Save a pipeline to a file.
pub fn save(model: &PipelineModel, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    fs::write(path, pipeline_to_json(model))?;
    Ok(())
}

/// Load a pipeline from a file — JSON or binary, sniffed by magic.
pub fn load(path: &Path) -> Result<PipelineModel> {
    pipeline_from_bytes(&fs::read(path)?)
}

/// The codec-agnostic version gate for pipelines: binary envelopes (by
/// magic sniff) decode through [`crate::artifact::codec`], anything
/// else through the JSON path.  JSON and binary payloads are fully
/// interchangeable — the conformance suite pins the cross-codec
/// round-trip bitwise.
pub fn pipeline_from_bytes(bytes: &[u8]) -> Result<PipelineModel> {
    if crate::artifact::codec::is_binary(bytes) {
        return crate::artifact::codec::decode_pipeline(bytes);
    }
    let text = std::str::from_utf8(bytes).map_err(|_| {
        AviError::Data("persist: pipeline envelope is neither binary (AVIB) nor UTF-8 JSON".into())
    })?;
    pipeline_from_json(text)
}

// ---------------------------------------------------------------------
// Payload codecs
// ---------------------------------------------------------------------

/// Generator-set payload: the order ideal's recipes (not raw exponent
/// vectors), so a loaded model evaluates through exactly the same
/// one-multiply-per-term path as a freshly fitted one.
pub fn generator_set_to_json(gs: &GeneratorSet) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("\"n_vars\": {},\n", gs.o_terms.n_vars()));
    // recipes: [[-1,-1]] for One, [parent, var] otherwise
    out.push_str("\"o_recipes\": [");
    for i in 0..gs.o_terms.len() {
        if i > 0 {
            out.push(',');
        }
        match gs.o_terms.recipe(i) {
            Recipe::One => out.push_str("[-1,-1]"),
            Recipe::Product { parent, var } => out.push_str(&format!("[{parent},{var}]")),
        }
    }
    out.push_str("],\n\"generators\": [\n");
    for (gi, g) in gs.generators.iter().enumerate() {
        if gi > 0 {
            out.push_str(",\n");
        }
        let coeffs: Vec<String> = g.coeffs.iter().map(|c| format!("{c:e}")).collect();
        out.push_str(&format!(
            "{{\"parent\": {}, \"var\": {}, \"mse\": {:e}, \"coeffs\": [{}]}}",
            g.leading_parent,
            g.leading_var,
            g.mse,
            coeffs.join(",")
        ));
    }
    out.push_str("\n]\n}");
    out
}

/// Parse a generator set back from [`generator_set_to_json`] output.
pub fn generator_set_from_json(text: &str) -> Result<GeneratorSet> {
    let n_vars = as_index(extract_f64(text, "\"n_vars\":")?)?;
    let recipes_src = extract_array(text, "\"o_recipes\":")?;
    let mut o = TermSet::with_one(n_vars);
    let pairs = parse_pairs(&recipes_src)?;
    if pairs.first() != Some(&(-1, -1)) {
        return Err(AviError::Data("persist: first recipe must be the One term".into()));
    }
    for (i, pair) in pairs.into_iter().enumerate() {
        match pair {
            (-1, -1) => {
                if i != 0 {
                    return Err(AviError::Data("persist: One recipe not first".into()));
                }
            }
            (p, v) => {
                if p < 0 || v < 0 {
                    return Err(AviError::Data("persist: bad recipe".into()));
                }
                o.push_product(p as usize, v as usize)?;
            }
        }
    }
    let gens_src = extract_array(text, "\"generators\":")?;
    let mut generators = Vec::new();
    for obj in split_objects(&gens_src) {
        let parent = as_index(extract_f64(obj, "\"parent\":")?)?;
        let var = as_index(extract_f64(obj, "\"var\":")?)?;
        let mse = extract_f64(obj, "\"mse\":")?;
        let coeffs = parse_num_list(&extract_array(obj, "\"coeffs\":")?)?;
        if parent >= o.len() || var >= n_vars {
            return Err(AviError::Data("persist: leading recipe out of range".into()));
        }
        let leading = o.terms()[parent].times_var(var);
        generators.push(Generator {
            coeffs,
            leading,
            leading_parent: parent,
            leading_var: var,
            mse,
        });
    }
    Ok(GeneratorSet { o_terms: o, generators })
}

/// VCA payload: each op-DAG node as a flat numeric record whose first
/// entry is the variant tag — `[0]` One, `[1, j]` Feature, `[2, a, b]`
/// Product, `[3, w0, id0, w1, id1, …]` LinComb — plus `n_vars` so loads
/// can bound every `Feature` index against the fitted data dimension.
pub fn vca_to_json(model: &VcaModel) -> String {
    let mut out = format!("{{\n\"n_vars\": {},\n\"nodes\": [", model.n_vars());
    for (i, node) in model.nodes().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        match node {
            VcaNode::One => out.push_str("[0]"),
            VcaNode::Feature(j) => out.push_str(&format!("[1,{j}]")),
            VcaNode::Product(a, b) => out.push_str(&format!("[2,{a},{b}]")),
            VcaNode::LinComb(terms) => {
                out.push_str("[3");
                for (w, id) in terms {
                    out.push_str(&format!(",{w:e},{id}"));
                }
                out.push(']');
            }
        }
    }
    out.push_str("],\n\"degrees\": [");
    let degs: Vec<String> = model.degrees().iter().map(|d| d.to_string()).collect();
    out.push_str(&degs.join(","));
    out.push_str("],\n\"vanishing\": [");
    let vans: Vec<String> = model.vanishing.iter().map(|v| v.to_string()).collect();
    out.push_str(&vans.join(","));
    out.push_str("],\n\"f_sets\": [");
    for (i, f) in model.f_sets.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let ids: Vec<String> = f.iter().map(|v| v.to_string()).collect();
        out.push_str(&format!("[{}]", ids.join(",")));
    }
    out.push_str("]\n}");
    out
}

/// Parse a VCA model back from [`vca_to_json`] output.
pub fn vca_from_json(text: &str) -> Result<VcaModel> {
    let n_vars = as_index(extract_f64(text, "\"n_vars\":")?)?;
    let node_rows = parse_nested_lists(&extract_array(text, "\"nodes\":")?)?;
    let mut nodes = Vec::with_capacity(node_rows.len());
    for row in &node_rows {
        let tag = *row.first().ok_or_else(|| AviError::Data("persist: empty node".into()))?;
        let node = match tag as i64 {
            0 if row.len() == 1 => VcaNode::One,
            1 if row.len() == 2 => VcaNode::Feature(as_index(row[1])?),
            2 if row.len() == 3 => VcaNode::Product(as_index(row[1])?, as_index(row[2])?),
            3 if row.len() % 2 == 1 => VcaNode::LinComb(
                row[1..]
                    .chunks_exact(2)
                    .map(|c| Ok((c[0], as_index(c[1])?)))
                    .collect::<Result<_>>()?,
            ),
            _ => {
                return Err(AviError::Data(format!("persist: malformed VCA node {row:?}")));
            }
        };
        nodes.push(node);
    }
    let degrees: Vec<u32> = parse_num_list(&extract_array(text, "\"degrees\":")?)?
        .into_iter()
        .map(|v| as_index(v).map(|i| i as u32))
        .collect::<Result<_>>()?;
    let vanishing: Vec<usize> = parse_num_list(&extract_array(text, "\"vanishing\":")?)?
        .into_iter()
        .map(as_index)
        .collect::<Result<_>>()?;
    let f_sets: Vec<Vec<usize>> = parse_nested_lists(&extract_array(text, "\"f_sets\":")?)?
        .into_iter()
        .map(|f| f.into_iter().map(as_index).collect::<Result<Vec<usize>>>())
        .collect::<Result<_>>()?;
    VcaModel::from_parts(nodes, vanishing, f_sets, degrees, n_vars)
}

/// Strict f64 → index conversion: rejects negative, fractional, and
/// non-finite values instead of saturating them into valid-looking ids
/// (corrupt payloads must fail the load, not mutate the model).
fn as_index(v: f64) -> Result<usize> {
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v > u32::MAX as f64 {
        return Err(AviError::Data(format!("persist: '{v}' is not a valid index")));
    }
    Ok(v as usize)
}

// ---------------------------------------------------------------------
// Hand-rolled JSON helpers
// ---------------------------------------------------------------------

/// Validate the envelope header: the format tag and a known version.
fn check_header(text: &str, expected_format: &str) -> Result<()> {
    let format = extract_str(text, "\"format\":")
        .map_err(|_| AviError::Data("persist: missing envelope header".into()))?;
    if format != expected_format {
        return Err(AviError::Data(format!(
            "persist: format '{format}', expected '{expected_format}'"
        )));
    }
    let version = extract_f64(text, "\"version\":")? as u64;
    if version != VERSION {
        return Err(AviError::Data(format!(
            "persist: unsupported envelope version {version} (supported: {VERSION})"
        )));
    }
    Ok(())
}

pub(crate) fn extract_str(text: &str, key: &str) -> Result<String> {
    let pos = text
        .find(key)
        .ok_or_else(|| AviError::Data(format!("persist: missing {key}")))?;
    let rest = &text[pos + key.len()..];
    let q1 = rest
        .find('"')
        .ok_or_else(|| AviError::Data(format!("persist: {key} not a string")))?;
    let q2 = rest[q1 + 1..]
        .find('"')
        .ok_or_else(|| AviError::Data(format!("persist: unterminated {key}")))?;
    Ok(rest[q1 + 1..q1 + 1 + q2].to_string())
}

pub(crate) fn extract_f64(text: &str, key: &str) -> Result<f64> {
    let pos = text
        .find(key)
        .ok_or_else(|| AviError::Data(format!("persist: missing {key}")))?;
    let rest = &text[pos + key.len()..];
    let end = rest.find([',', '}', '\n', ']']).unwrap_or(rest.len());
    rest[..end]
        .trim()
        .parse::<f64>()
        .map_err(|e| AviError::Data(format!("persist: {key} parse: {e}")))
}

/// Contents of the depth-matched `[…]` array after `key`.
pub(crate) fn extract_array(text: &str, key: &str) -> Result<String> {
    extract_delimited(text, key, '[', ']')
}

/// The depth-matched `{…}` object after `key`, braces included.
fn extract_object(text: &str, key: &str) -> Result<String> {
    let inner = extract_delimited(text, key, '{', '}')?;
    Ok(format!("{{{inner}}}"))
}

fn extract_delimited(text: &str, key: &str, open: char, close: char) -> Result<String> {
    let pos = text
        .find(key)
        .ok_or_else(|| AviError::Data(format!("persist: missing {key}")))?;
    let rest = &text[pos + key.len()..];
    let start = rest
        .find(open)
        .ok_or_else(|| AviError::Data(format!("persist: {key} missing '{open}'")))?;
    let mut depth = 0usize;
    for (i, ch) in rest[start..].char_indices() {
        if ch == open {
            depth += 1;
        } else if ch == close {
            depth -= 1;
            if depth == 0 {
                return Ok(rest[start + 1..start + i].to_string());
            }
        }
    }
    Err(AviError::Data(format!("persist: unbalanced {key}")))
}

/// Split an array body into its top-level `{…}` objects (depth-matched;
/// the format emits no braces inside strings).
pub(crate) fn split_objects(src: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in src.char_indices() {
        match ch {
            '{' => {
                if depth == 0 {
                    start = i;
                }
                depth += 1;
            }
            '}' => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    out.push(&src[start..i + 1]);
                }
            }
            _ => {}
        }
    }
    out
}

fn parse_num_list(src: &str) -> Result<Vec<f64>> {
    if src.trim().is_empty() {
        return Ok(Vec::new());
    }
    src.split(',')
        .map(|t| {
            t.trim()
                .parse::<f64>()
                .map_err(|e| AviError::Data(format!("persist: list: {e}")))
        })
        .collect()
}

/// Top-level `[…]` groups of an array body, each parsed as a number list
/// (empty groups allowed).
fn parse_nested_lists(src: &str) -> Result<Vec<Vec<f64>>> {
    let mut out = Vec::new();
    let mut rest = src;
    while let Some(start) = rest.find('[') {
        let end = rest[start..]
            .find(']')
            .ok_or_else(|| AviError::Data("persist: unbalanced nested list".into()))?
            + start;
        out.push(parse_num_list(&rest[start + 1..end])?);
        rest = &rest[end + 1..];
    }
    Ok(out)
}

fn parse_pairs(src: &str) -> Result<Vec<(i64, i64)>> {
    parse_nested_lists(src)?
        .into_iter()
        .map(|row| {
            if row.len() != 2 {
                return Err(AviError::Data("persist: pair arity".into()));
            }
            Ok((row[0] as i64, row[1] as i64))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::estimator::EstimatorConfig;
    use crate::linalg::dense::Matrix;
    use crate::util::rng::Rng;

    fn parabola(m: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        for i in 0..m {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, t * t);
        }
        x
    }

    #[test]
    fn model_envelope_roundtrips_every_estimator_bitwise() {
        let x = parabola(120, 5);
        let z = parabola(40, 6);
        for cfg in EstimatorConfig::battery(0.001) {
            let model = cfg.fit(&x, &NativeBackend).unwrap();
            let json = model_to_json(model.as_ref());
            let back = model_from_json(&json)
                .unwrap_or_else(|e| panic!("{}: {e}", cfg.name()));
            assert_eq!(back.report().name(), cfg.name());
            assert_eq!(back.n_generators(), model.n_generators());
            assert_eq!(back.total_size(), model.total_size());
            let a = model.transform_with(&z, &NativeBackend);
            let b = back.transform_with(&z, &NativeBackend);
            let bits = |m: &Matrix| m.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a), bits(&b), "{}: transform not bitwise equal", cfg.name());
        }
    }

    #[test]
    fn unknown_version_and_format_are_rejected() {
        let x = parabola(60, 7);
        let model = EstimatorConfig::parse("cgavi-ihb", 0.01)
            .unwrap()
            .fit(&x, &NativeBackend)
            .unwrap();
        let json = model_to_json(model.as_ref());
        let v99 = json.replace("\"version\": 1", "\"version\": 99");
        assert!(model_from_json(&v99).is_err());
        let bad_fmt = json.replace(FORMAT_MODEL, "mystery-format");
        assert!(model_from_json(&bad_fmt).is_err());
        let bad_kind = json.replace(KIND_GENERATOR_SET, "alien-kind");
        assert!(model_from_json(&bad_kind).is_err());
        assert!(model_from_json("{}").is_err());
        assert!(model_from_json("not json at all").is_err());
    }

    #[test]
    fn model_file_roundtrip() {
        let x = parabola(80, 8);
        let model = EstimatorConfig::parse("vca", 1e-4)
            .unwrap()
            .fit(&x, &NativeBackend)
            .unwrap();
        let path = std::env::temp_dir().join("avi_scale_estimator/vca.json");
        save_model(model.as_ref(), &path).unwrap();
        let back = load_model(&path).unwrap();
        assert_eq!(back.report().name(), "VCA");
        assert_eq!(back.total_size(), model.total_size());
    }

    #[test]
    fn generator_set_payload_rejects_garbage() {
        assert!(generator_set_from_json("{}").is_err());
        // bad first recipe
        assert!(generator_set_from_json(
            "{\"n_vars\": 2, \"o_recipes\": [[0,0]], \"generators\": []}"
        )
        .is_err());
    }

    #[test]
    fn vca_payload_rejects_malformed_nodes() {
        let doc = |nodes: &str, degrees: &str| {
            format!(
                "{{\"n_vars\": 2, \"nodes\": [{nodes}], \"degrees\": [{degrees}], \
                 \"vanishing\": [], \"f_sets\": []}}"
            )
        };
        assert!(vca_from_json("{}").is_err());
        // Feature node with wrong arity
        assert!(vca_from_json(&doc("[1]", "0")).is_err());
        // forward-referencing product
        assert!(vca_from_json(&doc("[2,0,1],[0]", "0,0")).is_err());
        // feature index beyond the stored n_vars
        assert!(vca_from_json(&doc("[1,5]", "1")).is_err());
        // negative / fractional ids must be rejected, not coerced
        assert!(vca_from_json(&doc("[2,-1,0],[0]", "0,0")).is_err());
        assert!(vca_from_json(&doc("[1,0.5]", "1")).is_err());
        // well-formed minimal doc parses
        assert!(vca_from_json(&doc("[0],[1,1]", "0,1")).is_ok());
    }
}

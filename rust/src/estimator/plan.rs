//! Compiled transform plans: prepare a fitted model once, serve its (FT)
//! transform from cached operands and reusable scratch.
//!
//! The legacy per-call path ([`crate::poly::poly::GeneratorSet::
//! transform_with`]) rebuilds everything that is *model*-side state on
//! every request: the dense zero-padded coefficient matrix `C`, the
//! leading-term matrix `U` via a column scatter, and a fresh
//! [`crate::backend::ColumnStore`] of term evaluations.  Theorem 4.2
//! prices evaluation at one multiply per (term, point); a plan gets the
//! per-request cost down to exactly the x-dependent work:
//!
//! * [`GeneratorPlan`] caches the flattened DegLex term-evaluation
//!   program ([`Recipe`] list), the dense `C`, the per-generator packed
//!   nonzero columns of `C`, and the `U` recipes `(parent, var)` — built
//!   once from a [`GeneratorSet`].
//! * [`VcaPlan`] caches VCA's polynomial op-DAG and the vanishing-node
//!   ids — the monomial-agnostic analogue.
//! * [`PlanScratch`] owns the term-evaluation buffer and counts capacity
//!   growths, so steady-state serving can *prove* it performs zero
//!   transform allocations (the serve bench asserts `grows() == 0` after
//!   warmup).
//!
//! # Bitwise contract
//!
//! The dense plan kernel replays the exact arithmetic of the legacy
//! path: recipe evaluation is the per-element `parent · x_var` multiply
//! of `ColumnStore::fill_product`, the accumulation per output cell is
//! the seed-from-`U`-then-ascending-`j` order of
//! `store::transform_block_into` (including its all-zero-`C`-row skip),
//! and the transform is per-row independent, so shard counts never enter.
//! Dense plan output is therefore **bitwise identical** to
//! `transform_with` on every backend (`tests/transform_plan_parity.rs`).
//!
//! # Sparsity gating
//!
//! CG-family generators are deliberately sparse (the paper's SPAR
//! statistic); the packed kernel skips the structural zeros.  Mirroring
//! the [`crate::backend::NumericsMode::Fast`] discipline, the packed
//! kernel is **opt-in** ([`PlanPolicy::sparse`]) and engages only past a
//! measured zero-fraction threshold; the dense bitwise-exact kernel
//! remains the default.  (Skipping `a_ij · 0.0` terms can only change
//! ±0.0 signs ahead of the final `abs`, but the conservative gating
//! keeps the default path exactly the legacy bits.)

use crate::backend::NativeBackend;
use crate::baselines::vca::{VcaModel, VcaNode};
use crate::estimator::FittedModel;
use crate::linalg::dense::Matrix;
use crate::poly::eval::Recipe;
use crate::poly::poly::GeneratorSet;

/// How a plan is compiled: dense bitwise-exact by default, packed sparse
/// kernel opt-in past a measured sparsity threshold (the
/// `NumericsMode::Fast` gating discipline).
#[derive(Clone, Copy, Debug)]
pub struct PlanPolicy {
    /// Opt into the packed sparse kernel (default: off — dense exact).
    pub sparse: bool,
    /// Minimum measured fraction of structural zeros in the live rows of
    /// `C` before the packed kernel engages.
    pub sparse_min_zero_frac: f64,
}

impl Default for PlanPolicy {
    fn default() -> Self {
        PlanPolicy { sparse: false, sparse_min_zero_frac: 0.5 }
    }
}

impl PlanPolicy {
    /// The opt-in sparse policy at the default engagement threshold.
    pub fn sparse_enabled() -> Self {
        PlanPolicy { sparse: true, ..PlanPolicy::default() }
    }
}

/// Reusable per-worker scratch for plan transforms.  One instance per
/// serving thread; buffers grow to the high-water mark and are then
/// reused, so steady-state requests allocate nothing.
#[derive(Debug, Default)]
pub struct PlanScratch {
    cols: Vec<f64>,
    grows: u64,
}

impl PlanScratch {
    pub fn new() -> Self {
        PlanScratch::default()
    }

    /// Buffer-capacity growth events since construction.  After warmup a
    /// steady-state serving loop must hold this constant — the serve
    /// bench and smoke assert it.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Record a growth performed on a caller-managed companion buffer
    /// (the pipeline-level slabs share this counter).
    pub fn note_grow(&mut self) {
        self.grows += 1;
    }

    /// The term/node evaluation buffer, grown (and counted) on demand.
    /// Contents are overwritten by every kernel before being read.
    pub fn cols_buf(&mut self, n: usize) -> &mut [f64] {
        if self.cols.len() < n {
            self.grows += 1;
            self.cols.resize(n, 0.0);
        }
        &mut self.cols[..n]
    }
}

/// A compiled per-class transform: all model-side operands cached, only
/// x-dependent work per call.  `Send + Sync` (plain data) so serving
/// threads can share plans behind an `Arc`.
pub trait PreparedTransform: Send + Sync + std::fmt::Debug {
    /// |G| — feature columns this class contributes.
    fn n_cols(&self) -> usize;

    /// Write |g(x)| for every generator into the caller's m×`stride`
    /// slab at column `col_off` (row `i` at `out[i*stride + col_off ..]`).
    /// On the dense path the written cells must be bitwise identical to
    /// the legacy `transform_with` on any backend.
    fn transform_into(
        &self,
        x: &Matrix,
        scratch: &mut PlanScratch,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    );

    /// Whether the packed sparse kernel is engaged for this class.
    fn sparse_engaged(&self) -> bool {
        false
    }

    /// Multiply-adds the packed kernel skips per transformed row
    /// (0 when the dense kernel is active) — feeds the FLOPs-saved
    /// serving counter.
    fn flops_saved_per_row(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------
// Monomial-aware plan (OAVI family, ABM)
// ---------------------------------------------------------------------

/// Compiled plan for a [`GeneratorSet`]: cached `C`/`U` operands, the
/// flattened term program, and the packed sparse columns.
#[derive(Clone, Debug)]
pub struct GeneratorPlan {
    /// Flattened DegLex evaluation program (one multiply per term).
    recipes: Vec<Recipe>,
    /// Dense zero-padded coefficient matrix (ℓ×g) — built once, not per
    /// request.
    dense_c: Matrix,
    /// Term indices whose `C` row has any nonzero, ascending — the
    /// column-granular skip of the legacy kernel, precomputed.
    live: Vec<usize>,
    /// Per-generator packed `(term, coeff)` pairs, ascending term index.
    packed: Vec<Vec<(usize, f64)>>,
    /// Per-generator `U` recipe: `u = terms[parent] · x_var`.
    u_recipes: Vec<(usize, usize)>,
    /// Measured fraction of structural zeros among the live-row cells.
    zero_frac: f64,
    /// Packed kernel engaged (policy opt-in AND threshold met).
    sparse: bool,
    flops_saved_per_row: u64,
}

impl GeneratorPlan {
    /// Compile a plan from a fitted generator set.
    pub fn new(set: &GeneratorSet, policy: &PlanPolicy) -> Self {
        let ell = set.o_terms.len();
        let g = set.generators.len();
        let mut dense_c = Matrix::zeros(ell, g);
        let mut packed: Vec<Vec<(usize, f64)>> = vec![Vec::new(); g];
        let mut u_recipes = Vec::with_capacity(g);
        for (gi, gen) in set.generators.iter().enumerate() {
            for (j, &cj) in gen.coeffs.iter().enumerate() {
                dense_c.set(j, gi, cj);
                if cj != 0.0 {
                    packed[gi].push((j, cj));
                }
            }
            u_recipes.push((gen.leading_parent, gen.leading_var));
        }
        let live: Vec<usize> =
            (0..ell).filter(|&j| dense_c.row(j).iter().any(|&v| v != 0.0)).collect();
        let dense_muladds = live.len() * g;
        let packed_muladds: usize = packed.iter().map(|p| p.len()).sum();
        let zero_frac = if dense_muladds == 0 {
            0.0
        } else {
            1.0 - packed_muladds as f64 / dense_muladds as f64
        };
        let sparse = policy.sparse && zero_frac >= policy.sparse_min_zero_frac;
        let flops_saved_per_row =
            if sparse { (dense_muladds - packed_muladds) as u64 } else { 0 };
        GeneratorPlan {
            recipes: set.o_terms.recipes().to_vec(),
            dense_c,
            live,
            packed,
            u_recipes,
            zero_frac,
            sparse,
            flops_saved_per_row,
        }
    }

    /// Measured structural-zero fraction of the live `C` rows.
    pub fn zero_frac(&self) -> f64 {
        self.zero_frac
    }

    /// Evaluate the term program over `x` into `cols` (column-major,
    /// term-major m-blocks) — the exact per-element arithmetic of
    /// `TermSet::eval_store` / `ColumnStore::fill_product`.
    fn eval_terms(&self, x: &Matrix, cols: &mut [f64]) {
        let m = x.rows();
        for (j, r) in self.recipes.iter().enumerate() {
            match *r {
                Recipe::One => cols[j * m..(j + 1) * m].fill(1.0),
                Recipe::Product { parent, var } => {
                    // DegLex append order guarantees parent < j
                    let (lo, hi) = cols.split_at_mut(j * m);
                    let p = &lo[parent * m..parent * m + m];
                    for (i, o) in hi[..m].iter_mut().enumerate() {
                        *o = p[i] * x.get(i, var);
                    }
                }
            }
        }
    }
}

impl PreparedTransform for GeneratorPlan {
    fn n_cols(&self) -> usize {
        self.u_recipes.len()
    }

    fn transform_into(
        &self,
        x: &Matrix,
        scratch: &mut PlanScratch,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        let m = x.rows();
        let g = self.u_recipes.len();
        let ell = self.recipes.len();
        let cols = scratch.cols_buf(ell * m);
        self.eval_terms(x, cols);
        if g == 0 {
            return;
        }
        if self.sparse {
            for i in 0..m {
                let base = i * stride + col_off;
                let orow = &mut out[base..base + g];
                for (gi, o) in orow.iter_mut().enumerate() {
                    let (p, v) = self.u_recipes[gi];
                    let mut acc = cols[p * m + i] * x.get(i, v);
                    for &(j, cj) in &self.packed[gi] {
                        acc += cols[j * m + i] * cj;
                    }
                    *o = acc.abs();
                }
            }
        } else {
            // dense bitwise-exact kernel: per (row, generator) the seed-
            // then-ascending-j accumulation of store::transform_block_into
            for i in 0..m {
                let base = i * stride + col_off;
                let orow = &mut out[base..base + g];
                for (o, &(p, v)) in orow.iter_mut().zip(self.u_recipes.iter()) {
                    *o = cols[p * m + i] * x.get(i, v);
                }
                for &j in &self.live {
                    let a_ij = cols[j * m + i];
                    for (o, &ck) in orow.iter_mut().zip(self.dense_c.row(j).iter()) {
                        *o += a_ij * ck;
                    }
                }
                for o in orow.iter_mut() {
                    *o = o.abs();
                }
            }
        }
    }

    fn sparse_engaged(&self) -> bool {
        self.sparse
    }

    fn flops_saved_per_row(&self) -> u64 {
        self.flops_saved_per_row
    }
}

// ---------------------------------------------------------------------
// Monomial-agnostic plan (VCA op-DAG)
// ---------------------------------------------------------------------

/// Compiled plan for a [`VcaModel`]: the op-DAG walk flattened onto the
/// shared scratch buffer.  VCA's `LinComb` nodes already skip zero
/// weights in the legacy path, so there is no separate packed kernel;
/// the walk replays the legacy per-element arithmetic exactly.
#[derive(Clone, Debug)]
pub struct VcaPlan {
    nodes: Vec<VcaNode>,
    vanishing: Vec<usize>,
}

impl VcaPlan {
    /// Compile a plan from a fitted VCA model.
    pub fn new(model: &VcaModel) -> Self {
        VcaPlan { nodes: model.nodes().to_vec(), vanishing: model.vanishing.clone() }
    }
}

impl PreparedTransform for VcaPlan {
    fn n_cols(&self) -> usize {
        self.vanishing.len()
    }

    fn transform_into(
        &self,
        x: &Matrix,
        scratch: &mut PlanScratch,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        let m = x.rows();
        let n_nodes = self.nodes.len();
        let cols = scratch.cols_buf(n_nodes * m);
        for (id, node) in self.nodes.iter().enumerate() {
            let (lo, hi) = cols.split_at_mut(id * m);
            let dst = &mut hi[..m];
            match node {
                VcaNode::One => dst.fill(1.0),
                VcaNode::Feature(j) => {
                    for (i, o) in dst.iter_mut().enumerate() {
                        *o = x.get(i, *j);
                    }
                }
                VcaNode::Product(a, b) => {
                    let (va, vb) = (&lo[a * m..a * m + m], &lo[b * m..b * m + m]);
                    for (o, (pa, pb)) in dst.iter_mut().zip(va.iter().zip(vb.iter())) {
                        *o = pa * pb;
                    }
                }
                VcaNode::LinComb(terms) => {
                    dst.fill(0.0);
                    for (w, idx) in terms {
                        if *w == 0.0 {
                            continue;
                        }
                        let src = &lo[idx * m..idx * m + m];
                        for (o, s) in dst.iter_mut().zip(src.iter()) {
                            *o += w * s;
                        }
                    }
                }
            }
        }
        for (gi, &nid) in self.vanishing.iter().enumerate() {
            let col = &cols[nid * m..nid * m + m];
            for (i, v) in col.iter().enumerate() {
                out[i * stride + col_off + gi] = v.abs();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Fallback for foreign FittedModel implementations
// ---------------------------------------------------------------------

/// Catch-all prepared transform for [`FittedModel`] implementations
/// without a compiled plan: runs the legacy native-backend transform and
/// copies it into the slab.  Correct (the transform is per-row
/// independent, so native bits are THE bits) but not allocation-free —
/// both in-tree model kinds override [`FittedModel::prepare`] instead.
#[derive(Debug)]
struct PreparedFallback {
    model: Box<dyn FittedModel>,
}

impl PreparedTransform for PreparedFallback {
    fn n_cols(&self) -> usize {
        self.model.n_generators()
    }

    fn transform_into(
        &self,
        x: &Matrix,
        _scratch: &mut PlanScratch,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        let block = self.model.transform_with(x, &NativeBackend);
        let g = block.cols();
        for i in 0..x.rows() {
            let base = i * stride + col_off;
            out[base..base + g].copy_from_slice(block.row(i));
        }
    }
}

/// Wrap a fitted model in the legacy-path fallback plan (the
/// [`FittedModel::prepare`] default).
pub fn fallback_prepared(model: Box<dyn FittedModel>) -> Box<dyn PreparedTransform> {
    Box::new(PreparedFallback { model })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::estimator::EstimatorConfig;
    use crate::util::rng::Rng;

    fn sample(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                x.set(i, j, rng.uniform());
            }
        }
        x
    }

    fn bits(m: &Matrix) -> Vec<u64> {
        m.data().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn dense_generator_plan_is_bitwise_identical_to_legacy() {
        let x = sample(120, 3, 7);
        for method in ["cgavi-ihb", "bpcgavi-wihb", "abm"] {
            let model =
                EstimatorConfig::parse(method, 0.01).unwrap().fit(&x, &NativeBackend).unwrap();
            let plan = model.prepare(&PlanPolicy::default());
            let fresh = sample(40, 3, 8);
            let legacy = model.transform_with(&fresh, &NativeBackend);
            let g = plan.n_cols();
            assert_eq!(g, legacy.cols(), "{method}");
            let mut scratch = PlanScratch::new();
            let mut out = vec![f64::NAN; fresh.rows() * g];
            plan.transform_into(&fresh, &mut scratch, &mut out, g, 0);
            let out_bits: Vec<u64> = out.iter().map(|v| v.to_bits()).collect();
            assert_eq!(out_bits, bits(&legacy), "{method}: plan diverges from legacy");
        }
    }

    #[test]
    fn vca_plan_is_bitwise_identical_to_legacy() {
        let x = sample(150, 2, 9);
        let model = EstimatorConfig::parse("vca", 0.01).unwrap().fit(&x, &NativeBackend).unwrap();
        let plan = model.prepare(&PlanPolicy::default());
        let fresh = sample(33, 2, 10);
        let legacy = model.transform_with(&fresh, &NativeBackend);
        let mut scratch = PlanScratch::new();
        let g = plan.n_cols();
        let mut out = vec![f64::NAN; fresh.rows() * g];
        plan.transform_into(&fresh, &mut scratch, &mut out, g, 0);
        let out_bits: Vec<u64> = out.iter().map(|v| v.to_bits()).collect();
        assert_eq!(out_bits, bits(&legacy));
    }

    #[test]
    fn sparse_gating_follows_policy_and_threshold() {
        let x = sample(150, 3, 11);
        let model = EstimatorConfig::parse("bpcgavi-wihb", 0.01)
            .unwrap()
            .fit(&x, &NativeBackend)
            .unwrap();
        // dense default never engages the packed kernel
        let dense = model.prepare(&PlanPolicy::default());
        assert!(!dense.sparse_engaged());
        assert_eq!(dense.flops_saved_per_row(), 0);
        // opt-in with an impossible threshold stays dense too
        let gated = model
            .prepare(&PlanPolicy { sparse: true, sparse_min_zero_frac: 1.1 });
        assert!(!gated.sparse_engaged());
        // opt-in with a zero threshold engages whenever any zero exists
        let engaged = model.prepare(&PlanPolicy { sparse: true, sparse_min_zero_frac: 0.0 });
        assert!(engaged.sparse_engaged());
        // engaged or not, results match the dense kernel to a tight budget
        let fresh = sample(25, 3, 12);
        let g = dense.n_cols();
        let mut scratch = PlanScratch::new();
        let mut a = vec![0.0; fresh.rows() * g];
        let mut b = vec![0.0; fresh.rows() * g];
        dense.transform_into(&fresh, &mut scratch, &mut a, g, 0);
        engaged.transform_into(&fresh, &mut scratch, &mut b, g, 0);
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() <= 1e-12, "sparse kernel diverged: {x} vs {y}");
        }
    }

    #[test]
    fn scratch_growth_settles_after_warmup() {
        let x = sample(100, 3, 13);
        let model =
            EstimatorConfig::parse("cgavi-ihb", 0.01).unwrap().fit(&x, &NativeBackend).unwrap();
        let plan = model.prepare(&PlanPolicy::default());
        let g = plan.n_cols();
        let mut scratch = PlanScratch::new();
        let row = sample(1, 3, 14);
        let mut out = vec![0.0; g];
        plan.transform_into(&row, &mut scratch, &mut out, g, 0);
        let after_warmup = scratch.grows();
        for _ in 0..50 {
            plan.transform_into(&row, &mut scratch, &mut out, g, 0);
        }
        assert_eq!(scratch.grows(), after_warmup, "steady state must not reallocate");
    }
}

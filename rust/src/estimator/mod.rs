//! The estimator layer: one typed fit/transform surface for every
//! generator-constructing algorithm (OAVI family, ABM, VCA).
//!
//! The paper's experiments treat the constructors as interchangeable
//! front-ends to the same (FT) feature transform + ℓ1-SVM pipeline
//! (Tables 2–3), and the CG-family follow-up swaps oracles under an
//! identical outer loop.  This module is that interchangeability made
//! typed:
//!
//! * [`VanishingIdealEstimator`] — the algorithm: `fit` one class's data
//!   through an explicit [`ComputeBackend`] and return a fitted model.
//!   `Oavi`, `Abm`, and `Vca` all implement it, so every call site
//!   (pipeline, grid search, serving, CLI) is algorithm-agnostic.
//! * [`FittedModel`] — the artifact: the (FT) feature-block producer plus
//!   the Table-3 statistics and a persistence payload.  Implementations
//!   wrap [`GeneratorSet`] (monomial-aware methods) and [`VcaModel`]
//!   (the polynomial op-DAG).
//! * [`FitReport`] — unified fit diagnostics: the method name, output
//!   sizes, wall-clock, and the raw [`FitStats`] counters (a superset of
//!   the old OAVI-only surface — ABM/VCA report wall-clock too).
//! * [`EstimatorConfig`] — the typed, copyable configuration that builds
//!   estimators; [`EstimatorBuilder`] constructs it from CLI-style method
//!   names.  This replaces the old untyped method enum and the
//!   per-algorithm `match` arms that used to live at every layer.
//!
//! Adding a constructor (e.g. the gradient-boosted AVI of Kera &
//! Hasegawa) means implementing the two traits and registering the
//! config variant here — no pipeline, serving, or CLI changes.
//!
//! Persistence for fitted models and whole pipelines lives in
//! [`persist`] (one versioned envelope for every estimator).

pub mod persist;
pub mod plan;

use crate::backend::{ComputeBackend, NativeBackend, NumericsMode, StoreMode};
use crate::baselines::abm::{Abm, AbmConfig};
use crate::baselines::vca::{Vca, VcaConfig, VcaModel};
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::oavi::{FitStats, Oavi, OaviConfig};
use crate::poly::poly::GeneratorSet;
use crate::util::timer::Timer;

/// Default ψ hyper-grid (log-spaced around the paper's 0.005 working
/// point) — [`VanishingIdealEstimator::hyper_grid`]'s default answer.
pub const PSI_GRID: &[f64] = &[0.05, 0.01, 0.005, 0.001];

/// ψ grid for VCA: its tolerance acts on singular values of the
/// projected candidate block rather than per-term MSE, so useful working
/// points sit coarser than the OAVI/ABM range.
pub const VCA_PSI_GRID: &[f64] = &[0.1, 0.05, 0.01, 0.005];

/// Default SVM ℓ1 grid (paper §6.2) — estimators can override it per
/// method through [`VanishingIdealEstimator::hyper_grid`].
pub const LAMBDA_GRID: &[f64] = &[1e-2, 1e-3, 1e-4];

/// λ grid for WIHB variants: their generators already carry sparse
/// coefficient vectors (§4.4.3), so the SVM needs less ℓ1 pressure and
/// the useful range shifts one decade down.
pub const WIHB_LAMBDA_GRID: &[f64] = &[1e-3, 1e-4, 1e-5];

/// τ grid for the ℓ1-constrained OAVI variants (CCOP radius τ−1; the
/// paper's working point is 1000).
pub const TAU_GRID: &[f64] = &[500.0, 1000.0, 2000.0];

/// The hyperparameter ranges one estimator wants cross-validated: the
/// ψ axis joined by per-method λ and (where the method is
/// ℓ1-constrained) τ axes — the typed answer of
/// [`VanishingIdealEstimator::hyper_grid`], consumed by
/// [`crate::pipeline::gridsearch::grid_search`].
#[derive(Clone, Copy, Debug)]
pub struct HyperGrid {
    /// Vanishing-parameter grid.
    pub psis: &'static [f64],
    /// SVM ℓ1 grid (used when the caller does not pin λ explicitly).
    pub lambdas: &'static [f64],
    /// ℓ1-bound grid; empty when τ does not apply to the method.
    pub taus: &'static [f64],
}

impl Default for HyperGrid {
    fn default() -> Self {
        HyperGrid { psis: PSI_GRID, lambdas: LAMBDA_GRID, taus: &[] }
    }
}

/// Unified fit diagnostics — the cross-estimator superset of the OAVI
/// driver's [`FitStats`].
#[derive(Clone, Debug, Default)]
pub struct FitReport {
    /// The paper's method name (CGAVI-IHB, ABM, VCA, …).
    pub name: String,
    /// |G| — number of (approximately) vanishing generators.
    pub n_generators: usize,
    /// |O| (monomial-aware) or Σ_d |F_d| (VCA) — the non-vanishing side.
    pub n_order_terms: usize,
    /// Wall-clock seconds of the fit, measured uniformly at the
    /// estimator boundary for every algorithm.
    pub wall_secs: f64,
    /// Raw algorithm counters (oracle calls, solver iterations, …).
    pub stats: FitStats,
}

impl FitReport {
    /// The paper's method name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// |G| + |O| — the paper's central size statistic.
    pub fn total_size(&self) -> usize {
        self.n_generators + self.n_order_terms
    }

    /// One-line JSON document of the report — sizes, wall-clock, and the
    /// raw [`FitStats`] counters (incl. the Table-3 panel attribution:
    /// `panel_passes`/`panel_cols`/`cross_cache_hits`, plus AGD
    /// `warm_starts` and the fast-numerics error budget), consumed by
    /// the CLI and the benches.
    pub fn to_json(&self) -> String {
        let s = &self.stats;
        format!(
            "{{\"name\":\"{}\",\"n_generators\":{},\"n_order_terms\":{},\
             \"wall_secs\":{:?},\"oracle_calls\":{},\"ihb_solves\":{},\
             \"solver_runs\":{},\"solver_iters\":{},\"warm_starts\":{},\
             \"wihb_resolves\":{},\"gram_rebuilds\":{},\
             \"inf_disabled_ihb\":{},\"degree_reached\":{},\
             \"panel_passes\":{},\"panel_cols\":{},\"cross_cache_hits\":{},\
             \"numerics\":\"{}\",\"fast_max_abs_err\":{:e},\
             \"fast_err_budget\":{:e},\"store\":\"{}\",\"store_loads\":{},\
             \"store_reloads\":{},\"store_evictions\":{},\
             \"store_peak_resident_bytes\":{}}}",
            crate::util::json_escape(&self.name),
            self.n_generators,
            self.n_order_terms,
            self.wall_secs,
            s.oracle_calls,
            s.ihb_solves,
            s.solver_runs,
            s.solver_iters,
            s.warm_starts,
            s.wihb_resolves,
            s.gram_rebuilds,
            s.inf_disabled_ihb,
            s.degree_reached,
            s.panel_passes,
            s.panel_cols,
            s.cross_cache_hits,
            s.numerics.as_str(),
            s.fast_max_abs_err,
            s.fast_err_budget,
            if s.store_spilled { "mmap" } else { "mem" },
            s.store_loads,
            s.store_reloads,
            s.store_evictions,
            s.store_peak_resident_bytes,
        )
    }
}

/// A fitted vanishing-ideal model: the per-class (FT) feature-block
/// producer plus reporting statistics and a persistence payload.
///
/// `Send + Sync` so fitted pipelines can be shared across serving
/// threads (the models are plain data; only backends are thread-pinned).
pub trait FittedModel: Send + Sync + std::fmt::Debug {
    /// |g(z)| for every generator over new data — the m × |G| feature
    /// block — through an explicit streaming backend.
    fn transform_with(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Matrix;

    /// [`FittedModel::transform_with`] written directly into a column
    /// range of a caller-owned concatenated m×`stride` slab (row `i`'s
    /// block at `out[i*stride + col_off ..]`).  The default materializes
    /// the block and copies; both in-tree wrappers override with the
    /// strided backend kernels, bitwise identical to the default.
    fn transform_into(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        let block = self.transform_with(x, backend);
        let g = block.cols();
        for i in 0..x.rows() {
            let base = i * stride + col_off;
            out[base..base + g].copy_from_slice(block.row(i));
        }
    }

    /// Compile this model's transform once into a [`plan::
    /// PreparedTransform`]: cached operands, reusable scratch, zero
    /// per-request rebuild work.  The default falls back to the legacy
    /// path behind the plan interface; both in-tree wrappers override
    /// with real compiled plans.
    fn prepare(&self, policy: &plan::PlanPolicy) -> Box<dyn plan::PreparedTransform> {
        let _ = policy;
        plan::fallback_prepared(self.clone_box())
    }

    /// Fit diagnostics (name, sizes, wall-clock, counters).
    fn report(&self) -> &FitReport;

    /// |G| (feature dimension contributed by this class).
    fn n_generators(&self) -> usize {
        self.report().n_generators
    }

    /// |G| + |O| — Table 3's size statistic.
    fn total_size(&self) -> usize {
        self.report().total_size()
    }

    /// Average generator degree (Table 3 "Degree").
    fn avg_degree(&self) -> f64;

    /// (SPAR) as a pooled `(zero_count, total_count)` pair so callers can
    /// aggregate across classes without averaging ratios.
    fn sparsity_pool(&self) -> (f64, f64);

    /// Stable payload discriminator for the persistence envelope.
    fn payload_kind(&self) -> &'static str;

    /// Serialize the transform-relevant state as the envelope payload.
    fn payload_json(&self) -> String;

    /// Concrete-type escape hatch for non-JSON codecs: the binary
    /// artifact codec ([`crate::artifact::codec`]) downcasts to the
    /// wrapper matching [`FittedModel::payload_kind`] instead of
    /// re-parsing `payload_json` output.
    fn as_any(&self) -> &dyn std::any::Any;

    /// Clone through the trait object (fitted models are plain data).
    fn clone_box(&self) -> Box<dyn FittedModel>;
}

impl Clone for Box<dyn FittedModel> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// [`FittedModel::transform_with`] on the native reference backend.
pub fn transform_native(model: &dyn FittedModel, x: &Matrix) -> Matrix {
    model.transform_with(x, &NativeBackend)
}

/// A generator-constructing algorithm, generic over the streaming
/// compute backend: the single fit surface of the crate.
pub trait VanishingIdealEstimator {
    /// The paper's method name (CGAVI-IHB, ABM, VCA, …).
    fn name(&self) -> String;

    /// Monomial-aware methods consume the Pearson feature ordering; VCA
    /// is ordering-agnostic (§5).
    fn is_monomial_aware(&self) -> bool {
        true
    }

    /// The hyperparameter grids this estimator wants cross-validated
    /// (paper §6.2): ψ plus per-method λ and τ ranges.
    fn hyper_grid(&self) -> HyperGrid {
        HyperGrid::default()
    }

    /// Fit one class's data (m×n, expected in [0,1]) through `backend`.
    fn fit(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Result<Box<dyn FittedModel>>;
}

// ---------------------------------------------------------------------
// Fitted-model wrappers
// ---------------------------------------------------------------------

/// Monomial-aware fitted model (OAVI family, ABM): a [`GeneratorSet`]
/// plus its report.
#[derive(Clone, Debug)]
pub struct FittedGeneratorSet {
    pub set: GeneratorSet,
    pub report: FitReport,
}

impl FittedModel for FittedGeneratorSet {
    fn transform_with(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
        self.set.transform_with(x, backend)
    }

    fn transform_into(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        self.set.transform_into(x, backend, out, stride, col_off)
    }

    fn prepare(&self, policy: &plan::PlanPolicy) -> Box<dyn plan::PreparedTransform> {
        Box::new(plan::GeneratorPlan::new(&self.set, policy))
    }

    fn report(&self) -> &FitReport {
        &self.report
    }

    fn avg_degree(&self) -> f64 {
        self.set.avg_degree()
    }

    fn sparsity_pool(&self) -> (f64, f64) {
        let (mut gz, mut ge) = (0usize, 0usize);
        for g in &self.set.generators {
            gz += g.n_zero_coeffs();
            ge += g.n_coeffs();
        }
        (gz as f64, ge as f64)
    }

    fn payload_kind(&self) -> &'static str {
        persist::KIND_GENERATOR_SET
    }

    fn payload_json(&self) -> String {
        persist::generator_set_to_json(&self.set)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn clone_box(&self) -> Box<dyn FittedModel> {
        Box::new(self.clone())
    }
}

/// Monomial-agnostic fitted model: VCA's polynomial op-DAG plus report.
#[derive(Clone, Debug)]
pub struct FittedVca {
    pub model: VcaModel,
    pub report: FitReport,
}

impl FittedModel for FittedVca {
    fn transform_with(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
        self.model.transform_with(x, backend)
    }

    fn transform_into(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        self.model.transform_into(x, backend, out, stride, col_off)
    }

    fn prepare(&self, _policy: &plan::PlanPolicy) -> Box<dyn plan::PreparedTransform> {
        Box::new(plan::VcaPlan::new(&self.model))
    }

    fn report(&self) -> &FitReport {
        &self.report
    }

    fn avg_degree(&self) -> f64 {
        self.model.avg_degree()
    }

    fn sparsity_pool(&self) -> (f64, f64) {
        // VCA's SPAR is already a pooled ratio; weight by its size
        let ge = self.model.n_generators().max(1) as f64;
        (self.model.sparsity() * ge, ge)
    }

    fn payload_kind(&self) -> &'static str {
        persist::KIND_VCA_DAG
    }

    fn payload_json(&self) -> String {
        persist::vca_to_json(&self.model)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn clone_box(&self) -> Box<dyn FittedModel> {
        Box::new(self.clone())
    }
}

fn report_for(
    name: String,
    n_generators: usize,
    n_order_terms: usize,
    stats: FitStats,
) -> FitReport {
    FitReport { name, n_generators, n_order_terms, wall_secs: 0.0, stats }
}

// ---------------------------------------------------------------------
// Trait impls for the three algorithms
// ---------------------------------------------------------------------

impl VanishingIdealEstimator for Oavi {
    fn name(&self) -> String {
        self.config().name()
    }

    fn hyper_grid(&self) -> HyperGrid {
        let cfg = self.config();
        HyperGrid {
            psis: PSI_GRID,
            // WIHB's re-solved generators are already sparse, so the SVM
            // wants less ℓ1 pressure
            lambdas: if cfg.ihb == crate::oavi::IhbMode::Wihb {
                WIHB_LAMBDA_GRID
            } else {
                LAMBDA_GRID
            },
            // τ only exists for the ℓ1-constrained (CCOP) variants
            taus: if cfg.constrained { TAU_GRID } else { &[] },
        }
    }

    fn fit(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Result<Box<dyn FittedModel>> {
        let timer = Timer::start();
        let model = self.fit_with_backend(x, backend)?;
        let mut report = report_for(
            self.config().name(),
            model.generators.len(),
            model.o_terms.len(),
            model.stats.clone(),
        );
        report.wall_secs = timer.secs();
        Ok(Box::new(FittedGeneratorSet { set: model.generator_set(), report }))
    }
}

impl VanishingIdealEstimator for Abm {
    fn name(&self) -> String {
        "ABM".into()
    }

    fn fit(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Result<Box<dyn FittedModel>> {
        let timer = Timer::start();
        let model = self.fit_with_backend(x, backend)?;
        let mut report = report_for(
            self.name(),
            model.generators.len(),
            model.o_terms.len(),
            model.stats.clone(),
        );
        report.wall_secs = timer.secs();
        Ok(Box::new(FittedGeneratorSet { set: model.generator_set(), report }))
    }
}

impl VanishingIdealEstimator for Vca {
    fn name(&self) -> String {
        "VCA".into()
    }

    fn is_monomial_aware(&self) -> bool {
        false
    }

    fn hyper_grid(&self) -> HyperGrid {
        HyperGrid { psis: VCA_PSI_GRID, ..HyperGrid::default() }
    }

    fn fit(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Result<Box<dyn FittedModel>> {
        let timer = Timer::start();
        let model = self.fit_with_backend(x, backend)?;
        let n_f: usize = model.f_sets.iter().map(|f| f.len()).sum();
        let mut report = report_for(self.name(), model.n_generators(), n_f, model.stats.clone());
        report.wall_secs = timer.secs();
        Ok(Box::new(FittedVca { model, report }))
    }
}

// ---------------------------------------------------------------------
// Typed configuration
// ---------------------------------------------------------------------

/// Typed, copyable estimator configuration — the value that travels
/// through grid search jobs, protocol structs, and the CLI, and builds
/// the trait object at fit time.
#[derive(Clone, Copy, Debug)]
pub enum EstimatorConfig {
    Oavi(OaviConfig),
    Abm(AbmConfig),
    Vca(VcaConfig),
}

impl EstimatorConfig {
    /// The paper's method name (CGAVI-IHB, ABM, VCA, …).
    pub fn name(&self) -> String {
        match self {
            EstimatorConfig::Oavi(cfg) => cfg.name(),
            EstimatorConfig::Abm(_) => "ABM".into(),
            EstimatorConfig::Vca(_) => "VCA".into(),
        }
    }

    /// The vanishing parameter ψ.
    pub fn psi(&self) -> f64 {
        match self {
            EstimatorConfig::Oavi(cfg) => cfg.psi,
            EstimatorConfig::Abm(cfg) => cfg.psi,
            EstimatorConfig::Vca(cfg) => cfg.psi,
        }
    }

    /// Same method with a different ψ (grid search).
    pub fn with_psi(&self, psi: f64) -> EstimatorConfig {
        let mut out = *self;
        match &mut out {
            EstimatorConfig::Oavi(cfg) => cfg.psi = psi,
            EstimatorConfig::Abm(cfg) => cfg.psi = psi,
            EstimatorConfig::Vca(cfg) => cfg.psi = psi,
        }
        out
    }

    /// The ℓ1 bound τ, when the method has one (constrained OAVI only).
    pub fn tau(&self) -> Option<f64> {
        match self {
            EstimatorConfig::Oavi(cfg) if cfg.constrained => Some(cfg.tau),
            _ => None,
        }
    }

    /// Same method with a different τ (grid search); a no-op for methods
    /// without an ℓ1 bound.
    pub fn with_tau(&self, tau: f64) -> EstimatorConfig {
        let mut out = *self;
        if let EstimatorConfig::Oavi(cfg) = &mut out {
            if cfg.constrained {
                cfg.tau = tau;
            }
        }
        out
    }

    /// Monomial-aware methods need the Pearson ordering; VCA is agnostic.
    pub fn is_monomial_aware(&self) -> bool {
        !matches!(self, EstimatorConfig::Vca(_))
    }

    /// Validate invariants before fitting.
    pub fn validate(&self) -> Result<()> {
        let psi = self.psi();
        if psi < 0.0 || !psi.is_finite() {
            return Err(AviError::Config(format!("psi must be ≥ 0, got {psi}")));
        }
        match self {
            EstimatorConfig::Oavi(cfg) => cfg.validate(),
            EstimatorConfig::Abm(_) | EstimatorConfig::Vca(_) => Ok(()),
        }
    }

    /// Build the estimator trait object.
    pub fn build(&self) -> Box<dyn VanishingIdealEstimator> {
        match self {
            EstimatorConfig::Oavi(cfg) => Box::new(Oavi::new(*cfg)),
            EstimatorConfig::Abm(cfg) => Box::new(Abm::new(*cfg)),
            EstimatorConfig::Vca(cfg) => Box::new(Vca::new(*cfg)),
        }
    }

    /// Convenience: build + fit in one call.
    pub fn fit(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> Result<Box<dyn FittedModel>> {
        self.validate()?;
        self.build().fit(x, backend)
    }

    /// Parse a CLI-style method name (`cgavi-ihb`, `abm`, `vca`, …).
    pub fn parse(method: &str, psi: f64) -> Result<EstimatorConfig> {
        EstimatorBuilder::new(method).psi(psi).build()
    }

    /// Every registered method name, in CLI/usage order.
    pub fn known_methods() -> &'static [&'static str] {
        &[
            "cgavi-ihb",
            "agdavi-ihb",
            "bpcgavi-wihb",
            "bpcgavi",
            "pcgavi",
            "cgavi",
            "abm",
            "vca",
        ]
    }

    /// The Table-3 method battery at one ψ: the paper's headline OAVI
    /// variants plus both baselines (mixed-method grid-search input).
    pub fn battery(psi: f64) -> Vec<EstimatorConfig> {
        vec![
            EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(psi)),
            EstimatorConfig::Oavi(OaviConfig::bpcgavi_wihb(psi)),
            EstimatorConfig::Abm(AbmConfig::new(psi)),
            EstimatorConfig::Vca(VcaConfig::new(psi)),
        ]
    }
}

/// Builder from CLI-style method names — the typed replacement for the
/// string `match` that used to live in `main.rs`.
#[derive(Clone, Debug)]
pub struct EstimatorBuilder {
    method: String,
    psi: f64,
    tau: Option<f64>,
    max_degree: Option<u32>,
    numerics: Option<NumericsMode>,
    fast_tol: Option<f64>,
    store: Option<StoreMode>,
}

impl EstimatorBuilder {
    /// Start from a method name (see [`EstimatorConfig::known_methods`]).
    pub fn new(method: impl Into<String>) -> Self {
        EstimatorBuilder {
            method: method.into(),
            psi: 0.005,
            tau: None,
            max_degree: None,
            numerics: None,
            fast_tol: None,
            store: None,
        }
    }

    /// Vanishing parameter ψ (default 0.005, the paper's working point).
    pub fn psi(mut self, psi: f64) -> Self {
        self.psi = psi;
        self
    }

    /// ℓ1 bound τ (OAVI family only; ignored by ABM/VCA).
    pub fn tau(mut self, tau: f64) -> Self {
        self.tau = Some(tau);
        self
    }

    /// Border-degree safety cap.
    pub fn max_degree(mut self, d: u32) -> Self {
        self.max_degree = Some(d);
        self
    }

    /// Panel-kernel numerics (OAVI family only): `NumericsMode::Fast`
    /// opts into the f32-accumulated panel kernels under the measured
    /// error budget.  Rejected for ABM/VCA, whose panel reads (bordered
    /// Gram eigenproblems, projections) stay on the exact path.
    pub fn numerics(mut self, mode: NumericsMode) -> Self {
        self.numerics = Some(mode);
        self
    }

    /// Fast-mode error tolerance (see `OaviConfig::fast_tol`).
    pub fn fast_tol(mut self, tol: f64) -> Self {
        self.fast_tol = Some(tol);
        self
    }

    /// Working-store backing (OAVI family only): `StoreMode::Spill`
    /// keeps evaluation columns in checksummed on-disk segments under a
    /// resident-byte budget.  Exact-mode results are bitwise identical
    /// to memory backing; rejected for ABM/VCA, whose fits materialize
    /// full matrices anyway.
    pub fn store(mut self, mode: StoreMode) -> Self {
        self.store = Some(mode);
        self
    }

    /// Resolve the name and produce a validated config.
    pub fn build(self) -> Result<EstimatorConfig> {
        let psi = self.psi;
        let mut cfg = match self.method.as_str() {
            "cgavi-ihb" => EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(psi)),
            "agdavi-ihb" => EstimatorConfig::Oavi(OaviConfig::agdavi_ihb(psi)),
            "bpcgavi-wihb" => EstimatorConfig::Oavi(OaviConfig::bpcgavi_wihb(psi)),
            "bpcgavi" => EstimatorConfig::Oavi(OaviConfig::bpcgavi(psi)),
            "pcgavi" => EstimatorConfig::Oavi(OaviConfig::pcgavi(psi)),
            "cgavi" => EstimatorConfig::Oavi(OaviConfig::cgavi(psi)),
            "abm" => EstimatorConfig::Abm(AbmConfig::new(psi)),
            "vca" => EstimatorConfig::Vca(VcaConfig::new(psi)),
            other => {
                return Err(AviError::Config(format!(
                    "unknown method '{other}' (known: {})",
                    EstimatorConfig::known_methods().join(", ")
                )))
            }
        };
        match &mut cfg {
            EstimatorConfig::Oavi(c) => {
                if let Some(tau) = self.tau {
                    c.tau = tau;
                }
                if let Some(d) = self.max_degree {
                    c.max_degree = d;
                }
                if let Some(n) = self.numerics {
                    c.numerics = n;
                }
                if let Some(t) = self.fast_tol {
                    c.fast_tol = t;
                }
                if let Some(s) = self.store {
                    c.store = s;
                }
            }
            EstimatorConfig::Abm(c) => {
                if self.numerics == Some(NumericsMode::Fast) {
                    return Err(AviError::Config(
                        "fast numerics is only supported by the OAVI family".into(),
                    ));
                }
                if self.store.map(|s| s.is_spill()) == Some(true) {
                    return Err(AviError::Config(
                        "spill-backed stores are only supported by the OAVI family".into(),
                    ));
                }
                if let Some(d) = self.max_degree {
                    c.max_degree = d;
                }
            }
            EstimatorConfig::Vca(c) => {
                if self.numerics == Some(NumericsMode::Fast) {
                    return Err(AviError::Config(
                        "fast numerics is only supported by the OAVI family".into(),
                    ));
                }
                if self.store.map(|s| s.is_spill()) == Some(true) {
                    return Err(AviError::Config(
                        "spill-backed stores are only supported by the OAVI family".into(),
                    ));
                }
                if let Some(d) = self.max_degree {
                    c.max_degree = d;
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn parabola(m: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        for i in 0..m {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, t * t);
        }
        x
    }

    #[test]
    fn every_estimator_fits_through_the_trait() {
        let x = parabola(150, 1);
        for cfg in EstimatorConfig::battery(0.01) {
            let model = cfg.fit(&x, &NativeBackend).unwrap();
            assert!(model.n_generators() > 0, "{}: no generators", cfg.name());
            assert!(model.total_size() >= model.n_generators());
            let t = transform_native(model.as_ref(), &x);
            assert_eq!(t.rows(), 150);
            assert_eq!(t.cols(), model.n_generators());
            let report = model.report();
            assert_eq!(report.name(), cfg.name());
            assert!(report.wall_secs > 0.0, "{}: no wall-clock", cfg.name());
            assert_eq!(report.total_size(), model.total_size());
        }
    }

    #[test]
    fn fit_report_json_carries_panel_counters() {
        let x = parabola(120, 5);
        let model =
            EstimatorConfig::parse("cgavi-ihb", 0.005).unwrap().fit(&x, &NativeBackend).unwrap();
        let json = model.report().to_json();
        assert!(json.starts_with('{') && json.ends_with('}'), "{json}");
        for key in [
            "\"name\":\"CGAVI-IHB\"",
            "\"panel_passes\":",
            "\"panel_cols\":",
            "\"cross_cache_hits\":",
            "\"warm_starts\":",
            "\"oracle_calls\":",
            "\"numerics\":\"exact\"",
            "\"fast_max_abs_err\":",
            "\"fast_err_budget\":",
            "\"store\":\"mem\"",
            "\"store_evictions\":",
            "\"store_peak_resident_bytes\":",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        // the default fit runs through panels, so the counters are live
        assert!(model.report().stats.panel_passes > 0);
        assert_eq!(model.report().stats.panel_cols, model.report().stats.oracle_calls);
    }

    #[test]
    fn builder_parses_every_known_method() {
        for name in EstimatorConfig::known_methods() {
            let cfg = EstimatorConfig::parse(name, 0.01).unwrap();
            assert_eq!(cfg.psi(), 0.01);
            let est = cfg.build();
            assert!(!est.name().is_empty());
            let grid = est.hyper_grid();
            assert!(!grid.psis.is_empty());
            assert!(!grid.lambdas.is_empty());
        }
        assert!(EstimatorConfig::parse("nope", 0.01).is_err());
    }

    #[test]
    fn hyper_grids_are_estimator_aware() {
        let grid = |name: &str| EstimatorConfig::parse(name, 0.01).unwrap().build().hyper_grid();
        // constrained OAVI variants sweep τ; unconstrained ones have none
        assert_eq!(grid("cgavi-ihb").taus, TAU_GRID);
        assert_eq!(grid("bpcgavi").taus, TAU_GRID);
        assert!(grid("agdavi-ihb").taus.is_empty());
        // WIHB's sparse generators shift the λ range down a decade
        assert_eq!(grid("bpcgavi-wihb").lambdas, WIHB_LAMBDA_GRID);
        assert_eq!(grid("cgavi-ihb").lambdas, LAMBDA_GRID);
        // VCA's ψ acts on singular values → its own coarser range
        assert_eq!(grid("vca").psis, VCA_PSI_GRID);
        assert!(grid("vca").taus.is_empty());
        // ABM keeps the defaults
        assert_eq!(grid("abm").psis, PSI_GRID);
        assert_eq!(grid("abm").lambdas, LAMBDA_GRID);
        assert!(grid("abm").taus.is_empty());
    }

    #[test]
    fn with_tau_applies_only_to_constrained_methods() {
        let cg = EstimatorConfig::parse("cgavi-ihb", 0.01).unwrap();
        assert_eq!(cg.tau(), Some(1000.0));
        assert_eq!(cg.with_tau(500.0).tau(), Some(500.0));
        assert_eq!(cg.with_tau(500.0).name(), cg.name());
        for name in ["agdavi-ihb", "abm", "vca"] {
            let cfg = EstimatorConfig::parse(name, 0.01).unwrap();
            assert_eq!(cfg.tau(), None, "{name}");
            assert_eq!(cfg.with_tau(500.0).tau(), None, "{name}");
        }
    }

    #[test]
    fn builder_overrides_apply() {
        let cfg = EstimatorBuilder::new("cgavi-ihb").psi(0.02).tau(500.0).build().unwrap();
        match cfg {
            EstimatorConfig::Oavi(c) => {
                assert_eq!(c.psi, 0.02);
                assert_eq!(c.tau, 500.0);
            }
            _ => unreachable!(),
        }
        let cfg = EstimatorBuilder::new("vca").psi(0.1).max_degree(3).build().unwrap();
        match cfg {
            EstimatorConfig::Vca(c) => {
                assert_eq!(c.psi, 0.1);
                assert_eq!(c.max_degree, 3);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn builder_numerics_is_oavi_only() {
        let cfg = EstimatorBuilder::new("cgavi-ihb")
            .numerics(NumericsMode::Fast)
            .fast_tol(1e-2)
            .build()
            .unwrap();
        match cfg {
            EstimatorConfig::Oavi(c) => {
                assert_eq!(c.numerics, NumericsMode::Fast);
                assert_eq!(c.fast_tol, 1e-2);
            }
            _ => unreachable!(),
        }
        for name in ["abm", "vca"] {
            assert!(
                EstimatorBuilder::new(name).numerics(NumericsMode::Fast).build().is_err(),
                "{name} must reject fast numerics"
            );
            // exact is the default everywhere and always accepted
            assert!(EstimatorBuilder::new(name).numerics(NumericsMode::Exact).build().is_ok());
        }
    }

    #[test]
    fn builder_store_mode_is_oavi_only() {
        let cfg = EstimatorBuilder::new("cgavi-ihb")
            .store(StoreMode::spill_mb(16))
            .build()
            .unwrap();
        match cfg {
            EstimatorConfig::Oavi(c) => assert!(c.store.is_spill()),
            _ => unreachable!(),
        }
        for name in ["abm", "vca"] {
            assert!(
                EstimatorBuilder::new(name).store(StoreMode::spill_mb(16)).build().is_err(),
                "{name} must reject spill stores"
            );
            assert!(EstimatorBuilder::new(name).store(StoreMode::Memory).build().is_ok());
        }
    }

    #[test]
    fn with_psi_rewrites_psi_everywhere() {
        for cfg in EstimatorConfig::battery(0.1) {
            assert_eq!(cfg.with_psi(0.03).psi(), 0.03);
            assert_eq!(cfg.with_psi(0.03).name(), cfg.name());
        }
    }

    #[test]
    fn validation_rejects_bad_psi() {
        for cfg in EstimatorConfig::battery(0.01) {
            assert!(cfg.with_psi(-1.0).validate().is_err());
            assert!(cfg.with_psi(f64::NAN).validate().is_err());
            assert!(cfg.validate().is_ok());
        }
    }

    #[test]
    fn monomial_awareness_matches_paper() {
        assert!(EstimatorConfig::parse("cgavi-ihb", 0.01).unwrap().is_monomial_aware());
        assert!(EstimatorConfig::parse("abm", 0.01).unwrap().is_monomial_aware());
        assert!(!EstimatorConfig::parse("vca", 0.01).unwrap().is_monomial_aware());
        assert!(!Vca::new(VcaConfig::new(0.01)).is_monomial_aware());
        assert!(Abm::new(AbmConfig::new(0.01)).is_monomial_aware());
    }

    #[test]
    fn fitted_models_clone_through_the_trait() {
        let x = parabola(80, 3);
        let model = EstimatorConfig::parse("abm", 1e-6).unwrap().fit(&x, &NativeBackend).unwrap();
        let cloned = model.clone_box();
        let a = transform_native(model.as_ref(), &x);
        let b = transform_native(cloned.as_ref(), &x);
        assert_eq!(a.data(), b.data());
    }
}

//! Row-sharded column store + candidate panels — the column currency of
//! the data plane.
//!
//! Every layer that touches evaluation columns (the OAVI driver, the
//! streaming backends, the (FT) transform, Pearson ordering, ABM/VCA)
//! goes through [`ColumnStore`].  Rows are partitioned once into
//! contiguous shards; each shard owns a column-major block
//! (`rows × ℓ`), so a column append is one `extend_from_slice` per shard
//! (amortized O(m), no per-column `Vec` allocation) and every kernel can
//! operate on plain `&[f64]` shard slices.
//!
//! # Kernel inventory (per-shard free functions)
//!
//! * [`gram_panel_partial`] / [`panel_cross_partial`] — the **primary
//!   training kernels** since the degree-batched refactor: one
//!   [`CandidatePanel`] holds every degree-d border candidate (filled
//!   from its parent columns in one pass, [`CandidatePanel::from_recipes`]),
//!   and the ℓ×k store-vs-panel block plus the k×k panel cross-Gram
//!   upper triangle replace |∂d| separate BLAS-1 sweeps with one
//!   BLAS-3-shaped pass per degree.
//! * [`gram_partial`] — the legacy per-candidate `(Aᵀb, bᵀb)` map side,
//!   still used by serving-time single-column queries and kept as the
//!   bitwise reference for the panel path.
//! * [`transform_block`] — the (FT) `|A·C + U|` map side (test time).
//!
//! All Gram-type kernels share **one per-entry dot discipline**: every
//! output entry is bitwise equal to [`crate::linalg::dot`] of the two
//! column slices involved (the blocked variants only share passes over
//! the right-hand column — see `dot4`'s contract).  That makes each
//! entry's bits independent of which kernel, blocking factor, or batch
//! boundary produced it, which is what lets the panel path reproduce the
//! legacy per-candidate path bit for bit.
//!
//! The kernels are shared verbatim by [`crate::backend::NativeBackend`]
//! (sequential over shards) and [`crate::backend::ShardedBackend`]
//! (thread-pool map over shard×panel tiles with a deterministic in-order
//! reduction).  Because both backends run the same per-shard code and
//! reduce partials in the same shard order, their results are
//! **bit-for-bit identical** for any fixed shard count — the
//! reproducibility contract `rust/tests/runtime_parity.rs` pins down.

use std::ops::Range;

use crate::linalg::dense::Matrix;
use crate::linalg::dot;

/// One contiguous row-range of every column, stored column-major.
#[derive(Clone, Debug)]
struct Shard {
    /// rows owned by this shard (may be 0 when m < shard count).
    rows: usize,
    /// column-major block: column j occupies `data[j*rows .. (j+1)*rows]`.
    data: Vec<f64>,
}

/// Row-sharded, append-only evaluation-column storage.
#[derive(Clone, Debug)]
pub struct ColumnStore {
    m: usize,
    n_cols: usize,
    /// shard row offsets; `offsets[s]..offsets[s+1]` are shard s's rows.
    offsets: Vec<usize>,
    shards: Vec<Shard>,
}

impl ColumnStore {
    /// Empty store over `m` rows split into `n_shards` balanced contiguous
    /// shards (clamped to ≥ 1; shards may own 0 rows when `m < n_shards`).
    pub fn new(m: usize, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        let base = m / n_shards;
        let rem = m % n_shards;
        let mut offsets = Vec::with_capacity(n_shards + 1);
        offsets.push(0);
        let mut shards = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let rows = base + usize::from(s < rem);
            offsets.push(offsets[s] + rows);
            shards.push(Shard { rows, data: Vec::new() });
        }
        ColumnStore { m, n_cols: 0, offsets, shards }
    }

    /// Store holding the single constant-1 column (OAVI Line 2: O = {𝟙}).
    pub fn with_ones(m: usize, n_shards: usize) -> Self {
        let mut store = ColumnStore::new(m, n_shards);
        for shard in &mut store.shards {
            shard.data.resize(shard.rows, 1.0);
        }
        store.n_cols = 1;
        store
    }

    /// Build from explicit full-length columns (tests, benches, rebuilds).
    pub fn from_cols(cols: &[Vec<f64>], n_shards: usize) -> Self {
        let m = cols.first().map(|c| c.len()).unwrap_or(0);
        let mut store = ColumnStore::new(m, n_shards);
        for col in cols {
            store.push_col(col);
        }
        store
    }

    /// Build from the columns of a row-major matrix (feature columns for
    /// the Pearson ordering, evaluation columns in tests).
    pub fn from_matrix(x: &Matrix, n_shards: usize) -> Self {
        let m = x.rows();
        let mut store = ColumnStore::new(m, n_shards);
        let mut buf = vec![0.0f64; m];
        for j in 0..x.cols() {
            for (i, v) in buf.iter_mut().enumerate() {
                *v = x.get(i, j);
            }
            store.push_col(&buf);
        }
        store
    }

    /// Number of rows m.
    #[inline]
    pub fn rows(&self) -> usize {
        self.m
    }

    /// Number of columns ℓ.
    #[inline]
    pub fn len(&self) -> usize {
        self.n_cols
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n_cols == 0
    }

    /// Number of row shards (fixed at construction).
    #[inline]
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Global row range owned by shard `s`.
    #[inline]
    pub fn shard_range(&self, s: usize) -> Range<usize> {
        self.offsets[s]..self.offsets[s + 1]
    }

    /// Column `j`'s contiguous slice within shard `s`.
    #[inline]
    pub fn col_shard(&self, j: usize, s: usize) -> &[f64] {
        let shard = &self.shards[s];
        &shard.data[j * shard.rows..(j + 1) * shard.rows]
    }

    /// Append a full-length column by copying its row-ranges into the
    /// shard blocks.  The caller's buffer is untouched and reusable — this
    /// is the amortized-append contract the OAVI driver relies on (no
    /// per-accepted-term `Vec` allocation).
    pub fn push_col(&mut self, col: &[f64]) {
        debug_assert_eq!(col.len(), self.m, "push_col: length mismatch");
        for (s, shard) in self.shards.iter_mut().enumerate() {
            let range = self.offsets[s]..self.offsets[s + 1];
            shard.data.extend_from_slice(&col[range]);
        }
        self.n_cols += 1;
    }

    /// Materialize column `j` as one contiguous vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.m);
        for s in 0..self.n_shards() {
            out.extend_from_slice(self.col_shard(j, s));
        }
        out
    }

    /// `out[i] = col_parent[i] * x[i, var]` — the border-term candidate
    /// evaluation (one multiply per sample, Theorem 4.2), written into a
    /// caller-owned reusable buffer.
    pub fn fill_product(&self, parent: usize, x: &Matrix, var: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.m, "fill_product: length mismatch");
        for s in 0..self.n_shards() {
            let p = self.col_shard(parent, s);
            for (k, i) in self.shard_range(s).enumerate() {
                out[i] = p[k] * x.get(i, var);
            }
        }
    }

    /// ⟨col_i, col_j⟩ accumulated shard-by-shard (deterministic order).
    pub fn dot_cols(&self, i: usize, j: usize) -> f64 {
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            acc += dot(self.col_shard(i, s), self.col_shard(j, s));
        }
        acc
    }

    /// ⟨col_j, v⟩ for a full-length vector `v`, shard-by-shard.
    pub fn dot_col_slice(&self, j: usize, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.m);
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            acc += dot(self.col_shard(j, s), &v[self.shard_range(s)]);
        }
        acc
    }

    /// Append candidate column `c` of a [`CandidatePanel`] built over
    /// this store's row partition — shard-to-shard copies, no full-length
    /// staging buffer.  Values (hence result bits) are identical to
    /// materializing the panel column and calling [`ColumnStore::push_col`].
    pub fn push_col_from_panel(&mut self, panel: &CandidatePanel, c: usize) {
        debug_assert_eq!(panel.m, self.m, "push_col_from_panel: row mismatch");
        debug_assert_eq!(
            panel.offsets, self.offsets,
            "push_col_from_panel: panel/store partitions must match"
        );
        for (s, shard) in self.shards.iter_mut().enumerate() {
            shard.data.extend_from_slice(panel.col_shard(c, s));
        }
        self.n_cols += 1;
    }

    /// Mean of column `j` (Pearson ordering helper).
    pub fn col_mean(&self, j: usize) -> f64 {
        if self.m == 0 {
            return 0.0;
        }
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            acc += self.col_shard(j, s).iter().sum::<f64>();
        }
        acc / self.m as f64
    }
}

/// Recipe for one border-term candidate column:
/// `panel[:, c] = store[:, parent] ⊙ x[:, var]` (Theorem 4.2 — one
/// multiply per sample from the parent's evaluation column).
#[derive(Clone, Copy, Debug)]
pub struct PanelRecipe {
    /// Store column index of the parent term `u / x_var`.
    pub parent: usize,
    /// Variable index such that `u = parent · x_var`.
    pub var: usize,
}

/// A degree-batch of candidate columns sharing a [`ColumnStore`]'s row
/// partition: the m×k right-hand side of the panel kernels.
///
/// Shards mirror the parent store's offsets exactly, so every panel
/// kernel pairs `store.col_shard(j, s)` with `panel.col_shard(c, s)`
/// slices of equal length — the precondition [`gram_panel_partial`]
/// asserts.  Built either from border recipes (OAVI/ABM: one pass over
/// the parent columns evaluates the whole degree-d border) or by pushing
/// full-length columns (VCA's candidate/projection batches).
#[derive(Clone, Debug)]
pub struct CandidatePanel {
    m: usize,
    k: usize,
    offsets: Vec<usize>,
    shards: Vec<Shard>,
}

impl CandidatePanel {
    /// Empty panel over `store`'s exact row partition.
    pub fn new_like(store: &ColumnStore) -> Self {
        CandidatePanel {
            m: store.m,
            k: 0,
            offsets: store.offsets.clone(),
            shards: store
                .shards
                .iter()
                .map(|sh| Shard { rows: sh.rows, data: Vec::new() })
                .collect(),
        }
    }

    /// Evaluate every recipe into a fresh panel in **one pass per
    /// shard**: each shard block stays hot while all k candidates read
    /// their parent columns from it.  The per-sample arithmetic
    /// (`parent[i] · x[i, var]`) is exactly
    /// [`ColumnStore::fill_product`]'s, so panel columns are bitwise
    /// identical to the legacy per-candidate evaluation buffers.
    pub fn from_recipes(store: &ColumnStore, x: &Matrix, recipes: &[PanelRecipe]) -> Self {
        let mut panel = Self::new_like(store);
        let k = recipes.len();
        for (s, shard) in panel.shards.iter_mut().enumerate() {
            shard.data.resize(shard.rows * k, 0.0);
            let start = panel.offsets[s];
            for (c, r) in recipes.iter().enumerate() {
                let p = store.col_shard(r.parent, s);
                let dst = &mut shard.data[c * shard.rows..(c + 1) * shard.rows];
                for (i, d) in dst.iter_mut().enumerate() {
                    *d = p[i] * x.get(start + i, r.var);
                }
            }
        }
        panel.k = k;
        panel
    }

    /// Append one full-length candidate column (VCA batches; benches).
    pub fn push_col(&mut self, col: &[f64]) {
        debug_assert_eq!(col.len(), self.m, "panel push_col: length mismatch");
        for (s, shard) in self.shards.iter_mut().enumerate() {
            let range = self.offsets[s]..self.offsets[s + 1];
            shard.data.extend_from_slice(&col[range]);
        }
        self.k += 1;
    }

    /// Number of candidate columns k.
    #[inline]
    pub fn len(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.k == 0
    }

    /// Number of rows m.
    #[inline]
    pub fn rows(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Global row range owned by shard `s` (mirrors the parent store).
    #[inline]
    pub fn shard_range(&self, s: usize) -> Range<usize> {
        self.offsets[s]..self.offsets[s + 1]
    }

    /// Candidate `c`'s contiguous slice within shard `s`.
    #[inline]
    pub fn col_shard(&self, c: usize, s: usize) -> &[f64] {
        let shard = &self.shards[s];
        &shard.data[c * shard.rows..(c + 1) * shard.rows]
    }

    /// Materialize candidate `c` as one contiguous vector (Schur-guard
    /// rebuilds, PJRT packing).
    pub fn col(&self, c: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.m);
        for s in 0..self.n_shards() {
            out.extend_from_slice(self.col_shard(c, s));
        }
        out
    }

    /// Same row partition as `store`?  (Precondition of every panel
    /// kernel.)
    pub fn partition_matches(&self, store: &ColumnStore) -> bool {
        self.offsets == store.offsets
    }

    /// Clamp a configured per-chunk column budget so one panel never
    /// exceeds ~256 MB regardless of m (the `m × |∂d|` blow-up guard at
    /// m ≫ 1e5): `min(requested, 256MB / (8·m))`, floored at 1.
    pub fn budget_cols(requested: usize, m: usize) -> usize {
        const PANEL_BUDGET_BYTES: usize = 256 << 20;
        let mem_cap = (PANEL_BUDGET_BYTES / (8 * m.max(1))).max(1);
        requested.max(1).min(mem_cap)
    }
}

/// Reduced result of one degree-batched panel pass:
/// the ℓ×k store-vs-panel block plus (optionally) the k×k panel
/// cross-Gram upper triangle, both accumulated in shard order.
///
/// Layouts: `atb` is candidate-major (`atb[c·ℓ + j] = ⟨store_j, panel_c⟩`,
/// so [`PanelStats::atb_col`] is the candidate's ready-to-use `Aᵀb`
/// prefix); `cross` packs the upper triangle candidate-major
/// (`cross[c(c+1)/2 + i] = ⟨panel_i, panel_c⟩` for `i ≤ c`, diagonal =
/// `bᵀb`).  The cross entries are what lets the driver resolve the
/// within-degree dependence in O(1) per (accepted, later-candidate)
/// pair: when candidate i joins O, later candidates extend their `Aᵀb`
/// with `cross_at(i, c)` instead of re-touching the data.
#[derive(Clone, Debug)]
pub struct PanelStats {
    ell: usize,
    k: usize,
    atb: Vec<f64>,
    cross: Vec<f64>,
}

impl PanelStats {
    /// Assemble from reduced blocks (backends only).
    pub fn new(ell: usize, k: usize, atb: Vec<f64>, cross: Vec<f64>) -> Self {
        debug_assert_eq!(atb.len(), ell * k);
        debug_assert!(cross.is_empty() || cross.len() == k * (k + 1) / 2);
        PanelStats { ell, k, atb, cross }
    }

    /// Store width ℓ the block was computed against.
    #[inline]
    pub fn ell(&self) -> usize {
        self.ell
    }

    /// Number of candidates k.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Whether the cross-Gram triangle was computed.
    #[inline]
    pub fn has_cross(&self) -> bool {
        !self.cross.is_empty()
    }

    /// `⟨store_j, panel_c⟩` for all j — candidate c's `Aᵀb` over the
    /// store columns present when the panel was filled.
    #[inline]
    pub fn atb_col(&self, c: usize) -> &[f64] {
        &self.atb[c * self.ell..(c + 1) * self.ell]
    }

    /// Cached cross-Gram entry `⟨panel_i, panel_c⟩`, `i ≤ c`.
    #[inline]
    pub fn cross_at(&self, i: usize, c: usize) -> f64 {
        debug_assert!(i <= c, "cross_at: upper triangle only ({i} > {c})");
        self.cross[c * (c + 1) / 2 + i]
    }

    /// `bᵀb` of candidate c (the cross diagonal).
    #[inline]
    pub fn btb(&self, c: usize) -> f64 {
        self.cross_at(c, c)
    }
}

/// Four dots sharing one pass over `b`: returns
/// `[dot(c0,b), dot(c1,b), dot(c2,b), dot(c3,b)]`, each entry **bitwise
/// equal** to [`crate::linalg::dot`] of that column with `b`.
///
/// This is the blocked building brick of the per-entry dot discipline:
/// every column keeps `dot`'s four lane accumulators, lane-combine
/// order, and sequential tail, so the result bits are independent of the
/// blocking — only the (cache-missing past the LLC) pass over `b` is
/// shared, cutting b traffic 4×.  Perf pass #2 (EXPERIMENTS.md §Perf)
/// originally used free-form per-column accumulators here; the panel
/// refactor pinned the lanes to `dot`'s schedule so blocked and
/// unblocked entries agree bit for bit (the property the panel path's
/// bitwise contract rests on).
fn dot4(c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64], b: &[f64]) -> [f64; 4] {
    let n = b.len();
    let chunks = n / 4;
    // l[col][lane] — each column's four dot lanes
    let mut l = [[0.0f64; 4]; 4];
    for i in 0..chunks {
        let j = i * 4;
        let (b0, b1, b2, b3) = (b[j], b[j + 1], b[j + 2], b[j + 3]);
        l[0][0] += c0[j] * b0;
        l[0][1] += c0[j + 1] * b1;
        l[0][2] += c0[j + 2] * b2;
        l[0][3] += c0[j + 3] * b3;
        l[1][0] += c1[j] * b0;
        l[1][1] += c1[j + 1] * b1;
        l[1][2] += c1[j + 2] * b2;
        l[1][3] += c1[j + 3] * b3;
        l[2][0] += c2[j] * b0;
        l[2][1] += c2[j + 1] * b1;
        l[2][2] += c2[j + 2] * b2;
        l[2][3] += c2[j + 3] * b3;
        l[3][0] += c3[j] * b0;
        l[3][1] += c3[j + 1] * b1;
        l[3][2] += c3[j + 2] * b2;
        l[3][3] += c3[j + 3] * b3;
    }
    let mut out = [
        (l[0][0] + l[0][1]) + (l[0][2] + l[0][3]),
        (l[1][0] + l[1][1]) + (l[1][2] + l[1][3]),
        (l[2][0] + l[2][1]) + (l[2][2] + l[2][3]),
        (l[3][0] + l[3][1]) + (l[3][2] + l[3][3]),
    ];
    for j in chunks * 4..n {
        out[0] += c0[j] * b[j];
        out[1] += c1[j] * b[j];
        out[2] += c2[j] * b[j];
        out[3] += c3[j] * b[j];
    }
    out
}

/// `out[j] = ⟨column j, bs⟩` for `n_cols` columns provided by `col`,
/// every entry bitwise equal to [`crate::linalg::dot`] — the one
/// Gram-entry code path shared by [`gram_partial`],
/// [`gram_panel_partial`], and [`panel_cross_partial`].  Past the LLC
/// scale, four columns share each pass over `bs` via [`dot4`]; for
/// cache-resident shards the plain per-column dot is faster.  The
/// branch affects wall-clock only — both sides produce identical bits.
fn dots_into<'a, F: Fn(usize) -> &'a [f64]>(col: F, n_cols: usize, bs: &[f64], out: &mut [f64]) {
    debug_assert_eq!(out.len(), n_cols);
    const BLOCK_THRESHOLD_BYTES: usize = 4 << 20; // ~LLC slice
    if bs.len() * std::mem::size_of::<f64>() < BLOCK_THRESHOLD_BYTES {
        for (j, a) in out.iter_mut().enumerate() {
            *a = dot(col(j), bs);
        }
        return;
    }
    let mut j = 0;
    while j + 4 <= n_cols {
        let d = dot4(col(j), col(j + 1), col(j + 2), col(j + 3), bs);
        out[j..j + 4].copy_from_slice(&d);
        j += 4;
    }
    while j < n_cols {
        out[j] = dot(col(j), bs);
        j += 1;
    }
}

/// Per-shard `(Aᵀb, bᵀb)` partial — the map side of gram_stats (the
/// legacy per-candidate kernel; serving-time single-column queries and
/// the bitwise reference path still use it).  Per-entry dot discipline
/// via [`dots_into`].
pub fn gram_partial(store: &ColumnStore, s: usize, b_full: &[f64]) -> (Vec<f64>, f64) {
    let bs = &b_full[store.shard_range(s)];
    let mut atb = vec![0.0f64; store.len()];
    dots_into(|j| store.col_shard(j, s), store.len(), bs, &mut atb);
    (atb, dot(bs, bs))
}

/// Per-shard store-vs-panel block for the candidate range `cr` — the map
/// side of [`gram_panel_seq`] and the primary training kernel.
///
/// Output is candidate-major: `out[(c − cr.start)·ℓ + j] =
/// ⟨store_j, panel_c⟩` in shard `s`, every entry bitwise-dot
/// ([`dots_into`]).  The shard's column block is streamed once per
/// candidate with 4-column b-pass sharing past the LLC; tiling over
/// `(shard, candidate range)` is the parallel backends' job.
pub fn gram_panel_partial(
    store: &ColumnStore,
    panel: &CandidatePanel,
    s: usize,
    cr: Range<usize>,
) -> Vec<f64> {
    debug_assert!(panel.partition_matches(store), "panel/store partitions must match");
    let ell = store.len();
    let mut out = vec![0.0f64; ell * cr.len()];
    if ell == 0 {
        return out;
    }
    for (ci, c) in cr.enumerate() {
        let bs = panel.col_shard(c, s);
        dots_into(|j| store.col_shard(j, s), ell, bs, &mut out[ci * ell..(ci + 1) * ell]);
    }
    out
}

/// Per-shard panel cross-Gram upper triangle for the candidate range
/// `cr`: for each `c ∈ cr`, the `c + 1` entries `⟨panel_i, panel_c⟩`
/// (`i ≤ c`), packed candidate-major in `cr` order.  Per-entry
/// bitwise-dot, so a cross entry carries exactly the bits the legacy
/// path would have produced by pushing candidate `i` into the store and
/// re-running `gram_partial` for candidate `c`.
pub fn panel_cross_partial(panel: &CandidatePanel, s: usize, cr: Range<usize>) -> Vec<f64> {
    let total: usize = cr.clone().map(|c| c + 1).sum();
    let mut out = vec![0.0f64; total];
    let mut base = 0usize;
    for c in cr {
        let bs = panel.col_shard(c, s);
        dots_into(|i| panel.col_shard(i, s), c + 1, bs, &mut out[base..base + c + 1]);
        base += c + 1;
    }
    out
}

/// Sequential in-shard-order reduction of the panel kernels — the exact
/// reduction every backend must reproduce (bit-reproducibility anchor,
/// like [`gram_stats_seq`] for the single-column kernel).  With
/// `want_cross = false` the k×k triangle is skipped (VCA's projection
/// batches need only the store-vs-panel block).
pub fn gram_panel_seq(
    store: &ColumnStore,
    panel: &CandidatePanel,
    want_cross: bool,
) -> PanelStats {
    debug_assert!(panel.partition_matches(store), "panel/store partitions must match");
    let ell = store.len();
    let k = panel.len();
    let mut atb = vec![0.0f64; ell * k];
    let mut cross = vec![0.0f64; if want_cross { k * (k + 1) / 2 } else { 0 }];
    for s in 0..store.n_shards() {
        let pa = gram_panel_partial(store, panel, s, 0..k);
        for (a, p) in atb.iter_mut().zip(pa.iter()) {
            *a += *p;
        }
        if want_cross {
            let pc = panel_cross_partial(panel, s, 0..k);
            for (a, p) in cross.iter_mut().zip(pc.iter()) {
                *a += *p;
            }
        }
    }
    PanelStats::new(ell, k, atb, cross)
}

/// Per-shard `|A_s·C + U_s|` written into a caller-owned row-major
/// `shard_rows × g` slice — the map side of transform_abs.  Writing
/// in place lets the sequential reduction accumulate directly into the
/// output matrix (no per-shard block allocation + stitch copy on the
/// test-time hot path).
///
/// Bench-gated branchless inner loop: the historical
/// `if a_ij == 0.0 { continue; }` skip was removed — see the verdict
/// comment in `backend/mod.rs` and the `transform_branch_gate` section of
/// `rust/benches/micro_runtime.rs` that measures it.
pub fn transform_block_into(
    store: &ColumnStore,
    s: usize,
    c: &Matrix,
    u: &Matrix,
    out: &mut [f64],
) {
    let range = store.shard_range(s);
    let g = u.cols();
    debug_assert_eq!(out.len(), range.len() * g);
    debug_assert_eq!(c.rows(), store.len());
    debug_assert_eq!(c.cols(), g);
    if g == 0 {
        return;
    }
    for (k, i) in range.enumerate() {
        out[k * g..(k + 1) * g].copy_from_slice(u.row(i));
    }
    for j in 0..store.len() {
        let crow = c.row(j);
        // WIHB/BPCG deliberately produce sparse coefficient vectors (the
        // SPAR payoff): a C row that is zero across every generator
        // contributes nothing — skip the whole O column.  This is the
        // column-granular form of the old per-generator `c == 0.0` skip;
        // the per-element a_ij branch stays removed (bench verdict in
        // backend/mod.rs).
        if crow.iter().all(|&v| v == 0.0) {
            continue;
        }
        let col = store.col_shard(j, s);
        for (k, &a_ij) in col.iter().enumerate() {
            let orow = &mut out[k * g..(k + 1) * g];
            for (o, ck) in orow.iter_mut().zip(crow.iter()) {
                *o += a_ij * ck;
            }
        }
    }
    for v in out.iter_mut() {
        *v = v.abs();
    }
}

/// Allocating wrapper over [`transform_block_into`] for the parallel
/// map path, where workers can't share `&mut` access to the output.
pub fn transform_block(store: &ColumnStore, s: usize, c: &Matrix, u: &Matrix) -> Vec<f64> {
    let rows = store.shard_range(s).len();
    let mut out = vec![0.0f64; rows * u.cols()];
    transform_block_into(store, s, c, u, &mut out);
    out
}

/// Sequential in-shard-order reduction of [`gram_partial`] — the exact
/// reduction both backends share (bit-reproducibility anchor).
pub fn gram_stats_seq(store: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64) {
    let mut atb = vec![0.0f64; store.len()];
    let mut btb = 0.0f64;
    for s in 0..store.n_shards() {
        let (pa, pb) = gram_partial(store, s, b_col);
        for (a, p) in atb.iter_mut().zip(pa.iter()) {
            *a += *p;
        }
        btb += pb;
    }
    (atb, btb)
}

/// Sequential shard-order application of [`transform_block_into`],
/// writing each shard's rows directly into the m×g result.
pub fn transform_abs_seq(store: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix {
    let m = u.rows();
    let g = u.cols();
    let mut out = Matrix::zeros(m, g);
    for s in 0..store.n_shards() {
        let r = store.shard_range(s);
        transform_block_into(store, s, c, u, &mut out.data_mut()[r.start * g..r.end * g]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{all_close, property};
    use crate::util::rng::Rng;

    fn random_cols(rng: &mut Rng, m: usize, ell: usize) -> Vec<Vec<f64>> {
        (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect()
    }

    #[test]
    fn balanced_partition_covers_all_rows() {
        for (m, k) in [(10usize, 3usize), (7, 7), (3, 7), (0, 4), (1, 1), (100, 8)] {
            let store = ColumnStore::new(m, k);
            assert_eq!(store.n_shards(), k.max(1));
            let mut total = 0;
            let mut prev_end = 0;
            for s in 0..store.n_shards() {
                let r = store.shard_range(s);
                assert_eq!(r.start, prev_end, "shards must be contiguous");
                prev_end = r.end;
                total += r.len();
            }
            assert_eq!(total, m);
            // balanced: sizes differ by at most 1
            let sizes: Vec<usize> =
                (0..store.n_shards()).map(|s| store.shard_range(s).len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced: {sizes:?}");
        }
    }

    #[test]
    fn push_col_and_materialize_roundtrip() {
        property(16, |rng| {
            let m = rng.below(40);
            let k = 1 + rng.below(6);
            let ell = 1 + rng.below(5);
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, k);
            if store.len() != ell || store.rows() != m {
                return Err("shape mismatch".into());
            }
            for (j, col) in cols.iter().enumerate() {
                if &store.col(j) != col {
                    return Err(format!("column {j} does not roundtrip"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn with_ones_is_the_constant_column() {
        let store = ColumnStore::with_ones(13, 4);
        assert_eq!(store.len(), 1);
        assert_eq!(store.col(0), vec![1.0; 13]);
    }

    #[test]
    fn fill_product_matches_direct() {
        property(16, |rng| {
            let m = 1 + rng.below(50);
            let k = 1 + rng.below(5);
            let n = 1 + rng.below(3);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let parent: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let store = ColumnStore::from_cols(std::slice::from_ref(&parent), k);
            let var = rng.below(n);
            let mut out = vec![0.0; m];
            store.fill_product(0, &x, var, &mut out);
            let expect: Vec<f64> = (0..m).map(|i| parent[i] * x.get(i, var)).collect();
            all_close(&out, &expect, 0.0, "fill_product")
        });
    }

    #[test]
    fn dots_and_means_match_dense() {
        property(16, |rng| {
            let m = 1 + rng.below(60);
            let k = 1 + rng.below(7);
            let cols = random_cols(rng, m, 3);
            let store = ColumnStore::from_cols(&cols, k);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            crate::util::proptest::close(
                store.dot_cols(0, 1),
                dot(&cols[0], &cols[1]),
                1e-10,
                "dot_cols",
            )?;
            crate::util::proptest::close(
                store.dot_col_slice(2, &v),
                dot(&cols[2], &v),
                1e-10,
                "dot_col_slice",
            )?;
            let mean = cols[0].iter().sum::<f64>() / m as f64;
            crate::util::proptest::close(store.col_mean(0), mean, 1e-10, "col_mean")
        });
    }

    #[test]
    fn gram_stats_seq_matches_definition_for_any_shard_count() {
        property(24, |rng| {
            let m = rng.below(80);
            let k = 1 + rng.below(9); // includes m < k
            let ell = 1 + rng.below(6);
            let cols = random_cols(rng, m, ell);
            let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let store = ColumnStore::from_cols(&cols, k);
            let (atb, btb) = gram_stats_seq(&store, &b);
            let expect: Vec<f64> = cols.iter().map(|c| dot(c, &b)).collect();
            all_close(&atb, &expect, 1e-10, "atb")?;
            crate::util::proptest::close(btb, dot(&b, &b), 1e-10, "btb")
        });
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn dot4_is_bitwise_equal_to_four_dots() {
        property(24, |rng| {
            // lengths straddling the 4-chunk boundary, incl. 0..3 tails
            let n = rng.below(70);
            let cols: Vec<Vec<f64>> =
                (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let d = dot4(&cols[0], &cols[1], &cols[2], &cols[3], &b);
            for (j, dj) in d.iter().enumerate() {
                if dj.to_bits() != dot(&cols[j], &b).to_bits() {
                    return Err(format!("dot4 lane {j} diverges at n={n}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn panel_from_recipes_matches_fill_product_bitwise() {
        property(16, |rng| {
            let m = 1 + rng.below(60);
            let shards = 1 + rng.below(5);
            let n = 1 + rng.below(3);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let cols = random_cols(rng, m, 2);
            let store = ColumnStore::from_cols(&cols, shards);
            let recipes: Vec<PanelRecipe> = (0..4)
                .map(|_| PanelRecipe { parent: rng.below(2), var: rng.below(n) })
                .collect();
            let panel = CandidatePanel::from_recipes(&store, &x, &recipes);
            if panel.len() != 4 || !panel.partition_matches(&store) {
                return Err("panel shape mismatch".into());
            }
            let mut buf = vec![0.0f64; m];
            for (c, r) in recipes.iter().enumerate() {
                store.fill_product(r.parent, &x, r.var, &mut buf);
                if bits(&panel.col(c)) != bits(&buf) {
                    return Err(format!("panel col {c} diverges from fill_product"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn push_col_from_panel_matches_push_col_bitwise() {
        let mut rng = Rng::new(23);
        let m = 37;
        let cols = random_cols(&mut rng, m, 2);
        for shards in [1usize, 3, 5] {
            let base = ColumnStore::from_cols(&cols, shards);
            let cand: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut panel = CandidatePanel::new_like(&base);
            panel.push_col(&cand);
            let mut via_panel = base.clone();
            via_panel.push_col_from_panel(&panel, 0);
            let mut via_buf = base.clone();
            via_buf.push_col(&cand);
            assert_eq!(via_panel.len(), via_buf.len());
            for s in 0..via_panel.n_shards() {
                assert_eq!(bits(via_panel.col_shard(2, s)), bits(via_buf.col_shard(2, s)));
            }
        }
    }

    #[test]
    fn gram_panel_seq_matches_per_candidate_gram_stats_bitwise() {
        property(20, |rng| {
            let m = rng.below(80);
            let shards = 1 + rng.below(6);
            let ell = 1 + rng.below(5);
            let k = 1 + rng.below(6);
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, shards);
            let cands = random_cols(rng, m, k);
            let mut panel = CandidatePanel::new_like(&store);
            for c in &cands {
                panel.push_col(c);
            }
            let ps = gram_panel_seq(&store, &panel, true);
            if ps.ell() != ell || ps.k() != k || !ps.has_cross() {
                return Err("panel stats shape mismatch".into());
            }
            for (c, cand) in cands.iter().enumerate() {
                let (atb, btb) = gram_stats_seq(&store, cand);
                if bits(&atb) != bits(ps.atb_col(c)) {
                    return Err(format!("atb col {c} diverges (shards {shards})"));
                }
                if btb.to_bits() != ps.btb(c).to_bits() {
                    return Err(format!("btb {c} diverges (shards {shards})"));
                }
            }
            // cross entry (i, c) must equal the legacy flow: push candidate
            // i into the store, then gram_stats of candidate c sees it as
            // its last atb entry
            for c in 0..k {
                for i in 0..c {
                    let mut grown = store.clone();
                    grown.push_col(&cands[i]);
                    let (atb, _) = gram_stats_seq(&grown, &cands[c]);
                    if atb[ell].to_bits() != ps.cross_at(i, c).to_bits() {
                        return Err(format!("cross ({i},{c}) diverges (shards {shards})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gram_panel_seq_without_cross_skips_triangle() {
        let mut rng = Rng::new(31);
        let cols = random_cols(&mut rng, 40, 3);
        let store = ColumnStore::from_cols(&cols, 2);
        let mut panel = CandidatePanel::new_like(&store);
        let cand: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        panel.push_col(&cand);
        let ps = gram_panel_seq(&store, &panel, false);
        assert!(!ps.has_cross());
        let (atb, _) = gram_stats_seq(&store, &cand);
        assert_eq!(bits(&atb), bits(ps.atb_col(0)));
    }

    #[test]
    fn panel_budget_clamps_to_memory_cap() {
        // small m: the configured budget wins
        assert_eq!(CandidatePanel::budget_cols(128, 1_000), 128);
        // huge m: the 256MB cap wins (256MB / 8 bytes / m rows)
        assert_eq!(CandidatePanel::budget_cols(512, 1 << 20), (256 << 20) / (8 << 20));
        // floors at 1 column even for absurd m
        assert_eq!(CandidatePanel::budget_cols(0, usize::MAX / 16), 1);
    }

    #[test]
    fn transform_abs_seq_matches_manual_for_any_shard_count() {
        property(24, |rng| {
            let m = rng.below(40);
            let k = 1 + rng.below(9);
            let ell = 1 + rng.below(4);
            let g = rng.below(4); // includes g = 0
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, k);
            let mut c = Matrix::zeros(ell, g);
            let mut u = Matrix::zeros(m, g);
            for i in 0..ell {
                for j in 0..g {
                    c.set(i, j, rng.normal());
                }
            }
            for i in 0..m {
                for j in 0..g {
                    u.set(i, j, rng.normal());
                }
            }
            let out = transform_abs_seq(&store, &c, &u);
            for i in 0..m {
                for j in 0..g {
                    let mut v = u.get(i, j);
                    for (kk, col) in cols.iter().enumerate() {
                        v += col[i] * c.get(kk, j);
                    }
                    if (out.get(i, j) - v.abs()).abs() > 1e-10 {
                        return Err(format!("({i},{j}): {} vs {}", out.get(i, j), v.abs()));
                    }
                }
            }
            Ok(())
        });
    }
}

//! Row-sharded column store — the single column currency of the data
//! plane.
//!
//! Every layer that touches evaluation columns (the OAVI driver, the
//! streaming backends, the (FT) transform, Pearson ordering, ABM/VCA)
//! goes through [`ColumnStore`].  Rows are partitioned once into
//! contiguous shards; each shard owns a column-major block
//! (`rows × ℓ`), so a column append is one `extend_from_slice` per shard
//! (amortized O(m), no per-column `Vec` allocation) and every kernel can
//! operate on plain `&[f64]` shard slices.
//!
//! The two hot kernels live here as **per-shard free functions**
//! ([`gram_partial`], [`transform_block`]) shared verbatim by
//! [`crate::backend::NativeBackend`] (sequential over shards) and
//! [`crate::backend::ShardedBackend`] (thread-pool map over shards with a
//! deterministic in-order reduction).  Because both backends run the same
//! per-shard code and reduce partials in the same shard order, their
//! results are **bit-for-bit identical** for any fixed shard count — the
//! reproducibility contract `rust/tests/runtime_parity.rs` pins down.

use std::ops::Range;

use crate::linalg::dense::Matrix;
use crate::linalg::dot;

/// One contiguous row-range of every column, stored column-major.
#[derive(Clone, Debug)]
struct Shard {
    /// rows owned by this shard (may be 0 when m < shard count).
    rows: usize,
    /// column-major block: column j occupies `data[j*rows .. (j+1)*rows]`.
    data: Vec<f64>,
}

/// Row-sharded, append-only evaluation-column storage.
#[derive(Clone, Debug)]
pub struct ColumnStore {
    m: usize,
    n_cols: usize,
    /// shard row offsets; `offsets[s]..offsets[s+1]` are shard s's rows.
    offsets: Vec<usize>,
    shards: Vec<Shard>,
}

impl ColumnStore {
    /// Empty store over `m` rows split into `n_shards` balanced contiguous
    /// shards (clamped to ≥ 1; shards may own 0 rows when `m < n_shards`).
    pub fn new(m: usize, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        let base = m / n_shards;
        let rem = m % n_shards;
        let mut offsets = Vec::with_capacity(n_shards + 1);
        offsets.push(0);
        let mut shards = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let rows = base + usize::from(s < rem);
            offsets.push(offsets[s] + rows);
            shards.push(Shard { rows, data: Vec::new() });
        }
        ColumnStore { m, n_cols: 0, offsets, shards }
    }

    /// Store holding the single constant-1 column (OAVI Line 2: O = {𝟙}).
    pub fn with_ones(m: usize, n_shards: usize) -> Self {
        let mut store = ColumnStore::new(m, n_shards);
        for shard in &mut store.shards {
            shard.data.resize(shard.rows, 1.0);
        }
        store.n_cols = 1;
        store
    }

    /// Build from explicit full-length columns (tests, benches, rebuilds).
    pub fn from_cols(cols: &[Vec<f64>], n_shards: usize) -> Self {
        let m = cols.first().map(|c| c.len()).unwrap_or(0);
        let mut store = ColumnStore::new(m, n_shards);
        for col in cols {
            store.push_col(col);
        }
        store
    }

    /// Build from the columns of a row-major matrix (feature columns for
    /// the Pearson ordering, evaluation columns in tests).
    pub fn from_matrix(x: &Matrix, n_shards: usize) -> Self {
        let m = x.rows();
        let mut store = ColumnStore::new(m, n_shards);
        let mut buf = vec![0.0f64; m];
        for j in 0..x.cols() {
            for (i, v) in buf.iter_mut().enumerate() {
                *v = x.get(i, j);
            }
            store.push_col(&buf);
        }
        store
    }

    /// Number of rows m.
    #[inline]
    pub fn rows(&self) -> usize {
        self.m
    }

    /// Number of columns ℓ.
    #[inline]
    pub fn len(&self) -> usize {
        self.n_cols
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n_cols == 0
    }

    /// Number of row shards (fixed at construction).
    #[inline]
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Global row range owned by shard `s`.
    #[inline]
    pub fn shard_range(&self, s: usize) -> Range<usize> {
        self.offsets[s]..self.offsets[s + 1]
    }

    /// Column `j`'s contiguous slice within shard `s`.
    #[inline]
    pub fn col_shard(&self, j: usize, s: usize) -> &[f64] {
        let shard = &self.shards[s];
        &shard.data[j * shard.rows..(j + 1) * shard.rows]
    }

    /// Append a full-length column by copying its row-ranges into the
    /// shard blocks.  The caller's buffer is untouched and reusable — this
    /// is the amortized-append contract the OAVI driver relies on (no
    /// per-accepted-term `Vec` allocation).
    pub fn push_col(&mut self, col: &[f64]) {
        debug_assert_eq!(col.len(), self.m, "push_col: length mismatch");
        for (s, shard) in self.shards.iter_mut().enumerate() {
            let range = self.offsets[s]..self.offsets[s + 1];
            shard.data.extend_from_slice(&col[range]);
        }
        self.n_cols += 1;
    }

    /// Materialize column `j` as one contiguous vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.m);
        for s in 0..self.n_shards() {
            out.extend_from_slice(self.col_shard(j, s));
        }
        out
    }

    /// `out[i] = col_parent[i] * x[i, var]` — the border-term candidate
    /// evaluation (one multiply per sample, Theorem 4.2), written into a
    /// caller-owned reusable buffer.
    pub fn fill_product(&self, parent: usize, x: &Matrix, var: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.m, "fill_product: length mismatch");
        for s in 0..self.n_shards() {
            let p = self.col_shard(parent, s);
            for (k, i) in self.shard_range(s).enumerate() {
                out[i] = p[k] * x.get(i, var);
            }
        }
    }

    /// ⟨col_i, col_j⟩ accumulated shard-by-shard (deterministic order).
    pub fn dot_cols(&self, i: usize, j: usize) -> f64 {
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            acc += dot(self.col_shard(i, s), self.col_shard(j, s));
        }
        acc
    }

    /// ⟨col_j, v⟩ for a full-length vector `v`, shard-by-shard.
    pub fn dot_col_slice(&self, j: usize, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.m);
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            acc += dot(self.col_shard(j, s), &v[self.shard_range(s)]);
        }
        acc
    }

    /// Mean of column `j` (Pearson ordering helper).
    pub fn col_mean(&self, j: usize) -> f64 {
        if self.m == 0 {
            return 0.0;
        }
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            acc += self.col_shard(j, s).iter().sum::<f64>();
        }
        acc / self.m as f64
    }
}

/// Per-shard `(Aᵀb, bᵀb)` partial — the map side of gram_stats.
///
/// Perf pass #2 (EXPERIMENTS.md §Perf) preserved per shard: past the
/// last-level-cache scale, four columns share each pass over the
/// (cache-missing) b slice so b traffic drops 4×; for cache-resident
/// shards the simple vectorized dot is faster.  Sharding itself pushes
/// most shards under the threshold — exactly the cache win row-sharding
/// is after.
pub fn gram_partial(store: &ColumnStore, s: usize, b_full: &[f64]) -> (Vec<f64>, f64) {
    let bs = &b_full[store.shard_range(s)];
    let ell = store.len();
    let rows = bs.len();
    let mut atb = vec![0.0f64; ell];
    const BLOCK_THRESHOLD_BYTES: usize = 4 << 20; // ~LLC slice
    if rows * std::mem::size_of::<f64>() < BLOCK_THRESHOLD_BYTES {
        for (j, a) in atb.iter_mut().enumerate() {
            *a = dot(store.col_shard(j, s), bs);
        }
        return (atb, dot(bs, bs));
    }
    let mut j = 0;
    while j + 4 <= ell {
        let (c0, c1, c2, c3) = (
            store.col_shard(j, s),
            store.col_shard(j + 1, s),
            store.col_shard(j + 2, s),
            store.col_shard(j + 3, s),
        );
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for (i, &bi) in bs.iter().enumerate() {
            s0 += c0[i] * bi;
            s1 += c1[i] * bi;
            s2 += c2[i] * bi;
            s3 += c3[i] * bi;
        }
        atb[j] = s0;
        atb[j + 1] = s1;
        atb[j + 2] = s2;
        atb[j + 3] = s3;
        j += 4;
    }
    while j < ell {
        atb[j] = dot(store.col_shard(j, s), bs);
        j += 1;
    }
    (atb, dot(bs, bs))
}

/// Per-shard `|A_s·C + U_s|` written into a caller-owned row-major
/// `shard_rows × g` slice — the map side of transform_abs.  Writing
/// in place lets the sequential reduction accumulate directly into the
/// output matrix (no per-shard block allocation + stitch copy on the
/// test-time hot path).
///
/// Bench-gated branchless inner loop: the historical
/// `if a_ij == 0.0 { continue; }` skip was removed — see the verdict
/// comment in `backend/mod.rs` and the `transform_branch_gate` section of
/// `rust/benches/micro_runtime.rs` that measures it.
pub fn transform_block_into(
    store: &ColumnStore,
    s: usize,
    c: &Matrix,
    u: &Matrix,
    out: &mut [f64],
) {
    let range = store.shard_range(s);
    let g = u.cols();
    debug_assert_eq!(out.len(), range.len() * g);
    debug_assert_eq!(c.rows(), store.len());
    debug_assert_eq!(c.cols(), g);
    if g == 0 {
        return;
    }
    for (k, i) in range.enumerate() {
        out[k * g..(k + 1) * g].copy_from_slice(u.row(i));
    }
    for j in 0..store.len() {
        let crow = c.row(j);
        // WIHB/BPCG deliberately produce sparse coefficient vectors (the
        // SPAR payoff): a C row that is zero across every generator
        // contributes nothing — skip the whole O column.  This is the
        // column-granular form of the old per-generator `c == 0.0` skip;
        // the per-element a_ij branch stays removed (bench verdict in
        // backend/mod.rs).
        if crow.iter().all(|&v| v == 0.0) {
            continue;
        }
        let col = store.col_shard(j, s);
        for (k, &a_ij) in col.iter().enumerate() {
            let orow = &mut out[k * g..(k + 1) * g];
            for (o, ck) in orow.iter_mut().zip(crow.iter()) {
                *o += a_ij * ck;
            }
        }
    }
    for v in out.iter_mut() {
        *v = v.abs();
    }
}

/// Allocating wrapper over [`transform_block_into`] for the parallel
/// map path, where workers can't share `&mut` access to the output.
pub fn transform_block(store: &ColumnStore, s: usize, c: &Matrix, u: &Matrix) -> Vec<f64> {
    let rows = store.shard_range(s).len();
    let mut out = vec![0.0f64; rows * u.cols()];
    transform_block_into(store, s, c, u, &mut out);
    out
}

/// Sequential in-shard-order reduction of [`gram_partial`] — the exact
/// reduction both backends share (bit-reproducibility anchor).
pub fn gram_stats_seq(store: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64) {
    let mut atb = vec![0.0f64; store.len()];
    let mut btb = 0.0f64;
    for s in 0..store.n_shards() {
        let (pa, pb) = gram_partial(store, s, b_col);
        for (a, p) in atb.iter_mut().zip(pa.iter()) {
            *a += *p;
        }
        btb += pb;
    }
    (atb, btb)
}

/// Sequential shard-order application of [`transform_block_into`],
/// writing each shard's rows directly into the m×g result.
pub fn transform_abs_seq(store: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix {
    let m = u.rows();
    let g = u.cols();
    let mut out = Matrix::zeros(m, g);
    for s in 0..store.n_shards() {
        let r = store.shard_range(s);
        transform_block_into(store, s, c, u, &mut out.data_mut()[r.start * g..r.end * g]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{all_close, property};
    use crate::util::rng::Rng;

    fn random_cols(rng: &mut Rng, m: usize, ell: usize) -> Vec<Vec<f64>> {
        (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect()
    }

    #[test]
    fn balanced_partition_covers_all_rows() {
        for (m, k) in [(10usize, 3usize), (7, 7), (3, 7), (0, 4), (1, 1), (100, 8)] {
            let store = ColumnStore::new(m, k);
            assert_eq!(store.n_shards(), k.max(1));
            let mut total = 0;
            let mut prev_end = 0;
            for s in 0..store.n_shards() {
                let r = store.shard_range(s);
                assert_eq!(r.start, prev_end, "shards must be contiguous");
                prev_end = r.end;
                total += r.len();
            }
            assert_eq!(total, m);
            // balanced: sizes differ by at most 1
            let sizes: Vec<usize> =
                (0..store.n_shards()).map(|s| store.shard_range(s).len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced: {sizes:?}");
        }
    }

    #[test]
    fn push_col_and_materialize_roundtrip() {
        property(16, |rng| {
            let m = rng.below(40);
            let k = 1 + rng.below(6);
            let ell = 1 + rng.below(5);
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, k);
            if store.len() != ell || store.rows() != m {
                return Err("shape mismatch".into());
            }
            for (j, col) in cols.iter().enumerate() {
                if &store.col(j) != col {
                    return Err(format!("column {j} does not roundtrip"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn with_ones_is_the_constant_column() {
        let store = ColumnStore::with_ones(13, 4);
        assert_eq!(store.len(), 1);
        assert_eq!(store.col(0), vec![1.0; 13]);
    }

    #[test]
    fn fill_product_matches_direct() {
        property(16, |rng| {
            let m = 1 + rng.below(50);
            let k = 1 + rng.below(5);
            let n = 1 + rng.below(3);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let parent: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let store = ColumnStore::from_cols(std::slice::from_ref(&parent), k);
            let var = rng.below(n);
            let mut out = vec![0.0; m];
            store.fill_product(0, &x, var, &mut out);
            let expect: Vec<f64> = (0..m).map(|i| parent[i] * x.get(i, var)).collect();
            all_close(&out, &expect, 0.0, "fill_product")
        });
    }

    #[test]
    fn dots_and_means_match_dense() {
        property(16, |rng| {
            let m = 1 + rng.below(60);
            let k = 1 + rng.below(7);
            let cols = random_cols(rng, m, 3);
            let store = ColumnStore::from_cols(&cols, k);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            crate::util::proptest::close(
                store.dot_cols(0, 1),
                dot(&cols[0], &cols[1]),
                1e-10,
                "dot_cols",
            )?;
            crate::util::proptest::close(
                store.dot_col_slice(2, &v),
                dot(&cols[2], &v),
                1e-10,
                "dot_col_slice",
            )?;
            let mean = cols[0].iter().sum::<f64>() / m as f64;
            crate::util::proptest::close(store.col_mean(0), mean, 1e-10, "col_mean")
        });
    }

    #[test]
    fn gram_stats_seq_matches_definition_for_any_shard_count() {
        property(24, |rng| {
            let m = rng.below(80);
            let k = 1 + rng.below(9); // includes m < k
            let ell = 1 + rng.below(6);
            let cols = random_cols(rng, m, ell);
            let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let store = ColumnStore::from_cols(&cols, k);
            let (atb, btb) = gram_stats_seq(&store, &b);
            let expect: Vec<f64> = cols.iter().map(|c| dot(c, &b)).collect();
            all_close(&atb, &expect, 1e-10, "atb")?;
            crate::util::proptest::close(btb, dot(&b, &b), 1e-10, "btb")
        });
    }

    #[test]
    fn transform_abs_seq_matches_manual_for_any_shard_count() {
        property(24, |rng| {
            let m = rng.below(40);
            let k = 1 + rng.below(9);
            let ell = 1 + rng.below(4);
            let g = rng.below(4); // includes g = 0
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, k);
            let mut c = Matrix::zeros(ell, g);
            let mut u = Matrix::zeros(m, g);
            for i in 0..ell {
                for j in 0..g {
                    c.set(i, j, rng.normal());
                }
            }
            for i in 0..m {
                for j in 0..g {
                    u.set(i, j, rng.normal());
                }
            }
            let out = transform_abs_seq(&store, &c, &u);
            for i in 0..m {
                for j in 0..g {
                    let mut v = u.get(i, j);
                    for (kk, col) in cols.iter().enumerate() {
                        v += col[i] * c.get(kk, j);
                    }
                    if (out.get(i, j) - v.abs()).abs() > 1e-10 {
                        return Err(format!("({i},{j}): {} vs {}", out.get(i, j), v.abs()));
                    }
                }
            }
            Ok(())
        });
    }
}

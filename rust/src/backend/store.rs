//! Row-sharded column store + candidate panels — the column currency of
//! the data plane.
//!
//! Every layer that touches evaluation columns (the OAVI driver, the
//! streaming backends, the (FT) transform, Pearson ordering, ABM/VCA)
//! goes through [`ColumnStore`].  Rows are partitioned once into
//! contiguous shards; each shard owns a column-major block
//! (`rows × ℓ`), so a column append is one `extend_from_slice` per shard
//! (amortized O(m), no per-column `Vec` allocation) and every kernel can
//! operate on plain `&[f64]` shard slices.
//!
//! # Kernel inventory (per-shard free functions)
//!
//! * [`gram_panel_partial`] — the **primary training kernel**: the ℓ×k
//!   store-vs-panel block for one shard.  One [`CandidatePanel`] holds
//!   every degree-d border candidate (filled from its parent columns in
//!   one pass, [`CandidatePanel::from_recipes`]); per shard the kernel
//!   runtime-selects between a cache-resident per-candidate pass and the
//!   **row-tiled micro-kernel** ([`gram_panel_partial_tiled`]): L1/L2-
//!   sized row blocks with carried `[f64; 4]` dot lanes per (store col,
//!   candidate) entry, streamed through the wide-lane `dotN` bricks of
//!   [`crate::linalg::simd`] (8- or 4-column passes over each candidate
//!   tile).  The switch point is the once-per-process calibrated
//!   [`block_threshold_bytes`].
//! * [`panel_cross_partial`] / [`panel_diag_partial`] — the k×k panel
//!   cross-Gram upper triangle (eager mode) or just its diagonal (lazy
//!   mode).  Under [`CrossMode::Lazy`] the off-diagonal rows are **not**
//!   computed in the panel pass at all: [`PanelStats::ensure_cross_row`]
//!   materializes row i on demand when candidate i is accepted into O,
//!   so ψ-regimes where most candidates vanish skip the O(k²) triangle
//!   they never read.
//! * [`gram_partial`] — the legacy per-candidate `(Aᵀb, bᵀb)` map side,
//!   still used by serving-time single-column queries and kept as the
//!   bitwise reference for the panel path.
//! * [`transform_block`] — the (FT) `|A·C + U|` map side (test time).
//!
//! # Exact vs fast: the numerics contract
//!
//! All **exact** Gram kernels share **one per-entry dot discipline**:
//! every output entry is bitwise equal to [`crate::linalg::dot`] of the
//! two column slices involved.  The blocked/tiled variants only change
//! *which passes are shared* — each entry keeps `dot`'s four-lane
//! schedule (lanes carried across 4-multiple row tiles, combined
//! `(s0+s1)+(s2+s3)`, sequential `n%4` tail; see `linalg::simd`) — so
//! entry bits are independent of kernel choice, lane width, blocking
//! factor, tile boundary, or batch boundary.  Laziness is equally
//! transparent: a cross row materialized on demand runs the same
//! per-shard dots in the same shard order as the eager triangle.  This
//! is what lets the panel path reproduce the legacy per-candidate path
//! bit for bit, and what makes the `BLOCK_THRESHOLD`/`dotN`/tile-size
//! heuristics pure wall-clock knobs.
//!
//! The `*_fast` kernels ([`gram_panel_partial_fast`],
//! [`panel_diag_partial_fast`], reduced by [`gram_panel_fast_seq`])
//! implement the **opt-in** `NumericsMode::Fast` path: f32 accumulation
//! within fixed row tiles, f64 carry across tiles
//! ([`crate::linalg::simd::dot_fast`]).  They carry *no* bitwise
//! contract — the OAVI driver measures their max |Δ| against the exact
//! f64 reference on a sampled Gram sub-block and fails the fit if the
//! configured error budget is exceeded.  Off-diagonal cross rows stay
//! exact even in fast mode (they feed the Theorem 4.9 inverse append,
//! where rounding would accumulate into the maintained N — same policy
//! as the f32 PJRT path in `runtime/backend.rs`).
//!
//! The kernels are shared verbatim by [`crate::backend::NativeBackend`]
//! (sequential over shards) and [`crate::backend::ShardedBackend`]
//! (thread-pool map over shard×panel tiles with a deterministic in-order
//! reduction).  Because both backends run the same per-shard code and
//! reduce partials in the same shard order, their results are
//! **bit-for-bit identical** for any fixed shard count — the
//! reproducibility contract `rust/tests/runtime_parity.rs` pins down.
//!
//! # Backing layer (where the bytes live)
//!
//! Since the out-of-core PR, a store's shard blocks live behind
//! [`crate::backend::backing::ShardBacking`]: in-memory `Vec<f64>`
//! blocks (the default — bitwise-unchanged legacy layout) or on-disk
//! segments with an LRU resident pool
//! ([`StoreMode::Spill`]).  Kernels read shard
//! blocks through a per-(shard, pass) [`ShardLease`] — acquire it once
//! at the top of the shard loop, call `lease.col(j)` inside, and drop
//! it before mutating the store (full lifetime rules in
//! `backend/backing.rs`).  [`ColumnStore::col_shard`] remains the
//! direct-borrow accessor for memory-backed stores (all historical
//! call sites and tests) and panics on spilled stores.  The exact path
//! is **bitwise identical** across backings: leases hand the kernels
//! the same f64 values, and the per-entry dot discipline above does the
//! rest — `rust/tests/storage_parity.rs` pins this at fit level.
//! [`CandidatePanel`]s stay memory-only: they are transient (one degree
//! chunk, capped at ~256 MB by [`CandidatePanel::budget_cols`]), so
//! spilling them would buy nothing.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use crate::backend::backing::{BackingCounters, ShardBacking, ShardLease, StoreMode};
use crate::error::Result;
use crate::linalg::dense::Matrix;
use crate::linalg::dot;
use crate::linalg::simd;

/// How much of the panel cross-Gram a [`gram_panel_seq`]-family call
/// should produce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrossMode {
    /// No cross data at all (VCA's projection batches read only the
    /// store-vs-panel block).
    Skip,
    /// The full k×k upper triangle, computed in the panel pass.
    Eager,
    /// Only the diagonal (`bᵀb`, read for every candidate) in the panel
    /// pass; off-diagonal rows materialize on demand via
    /// [`PanelStats::ensure_cross_row`] when a candidate is accepted.
    /// Bitwise identical to [`CrossMode::Eager`] for every entry that is
    /// actually read (per-entry dot discipline + shard-order sums).
    Lazy,
}

/// Numerics policy for the panel kernels.
///
/// `Exact` is the default everywhere and carries the bitwise per-entry
/// dot contract.  `Fast` is **opt-in only** (config/CLI): f32 tile
/// accumulation with f64 carry, guarded at fit time by a measured error
/// budget against the f64 reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum NumericsMode {
    /// Bitwise-reproducible f64 kernels (the default).
    #[default]
    Exact,
    /// Mixed-precision kernels ([`crate::linalg::simd::dot_fast`]) for
    /// the store-vs-panel block and the cross diagonal.
    Fast,
}

impl NumericsMode {
    /// Stable lowercase name (CLI parsing, JSON reports).
    pub fn as_str(self) -> &'static str {
        match self {
            NumericsMode::Exact => "exact",
            NumericsMode::Fast => "fast",
        }
    }
}

/// One contiguous row-range of every column, stored column-major
/// (in-memory panel shards; store shards live in
/// [`crate::backend::backing::MemShard`] / segment files).
#[derive(Clone, Debug)]
struct Shard {
    /// rows owned by this shard (may be 0 when m < shard count).
    rows: usize,
    /// column-major block: column j occupies `data[j*rows .. (j+1)*rows]`.
    data: Vec<f64>,
}

/// Row-sharded, append-only evaluation-column storage over a pluggable
/// [`ShardBacking`] (in-memory by default; spillable segments via
/// [`StoreMode::Spill`]).
///
/// Cloning deep-copies a memory-backed store and *shares* a spilled
/// store's segments (see `backend/backing.rs`).
#[derive(Clone, Debug)]
pub struct ColumnStore {
    m: usize,
    n_cols: usize,
    /// shard row offsets; `offsets[s]..offsets[s+1]` are shard s's rows.
    offsets: Vec<usize>,
    backing: ShardBacking,
}

/// Balanced contiguous partition of `m` rows into `n_shards` shards
/// (clamped to ≥ 1): the offsets vector every store/panel shares.
fn balanced_offsets(m: usize, n_shards: usize) -> Vec<usize> {
    let n_shards = n_shards.max(1);
    let base = m / n_shards;
    let rem = m % n_shards;
    let mut offsets = Vec::with_capacity(n_shards + 1);
    offsets.push(0);
    for s in 0..n_shards {
        let rows = base + usize::from(s < rem);
        offsets.push(offsets[s] + rows);
    }
    offsets
}

impl ColumnStore {
    /// Empty memory-backed store over `m` rows split into `n_shards`
    /// balanced contiguous shards (clamped to ≥ 1; shards may own 0 rows
    /// when `m < n_shards`).
    pub fn new(m: usize, n_shards: usize) -> Self {
        Self::new_with_backing(m, n_shards, StoreMode::Memory)
            .expect("memory backing is infallible")
    }

    /// Empty store with an explicit backing mode.  Spill mode creates an
    /// ephemeral per-process segment directory (removed when the last
    /// clone drops).
    pub fn new_with_backing(m: usize, n_shards: usize, mode: StoreMode) -> Result<Self> {
        let offsets = balanced_offsets(m, n_shards);
        let shard_rows: Vec<usize> =
            (0..offsets.len() - 1).map(|s| offsets[s + 1] - offsets[s]).collect();
        let backing = ShardBacking::build(&shard_rows, mode)?;
        Ok(ColumnStore { m, n_cols: 0, offsets, backing })
    }

    /// Store holding the single constant-1 column (OAVI Line 2: O = {𝟙}).
    pub fn with_ones(m: usize, n_shards: usize) -> Self {
        Self::with_ones_backed(m, n_shards, StoreMode::Memory)
            .expect("memory backing is infallible")
    }

    /// [`ColumnStore::with_ones`] with an explicit backing mode — the
    /// OAVI driver's construction point for spillable working stores.
    pub fn with_ones_backed(m: usize, n_shards: usize, mode: StoreMode) -> Result<Self> {
        let mut store = Self::new_with_backing(m, n_shards, mode)?;
        match &mut store.backing {
            ShardBacking::Memory(shards) => {
                for shard in shards.iter_mut() {
                    shard.data.resize(shard.rows, 1.0);
                }
            }
            ShardBacking::Spill(fb) => {
                let mut ones = Vec::new();
                for s in 0..store.offsets.len() - 1 {
                    let rows = store.offsets[s + 1] - store.offsets[s];
                    ones.clear();
                    ones.resize(rows, 1.0);
                    fb.append_col(s, &ones, 0);
                }
            }
        }
        store.n_cols = 1;
        Ok(store)
    }

    /// Assemble a store around an existing backing (the manifest-open
    /// path in `crate::storage`; `offsets` must match the backing's
    /// shard partition).
    pub(crate) fn from_backing_parts(
        m: usize,
        n_cols: usize,
        offsets: Vec<usize>,
        backing: ShardBacking,
    ) -> Self {
        ColumnStore { m, n_cols, offsets, backing }
    }

    /// Build from explicit full-length columns (tests, benches, rebuilds).
    pub fn from_cols(cols: &[Vec<f64>], n_shards: usize) -> Self {
        let m = cols.first().map(|c| c.len()).unwrap_or(0);
        let mut store = ColumnStore::new(m, n_shards);
        for col in cols {
            store.push_col(col);
        }
        store
    }

    /// Build from the columns of a row-major matrix (feature columns for
    /// the Pearson ordering, evaluation columns in tests).
    pub fn from_matrix(x: &Matrix, n_shards: usize) -> Self {
        let m = x.rows();
        let mut store = ColumnStore::new(m, n_shards);
        let mut buf = vec![0.0f64; m];
        for j in 0..x.cols() {
            for (i, v) in buf.iter_mut().enumerate() {
                *v = x.get(i, j);
            }
            store.push_col(&buf);
        }
        store
    }

    /// Number of rows m.
    #[inline]
    pub fn rows(&self) -> usize {
        self.m
    }

    /// Number of columns ℓ.
    #[inline]
    pub fn len(&self) -> usize {
        self.n_cols
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n_cols == 0
    }

    /// Number of row shards (fixed at construction).
    #[inline]
    pub fn n_shards(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Global row range owned by shard `s`.
    #[inline]
    pub fn shard_range(&self, s: usize) -> Range<usize> {
        self.offsets[s]..self.offsets[s + 1]
    }

    /// Backing mode name (`mem` / `mmap`) for reports.
    pub fn mode_str(&self) -> &'static str {
        self.backing.mode_str()
    }

    /// Is this store spilled to disk?
    pub fn is_spilled(&self) -> bool {
        matches!(self.backing, ShardBacking::Spill(_))
    }

    /// Spill-backing activity counters (`None` on memory stores).
    pub fn backing_counters(&self) -> Option<BackingCounters> {
        self.backing.counters()
    }

    /// Lease shard `s`'s column block for one kernel pass — the only
    /// read surface that works on every backing.  Memory leases are free
    /// borrows; spill leases pin the resident block (lifetime rules in
    /// `backend/backing.rs`).  Acquire once per shard loop, not per
    /// column.
    #[inline]
    pub fn lease(&self, s: usize) -> ShardLease<'_> {
        match &self.backing {
            ShardBacking::Memory(shards) => {
                let sh = &shards[s];
                ShardLease::Mem { data: &sh.data, rows: sh.rows }
            }
            ShardBacking::Spill(fb) => fb.lease(s, self.n_cols),
        }
    }

    /// Column `j`'s contiguous slice within shard `s` — direct borrow,
    /// **memory backing only** (the historical accessor; every borrowed
    /// slice would dangle under eviction).  Spilled stores panic: go
    /// through [`ColumnStore::lease`].
    #[inline]
    pub fn col_shard(&self, j: usize, s: usize) -> &[f64] {
        match &self.backing {
            ShardBacking::Memory(shards) => {
                let shard = &shards[s];
                &shard.data[j * shard.rows..(j + 1) * shard.rows]
            }
            ShardBacking::Spill(_) => {
                panic!("col_shard on a spilled store: acquire a ShardLease via lease(s)")
            }
        }
    }

    /// Append a full-length column by copying its row-ranges into the
    /// shard blocks.  The caller's buffer is untouched and reusable — this
    /// is the amortized-append contract the OAVI driver relies on (no
    /// per-accepted-term `Vec` allocation).  On spilled stores the slices
    /// go straight to the segment files (the resident block is
    /// invalidated; the next lease reloads at the new width).
    pub fn push_col(&mut self, col: &[f64]) {
        debug_assert_eq!(col.len(), self.m, "push_col: length mismatch");
        match &mut self.backing {
            ShardBacking::Memory(shards) => {
                for (s, shard) in shards.iter_mut().enumerate() {
                    let range = self.offsets[s]..self.offsets[s + 1];
                    shard.data.extend_from_slice(&col[range]);
                }
            }
            ShardBacking::Spill(fb) => {
                for s in 0..self.offsets.len() - 1 {
                    let range = self.offsets[s]..self.offsets[s + 1];
                    fb.append_col(s, &col[range], self.n_cols);
                }
            }
        }
        self.n_cols += 1;
    }

    /// Materialize column `j` as one contiguous vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.m);
        for s in 0..self.n_shards() {
            out.extend_from_slice(self.lease(s).col(j));
        }
        out
    }

    /// `out[i] = col_parent[i] * x[i, var]` — the border-term candidate
    /// evaluation (one multiply per sample, Theorem 4.2), written into a
    /// caller-owned reusable buffer.
    pub fn fill_product(&self, parent: usize, x: &Matrix, var: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.m, "fill_product: length mismatch");
        for s in 0..self.n_shards() {
            let lease = self.lease(s);
            let p = lease.col(parent);
            for (k, i) in self.shard_range(s).enumerate() {
                out[i] = p[k] * x.get(i, var);
            }
        }
    }

    /// ⟨col_i, col_j⟩ accumulated shard-by-shard (deterministic order).
    pub fn dot_cols(&self, i: usize, j: usize) -> f64 {
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            let lease = self.lease(s);
            acc += dot(lease.col(i), lease.col(j));
        }
        acc
    }

    /// ⟨col_j, v⟩ for a full-length vector `v`, shard-by-shard.
    pub fn dot_col_slice(&self, j: usize, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.m);
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            acc += dot(self.lease(s).col(j), &v[self.shard_range(s)]);
        }
        acc
    }

    /// Append candidate column `c` of a [`CandidatePanel`] built over
    /// this store's row partition — shard-to-shard copies, no full-length
    /// staging buffer.  Values (hence result bits) are identical to
    /// materializing the panel column and calling [`ColumnStore::push_col`].
    pub fn push_col_from_panel(&mut self, panel: &CandidatePanel, c: usize) {
        debug_assert_eq!(panel.m, self.m, "push_col_from_panel: row mismatch");
        debug_assert_eq!(
            panel.offsets, self.offsets,
            "push_col_from_panel: panel/store partitions must match"
        );
        match &mut self.backing {
            ShardBacking::Memory(shards) => {
                for (s, shard) in shards.iter_mut().enumerate() {
                    shard.data.extend_from_slice(panel.col_shard(c, s));
                }
            }
            ShardBacking::Spill(fb) => {
                for s in 0..self.offsets.len() - 1 {
                    fb.append_col(s, panel.col_shard(c, s), self.n_cols);
                }
            }
        }
        self.n_cols += 1;
    }

    /// Mean of column `j` (Pearson ordering helper).
    pub fn col_mean(&self, j: usize) -> f64 {
        if self.m == 0 {
            return 0.0;
        }
        let mut acc = 0.0;
        for s in 0..self.n_shards() {
            acc += self.lease(s).col(j).iter().sum::<f64>();
        }
        acc / self.m as f64
    }
}

/// Recipe for one border-term candidate column:
/// `panel[:, c] = store[:, parent] ⊙ x[:, var]` (Theorem 4.2 — one
/// multiply per sample from the parent's evaluation column).
#[derive(Clone, Copy, Debug)]
pub struct PanelRecipe {
    /// Store column index of the parent term `u / x_var`.
    pub parent: usize,
    /// Variable index such that `u = parent · x_var`.
    pub var: usize,
}

/// A degree-batch of candidate columns sharing a [`ColumnStore`]'s row
/// partition: the m×k right-hand side of the panel kernels.
///
/// Shards mirror the parent store's offsets exactly, so every panel
/// kernel pairs `store.col_shard(j, s)` with `panel.col_shard(c, s)`
/// slices of equal length — the precondition [`gram_panel_partial`]
/// asserts.  Built either from border recipes (OAVI/ABM: one pass over
/// the parent columns evaluates the whole degree-d border) or by pushing
/// full-length columns (VCA's candidate/projection batches).
#[derive(Clone, Debug)]
pub struct CandidatePanel {
    m: usize,
    k: usize,
    offsets: Vec<usize>,
    shards: Vec<Shard>,
}

impl CandidatePanel {
    /// Empty panel over `store`'s exact row partition.  Panels are
    /// always memory-backed (transient, budget-capped) regardless of the
    /// store's backing.
    pub fn new_like(store: &ColumnStore) -> Self {
        let offsets = store.offsets.clone();
        let shards = (0..offsets.len() - 1)
            .map(|s| Shard { rows: offsets[s + 1] - offsets[s], data: Vec::new() })
            .collect();
        CandidatePanel { m: store.m, k: 0, offsets, shards }
    }

    /// Evaluate every recipe into a fresh panel in **one pass per
    /// shard**: each shard block stays hot while all k candidates read
    /// their parent columns from it.  The per-sample arithmetic
    /// (`parent[i] · x[i, var]`) is exactly
    /// [`ColumnStore::fill_product`]'s, so panel columns are bitwise
    /// identical to the legacy per-candidate evaluation buffers.
    pub fn from_recipes(store: &ColumnStore, x: &Matrix, recipes: &[PanelRecipe]) -> Self {
        let mut panel = Self::new_like(store);
        let k = recipes.len();
        for (s, shard) in panel.shards.iter_mut().enumerate() {
            shard.data.resize(shard.rows * k, 0.0);
            let start = panel.offsets[s];
            let lease = store.lease(s);
            for (c, r) in recipes.iter().enumerate() {
                let p = lease.col(r.parent);
                let dst = &mut shard.data[c * shard.rows..(c + 1) * shard.rows];
                for (i, d) in dst.iter_mut().enumerate() {
                    *d = p[i] * x.get(start + i, r.var);
                }
            }
        }
        panel.k = k;
        panel
    }

    /// Append one full-length candidate column (VCA batches; benches).
    pub fn push_col(&mut self, col: &[f64]) {
        debug_assert_eq!(col.len(), self.m, "panel push_col: length mismatch");
        for (s, shard) in self.shards.iter_mut().enumerate() {
            let range = self.offsets[s]..self.offsets[s + 1];
            shard.data.extend_from_slice(&col[range]);
        }
        self.k += 1;
    }

    /// Number of candidate columns k.
    #[inline]
    pub fn len(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.k == 0
    }

    /// Number of rows m.
    #[inline]
    pub fn rows(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Global row range owned by shard `s` (mirrors the parent store).
    #[inline]
    pub fn shard_range(&self, s: usize) -> Range<usize> {
        self.offsets[s]..self.offsets[s + 1]
    }

    /// Candidate `c`'s contiguous slice within shard `s`.
    #[inline]
    pub fn col_shard(&self, c: usize, s: usize) -> &[f64] {
        let shard = &self.shards[s];
        &shard.data[c * shard.rows..(c + 1) * shard.rows]
    }

    /// Materialize candidate `c` as one contiguous vector (Schur-guard
    /// rebuilds, PJRT packing).
    pub fn col(&self, c: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.m);
        for s in 0..self.n_shards() {
            out.extend_from_slice(self.col_shard(c, s));
        }
        out
    }

    /// Same row partition as `store`?  (Precondition of every panel
    /// kernel.)
    pub fn partition_matches(&self, store: &ColumnStore) -> bool {
        self.offsets == store.offsets
    }

    /// Clamp a configured per-chunk column budget so one panel never
    /// exceeds ~256 MB regardless of m (the `m × |∂d|` blow-up guard at
    /// m ≫ 1e5): `min(requested, 256MB / (8·m))`, floored at 1.
    pub fn budget_cols(requested: usize, m: usize) -> usize {
        const PANEL_BUDGET_BYTES: usize = 256 << 20;
        let mem_cap = (PANEL_BUDGET_BYTES / (8 * m.max(1))).max(1);
        requested.max(1).min(mem_cap)
    }
}

/// Reduced result of one degree-batched panel pass:
/// the ℓ×k store-vs-panel block plus (optionally) the k×k panel
/// cross-Gram upper triangle, both accumulated in shard order.
///
/// Layouts: `atb` is candidate-major (`atb[c·ℓ + j] = ⟨store_j, panel_c⟩`,
/// so [`PanelStats::atb_col`] is the candidate's ready-to-use `Aᵀb`
/// prefix); `cross` packs the upper triangle candidate-major
/// (`cross[c(c+1)/2 + i] = ⟨panel_i, panel_c⟩` for `i ≤ c`, diagonal =
/// `bᵀb`).  The cross entries are what lets the driver resolve the
/// within-degree dependence in O(1) per (accepted, later-candidate)
/// pair: when candidate i joins O, later candidates extend their `Aᵀb`
/// with `cross_at(i, c)` instead of re-touching the data.
///
/// Under [`CrossMode::Lazy`] the packed triangle is replaced by an
/// eager `diag` (`bᵀb` is read for *every* candidate's oracle call)
/// plus a row-on-demand cache: [`PanelStats::ensure_cross_row`]
/// materializes row i (`⟨panel_i, panel_c⟩` for `c ≥ i`) only when
/// candidate i is accepted into O.  Since only accepted candidates'
/// rows are ever read by the driver, vanishing-heavy ψ-regimes skip the
/// O(k²) triangle work entirely; every materialized entry is bitwise
/// equal to its eager counterpart.
#[derive(Clone, Debug)]
pub struct PanelStats {
    ell: usize,
    k: usize,
    atb: Vec<f64>,
    cross: Vec<f64>,
    /// Eager cross diagonal (lazy mode only; empty otherwise).
    diag: Vec<f64>,
    /// Lazy row cache: `rows[i][c - i] = ⟨panel_i, panel_c⟩` for
    /// `c ∈ i..k`, filled by [`PanelStats::ensure_cross_row`].
    rows: Vec<Option<Vec<f64>>>,
}

impl PanelStats {
    /// Assemble from reduced blocks (backends only): eager cross when
    /// `cross` is the packed triangle, cross-free when it's empty.
    pub fn new(ell: usize, k: usize, atb: Vec<f64>, cross: Vec<f64>) -> Self {
        debug_assert_eq!(atb.len(), ell * k);
        debug_assert!(cross.is_empty() || cross.len() == k * (k + 1) / 2);
        PanelStats { ell, k, atb, cross, diag: Vec::new(), rows: Vec::new() }
    }

    /// Assemble a lazy-cross result (backends only): eager diagonal,
    /// off-diagonal rows on demand.
    pub fn new_lazy(ell: usize, k: usize, atb: Vec<f64>, diag: Vec<f64>) -> Self {
        debug_assert_eq!(atb.len(), ell * k);
        debug_assert_eq!(diag.len(), k);
        PanelStats { ell, k, atb, cross: Vec::new(), diag, rows: vec![None; k] }
    }

    /// Store width ℓ the block was computed against.
    #[inline]
    pub fn ell(&self) -> usize {
        self.ell
    }

    /// Number of candidates k.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Whether the full cross-Gram triangle was computed eagerly.
    #[inline]
    pub fn has_cross(&self) -> bool {
        !self.cross.is_empty()
    }

    /// Whether this is a lazy-cross result (eager diagonal, rows on
    /// demand).
    #[inline]
    pub fn is_lazy(&self) -> bool {
        self.cross.is_empty() && !self.diag.is_empty()
    }

    /// `⟨store_j, panel_c⟩` for all j — candidate c's `Aᵀb` over the
    /// store columns present when the panel was filled.
    #[inline]
    pub fn atb_col(&self, c: usize) -> &[f64] {
        &self.atb[c * self.ell..(c + 1) * self.ell]
    }

    /// Cached cross-Gram entry `⟨panel_i, panel_c⟩`, `i ≤ c`.  In lazy
    /// mode, off-diagonal reads require row i to have been materialized
    /// by [`PanelStats::ensure_cross_row`] (the driver does so when it
    /// accepts candidate i).
    #[inline]
    pub fn cross_at(&self, i: usize, c: usize) -> f64 {
        debug_assert!(i <= c, "cross_at: upper triangle only ({i} > {c})");
        if !self.cross.is_empty() {
            return self.cross[c * (c + 1) / 2 + i];
        }
        if i == c {
            return self.diag[c];
        }
        match &self.rows[i] {
            Some(row) => row[c - i],
            None => panic!("lazy cross row {i} read before ensure_cross_row"),
        }
    }

    /// `bᵀb` of candidate c (the cross diagonal — eager in every mode).
    #[inline]
    pub fn btb(&self, c: usize) -> f64 {
        if !self.cross.is_empty() {
            self.cross[c * (c + 1) / 2 + c]
        } else {
            self.diag[c]
        }
    }

    /// Materialize lazy cross row `i` (`⟨panel_i, panel_c⟩` for
    /// `c ∈ i..k`) if not already present.  No-op on eager results.
    ///
    /// Runs **sequentially** on the caller's thread: per shard, one
    /// [`dots_into`] pass with `panel_i`'s shard slice as the shared
    /// right-hand column, accumulated in ascending shard order — the
    /// same per-entry dots in the same order as the eager triangle, so
    /// materialized entries are bitwise identical to
    /// [`CrossMode::Eager`]'s.  (Sequential is deliberate: a lazy row is
    /// O((k−i)·m/shards) work per accepted candidate, and keeping it off
    /// the pool preserves the one-dispatch-per-panel-pass contract.)
    pub fn ensure_cross_row(&mut self, panel: &CandidatePanel, i: usize) {
        if !self.cross.is_empty() {
            return;
        }
        debug_assert!(
            !self.diag.is_empty() || self.k == 0,
            "ensure_cross_row on a Skip-mode PanelStats"
        );
        debug_assert_eq!(panel.len(), self.k, "panel/stats width mismatch");
        if self.rows[i].is_some() {
            return;
        }
        let span = self.k - i;
        let mut row = vec![0.0f64; span];
        let mut tmp = vec![0.0f64; span];
        for s in 0..panel.n_shards() {
            let bs = panel.col_shard(i, s);
            dots_into(|w| panel.col_shard(i + w, s), span, bs, &mut tmp);
            for (r, t) in row.iter_mut().zip(tmp.iter()) {
                *r += *t;
            }
        }
        self.rows[i] = Some(row);
    }
}

/// Fallback block threshold when calibration is skipped or
/// inconclusive: ~one LLC slice (the pre-calibration hard-coded value).
pub const BLOCK_THRESHOLD_DEFAULT: usize = 4 << 20;

/// Calibrated threshold clamp: below 1 MiB even L2-resident shards
/// would take the blocked path for no gain; above 64 MiB no realistic
/// LLC keeps a column resident anyway.
const BLOCK_THRESHOLD_FLOOR: usize = 1 << 20;
const BLOCK_THRESHOLD_CEIL: usize = 64 << 20;

/// Once-per-process memoized threshold; 0 = not yet calibrated.
static BLOCK_THRESHOLD: AtomicUsize = AtomicUsize::new(0);

/// Test/bench override hook for [`block_threshold_bytes`]: pin the
/// kernel-path selection deterministically (`1` forces the blocked/
/// tiled kernels everywhere, `usize::MAX` forces the scalar per-column
/// path, `0` clears the override so the next query re-calibrates).
/// Process-global; safe to flip at any time because every path the
/// threshold selects between is bitwise identical.
pub fn set_block_threshold_bytes(bytes: usize) {
    BLOCK_THRESHOLD.store(bytes, Ordering::Relaxed);
}

/// Column-bytes threshold above which the panel kernels switch from the
/// cache-resident per-column pass to the blocked/tiled wide-lane
/// kernels.
///
/// Calibrated **once per process** on first query (the analogue of
/// `PoolHandle::adaptive_min_work()` for the kernel layer, but lock-free
/// on the hot path): streaming-dot throughput is probed at doubling
/// buffer sizes and the threshold is the first size whose ns/element
/// degrades ≥ 30% versus a cache-resident buffer — i.e. where passes
/// actually start missing cache and b-pass sharing starts paying.
/// Falls back to [`BLOCK_THRESHOLD_DEFAULT`] when no clear knee exists
/// (huge LLC, noisy machine).  The selected value changes wall-clock
/// only — every candidate path produces identical bits.
pub fn block_threshold_bytes() -> usize {
    let v = BLOCK_THRESHOLD.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let cal = calibrate_block_threshold();
    // racing calibrators agree via CAS; a concurrent test override wins
    let _ = BLOCK_THRESHOLD.compare_exchange(0, cal, Ordering::Relaxed, Ordering::Relaxed);
    BLOCK_THRESHOLD.load(Ordering::Relaxed)
}

/// Median-free micro-probe: ns per element of a streaming dot over
/// `elems`-element f64 buffers (best of 3 reps to shed scheduling
/// noise).
fn dot_ns_per_elem(elems: usize) -> f64 {
    let a = vec![1.000_000_3f64; elems];
    let b = vec![0.999_999_7f64; elems];
    // enough reps that each probe is ≥ ~1M elements of work
    let reps = ((1usize << 21) / elems.max(1)).max(1);
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let mut acc = 0.0f64;
        for _ in 0..reps {
            acc += dot(std::hint::black_box(&a), std::hint::black_box(&b));
        }
        std::hint::black_box(acc);
        let ns = t0.elapsed().as_nanos() as f64 / (reps * elems.max(1)) as f64;
        best = best.min(ns);
    }
    best
}

fn calibrate_block_threshold() -> usize {
    // cache-resident baseline: 128 KiB per buffer
    let resident = dot_ns_per_elem((1 << 17) / 8);
    if !resident.is_finite() || resident <= 0.0 {
        return BLOCK_THRESHOLD_DEFAULT;
    }
    for shift in 20..=24usize {
        let bytes = 1usize << shift; // 1 MiB .. 16 MiB per buffer
        if dot_ns_per_elem(bytes / 8) > resident * 1.3 {
            return bytes.clamp(BLOCK_THRESHOLD_FLOOR, BLOCK_THRESHOLD_CEIL);
        }
    }
    BLOCK_THRESHOLD_DEFAULT
}

/// Four dots sharing one pass over `b` — thin wrapper over the generic
/// wide-lane brick [`crate::linalg::simd::dotn`], kept as the named
/// 4-wide kernel (and its historical bitwise test anchor).  Every entry
/// is bitwise equal to [`crate::linalg::dot`] of that column with `b`:
/// each column keeps `dot`'s four lane accumulators, lane-combine order,
/// and sequential tail, so the result bits are independent of the
/// blocking — only the (cache-missing past the LLC) pass over `b` is
/// shared, cutting b traffic 4×.
fn dot4(c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64], b: &[f64]) -> [f64; 4] {
    simd::dotn(&[c0, c1, c2, c3], b)
}

/// `out[j] = ⟨column j, bs⟩` for `n_cols` columns provided by `col`,
/// every entry bitwise equal to [`crate::linalg::dot`] — the one
/// Gram-entry code path shared by [`gram_partial`],
/// [`gram_panel_partial`], [`panel_cross_partial`], and the lazy cross
/// rows.  Past the calibrated [`block_threshold_bytes`] scale, columns
/// share each pass over `bs` through the wide-lane `dotN` bricks —
/// 8-wide once a column is ≥ 4× the threshold (the further past the LLC
/// the stream, the more columns should amortize it), 4-wide in between;
/// cache-resident shards keep the plain per-column dot.  The branch
/// affects wall-clock only — all sides produce identical bits.
fn dots_into<'a, F: Fn(usize) -> &'a [f64]>(col: F, n_cols: usize, bs: &[f64], out: &mut [f64]) {
    debug_assert_eq!(out.len(), n_cols);
    let bytes = bs.len() * std::mem::size_of::<f64>();
    let threshold = block_threshold_bytes();
    if bytes < threshold {
        for (j, a) in out.iter_mut().enumerate() {
            *a = dot(col(j), bs);
        }
        return;
    }
    let mut j = 0;
    if bytes >= threshold.saturating_mul(4) {
        while j + 8 <= n_cols {
            let cols: [&[f64]; 8] = std::array::from_fn(|w| col(j + w));
            out[j..j + 8].copy_from_slice(&simd::dotn(&cols, bs));
            j += 8;
        }
    }
    while j + 4 <= n_cols {
        out[j..j + 4].copy_from_slice(&dot4(col(j), col(j + 1), col(j + 2), col(j + 3), bs));
        j += 4;
    }
    while j < n_cols {
        out[j] = dot(col(j), bs);
        j += 1;
    }
}

/// Per-shard `(Aᵀb, bᵀb)` partial — the map side of gram_stats (the
/// legacy per-candidate kernel; serving-time single-column queries and
/// the bitwise reference path still use it).  Per-entry dot discipline
/// via [`dots_into`].
pub fn gram_partial(store: &ColumnStore, s: usize, b_full: &[f64]) -> (Vec<f64>, f64) {
    let bs = &b_full[store.shard_range(s)];
    let mut atb = vec![0.0f64; store.len()];
    let lease = store.lease(s);
    dots_into(|j| lease.col(j), store.len(), bs, &mut atb);
    (atb, dot(bs, bs))
}

/// Row-tile length (rows) of the tiled panel micro-kernel: a multiple
/// of 4 (lane alignment) sized so one candidate tile (8 KiB) plus a few
/// dozen store-column tiles stay L1/L2-resident while the lane state is
/// carried in registers/L1.
pub const PANEL_TILE_ROWS: usize = 1024;

/// Candidate-block width of the tiled kernel: bounds the carried lane
/// state at `ℓ × 16 × 32` bytes (L1-resident for training-sized ℓ) and
/// is the reuse factor each store-column tile gets per row tile.
const PANEL_TILE_CANDS: usize = 16;

/// Per-shard store-vs-panel block for the candidate range `cr` — the map
/// side of [`gram_panel_seq`] and the primary training kernel.
///
/// Output is candidate-major: `out[(c − cr.start)·ℓ + j] =
/// ⟨store_j, panel_c⟩` in shard `s`, every entry bitwise-dot.  Shards
/// whose columns fit in cache stream once per candidate via
/// [`dots_into`]; past [`block_threshold_bytes`] the row-tiled
/// micro-kernel ([`gram_panel_partial_tiled`]) takes over.  Both sides
/// produce identical bits — the switch is wall-clock only.
pub fn gram_panel_partial(
    store: &ColumnStore,
    panel: &CandidatePanel,
    s: usize,
    cr: Range<usize>,
) -> Vec<f64> {
    debug_assert!(panel.partition_matches(store), "panel/store partitions must match");
    let ell = store.len();
    if ell == 0 || cr.is_empty() {
        return vec![0.0f64; ell * cr.len()];
    }
    let rows = store.shard_range(s).len();
    if rows * std::mem::size_of::<f64>() >= block_threshold_bytes() {
        return gram_panel_partial_tiled(store, panel, s, cr, PANEL_TILE_ROWS);
    }
    let mut out = vec![0.0f64; ell * cr.len()];
    let lease = store.lease(s);
    for (ci, c) in cr.enumerate() {
        let bs = panel.col_shard(c, s);
        dots_into(|j| lease.col(j), ell, bs, &mut out[ci * ell..(ci + 1) * ell]);
    }
    out
}

/// The row-tiled panel micro-kernel: the same ℓ×|cr| block as
/// [`gram_panel_partial`], computed in `tile_rows`-row blocks with
/// carried dot lanes.
///
/// Loop structure: candidates are processed in [`PANEL_TILE_CANDS`]-wide
/// blocks; within a block, row tiles advance over the shard, and within
/// a (row tile, candidate) pair the store columns are swept through the
/// wide-lane `dotN` bricks (8-wide, then 4-wide, then single-lane
/// remainder).  Each (store col, candidate) entry owns a `[f64; 4]`
/// lane accumulator carried across every tile; after the last tile the
/// lanes are combined and the `< 4`-row shard tail is added
/// sequentially — exactly [`crate::linalg::dot`]'s schedule per entry
/// (see `linalg::simd`), so the output is **bitwise identical** to the
/// untiled kernel for every `tile_rows` that is a positive multiple
/// of 4.  The payoff is cache locality: per row tile, ℓ + 16 column
/// tiles are touched for ℓ × 16 × `tile_rows` multiply-adds, instead of
/// the untiled kernel's one full-shard stream per candidate.
pub fn gram_panel_partial_tiled(
    store: &ColumnStore,
    panel: &CandidatePanel,
    s: usize,
    cr: Range<usize>,
    tile_rows: usize,
) -> Vec<f64> {
    debug_assert!(panel.partition_matches(store), "panel/store partitions must match");
    debug_assert!(tile_rows >= 4 && tile_rows % 4 == 0, "tile_rows must be a 4-multiple");
    let ell = store.len();
    let kc = cr.len();
    let mut out = vec![0.0f64; ell * kc];
    if ell == 0 || kc == 0 {
        return out;
    }
    let rows = store.shard_range(s).len();
    let full = rows & !3usize; // lane region; the < 4-row tail is sequential
    let lease = store.lease(s);
    let mut lanes: Vec<[f64; 4]> = Vec::new();
    let mut cb0 = 0usize; // candidate-block start, relative to cr.start
    while cb0 < kc {
        let cb1 = (cb0 + PANEL_TILE_CANDS).min(kc);
        let width = cb1 - cb0;
        lanes.clear();
        lanes.resize(ell * width, [0.0f64; 4]);
        let mut t0 = 0usize;
        while t0 < full {
            let t1 = (t0 + tile_rows).min(full);
            for w in 0..width {
                let b = &panel.col_shard(cr.start + cb0 + w, s)[t0..t1];
                let lrow = &mut lanes[w * ell..(w + 1) * ell];
                let mut j = 0usize;
                while j + 8 <= ell {
                    let cols: [&[f64]; 8] =
                        std::array::from_fn(|x| &lease.col(j + x)[t0..t1]);
                    simd::dotn_update(&mut lrow[j..j + 8], &cols, b);
                    j += 8;
                }
                while j + 4 <= ell {
                    let cols: [&[f64]; 4] =
                        std::array::from_fn(|x| &lease.col(j + x)[t0..t1]);
                    simd::dotn_update(&mut lrow[j..j + 4], &cols, b);
                    j += 4;
                }
                while j < ell {
                    simd::lanes_update(&mut lrow[j], &lease.col(j)[t0..t1], b);
                    j += 1;
                }
            }
            t0 = t1;
        }
        for w in 0..width {
            let btail = &panel.col_shard(cr.start + cb0 + w, s)[full..rows];
            let dst = &mut out[(cb0 + w) * ell..(cb0 + w + 1) * ell];
            for (j, d) in dst.iter_mut().enumerate() {
                *d = simd::lanes_finish(
                    lanes[w * ell + j],
                    &lease.col(j)[full..rows],
                    btail,
                );
            }
        }
        cb0 = cb1;
    }
    out
}

/// Fast-path (mixed-precision) variant of [`gram_panel_partial`]: every
/// entry is [`crate::linalg::simd::dot_fast`] of the shard slices — f32
/// tile accumulation, f64 carry.  **No bitwise contract**; reachable
/// only through `NumericsMode::Fast`.
pub fn gram_panel_partial_fast(
    store: &ColumnStore,
    panel: &CandidatePanel,
    s: usize,
    cr: Range<usize>,
) -> Vec<f64> {
    debug_assert!(panel.partition_matches(store), "panel/store partitions must match");
    let ell = store.len();
    let mut out = vec![0.0f64; ell * cr.len()];
    if ell == 0 {
        return out;
    }
    let lease = store.lease(s);
    for (ci, c) in cr.enumerate() {
        let bs = panel.col_shard(c, s);
        for (j, o) in out[ci * ell..(ci + 1) * ell].iter_mut().enumerate() {
            *o = simd::dot_fast(lease.col(j), bs);
        }
    }
    out
}

/// Per-shard panel cross-Gram upper triangle for the candidate range
/// `cr`: for each `c ∈ cr`, the `c + 1` entries `⟨panel_i, panel_c⟩`
/// (`i ≤ c`), packed candidate-major in `cr` order.  Per-entry
/// bitwise-dot, so a cross entry carries exactly the bits the legacy
/// path would have produced by pushing candidate `i` into the store and
/// re-running `gram_partial` for candidate `c`.
pub fn panel_cross_partial(panel: &CandidatePanel, s: usize, cr: Range<usize>) -> Vec<f64> {
    let total: usize = cr.clone().map(|c| c + 1).sum();
    let mut out = vec![0.0f64; total];
    let mut base = 0usize;
    for c in cr {
        let bs = panel.col_shard(c, s);
        dots_into(|i| panel.col_shard(i, s), c + 1, bs, &mut out[base..base + c + 1]);
        base += c + 1;
    }
    out
}

/// Per-shard cross-Gram **diagonal** for the candidate range `cr`:
/// `out[c − cr.start] = ⟨panel_c, panel_c⟩` in shard `s`, per-entry
/// bitwise-dot — the eager half of [`CrossMode::Lazy`] (`bᵀb` is read
/// for every candidate's oracle call, so it never pays to defer it).
pub fn panel_diag_partial(panel: &CandidatePanel, s: usize, cr: Range<usize>) -> Vec<f64> {
    cr.map(|c| {
        let bs = panel.col_shard(c, s);
        dot(bs, bs)
    })
    .collect()
}

/// Fast-path variant of [`panel_diag_partial`]
/// ([`crate::linalg::simd::dot_fast`]; no bitwise contract).
pub fn panel_diag_partial_fast(panel: &CandidatePanel, s: usize, cr: Range<usize>) -> Vec<f64> {
    cr.map(|c| {
        let bs = panel.col_shard(c, s);
        simd::dot_fast(bs, bs)
    })
    .collect()
}

/// Sequential in-shard-order reduction of the panel kernels — the exact
/// reduction every backend must reproduce (bit-reproducibility anchor,
/// like [`gram_stats_seq`] for the single-column kernel).  The
/// [`CrossMode`] selects how much of the k×k triangle rides the pass:
/// all of it (`Eager`), just the diagonal with rows on demand (`Lazy`),
/// or none (`Skip` — VCA's projection batches need only the
/// store-vs-panel block).  Lazy and Eager agree bitwise on every entry
/// that is ever read.
pub fn gram_panel_seq(store: &ColumnStore, panel: &CandidatePanel, cross: CrossMode) -> PanelStats {
    debug_assert!(panel.partition_matches(store), "panel/store partitions must match");
    let ell = store.len();
    let k = panel.len();
    let mut atb = vec![0.0f64; ell * k];
    let want_cross = cross == CrossMode::Eager;
    let mut tri = vec![0.0f64; if want_cross { k * (k + 1) / 2 } else { 0 }];
    let mut diag = vec![0.0f64; if cross == CrossMode::Lazy { k } else { 0 }];
    for s in 0..store.n_shards() {
        let pa = gram_panel_partial(store, panel, s, 0..k);
        for (a, p) in atb.iter_mut().zip(pa.iter()) {
            *a += *p;
        }
        match cross {
            CrossMode::Eager => {
                let pc = panel_cross_partial(panel, s, 0..k);
                for (a, p) in tri.iter_mut().zip(pc.iter()) {
                    *a += *p;
                }
            }
            CrossMode::Lazy => {
                let pd = panel_diag_partial(panel, s, 0..k);
                for (a, p) in diag.iter_mut().zip(pd.iter()) {
                    *a += *p;
                }
            }
            CrossMode::Skip => {}
        }
    }
    match cross {
        CrossMode::Lazy => PanelStats::new_lazy(ell, k, atb, diag),
        _ => PanelStats::new(ell, k, atb, tri),
    }
}

/// Mixed-precision counterpart of [`gram_panel_seq`] — the
/// `NumericsMode::Fast` reference reduction.  The store-vs-panel block
/// and the cross diagonal run the f32-tile/f64-carry kernels; an
/// `Eager` triangle stays on the exact kernels (off-diagonal cross
/// entries feed the Theorem 4.9 inverse append — same policy as the
/// PJRT f32 path).
pub fn gram_panel_fast_seq(
    store: &ColumnStore,
    panel: &CandidatePanel,
    cross: CrossMode,
) -> PanelStats {
    debug_assert!(panel.partition_matches(store), "panel/store partitions must match");
    let ell = store.len();
    let k = panel.len();
    let mut atb = vec![0.0f64; ell * k];
    let want_cross = cross == CrossMode::Eager;
    let mut tri = vec![0.0f64; if want_cross { k * (k + 1) / 2 } else { 0 }];
    let mut diag = vec![0.0f64; if cross == CrossMode::Lazy { k } else { 0 }];
    for s in 0..store.n_shards() {
        let pa = gram_panel_partial_fast(store, panel, s, 0..k);
        for (a, p) in atb.iter_mut().zip(pa.iter()) {
            *a += *p;
        }
        match cross {
            CrossMode::Eager => {
                let pc = panel_cross_partial(panel, s, 0..k);
                for (a, p) in tri.iter_mut().zip(pc.iter()) {
                    *a += *p;
                }
            }
            CrossMode::Lazy => {
                let pd = panel_diag_partial_fast(panel, s, 0..k);
                for (a, p) in diag.iter_mut().zip(pd.iter()) {
                    *a += *p;
                }
            }
            CrossMode::Skip => {}
        }
    }
    match cross {
        CrossMode::Lazy => PanelStats::new_lazy(ell, k, atb, diag),
        _ => PanelStats::new(ell, k, atb, tri),
    }
}

/// Per-shard `|A_s·C + U_s|` written into a caller-owned row-major
/// `shard_rows × g` slice — the map side of transform_abs.  Writing
/// in place lets the sequential reduction accumulate directly into the
/// output matrix (no per-shard block allocation + stitch copy on the
/// test-time hot path).
///
/// Bench-gated branchless inner loop: the historical
/// `if a_ij == 0.0 { continue; }` skip was removed — see the verdict
/// comment in `backend/mod.rs` and the `transform_branch_gate` section of
/// `rust/benches/micro_runtime.rs` that measures it.
pub fn transform_block_into(
    store: &ColumnStore,
    s: usize,
    c: &Matrix,
    u: &Matrix,
    out: &mut [f64],
) {
    let range = store.shard_range(s);
    let g = u.cols();
    debug_assert_eq!(out.len(), range.len() * g);
    debug_assert_eq!(c.rows(), store.len());
    debug_assert_eq!(c.cols(), g);
    if g == 0 {
        return;
    }
    for (k, i) in range.enumerate() {
        out[k * g..(k + 1) * g].copy_from_slice(u.row(i));
    }
    let lease = store.lease(s);
    for j in 0..store.len() {
        let crow = c.row(j);
        // WIHB/BPCG deliberately produce sparse coefficient vectors (the
        // SPAR payoff): a C row that is zero across every generator
        // contributes nothing — skip the whole O column.  This is the
        // column-granular form of the old per-generator `c == 0.0` skip;
        // the per-element a_ij branch stays removed (bench verdict in
        // backend/mod.rs).
        if crow.iter().all(|&v| v == 0.0) {
            continue;
        }
        let col = lease.col(j);
        for (k, &a_ij) in col.iter().enumerate() {
            let orow = &mut out[k * g..(k + 1) * g];
            for (o, ck) in orow.iter_mut().zip(crow.iter()) {
                *o += a_ij * ck;
            }
        }
    }
    for v in out.iter_mut() {
        *v = v.abs();
    }
}

/// Allocating wrapper over [`transform_block_into`] for the parallel
/// map path, where workers can't share `&mut` access to the output.
pub fn transform_block(store: &ColumnStore, s: usize, c: &Matrix, u: &Matrix) -> Vec<f64> {
    let rows = store.shard_range(s).len();
    let mut out = vec![0.0f64; rows * u.cols()];
    transform_block_into(store, s, c, u, &mut out);
    out
}

/// [`transform_block_into`] with an arbitrary output row stride and
/// column offset: shard row `i` lands at
/// `out[i*stride + col_off .. i*stride + col_off + g]`, where `out` is
/// the caller's full m×stride slab.  This is how the pipeline writes one
/// class's (FT) block directly into its column range of the concatenated
/// feature matrix — no per-class block allocation, no row-by-row stitch.
///
/// Per (row, generator) element the arithmetic is the seed-then-
/// ascending-j accumulation of [`transform_block_into`], so the written
/// cells are bitwise identical to the contiguous kernel's.
pub fn transform_block_into_strided(
    store: &ColumnStore,
    s: usize,
    c: &Matrix,
    u: &Matrix,
    out: &mut [f64],
    stride: usize,
    col_off: usize,
) {
    let range = store.shard_range(s);
    let g = u.cols();
    debug_assert!(col_off + g <= stride);
    debug_assert_eq!(c.rows(), store.len());
    debug_assert_eq!(c.cols(), g);
    if g == 0 {
        return;
    }
    for i in range.clone() {
        let base = i * stride + col_off;
        out[base..base + g].copy_from_slice(u.row(i));
    }
    let lease = store.lease(s);
    for j in 0..store.len() {
        let crow = c.row(j);
        // same column-granular sparse skip as the contiguous kernel
        if crow.iter().all(|&v| v == 0.0) {
            continue;
        }
        let col = lease.col(j);
        for (k, &a_ij) in col.iter().enumerate() {
            let base = (range.start + k) * stride + col_off;
            let orow = &mut out[base..base + g];
            for (o, ck) in orow.iter_mut().zip(crow.iter()) {
                *o += a_ij * ck;
            }
        }
    }
    for i in range {
        let base = i * stride + col_off;
        for v in out[base..base + g].iter_mut() {
            *v = v.abs();
        }
    }
}

/// Sequential in-shard-order reduction of [`gram_partial`] — the exact
/// reduction both backends share (bit-reproducibility anchor).
pub fn gram_stats_seq(store: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64) {
    let mut atb = vec![0.0f64; store.len()];
    let mut btb = 0.0f64;
    for s in 0..store.n_shards() {
        let (pa, pb) = gram_partial(store, s, b_col);
        for (a, p) in atb.iter_mut().zip(pa.iter()) {
            *a += *p;
        }
        btb += pb;
    }
    (atb, btb)
}

/// Sequential shard-order application of [`transform_block_into`],
/// writing each shard's rows directly into the m×g result.
pub fn transform_abs_seq(store: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix {
    let m = u.rows();
    let g = u.cols();
    let mut out = Matrix::zeros(m, g);
    for s in 0..store.n_shards() {
        let r = store.shard_range(s);
        transform_block_into(store, s, c, u, &mut out.data_mut()[r.start * g..r.end * g]);
    }
    out
}

/// Sequential shard-order application of [`transform_block_into_strided`]
/// — the strided sibling of [`transform_abs_seq`], writing into a column
/// range of the caller's m×stride slab.
pub fn transform_abs_strided_seq(
    store: &ColumnStore,
    c: &Matrix,
    u: &Matrix,
    out: &mut [f64],
    stride: usize,
    col_off: usize,
) {
    for s in 0..store.n_shards() {
        transform_block_into_strided(store, s, c, u, out, stride, col_off);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{all_close, property};
    use crate::util::rng::Rng;

    fn random_cols(rng: &mut Rng, m: usize, ell: usize) -> Vec<Vec<f64>> {
        (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect()
    }

    #[test]
    fn balanced_partition_covers_all_rows() {
        for (m, k) in [(10usize, 3usize), (7, 7), (3, 7), (0, 4), (1, 1), (100, 8)] {
            let store = ColumnStore::new(m, k);
            assert_eq!(store.n_shards(), k.max(1));
            let mut total = 0;
            let mut prev_end = 0;
            for s in 0..store.n_shards() {
                let r = store.shard_range(s);
                assert_eq!(r.start, prev_end, "shards must be contiguous");
                prev_end = r.end;
                total += r.len();
            }
            assert_eq!(total, m);
            // balanced: sizes differ by at most 1
            let sizes: Vec<usize> =
                (0..store.n_shards()).map(|s| store.shard_range(s).len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced: {sizes:?}");
        }
    }

    #[test]
    fn push_col_and_materialize_roundtrip() {
        property(16, |rng| {
            let m = rng.below(40);
            let k = 1 + rng.below(6);
            let ell = 1 + rng.below(5);
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, k);
            if store.len() != ell || store.rows() != m {
                return Err("shape mismatch".into());
            }
            for (j, col) in cols.iter().enumerate() {
                if &store.col(j) != col {
                    return Err(format!("column {j} does not roundtrip"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn with_ones_is_the_constant_column() {
        let store = ColumnStore::with_ones(13, 4);
        assert_eq!(store.len(), 1);
        assert_eq!(store.col(0), vec![1.0; 13]);
    }

    #[test]
    fn fill_product_matches_direct() {
        property(16, |rng| {
            let m = 1 + rng.below(50);
            let k = 1 + rng.below(5);
            let n = 1 + rng.below(3);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let parent: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let store = ColumnStore::from_cols(std::slice::from_ref(&parent), k);
            let var = rng.below(n);
            let mut out = vec![0.0; m];
            store.fill_product(0, &x, var, &mut out);
            let expect: Vec<f64> = (0..m).map(|i| parent[i] * x.get(i, var)).collect();
            all_close(&out, &expect, 0.0, "fill_product")
        });
    }

    #[test]
    fn dots_and_means_match_dense() {
        property(16, |rng| {
            let m = 1 + rng.below(60);
            let k = 1 + rng.below(7);
            let cols = random_cols(rng, m, 3);
            let store = ColumnStore::from_cols(&cols, k);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            crate::util::proptest::close(
                store.dot_cols(0, 1),
                dot(&cols[0], &cols[1]),
                1e-10,
                "dot_cols",
            )?;
            crate::util::proptest::close(
                store.dot_col_slice(2, &v),
                dot(&cols[2], &v),
                1e-10,
                "dot_col_slice",
            )?;
            let mean = cols[0].iter().sum::<f64>() / m as f64;
            crate::util::proptest::close(store.col_mean(0), mean, 1e-10, "col_mean")
        });
    }

    #[test]
    fn gram_stats_seq_matches_definition_for_any_shard_count() {
        property(24, |rng| {
            let m = rng.below(80);
            let k = 1 + rng.below(9); // includes m < k
            let ell = 1 + rng.below(6);
            let cols = random_cols(rng, m, ell);
            let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let store = ColumnStore::from_cols(&cols, k);
            let (atb, btb) = gram_stats_seq(&store, &b);
            let expect: Vec<f64> = cols.iter().map(|c| dot(c, &b)).collect();
            all_close(&atb, &expect, 1e-10, "atb")?;
            crate::util::proptest::close(btb, dot(&b, &b), 1e-10, "btb")
        });
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn dot4_is_bitwise_equal_to_four_dots() {
        property(24, |rng| {
            // lengths straddling the 4-chunk boundary, incl. 0..3 tails
            let n = rng.below(70);
            let cols: Vec<Vec<f64>> =
                (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let d = dot4(&cols[0], &cols[1], &cols[2], &cols[3], &b);
            for (j, dj) in d.iter().enumerate() {
                if dj.to_bits() != dot(&cols[j], &b).to_bits() {
                    return Err(format!("dot4 lane {j} diverges at n={n}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn panel_from_recipes_matches_fill_product_bitwise() {
        property(16, |rng| {
            let m = 1 + rng.below(60);
            let shards = 1 + rng.below(5);
            let n = 1 + rng.below(3);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let cols = random_cols(rng, m, 2);
            let store = ColumnStore::from_cols(&cols, shards);
            let recipes: Vec<PanelRecipe> = (0..4)
                .map(|_| PanelRecipe { parent: rng.below(2), var: rng.below(n) })
                .collect();
            let panel = CandidatePanel::from_recipes(&store, &x, &recipes);
            if panel.len() != 4 || !panel.partition_matches(&store) {
                return Err("panel shape mismatch".into());
            }
            let mut buf = vec![0.0f64; m];
            for (c, r) in recipes.iter().enumerate() {
                store.fill_product(r.parent, &x, r.var, &mut buf);
                if bits(&panel.col(c)) != bits(&buf) {
                    return Err(format!("panel col {c} diverges from fill_product"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn push_col_from_panel_matches_push_col_bitwise() {
        let mut rng = Rng::new(23);
        let m = 37;
        let cols = random_cols(&mut rng, m, 2);
        for shards in [1usize, 3, 5] {
            let base = ColumnStore::from_cols(&cols, shards);
            let cand: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut panel = CandidatePanel::new_like(&base);
            panel.push_col(&cand);
            let mut via_panel = base.clone();
            via_panel.push_col_from_panel(&panel, 0);
            let mut via_buf = base.clone();
            via_buf.push_col(&cand);
            assert_eq!(via_panel.len(), via_buf.len());
            for s in 0..via_panel.n_shards() {
                assert_eq!(bits(via_panel.col_shard(2, s)), bits(via_buf.col_shard(2, s)));
            }
        }
    }

    #[test]
    fn gram_panel_seq_matches_per_candidate_gram_stats_bitwise() {
        property(20, |rng| {
            let m = rng.below(80);
            let shards = 1 + rng.below(6);
            let ell = 1 + rng.below(5);
            let k = 1 + rng.below(6);
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, shards);
            let cands = random_cols(rng, m, k);
            let mut panel = CandidatePanel::new_like(&store);
            for c in &cands {
                panel.push_col(c);
            }
            let ps = gram_panel_seq(&store, &panel, CrossMode::Eager);
            if ps.ell() != ell || ps.k() != k || !ps.has_cross() {
                return Err("panel stats shape mismatch".into());
            }
            for (c, cand) in cands.iter().enumerate() {
                let (atb, btb) = gram_stats_seq(&store, cand);
                if bits(&atb) != bits(ps.atb_col(c)) {
                    return Err(format!("atb col {c} diverges (shards {shards})"));
                }
                if btb.to_bits() != ps.btb(c).to_bits() {
                    return Err(format!("btb {c} diverges (shards {shards})"));
                }
            }
            // cross entry (i, c) must equal the legacy flow: push candidate
            // i into the store, then gram_stats of candidate c sees it as
            // its last atb entry
            for c in 0..k {
                for i in 0..c {
                    let mut grown = store.clone();
                    grown.push_col(&cands[i]);
                    let (atb, _) = gram_stats_seq(&grown, &cands[c]);
                    if atb[ell].to_bits() != ps.cross_at(i, c).to_bits() {
                        return Err(format!("cross ({i},{c}) diverges (shards {shards})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gram_panel_seq_without_cross_skips_triangle() {
        let mut rng = Rng::new(31);
        let cols = random_cols(&mut rng, 40, 3);
        let store = ColumnStore::from_cols(&cols, 2);
        let mut panel = CandidatePanel::new_like(&store);
        let cand: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        panel.push_col(&cand);
        let ps = gram_panel_seq(&store, &panel, CrossMode::Skip);
        assert!(!ps.has_cross());
        assert!(!ps.is_lazy());
        let (atb, _) = gram_stats_seq(&store, &cand);
        assert_eq!(bits(&atb), bits(ps.atb_col(0)));
    }

    #[test]
    fn lazy_cross_matches_eager_bitwise_after_ensure() {
        property(16, |rng| {
            let m = rng.below(90);
            let shards = 1 + rng.below(5);
            let ell = 1 + rng.below(4);
            let k = 1 + rng.below(7);
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, shards);
            let mut panel = CandidatePanel::new_like(&store);
            for c in &random_cols(rng, m, k) {
                panel.push_col(c);
            }
            let eager = gram_panel_seq(&store, &panel, CrossMode::Eager);
            let mut lazy = gram_panel_seq(&store, &panel, CrossMode::Lazy);
            if !lazy.is_lazy() || lazy.has_cross() {
                return Err("lazy stats shape mismatch".into());
            }
            for c in 0..k {
                if bits(eager.atb_col(c)) != bits(lazy.atb_col(c)) {
                    return Err(format!("lazy atb {c} diverges"));
                }
                // diagonal is eager in lazy mode — readable immediately
                if eager.btb(c).to_bits() != lazy.btb(c).to_bits() {
                    return Err(format!("lazy diag {c} diverges"));
                }
            }
            for i in 0..k {
                lazy.ensure_cross_row(&panel, i);
                lazy.ensure_cross_row(&panel, i); // idempotent
            }
            for c in 0..k {
                for i in 0..=c {
                    if eager.cross_at(i, c).to_bits() != lazy.cross_at(i, c).to_bits() {
                        return Err(format!("lazy cross ({i},{c}) diverges"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tiled_panel_kernel_is_bitwise_equal_across_tile_sizes() {
        property(12, |rng| {
            // m deliberately NOT a multiple of the tile sizes below
            let m = 1 + rng.below(150);
            let shards = 1 + rng.below(4);
            let ell = 1 + rng.below(12); // straddles the 8- and 4-wide bricks
            let k = 1 + rng.below(20); // straddles the 16-candidate block
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, shards);
            let mut panel = CandidatePanel::new_like(&store);
            for c in &random_cols(rng, m, k) {
                panel.push_col(c);
            }
            for s in 0..store.n_shards() {
                let reference: Vec<f64> = (0..k)
                    .flat_map(|c| {
                        (0..ell)
                            .map(|j| dot(store.col_shard(j, s), panel.col_shard(c, s)))
                            .collect::<Vec<f64>>()
                    })
                    .collect();
                for tile in [4usize, 8, 12, 64, 1024] {
                    let tiled = gram_panel_partial_tiled(&store, &panel, s, 0..k, tile);
                    if bits(&tiled) != bits(&reference) {
                        return Err(format!(
                            "tiled kernel diverges at shard {s} tile {tile} (m={m})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fast_panel_kernels_stay_within_f32_error_on_benign_data() {
        let mut rng = Rng::new(53);
        let m = 5000;
        let cols: Vec<Vec<f64>> =
            (0..3).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let store = ColumnStore::from_cols(&cols, 3);
        let mut panel = CandidatePanel::new_like(&store);
        for _ in 0..4 {
            let c: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
            panel.push_col(&c);
        }
        let exact = gram_panel_seq(&store, &panel, CrossMode::Lazy);
        let fast = gram_panel_fast_seq(&store, &panel, CrossMode::Lazy);
        assert!(fast.is_lazy());
        let mut scale = 1.0f64;
        for c in 0..4 {
            for j in 0..3 {
                scale = scale.max(exact.atb_col(c)[j].abs());
            }
            scale = scale.max(exact.btb(c).abs());
        }
        for c in 0..4 {
            for j in 0..3 {
                let d = (fast.atb_col(c)[j] - exact.atb_col(c)[j]).abs();
                assert!(d <= 1e-3 * scale, "fast atb ({j},{c}) off by {d}");
            }
            let d = (fast.btb(c) - exact.btb(c)).abs();
            assert!(d <= 1e-3 * scale, "fast diag {c} off by {d}");
        }
    }

    #[test]
    fn block_threshold_override_and_calibration_bounds() {
        // the override hook pins the value verbatim…
        set_block_threshold_bytes(12345);
        assert_eq!(block_threshold_bytes(), 12345);
        // …and clearing it re-calibrates into the clamp (or the default)
        set_block_threshold_bytes(0);
        let v = block_threshold_bytes();
        assert!(
            (1usize << 20..=64 << 20).contains(&v) || v == BLOCK_THRESHOLD_DEFAULT,
            "calibrated block threshold {v} outside clamp"
        );
        // leave the memoized value in place for sibling tests (any value
        // is bit-safe; re-calibration is just wasted time)
    }

    #[test]
    fn panel_budget_clamps_to_memory_cap() {
        // small m: the configured budget wins
        assert_eq!(CandidatePanel::budget_cols(128, 1_000), 128);
        // huge m: the 256MB cap wins (256MB / 8 bytes / m rows)
        assert_eq!(CandidatePanel::budget_cols(512, 1 << 20), (256 << 20) / (8 << 20));
        // floors at 1 column even for absurd m
        assert_eq!(CandidatePanel::budget_cols(0, usize::MAX / 16), 1);
    }

    #[test]
    fn transform_abs_seq_matches_manual_for_any_shard_count() {
        property(24, |rng| {
            let m = rng.below(40);
            let k = 1 + rng.below(9);
            let ell = 1 + rng.below(4);
            let g = rng.below(4); // includes g = 0
            let cols = random_cols(rng, m, ell);
            let store = ColumnStore::from_cols(&cols, k);
            let mut c = Matrix::zeros(ell, g);
            let mut u = Matrix::zeros(m, g);
            for i in 0..ell {
                for j in 0..g {
                    c.set(i, j, rng.normal());
                }
            }
            for i in 0..m {
                for j in 0..g {
                    u.set(i, j, rng.normal());
                }
            }
            let out = transform_abs_seq(&store, &c, &u);
            for i in 0..m {
                for j in 0..g {
                    let mut v = u.get(i, j);
                    for (kk, col) in cols.iter().enumerate() {
                        v += col[i] * c.get(kk, j);
                    }
                    if (out.get(i, j) - v.abs()).abs() > 1e-10 {
                        return Err(format!("({i},{j}): {} vs {}", out.get(i, j), v.abs()));
                    }
                }
            }
            Ok(())
        });
    }

    /// Spilled twin of a memory store: same columns pushed in the same
    /// order through the spill backing.
    fn spilled_twin(cols: &[Vec<f64>], shards: usize, budget: usize) -> ColumnStore {
        let m = cols.first().map(|c| c.len()).unwrap_or(0);
        let mut st = ColumnStore::new_with_backing(
            m,
            shards,
            StoreMode::Spill { budget_bytes: budget },
        )
        .unwrap();
        for c in cols {
            st.push_col(c);
        }
        st
    }

    #[test]
    fn memory_lease_matches_col_shard_exactly() {
        let mut rng = Rng::new(11);
        let cols = random_cols(&mut rng, 37, 4);
        let store = ColumnStore::from_cols(&cols, 3);
        for s in 0..store.n_shards() {
            let lease = store.lease(s);
            assert_eq!(lease.rows(), store.shard_range(s).len());
            for j in 0..store.len() {
                assert_eq!(lease.col(j), store.col_shard(j, s));
            }
        }
    }

    #[test]
    fn spilled_store_roundtrips_columns_bitwise() {
        let mut rng = Rng::new(12);
        let cols = random_cols(&mut rng, 41, 5);
        let mem = ColumnStore::from_cols(&cols, 3);
        let spill = spilled_twin(&cols, 3, 1 << 20);
        assert!(spill.is_spilled());
        assert_eq!(spill.mode_str(), "mmap");
        assert_eq!(mem.mode_str(), "mem");
        for j in 0..cols.len() {
            assert_eq!(bits(&mem.col(j)), bits(&spill.col(j)));
        }
    }

    #[test]
    fn spilled_kernels_are_bitwise_equal_to_memory() {
        let mut rng = Rng::new(13);
        let m = 53;
        let cols = random_cols(&mut rng, m, 4);
        let mem = ColumnStore::from_cols(&cols, 3);
        // budget below one block: every lease reloads from disk
        let spill = spilled_twin(&cols, 3, 64);
        let cands = random_cols(&mut rng, m, 5);
        let (mut pm, mut ps) = (CandidatePanel::new_like(&mem), CandidatePanel::new_like(&spill));
        for c in &cands {
            pm.push_col(c);
            ps.push_col(c);
        }
        for cross in [CrossMode::Eager, CrossMode::Lazy, CrossMode::Skip] {
            let a = gram_panel_seq(&mem, &pm, cross);
            let b = gram_panel_seq(&spill, &ps, cross);
            for c in 0..cands.len() {
                assert_eq!(bits(a.atb_col(c)), bits(b.atb_col(c)));
                if cross != CrossMode::Skip {
                    assert_eq!(a.btb(c).to_bits(), b.btb(c).to_bits());
                }
            }
        }
        let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let (atb_m, btb_m) = gram_stats_seq(&mem, &b);
        let (atb_s, btb_s) = gram_stats_seq(&spill, &b);
        assert_eq!(bits(&atb_m), bits(&atb_s));
        assert_eq!(btb_m.to_bits(), btb_s.to_bits());
        assert_eq!(mem.dot_cols(0, 3).to_bits(), spill.dot_cols(0, 3).to_bits());
        assert_eq!(mem.col_mean(2).to_bits(), spill.col_mean(2).to_bits());
        let c = spill.backing_counters().unwrap();
        assert!(c.reloads > 0, "tiny budget must force reloads: {c:?}");
        let max_block = ((m + 2) / 3) * 4 * 8; // largest shard block exceeds the budget
        assert!(c.peak_resident_bytes <= c.budget_bytes.max(max_block as u64));
    }

    #[test]
    fn panel_from_recipes_reads_spilled_parents_bitwise() {
        let mut rng = Rng::new(14);
        let m = 29;
        let n = 2;
        let mut x = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                x.set(i, j, rng.uniform());
            }
        }
        let cols = random_cols(&mut rng, m, 3);
        let mem = ColumnStore::from_cols(&cols, 4);
        let spill = spilled_twin(&cols, 4, 128);
        let recipes =
            vec![PanelRecipe { parent: 0, var: 1 }, PanelRecipe { parent: 2, var: 0 }];
        let pm = CandidatePanel::from_recipes(&mem, &x, &recipes);
        let ps = CandidatePanel::from_recipes(&spill, &x, &recipes);
        for c in 0..recipes.len() {
            assert_eq!(bits(&pm.col(c)), bits(&ps.col(c)));
        }
    }

    #[test]
    fn push_col_from_panel_appends_to_spilled_store_bitwise() {
        let mut rng = Rng::new(15);
        let m = 33;
        let cols = random_cols(&mut rng, m, 2);
        let mut mem = ColumnStore::from_cols(&cols, 3);
        let mut spill = spilled_twin(&cols, 3, 1 << 20);
        let cand: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let (mut pm, mut ps) = (CandidatePanel::new_like(&mem), CandidatePanel::new_like(&spill));
        pm.push_col(&cand);
        ps.push_col(&cand);
        mem.push_col_from_panel(&pm, 0);
        spill.push_col_from_panel(&ps, 0);
        assert_eq!(mem.len(), spill.len());
        assert_eq!(bits(&mem.col(2)), bits(&spill.col(2)));
    }

    #[test]
    #[should_panic(expected = "col_shard on a spilled store")]
    fn col_shard_panics_on_spilled_store() {
        let spill = spilled_twin(&[vec![1.0, 2.0, 3.0]], 2, 1 << 20);
        let _ = spill.col_shard(0, 0);
    }

    #[test]
    fn with_ones_backed_spill_matches_memory() {
        let mem = ColumnStore::with_ones(17, 4);
        let spill =
            ColumnStore::with_ones_backed(17, 4, StoreMode::Spill { budget_bytes: 1 << 20 })
                .unwrap();
        assert_eq!(spill.len(), 1);
        assert_eq!(bits(&mem.col(0)), bits(&spill.col(0)));
    }
}

//! Map-reduce compute backend: the two streaming kernels fan out one job
//! per [`ColumnStore`] shard onto the persistent
//! [`crate::coordinator::pool::ThreadPool`], then reduce partials
//! **in shard order**.
//!
//! Determinism contract: for a fixed store shard count the result is a
//! pure function of the inputs — independent of worker count, thread
//! scheduling, or repetition — because every shard runs the exact
//! per-shard kernel [`crate::backend::store::gram_partial`] /
//! [`crate::backend::store::transform_block`] that
//! [`crate::backend::NativeBackend`] runs sequentially, and the reduction
//! order is the shard order.  `ShardedBackend` therefore matches
//! `NativeBackend` bit-for-bit on any store (shards = 1 included), which
//! `rust/tests/runtime_parity.rs` and the property tests below enforce.
//!
//! Two ways to get a backend:
//!
//! * [`ShardedBackend::new`] owns a private pool (standalone use, tests,
//!   benches) — workers are spawned once and live for the backend's
//!   lifetime, not per call.
//! * [`ShardedBackend::with_handle`] shares an existing pool through a
//!   [`PoolHandle`] — the two-level configuration, where outer jobs
//!   (grid points, per-class fits) and these inner shard kernels draw
//!   from one worker budget.  Nested submission is deadlock-free (the
//!   pool's helping loop runs a submitter's own jobs in place).
//!
//! The `ComputeBackend` trait itself stays `!Send` (PJRT handles are
//! `Rc`-based); the shard workers only ever see `&[f64]` slices and the
//! plain-data [`ColumnStore`], both `Sync`, so the pool fan-out lives
//! entirely below the trait boundary.

use std::ops::Range;

use crate::backend::store::{
    gram_panel_fast_seq, gram_panel_partial, gram_panel_partial_fast, gram_panel_seq,
    gram_partial, gram_stats_seq, panel_cross_partial, panel_diag_partial,
    panel_diag_partial_fast, transform_abs_seq, transform_abs_strided_seq, transform_block,
    CandidatePanel, ColumnStore, CrossMode, NumericsMode, PanelStats,
};
use crate::backend::{ComputeBackend, NativeBackend};
use crate::coordinator::pool::{PoolHandle, ThreadPool};
use crate::linalg::dense::Matrix;

/// Default shard floor for training fits: below this many rows per
/// shard the per-shard thread hand-off costs more than the dot
/// products it parallelizes.  Serving overrides it downward
/// ([`ShardedBackend::with_min_rows`]) because transform work per row
/// (ℓ·g fused multiply-adds) is much heavier than a dot.
pub const MIN_ROWS_PER_SHARD: usize = 4096;

/// Intra-fit parallel backend (map-reduce over row shards).
pub struct ShardedBackend {
    /// Present when this backend spawned its own pool; keeps the workers
    /// alive exactly as long as the backend.  `None` in the shared
    /// (two-level) configuration.
    _owned: Option<ThreadPool>,
    pool: PoolHandle,
    /// Inner-axis worker budget.  It shapes **store sizing**
    /// ([`ComputeBackend::preferred_shards`] caps at this value) and
    /// gates the sequential fallback (`inner_workers == 1`); the kernel
    /// fan-out itself always submits one job per *store* shard, so a
    /// store sized elsewhere (pinned parity tests, foreign drivers) can
    /// enqueue more jobs than the budget — they queue, they don't spawn
    /// threads.
    inner_workers: usize,
    min_rows_per_shard: usize,
    /// The per-shard work threshold, copied out of the pool's one-time
    /// calibration at construction (or overridden by
    /// [`ShardedBackend::with_min_work`]).  A plain field — the kernel
    /// hot path must not take the pool's calibration mutex per call.
    min_work: usize,
}

impl ShardedBackend {
    /// Backend owning a fresh pool with `workers` shard-worker threads
    /// (clamped to ≥ 1) and the default [`MIN_ROWS_PER_SHARD`] floor.
    pub fn new(workers: usize) -> Self {
        Self::with_min_rows(workers, MIN_ROWS_PER_SHARD)
    }

    /// [`ShardedBackend::new`] with an explicit shard floor — the knob
    /// callers with lighter- or heavier-than-training per-row work use
    /// to decide when sharding starts paying off.
    pub fn with_min_rows(workers: usize, min_rows_per_shard: usize) -> Self {
        let pool = ThreadPool::new(workers);
        let handle = pool.handle();
        let inner_workers = pool.workers();
        let min_work = handle.adaptive_min_work();
        ShardedBackend {
            _owned: Some(pool),
            pool: handle,
            inner_workers,
            min_rows_per_shard: min_rows_per_shard.max(1),
            min_work,
        }
    }

    /// Backend sized to the machine (available parallelism − 1).
    pub fn default_parallel() -> Self {
        let pool = ThreadPool::default_size();
        let handle = pool.handle();
        let inner_workers = pool.workers();
        let min_work = handle.adaptive_min_work();
        ShardedBackend {
            _owned: Some(pool),
            pool: handle,
            inner_workers,
            min_rows_per_shard: MIN_ROWS_PER_SHARD,
            min_work,
        }
    }

    /// Backend drawing from a **shared** pool: `inner_workers` is this
    /// backend's slice of the worker budget (usually the `inner` half of
    /// [`PoolHandle::budget_split`]), not the pool's total size.
    pub fn with_handle(handle: PoolHandle, inner_workers: usize, min_rows: usize) -> Self {
        let min_work = handle.adaptive_min_work();
        ShardedBackend {
            _owned: None,
            pool: handle,
            inner_workers: inner_workers.max(1),
            min_rows_per_shard: min_rows.max(1),
            min_work,
        }
    }

    /// The worker-count-to-backend policy shared by the grid search,
    /// the serving path, and the CLI: sharded when `workers > 1`,
    /// native otherwise.
    pub fn boxed_for(workers: usize) -> Box<dyn ComputeBackend> {
        Self::boxed_with_min_rows(workers, MIN_ROWS_PER_SHARD)
    }

    /// [`ShardedBackend::boxed_for`] with an explicit shard floor.
    pub fn boxed_with_min_rows(workers: usize, min_rows: usize) -> Box<dyn ComputeBackend> {
        if workers > 1 {
            Box::new(ShardedBackend::with_min_rows(workers, min_rows))
        } else {
            Box::new(NativeBackend)
        }
    }

    /// [`ShardedBackend::boxed_for`] over a shared pool: sharded when the
    /// inner budget exceeds 1, native otherwise.
    pub fn boxed_with_handle(
        handle: PoolHandle,
        inner_workers: usize,
        min_rows: usize,
    ) -> Box<dyn ComputeBackend> {
        if inner_workers > 1 {
            Box::new(ShardedBackend::with_handle(handle, inner_workers, min_rows))
        } else {
            Box::new(NativeBackend)
        }
    }

    /// Override the calibrated dispatch threshold (tests/benches: pin the
    /// parallel or sequential path deterministically; 0 forces parallel).
    pub fn with_min_work(mut self, min_work: usize) -> Self {
        self.min_work = min_work;
        self
    }

    /// Inner-axis worker budget.
    pub fn workers(&self) -> usize {
        self.inner_workers
    }

    /// The per-shard multiply-add count below which this backend takes
    /// the (bit-identical) sequential path — the pool's calibrated
    /// [`PoolHandle::adaptive_min_work`] copied at construction, unless
    /// overridden via [`ShardedBackend::with_min_work`].
    pub fn min_work_threshold(&self) -> usize {
        self.min_work
    }
}

impl ComputeBackend for ShardedBackend {
    fn gram_stats(&self, cols: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64) {
        let n = cols.n_shards();
        if n == 1 || self.inner_workers == 1 {
            return gram_stats_seq(cols, b_col);
        }
        // Falling back below the threshold is free of determinism
        // concerns: both paths produce identical bits, so the switch is
        // invisible in results.
        let work_per_shard = cols.len().max(1) * (cols.rows() / n);
        if work_per_shard < self.min_work_threshold() {
            return gram_stats_seq(cols, b_col);
        }
        let ids: Vec<usize> = (0..n).collect();
        let parts = self.pool.map(&ids, |&s| gram_partial(cols, s, b_col));
        // deterministic in-order reduction: identical to the sequential
        // accumulation in gram_stats_seq, bit for bit
        let mut atb = vec![0.0f64; cols.len()];
        let mut btb = 0.0f64;
        for (pa, pb) in &parts {
            for (a, p) in atb.iter_mut().zip(pa.iter()) {
                *a += *p;
            }
            btb += *pb;
        }
        (atb, btb)
    }

    fn gram_panel(
        &self,
        cols: &ColumnStore,
        panel: &CandidatePanel,
        cross: CrossMode,
        numerics: NumericsMode,
    ) -> PanelStats {
        let n = cols.n_shards();
        let ell = cols.len();
        let k = panel.len();
        let seq = |cols: &ColumnStore, panel: &CandidatePanel| match numerics {
            NumericsMode::Exact => gram_panel_seq(cols, panel, cross),
            NumericsMode::Fast => gram_panel_fast_seq(cols, panel, cross),
        };
        if n == 1 || self.inner_workers == 1 || k == 0 {
            return seq(cols, panel);
        }
        // cross work: eager averages (k+1)/2 columns per candidate, lazy
        // pays only the diagonal up front
        let cross_cols = match cross {
            CrossMode::Eager => (k + 1) / 2,
            CrossMode::Lazy => 1,
            CrossMode::Skip => 0,
        };
        let work_per_shard = (ell + cross_cols).max(1) * k * (cols.rows() / n);
        if work_per_shard < self.min_work_threshold() {
            return seq(cols, panel);
        }
        // ONE pool dispatch per panel pass: shard × candidate-range tiles
        // submitted shard-major, so the in-order reduction below
        // accumulates every entry's per-shard partials in ascending shard
        // order — bit-identical to gram_panel_seq (in exact mode)
        const PANEL_TILE_COLS: usize = 32;
        let mut tiles: Vec<(usize, Range<usize>)> = Vec::new();
        for s in 0..n {
            let mut c0 = 0usize;
            while c0 < k {
                let c1 = (c0 + PANEL_TILE_COLS).min(k);
                tiles.push((s, c0..c1));
                c0 = c1;
            }
        }
        let parts = self.pool.map(&tiles, |(s, cr)| {
            let a = match numerics {
                NumericsMode::Exact => gram_panel_partial(cols, panel, *s, cr.clone()),
                NumericsMode::Fast => gram_panel_partial_fast(cols, panel, *s, cr.clone()),
            };
            // eager triangles stay exact even in fast mode: the
            // off-diagonal entries feed the Theorem 4.9 append (see
            // store.rs numerics contract)
            let c = match cross {
                CrossMode::Eager => panel_cross_partial(panel, *s, cr.clone()),
                CrossMode::Lazy => match numerics {
                    NumericsMode::Exact => panel_diag_partial(panel, *s, cr.clone()),
                    NumericsMode::Fast => panel_diag_partial_fast(panel, *s, cr.clone()),
                },
                CrossMode::Skip => Vec::new(),
            };
            (a, c)
        });
        let mut atb = vec![0.0f64; ell * k];
        let mut cross_buf =
            vec![0.0f64; if cross == CrossMode::Eager { k * (k + 1) / 2 } else { 0 }];
        let mut diag = vec![0.0f64; if cross == CrossMode::Lazy { k } else { 0 }];
        for ((_, cr), (pa, pc)) in tiles.iter().zip(parts.iter()) {
            for (ci, c) in cr.clone().enumerate() {
                let dst = &mut atb[c * ell..(c + 1) * ell];
                for (d, v) in dst.iter_mut().zip(pa[ci * ell..(ci + 1) * ell].iter()) {
                    *d += *v;
                }
            }
            match cross {
                CrossMode::Eager => {
                    let mut off = 0usize;
                    for c in cr.clone() {
                        let base = c * (c + 1) / 2;
                        let dst = &mut cross_buf[base..base + c + 1];
                        for (d, v) in dst.iter_mut().zip(pc[off..off + c + 1].iter()) {
                            *d += *v;
                        }
                        off += c + 1;
                    }
                }
                CrossMode::Lazy => {
                    for (ci, c) in cr.clone().enumerate() {
                        diag[c] += pc[ci];
                    }
                }
                CrossMode::Skip => {}
            }
        }
        match cross {
            CrossMode::Lazy => PanelStats::new_lazy(ell, k, atb, diag),
            _ => PanelStats::new(ell, k, atb, cross_buf),
        }
    }

    fn transform_abs(&self, cols: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix {
        let n = cols.n_shards();
        if n == 1 || self.inner_workers == 1 {
            return transform_abs_seq(cols, c, u);
        }
        let work_per_shard = cols.len().max(1) * u.cols().max(1) * (cols.rows() / n);
        if work_per_shard < self.min_work_threshold() {
            return transform_abs_seq(cols, c, u);
        }
        let ids: Vec<usize> = (0..n).collect();
        let blocks = self.pool.map(&ids, |&s| transform_block(cols, s, c, u));
        let m = u.rows();
        let g = u.cols();
        let mut out = Matrix::zeros(m, g);
        for (s, block) in blocks.iter().enumerate() {
            let r = cols.shard_range(s);
            out.data_mut()[r.start * g..r.end * g].copy_from_slice(block);
        }
        out
    }

    fn transform_abs_into(
        &self,
        cols: &ColumnStore,
        c: &Matrix,
        u: &Matrix,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        let n = cols.n_shards();
        if n == 1 || self.inner_workers == 1 {
            return transform_abs_strided_seq(cols, c, u, out, stride, col_off);
        }
        let work_per_shard = cols.len().max(1) * u.cols().max(1) * (cols.rows() / n);
        if work_per_shard < self.min_work_threshold() {
            return transform_abs_strided_seq(cols, c, u, out, stride, col_off);
        }
        // workers can't share `&mut` slices of the strided slab without
        // unsafe, so the parallel path maps owned contiguous blocks (the
        // exact per-shard kernel) and strided-copies them in shard order
        let ids: Vec<usize> = (0..n).collect();
        let blocks = self.pool.map(&ids, |&s| transform_block(cols, s, c, u));
        let g = u.cols();
        for (s, block) in blocks.iter().enumerate() {
            let r = cols.shard_range(s);
            for (k, i) in r.enumerate() {
                let base = i * stride + col_off;
                out[base..base + g].copy_from_slice(&block[k * g..(k + 1) * g]);
            }
        }
    }

    fn name(&self) -> &'static str {
        "sharded"
    }

    fn preferred_shards(&self, m: usize) -> usize {
        // one shard per inner-budget worker, but never shard below the
        // hand-off floor — small inputs stay single-shard and
        // bit-identical to NativeBackend
        let cap = (m / self.min_rows_per_shard).max(1);
        self.inner_workers.min(cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn random_cols(rng: &mut Rng, m: usize, ell: usize) -> Vec<Vec<f64>> {
        (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect()
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn gram_stats_bitwise_equals_native_across_shard_counts() {
        // shard counts from the issue checklist, uneven m including m < shards
        property(12, |rng| {
            let ell = 1 + rng.below(6);
            let sharded = ShardedBackend::new(4);
            for &k in &[1usize, 2, 3, 7] {
                for &m in &[1usize, 3, 5, 7, 8, 41, 137] {
                    let cols = random_cols(rng, m, ell);
                    let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
                    let store = ColumnStore::from_cols(&cols, k);
                    let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
                    let (atb_s, btb_s) = sharded.gram_stats(&store, &b);
                    if bits(&atb_n) != bits(&atb_s) || btb_n.to_bits() != btb_s.to_bits() {
                        return Err(format!("bitwise mismatch at m={m} shards={k}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn forced_parallel_path_is_bitwise_identical_on_tiny_inputs() {
        // min_work 0 pins the pool fan-out even where the adaptive
        // threshold would fall back — the parallel path itself must be
        // bit-identical, not just the fallback
        property(8, |rng| {
            let forced = ShardedBackend::new(3).with_min_work(0);
            for &k in &[2usize, 3, 5] {
                for &m in &[2usize, 7, 23, 64] {
                    let cols = random_cols(rng, m, 3);
                    let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
                    let store = ColumnStore::from_cols(&cols, k);
                    let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
                    let (atb_s, btb_s) = forced.gram_stats(&store, &b);
                    if bits(&atb_n) != bits(&atb_s) || btb_n.to_bits() != btb_s.to_bits() {
                        return Err(format!("forced-parallel mismatch at m={m} shards={k}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gram_panel_forced_parallel_is_bitwise_identical_to_seq() {
        // forces the shard×candidate tile fan-out (min_work 0) across
        // shard counts and candidate counts straddling the 32-col tile
        property(8, |rng| {
            let forced = ShardedBackend::new(3).with_min_work(0);
            for &shards in &[2usize, 3, 5] {
                for &k in &[1usize, 2, 7, 33] {
                    let m = 1 + rng.below(60);
                    let ell = 1 + rng.below(4);
                    let cols = random_cols(rng, m, ell);
                    let store = ColumnStore::from_cols(&cols, shards);
                    let mut panel = CandidatePanel::new_like(&store);
                    for _ in 0..k {
                        let c: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
                        panel.push_col(&c);
                    }
                    for cross in [CrossMode::Eager, CrossMode::Lazy, CrossMode::Skip] {
                        let seq = gram_panel_seq(&store, &panel, cross);
                        let mut par =
                            forced.gram_panel(&store, &panel, cross, NumericsMode::Exact);
                        for c in 0..k {
                            if bits(seq.atb_col(c)) != bits(par.atb_col(c)) {
                                return Err(format!(
                                    "panel atb diverges at shards={shards} k={k} c={c}"
                                ));
                            }
                        }
                        match cross {
                            CrossMode::Eager => {
                                for c in 0..k {
                                    for i in 0..=c {
                                        if seq.cross_at(i, c).to_bits()
                                            != par.cross_at(i, c).to_bits()
                                        {
                                            return Err(format!(
                                                "cross diverges at shards={shards} ({i},{c})"
                                            ));
                                        }
                                    }
                                }
                            }
                            CrossMode::Lazy => {
                                if !par.is_lazy() {
                                    return Err("parallel lazy stats not lazy".into());
                                }
                                for c in 0..k {
                                    if seq.btb(c).to_bits() != par.btb(c).to_bits() {
                                        return Err(format!(
                                            "lazy diag diverges at shards={shards} c={c}"
                                        ));
                                    }
                                }
                                // lazy rows materialize on the caller's
                                // thread, bitwise equal to the seq rows
                                let mut seq = seq;
                                for i in 0..k {
                                    seq.ensure_cross_row(&panel, i);
                                    par.ensure_cross_row(&panel, i);
                                    for c in i..k {
                                        if seq.cross_at(i, c).to_bits()
                                            != par.cross_at(i, c).to_bits()
                                        {
                                            return Err(format!(
                                                "lazy row diverges at shards={shards} ({i},{c})"
                                            ));
                                        }
                                    }
                                }
                            }
                            CrossMode::Skip => {
                                if par.has_cross() || par.is_lazy() {
                                    return Err("unexpected cross block".into());
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gram_panel_issues_one_dispatch_per_call() {
        let pool = ThreadPool::new(4);
        let be = ShardedBackend::with_handle(pool.handle(), 4, 1).with_min_work(0);
        let mut rng = Rng::new(13);
        let cols = random_cols(&mut rng, 300, 4);
        let store = ColumnStore::from_cols(&cols, 4);
        let mut panel = CandidatePanel::new_like(&store);
        for _ in 0..40 {
            let c: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
            panel.push_col(&c);
        }
        let before = pool.handle().batches_dispatched();
        let _ = be.gram_panel(&store, &panel, CrossMode::Eager, NumericsMode::Exact);
        let one = pool.handle().batches_dispatched();
        assert_eq!(one - before, 1, "panel pass must be one pool dispatch");
        // the per-candidate loop over the same work is 40 dispatches
        for c in 0..panel.len() {
            let _ = be.gram_stats(&store, &panel.col(c));
        }
        let many = pool.handle().batches_dispatched();
        assert_eq!(many - one, 40);
    }

    #[test]
    fn transform_abs_matches_native_across_shard_counts() {
        property(12, |rng| {
            let ell = 1 + rng.below(4);
            let g = 1 + rng.below(4);
            let sharded = ShardedBackend::new(3);
            for &k in &[1usize, 2, 3, 7] {
                for &m in &[1usize, 3, 6, 7, 40] {
                    let cols = random_cols(rng, m, ell);
                    let store = ColumnStore::from_cols(&cols, k);
                    let mut c = Matrix::zeros(ell, g);
                    let mut u = Matrix::zeros(m, g);
                    for i in 0..ell {
                        for j in 0..g {
                            c.set(i, j, rng.normal());
                        }
                    }
                    for i in 0..m {
                        for j in 0..g {
                            u.set(i, j, rng.normal());
                        }
                    }
                    let tn = NativeBackend.transform_abs(&store, &c, &u);
                    let ts = sharded.transform_abs(&store, &c, &u);
                    for (a, b) in tn.data().iter().zip(ts.data().iter()) {
                        if (a - b).abs() > 1e-12 {
                            return Err(format!(
                                "transform mismatch {a} vs {b} at m={m} shards={k}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn repeated_calls_are_deterministic() {
        let mut rng = Rng::new(9);
        let cols = random_cols(&mut rng, 500, 5);
        let b: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        let store = ColumnStore::from_cols(&cols, 7);
        let sharded = ShardedBackend::new(4).with_min_work(0); // force the pool path
        let (atb0, btb0) = sharded.gram_stats(&store, &b);
        for _ in 0..5 {
            let (atb, btb) = sharded.gram_stats(&store, &b);
            assert_eq!(bits(&atb0), bits(&atb));
            assert_eq!(btb0.to_bits(), btb.to_bits());
        }
    }

    #[test]
    fn preferred_shards_respects_floor_and_workers() {
        let be = ShardedBackend::new(8);
        assert_eq!(be.preferred_shards(100), 1); // tiny: never shard
        assert_eq!(be.preferred_shards(MIN_ROWS_PER_SHARD * 2), 2);
        assert_eq!(be.preferred_shards(MIN_ROWS_PER_SHARD * 100), 8); // capped by workers
        assert_eq!(ShardedBackend::new(1).preferred_shards(1_000_000), 1);
        assert_eq!(be.name(), "sharded");
        // custom floor: serving-sized batches shard once m clears it
        let serve = ShardedBackend::with_min_rows(4, 512);
        assert_eq!(serve.preferred_shards(256), 1);
        assert_eq!(serve.preferred_shards(1024), 2);
        assert_eq!(serve.preferred_shards(4096), 4);
    }

    #[test]
    fn boxed_policy_selects_backend_by_worker_count() {
        assert_eq!(ShardedBackend::boxed_for(1).name(), "native");
        assert_eq!(ShardedBackend::boxed_for(4).name(), "sharded");
        assert_eq!(ShardedBackend::boxed_with_min_rows(0, 64).name(), "native");
        assert_eq!(ShardedBackend::boxed_with_min_rows(2, 64).name(), "sharded");
    }

    #[test]
    fn shared_handle_backends_draw_from_one_pool() {
        let pool = ThreadPool::new(4);
        let (outer, inner) = pool.handle().budget_split(2);
        assert_eq!((outer, inner), (2, 2));
        let a = ShardedBackend::with_handle(pool.handle(), inner, 64).with_min_work(0);
        let b = ShardedBackend::with_handle(pool.handle(), inner, 64).with_min_work(0);
        assert_eq!(a.workers(), 2);
        assert_eq!(
            ShardedBackend::boxed_with_handle(pool.handle(), 1, 64).name(),
            "native"
        );
        assert_eq!(
            ShardedBackend::boxed_with_handle(pool.handle(), 3, 64).name(),
            "sharded"
        );
        // both backends compute correctly over the shared queue
        let mut rng = Rng::new(11);
        let cols = random_cols(&mut rng, 200, 4);
        let v: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
        let store = ColumnStore::from_cols(&cols, 3);
        let (atb_a, btb_a) = a.gram_stats(&store, &v);
        let (atb_b, btb_b) = b.gram_stats(&store, &v);
        let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &v);
        assert_eq!(bits(&atb_a), bits(&atb_n));
        assert_eq!(bits(&atb_b), bits(&atb_n));
        assert_eq!(btb_a.to_bits(), btb_n.to_bits());
        assert_eq!(btb_b.to_bits(), btb_n.to_bits());
    }

    #[test]
    fn min_work_threshold_prefers_override() {
        let be = ShardedBackend::new(2).with_min_work(123);
        assert_eq!(be.min_work_threshold(), 123);
        let be = ShardedBackend::new(2);
        let v = be.min_work_threshold();
        assert!((1usize << 12..=1usize << 20).contains(&v), "calibrated threshold {v}");
    }
}

//! The data plane: sharded column storage + streaming compute backends
//! for the O(m·ℓ) hot path.
//!
//! OAVI touches the full data set only through two kernels:
//!
//! 1. **gram_stats** — `(Aᵀb, bᵀb)` for a candidate column b (per border
//!    term; the dominant training cost), and
//! 2. **transform_abs** — the (FT) feature map `|A·C + U|` (test time).
//!
//! # Layering (store → backend → driver)
//!
//! * [`ColumnStore`] (`store.rs`) owns the evaluation columns in
//!   contiguous **row-sharded** blocks and is the only column currency
//!   above `linalg`: the OAVI/ABM drivers append candidate columns into
//!   it, `poly` evaluates term sets into it, `ordering` computes Pearson
//!   statistics from it.  The per-shard kernels (`gram_partial`,
//!   `transform_block`) live next to the store so every backend runs the
//!   same per-shard code.
//! * [`ComputeBackend`] (this file) is the execution strategy over a
//!   store.  [`NativeBackend`] reduces the shards sequentially and is the
//!   correctness reference; [`ShardedBackend`] (`sharded.rs`) maps shards
//!   onto a [`crate::coordinator::pool::ThreadPool`] and reduces partials
//!   in shard order — bit-identical to native for a fixed shard count,
//!   wall-clock ≈ linear in m / workers.
//! * Drivers ([`crate::oavi::Oavi`], [`crate::baselines::abm::Abm`], the
//!   pipeline transform) ask the backend for its
//!   [`ComputeBackend::preferred_shards`] when building stores, so the
//!   intra-fit parallelism knob travels with the backend, not the config.
//!
//! # The `!Send` trait vs `Send` shard workers
//!
//! The trait is deliberately NOT `Send`/`Sync`: the `xla` crate's PJRT
//! handles are `Rc`-based, so a backend must stay on the thread that made
//! it.  Cross-thread parallelism happens either **above** the trait (one
//! backend per job — grid search, per-class fits) or **below** it (shard
//! workers inside `ShardedBackend` see only `&[f64]` slices and the
//! plain-data store, both `Sync`).  Nothing ever shares a backend across
//! threads.
//!
//! # Where PJRT fits
//!
//! [`crate::runtime::XlaBackend`] implements the same trait by tiling
//! each shard through the AOT-compiled Pallas artifacts (f32, padded to
//! the artifact shapes) and must agree with the native path within f32
//! tolerance — enforced by `rust/tests/runtime_parity.rs`, which also
//! pins the native↔sharded bit-for-bit contract.

pub mod sharded;
pub mod store;

pub use sharded::ShardedBackend;
pub use store::ColumnStore;

use crate::backend::store::{gram_stats_seq, transform_abs_seq};
use crate::linalg::dense::Matrix;

/// Streaming compute abstraction over the per-sample hot loops.
///
/// Deliberately NOT `Send`/`Sync` (see module docs): parallelism happens
/// above this trait (one backend per job) or below it (shard workers).
pub trait ComputeBackend {
    /// `(Aᵀb, bᵀb)` where A's columns live in `cols` and b is `b_col`.
    fn gram_stats(&self, cols: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64);

    /// `|A·C + U|` where A is m×ℓ (the store), C is ℓ×g, U is m×g.
    /// Row-major output m×g.
    fn transform_abs(&self, cols: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix;

    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str;

    /// How many row shards this backend wants drivers to build
    /// [`ColumnStore`]s with for an m-row fit.  Results are deterministic
    /// per shard count, so this is a reproducibility-relevant knob:
    /// sequential backends return 1.
    fn preferred_shards(&self, m: usize) -> usize {
        let _ = m;
        1
    }
}

/// Plain-Rust reference backend: the shared per-shard kernels reduced
/// sequentially in shard order.
//
// Bench gate (ISSUE satellite): the old transform_abs inner loop skipped
// `a_ij == 0.0` entries.  Verdict from `rust/benches/micro_runtime.rs`
// (`transform_branch_gate` section, dense [0,1) columns, m = 65536): the
// branch blocks vectorization of the g-loop and real evaluation columns
// are essentially never exactly 0 (the constant column is all ones), so
// the branchless kernel wins on the dense generator matrices the (FT)
// transform actually sees.  The skip only pays on artificially sparse
// columns, which this data plane does not produce.  The kernel in
// `store::transform_block` is therefore branchless; re-run the gate
// before reintroducing the skip.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn gram_stats(&self, cols: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64) {
        gram_stats_seq(cols, b_col)
    }

    fn transform_abs(&self, cols: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix {
        transform_abs_seq(cols, c, u)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;
    use crate::util::proptest::{all_close, property};

    #[test]
    fn gram_stats_matches_definition() {
        property(16, |rng| {
            let m = 10 + rng.below(40);
            let ell = 1 + rng.below(6);
            let shards = 1 + rng.below(4);
            let cols: Vec<Vec<f64>> =
                (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
            let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let store = ColumnStore::from_cols(&cols, shards);
            let (atb, btb) = NativeBackend.gram_stats(&store, &b);
            let expect: Vec<f64> = cols.iter().map(|c| dot(c, &b)).collect();
            all_close(&atb, &expect, 1e-12, "atb")?;
            crate::util::proptest::close(btb, dot(&b, &b), 1e-12, "btb")
        });
    }

    #[test]
    fn transform_matches_manual() {
        property(16, |rng| {
            let m = 5 + rng.below(20);
            let ell = 1 + rng.below(4);
            let g = 1 + rng.below(4);
            let shards = 1 + rng.below(4);
            let cols: Vec<Vec<f64>> =
                (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
            let store = ColumnStore::from_cols(&cols, shards);
            let mut c = Matrix::zeros(ell, g);
            let mut u = Matrix::zeros(m, g);
            for i in 0..ell {
                for j in 0..g {
                    c.set(i, j, rng.normal());
                }
            }
            for i in 0..m {
                for j in 0..g {
                    u.set(i, j, rng.normal());
                }
            }
            let out = NativeBackend.transform_abs(&store, &c, &u);
            for i in 0..m {
                for j in 0..g {
                    let mut v = u.get(i, j);
                    for (k, col) in cols.iter().enumerate() {
                        v += col[i] * c.get(k, j);
                    }
                    if (out.get(i, j) - v.abs()).abs() > 1e-10 {
                        return Err(format!("({i},{j}): {} vs {}", out.get(i, j), v.abs()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn backend_name_and_default_shards() {
        assert_eq!(NativeBackend.name(), "native");
        assert_eq!(NativeBackend.preferred_shards(1_000_000), 1);
    }
}

//! The data plane: sharded column storage + streaming compute backends
//! for the O(m·ℓ) hot path.
//!
//! OAVI touches the full data set only through three kernels:
//!
//! 1. **gram_panel** — the **primary training kernel**: one
//!    [`CandidatePanel`] holds every degree-d border candidate, and a
//!    single pass per degree produces the ℓ×k store-vs-panel block plus
//!    the panel cross-Gram ([`PanelStats`]).  The cross part is
//!    mode-selected ([`CrossMode`]): `Eager` materializes the full k×k
//!    upper triangle in the pass, `Lazy` computes only the diagonal up
//!    front and materializes row i on demand
//!    (`PanelStats::ensure_cross_row`) when candidate i is *accepted* —
//!    ψ-regimes where most candidates vanish skip the O(k²) triangle
//!    they never read.  The drivers then walk the candidates in DegLex
//!    order resolving the within-degree dependence from the cached cross
//!    entries — O(1) per (accepted, later-candidate) pair, no extra data
//!    pass.  Panels are chunked under a memory budget
//!    ([`CandidatePanel::budget_cols`]), and the whole exact pass is
//!    **bitwise identical** to the legacy per-candidate flow below
//!    because every Gram entry shares one per-entry dot discipline (see
//!    `store.rs`).  [`NumericsMode::Fast`] is the explicitly opt-in
//!    exception: f32-accumulated `atb`/diagonal under a driver-measured
//!    error budget (off-diagonal cross rows stay exact — they feed the
//!    Theorem 4.9 inverse-Gram append).
//! 2. **gram_stats** — `(Aᵀb, bᵀb)` for a single candidate column b:
//!    the legacy per-candidate kernel, still the right shape for
//!    serving-time queries and kept as the bitwise reference the panel
//!    parity suite compares against.
//! 3. **transform_abs** — the (FT) feature map `|A·C + U|` (test time).
//!
//! # Layering (store → backend → driver, over one persistent pool)
//!
//! * [`ShardBacking`] (`backing.rs`) is the **physical layer under the
//!   store**: where each shard's column block lives.  Memory backing
//!   (owned `Vec<f64>`, the default — bitwise-unchanged legacy layout)
//!   or spill backing ([`StoreMode::Spill`]: one on-disk segment per
//!   shard plus an LRU resident pool under a byte budget, with
//!   load/reload/eviction counters).  Kernels read blocks through a
//!   per-(shard, pass) [`ShardLease`] — a free borrow on memory
//!   backings, an `Arc` pin on spill backings, so eviction can never
//!   invalidate a slice a kernel is reading.  **Lease lifetime rules:**
//!   acquire once per shard loop, read columns via `lease.col(j)`, drop
//!   before any `push_col` on the same store (appends widen the block),
//!   never cache a lease across kernel passes (each pinned block is
//!   charged against the resident budget while held).
//! * [`ColumnStore`] (`store.rs`) owns the evaluation columns in
//!   contiguous **row-sharded** blocks over a pluggable backing and is
//!   the only column currency above `linalg`: the OAVI/ABM drivers
//!   append candidate columns into it, `poly` evaluates term sets into
//!   it, `ordering` computes Pearson statistics from it.  The per-shard
//!   kernels (`gram_partial`, `transform_block`) live next to the store
//!   so every backend runs the same per-shard code, and acquire their
//!   leases internally — backends above them are backing-agnostic, and
//!   the exact path stays bitwise identical across backings
//!   (`rust/tests/storage_parity.rs`).
//! * [`ComputeBackend`] (this file) is the execution strategy over a
//!   store.  [`NativeBackend`] reduces the shards sequentially and is the
//!   correctness reference; [`ShardedBackend`] (`sharded.rs`) maps shards
//!   onto the **persistent** [`crate::coordinator::pool::ThreadPool`]
//!   (workers spawned once at pool construction, jobs over an MPMC
//!   queue — no per-call spawn/join) and reduces partials in shard
//!   order — bit-identical to native for a fixed shard count,
//!   wall-clock ≈ linear in m / workers.
//! * Drivers ([`crate::oavi::Oavi`], [`crate::baselines::abm::Abm`], the
//!   pipeline transform) ask the backend for its
//!   [`ComputeBackend::preferred_shards`] when building stores, so the
//!   intra-fit parallelism knob travels with the backend, not the config.
//!
//! # Pool lifecycle, budget split, adaptive threshold
//!
//! One [`crate::coordinator::pool::ThreadPool`] per process-level entry
//! point (CLI `--workers`, grid search, serving) is the intended shape;
//! everything below it shares the pool through a cheaply clonable
//! [`crate::coordinator::pool::PoolHandle`]:
//!
//! * **Lifecycle** — workers live from `ThreadPool::new` until drop
//!   (drain + join).  A backend built with [`ShardedBackend::new`] owns
//!   a private pool for standalone use; one built with
//!   [`ShardedBackend::with_handle`] borrows the shared queue and spawns
//!   nothing.
//! * **Budget split** — two-level parallelism composes the outer job
//!   axis (grid points, per-class fits) with the inner shard axis on the
//!   same workers: `PoolHandle::budget_split(outer_jobs)` yields
//!   `(outer, inner)` with `outer × inner ≤ workers`, and each outer job
//!   builds its backend with the `inner` budget.  The budget acts
//!   through **store sizing** (`preferred_shards` caps at it); the
//!   kernels submit one job per store shard, so an externally sized
//!   store can enqueue more jobs than the budget — excess jobs queue on
//!   the shared workers rather than spawning threads.  Nested submission
//!   is deadlock-free because a submitter executes its own queued jobs
//!   in place (work stealing).
//! * **Adaptive threshold** — the old hard-coded `MIN_WORK_PER_SHARD`
//!   constant is replaced by `PoolHandle::adaptive_min_work()`,
//!   calibrated once per pool (measured job hand-off cost over the live
//!   queue vs. multiply-add throughput, clamped to `[2^12, 2^20]`).
//!   Below it `ShardedBackend` takes the sequential path — invisible in
//!   results, since both paths are bit-identical.
//!
//! # The `!Send` trait vs `Send` shard workers
//!
//! The trait is deliberately NOT `Send`/`Sync`: the `xla` crate's PJRT
//! handles are `Rc`-based, so a backend must stay on the thread that made
//! it.  Cross-thread parallelism happens either **above** the trait (one
//! backend per job — grid search, per-class fits) or **below** it (shard
//! workers inside `ShardedBackend` see only `&[f64]` slices and the
//! plain-data store, both `Sync`).  Nothing ever shares a backend across
//! threads — only `PoolHandle`s cross threads, and each job constructs
//! its own backend around one.
//!
//! # Where PJRT fits
//!
//! [`crate::runtime::XlaBackend`] implements the same trait by tiling
//! each shard through the AOT-compiled Pallas artifacts (f32, padded to
//! the artifact shapes) and must agree with the native path within f32
//! tolerance — enforced by `rust/tests/runtime_parity.rs`, which also
//! pins the native↔sharded bit-for-bit contract.

pub mod backing;
pub mod sharded;
pub mod store;

pub use backing::{BackingCounters, FileBacking, ShardBacking, ShardLease, StoreMode};
pub use sharded::ShardedBackend;
pub use store::{CandidatePanel, ColumnStore, CrossMode, NumericsMode, PanelRecipe, PanelStats};

use crate::backend::store::{
    gram_panel_fast_seq, gram_panel_seq, gram_stats_seq, transform_abs_seq,
    transform_abs_strided_seq,
};
use crate::linalg::dense::Matrix;

/// Streaming compute abstraction over the per-sample hot loops.
///
/// Deliberately NOT `Send`/`Sync` (see module docs): parallelism happens
/// above this trait (one backend per job) or below it (shard workers).
pub trait ComputeBackend {
    /// `(Aᵀb, bᵀb)` where A's columns live in `cols` and b is `b_col`.
    fn gram_stats(&self, cols: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64);

    /// Degree-batched panel kernel: the ℓ×k block `⟨store_j, panel_c⟩`
    /// plus the panel cross-Gram selected by `cross` (full upper
    /// triangle, diagonal-only with lazy rows, or nothing), reduced in
    /// shard order.  The default is the sequential reference reduction;
    /// parallel backends may tile `(shard × candidate range)` but — in
    /// [`NumericsMode::Exact`] — must reproduce its bits exactly
    /// (per-entry dot discipline + shard-order accumulation).
    /// [`NumericsMode::Fast`] has no bitwise contract; the driver
    /// measures its error budget against the f64 reference.
    fn gram_panel(
        &self,
        cols: &ColumnStore,
        panel: &CandidatePanel,
        cross: CrossMode,
        numerics: NumericsMode,
    ) -> PanelStats {
        match numerics {
            NumericsMode::Exact => gram_panel_seq(cols, panel, cross),
            NumericsMode::Fast => gram_panel_fast_seq(cols, panel, cross),
        }
    }

    /// `|A·C + U|` where A is m×ℓ (the store), C is ℓ×g, U is m×g.
    /// Row-major output m×g.
    fn transform_abs(&self, cols: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix;

    /// [`ComputeBackend::transform_abs`] written into a column range of a
    /// caller-owned m×`stride` slab: row `i`'s g-wide block lands at
    /// `out[i*stride + col_off ..]`.  Lets the pipeline concatenate
    /// per-class (FT) blocks without intermediate block matrices.  The
    /// written cells must be bitwise identical to `transform_abs`'s; the
    /// default materializes the block and copies it, sequential backends
    /// override with direct strided writes.
    fn transform_abs_into(
        &self,
        cols: &ColumnStore,
        c: &Matrix,
        u: &Matrix,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        let block = self.transform_abs(cols, c, u);
        let g = u.cols();
        for i in 0..u.rows() {
            let base = i * stride + col_off;
            out[base..base + g].copy_from_slice(block.row(i));
        }
    }

    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str;

    /// How many row shards this backend wants drivers to build
    /// [`ColumnStore`]s with for an m-row fit.  Results are deterministic
    /// per shard count, so this is a reproducibility-relevant knob:
    /// sequential backends return 1.
    fn preferred_shards(&self, m: usize) -> usize {
        let _ = m;
        1
    }
}

/// Plain-Rust reference backend: the shared per-shard kernels reduced
/// sequentially in shard order.
//
// Bench gate (ISSUE satellite): the old transform_abs inner loop skipped
// `a_ij == 0.0` entries.  Verdict from `rust/benches/micro_runtime.rs`
// (`transform_branch_gate` section, dense [0,1) columns, m = 65536): the
// branch blocks vectorization of the g-loop and real evaluation columns
// are essentially never exactly 0 (the constant column is all ones), so
// the branchless kernel wins on the dense generator matrices the (FT)
// transform actually sees.  The skip only pays on artificially sparse
// columns, which this data plane does not produce.  The kernel in
// `store::transform_block` is therefore branchless; re-run the gate
// before reintroducing the skip.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn gram_stats(&self, cols: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64) {
        gram_stats_seq(cols, b_col)
    }

    fn transform_abs(&self, cols: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix {
        transform_abs_seq(cols, c, u)
    }

    fn transform_abs_into(
        &self,
        cols: &ColumnStore,
        c: &Matrix,
        u: &Matrix,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        transform_abs_strided_seq(cols, c, u, out, stride, col_off)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Adapter pinning [`ComputeBackend::preferred_shards`] to a fixed value
/// while delegating both kernels untouched.
///
/// Two *execution strategies* (sequential native vs pool-sharded) are
/// bit-identical only on byte-identical store layouts; pinning the shard
/// count is how parity tests and reproducibility-sensitive callers (the
/// two-level grid search's `pin_store_shards` knob) guarantee that
/// precondition regardless of each backend's own sizing policy.
pub struct PinnedShards {
    inner: Box<dyn ComputeBackend>,
    shards: usize,
}

impl PinnedShards {
    /// Pin `inner`'s store sizing to `shards` (clamped to ≥ 1).
    pub fn new(inner: Box<dyn ComputeBackend>, shards: usize) -> Self {
        PinnedShards { inner, shards: shards.max(1) }
    }
}

impl ComputeBackend for PinnedShards {
    fn gram_stats(&self, cols: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64) {
        self.inner.gram_stats(cols, b_col)
    }

    fn gram_panel(
        &self,
        cols: &ColumnStore,
        panel: &CandidatePanel,
        cross: CrossMode,
        numerics: NumericsMode,
    ) -> PanelStats {
        // delegate (NOT the trait default): pinned-sharded parity runs
        // must exercise the inner backend's tiled panel path
        self.inner.gram_panel(cols, panel, cross, numerics)
    }

    fn transform_abs(&self, cols: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix {
        self.inner.transform_abs(cols, c, u)
    }

    fn transform_abs_into(
        &self,
        cols: &ColumnStore,
        c: &Matrix,
        u: &Matrix,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        self.inner.transform_abs_into(cols, c, u, out, stride, col_off)
    }

    fn name(&self) -> &'static str {
        "pinned"
    }

    fn preferred_shards(&self, _m: usize) -> usize {
        self.shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;
    use crate::util::proptest::{all_close, property};

    #[test]
    fn gram_stats_matches_definition() {
        property(16, |rng| {
            let m = 10 + rng.below(40);
            let ell = 1 + rng.below(6);
            let shards = 1 + rng.below(4);
            let cols: Vec<Vec<f64>> =
                (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
            let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let store = ColumnStore::from_cols(&cols, shards);
            let (atb, btb) = NativeBackend.gram_stats(&store, &b);
            let expect: Vec<f64> = cols.iter().map(|c| dot(c, &b)).collect();
            all_close(&atb, &expect, 1e-12, "atb")?;
            crate::util::proptest::close(btb, dot(&b, &b), 1e-12, "btb")
        });
    }

    #[test]
    fn transform_matches_manual() {
        property(16, |rng| {
            let m = 5 + rng.below(20);
            let ell = 1 + rng.below(4);
            let g = 1 + rng.below(4);
            let shards = 1 + rng.below(4);
            let cols: Vec<Vec<f64>> =
                (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
            let store = ColumnStore::from_cols(&cols, shards);
            let mut c = Matrix::zeros(ell, g);
            let mut u = Matrix::zeros(m, g);
            for i in 0..ell {
                for j in 0..g {
                    c.set(i, j, rng.normal());
                }
            }
            for i in 0..m {
                for j in 0..g {
                    u.set(i, j, rng.normal());
                }
            }
            let out = NativeBackend.transform_abs(&store, &c, &u);
            for i in 0..m {
                for j in 0..g {
                    let mut v = u.get(i, j);
                    for (k, col) in cols.iter().enumerate() {
                        v += col[i] * c.get(k, j);
                    }
                    if (out.get(i, j) - v.abs()).abs() > 1e-10 {
                        return Err(format!("({i},{j}): {} vs {}", out.get(i, j), v.abs()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn backend_name_and_default_shards() {
        assert_eq!(NativeBackend.name(), "native");
        assert_eq!(NativeBackend.preferred_shards(1_000_000), 1);
    }

    #[test]
    fn gram_panel_default_matches_per_candidate_gram_stats() {
        let mut rng = crate::util::rng::Rng::new(3);
        let m = 60;
        let cols: Vec<Vec<f64>> =
            (0..4).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
        let store = ColumnStore::from_cols(&cols, 3);
        let cands: Vec<Vec<f64>> =
            (0..5).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
        let mut panel = CandidatePanel::new_like(&store);
        for c in &cands {
            panel.push_col(c);
        }
        let ps = NativeBackend.gram_panel(&store, &panel, CrossMode::Eager, NumericsMode::Exact);
        for (c, cand) in cands.iter().enumerate() {
            let (atb, btb) = NativeBackend.gram_stats(&store, cand);
            assert_eq!(atb, ps.atb_col(c));
            assert_eq!(btb.to_bits(), ps.btb(c).to_bits());
        }
        // pinned adapter delegates the panel kernel too
        let pinned = PinnedShards::new(Box::new(NativeBackend), 3);
        let pp = pinned.gram_panel(&store, &panel, CrossMode::Eager, NumericsMode::Exact);
        assert_eq!(pp.atb_col(2), ps.atb_col(2));
        assert_eq!(pp.cross_at(1, 3).to_bits(), ps.cross_at(1, 3).to_bits());
    }

    #[test]
    fn pinned_shards_delegates_kernels_and_pins_sizing() {
        let pinned = PinnedShards::new(Box::new(NativeBackend), 5);
        assert_eq!(pinned.preferred_shards(10), 5);
        assert_eq!(pinned.preferred_shards(1_000_000), 5);
        assert_eq!(pinned.name(), "pinned");
        assert_eq!(PinnedShards::new(Box::new(NativeBackend), 0).preferred_shards(7), 1);
        let cols = vec![vec![1.0, 2.0, 3.0], vec![0.5, -1.0, 2.0]];
        let b = vec![1.0, 1.0, 1.0];
        let store = ColumnStore::from_cols(&cols, 2);
        let (atb_p, btb_p) = pinned.gram_stats(&store, &b);
        let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
        assert_eq!(atb_p, atb_n);
        assert_eq!(btb_p, btb_n);
    }
}

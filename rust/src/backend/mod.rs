//! Compute backends for the O(m·ℓ) streaming hot path.
//!
//! OAVI touches the full data set only through two operations:
//!
//! 1. **gram_stats** — `(Aᵀb, bᵀb)` for a candidate column b (per border
//!    term; the dominant training cost), and
//! 2. **transform** — the (FT) feature map `|A·C + U|` (test time).
//!
//! [`NativeBackend`] implements both in plain Rust (f64) and is the
//! correctness reference.  [`crate::runtime::XlaBackend`] dispatches to the
//! AOT-compiled Pallas artifacts via PJRT (f32, tiled to the artifact
//! shapes) and must agree with the native path within f32 tolerance —
//! enforced by `rust/tests/runtime_parity.rs`.

use crate::linalg::dense::Matrix;
use crate::linalg::dot;

/// Streaming compute abstraction over the per-sample hot loops.
///
/// Deliberately NOT `Send`/`Sync`: the `xla` crate's PJRT handles are
/// `Rc`-based.  Cross-thread parallelism in this codebase happens at the
/// job level (one backend per worker), never by sharing a backend.
pub trait ComputeBackend {
    /// `(Aᵀb, bᵀb)` where A's columns are `cols` and b is `b_col`.
    fn gram_stats(&self, cols: &[Vec<f64>], b_col: &[f64]) -> (Vec<f64>, f64);

    /// `|A·C + U|` where A is m×ℓ (columns `cols`), C is ℓ×g, U is m×g.
    /// Row-major output m×g.
    fn transform_abs(&self, cols: &[Vec<f64>], c: &Matrix, u: &Matrix) -> Matrix;

    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str;
}

/// Plain-Rust reference backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn gram_stats(&self, cols: &[Vec<f64>], b_col: &[f64]) -> (Vec<f64>, f64) {
        // Perf pass #2 (EXPERIMENTS.md §Perf): for DRAM-resident columns,
        // process four at a time so each pass over the (cache-missing) b
        // column amortizes across four dot products — b traffic drops 4×.
        // For cache-resident m the simple vectorized dot is faster, so the
        // blocked path only kicks in past the last-level-cache scale.
        let m = b_col.len();
        const BLOCK_THRESHOLD_BYTES: usize = 4 << 20; // ~LLC slice
        if m * std::mem::size_of::<f64>() < BLOCK_THRESHOLD_BYTES {
            let atb: Vec<f64> = cols.iter().map(|c| dot(c, b_col)).collect();
            return (atb, dot(b_col, b_col));
        }
        let mut atb = vec![0.0f64; cols.len()];
        let mut j = 0;
        while j + 4 <= cols.len() {
            let (c0, c1, c2, c3) = (&cols[j], &cols[j + 1], &cols[j + 2], &cols[j + 3]);
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for i in 0..m {
                let bi = b_col[i];
                s0 += c0[i] * bi;
                s1 += c1[i] * bi;
                s2 += c2[i] * bi;
                s3 += c3[i] * bi;
            }
            atb[j] = s0;
            atb[j + 1] = s1;
            atb[j + 2] = s2;
            atb[j + 3] = s3;
            j += 4;
        }
        for (jj, c) in cols.iter().enumerate().skip(j) {
            atb[jj] = dot(c, b_col);
        }
        (atb, dot(b_col, b_col))
    }

    fn transform_abs(&self, cols: &[Vec<f64>], c: &Matrix, u: &Matrix) -> Matrix {
        let m = u.rows();
        let g = u.cols();
        debug_assert_eq!(c.rows(), cols.len());
        debug_assert_eq!(c.cols(), g);
        let mut out = u.clone();
        // out += A @ C, column-of-A major: cache-friendly over the long m axis
        for (j, col) in cols.iter().enumerate() {
            let crow = c.row(j);
            for i in 0..m {
                let a_ij = col[i];
                if a_ij == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for (o, ck) in orow.iter_mut().zip(crow.iter()) {
                    *o += a_ij * ck;
                }
            }
        }
        for v in out.data_mut().iter_mut() {
            *v = v.abs();
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{all_close, property};

    #[test]
    fn gram_stats_matches_definition() {
        property(16, |rng| {
            let m = 10 + rng.below(40);
            let ell = 1 + rng.below(6);
            let cols: Vec<Vec<f64>> =
                (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
            let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let (atb, btb) = NativeBackend.gram_stats(&cols, &b);
            let expect: Vec<f64> = cols.iter().map(|c| dot(c, &b)).collect();
            all_close(&atb, &expect, 1e-12, "atb")?;
            crate::util::proptest::close(btb, dot(&b, &b), 1e-12, "btb")
        });
    }

    #[test]
    fn transform_matches_manual() {
        property(16, |rng| {
            let m = 5 + rng.below(20);
            let ell = 1 + rng.below(4);
            let g = 1 + rng.below(4);
            let cols: Vec<Vec<f64>> =
                (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
            let mut c = Matrix::zeros(ell, g);
            let mut u = Matrix::zeros(m, g);
            for i in 0..ell {
                for j in 0..g {
                    c.set(i, j, rng.normal());
                }
            }
            for i in 0..m {
                for j in 0..g {
                    u.set(i, j, rng.normal());
                }
            }
            let out = NativeBackend.transform_abs(&cols, &c, &u);
            for i in 0..m {
                for j in 0..g {
                    let mut v = u.get(i, j);
                    for (k, col) in cols.iter().enumerate() {
                        v += col[i] * c.get(k, j);
                    }
                    if (out.get(i, j) - v.abs()).abs() > 1e-10 {
                        return Err(format!("({i},{j}): {} vs {}", out.get(i, j), v.abs()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn backend_name() {
        assert_eq!(NativeBackend.name(), "native");
    }
}

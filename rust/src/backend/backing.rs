//! Pluggable shard backing for [`crate::backend::ColumnStore`]: where a
//! shard's column block physically lives.
//!
//! Two implementations:
//!
//! * [`ShardBacking::Memory`] — the historical owned-`Vec<f64>` blocks.
//!   Default, zero-overhead, bitwise-unchanged from before this layer
//!   existed: leases borrow the block directly.
//! * [`ShardBacking::Spill`] — each shard's block lives in an on-disk
//!   [`crate::storage::segment::Segment`] (column-major little-endian
//!   f64; one file per shard, so every block starts page-aligned).  A
//!   bounded **resident pool** keeps recently-used blocks decoded in
//!   RAM under a configurable byte budget with LRU eviction; loads,
//!   reloads, evictions, and the peak resident footprint are counted.
//!
//! # Chunk-lease lifetime rules
//!
//! Kernels never hold raw `&[f64]` borrows into evictable blocks.
//! Access goes through a [`ShardLease`] acquired per (shard, kernel
//! pass):
//!
//! * a **memory** lease is a plain borrow of the shard's `Vec` — free;
//! * a **spill** lease clones the block's `Arc`, *pinning* it: eviction
//!   only drops the pool's reference, so an outstanding lease keeps its
//!   block alive (and that block's bytes are charged to the pool until
//!   every lease drops — hold leases for one kernel pass, not across
//!   passes).
//!
//! Acquire the lease once per shard loop, not per column: each spill
//! acquisition takes the pool lock and may touch disk.  Never hold a
//! lease across a mutation of the same store (`push_col`) — appends
//! widen the block, so the lease would see the pre-append width (the
//! borrow checker enforces this for memory leases; spill leases get the
//! same rule by convention).
//!
//! # Why the exact path stays bitwise identical
//!
//! The backing changes *where bytes live*, never what they are: the
//! le-f64 encoding round-trips every bit pattern, and the kernels in
//! `store.rs` run the identical per-entry dot discipline over the
//! leased slices.  `tests/storage_parity.rs` pins this at fit level.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{AviError, Result};
use crate::storage::segment::Segment;

/// Where a [`crate::backend::ColumnStore`]'s shard blocks live.
/// `Copy` so it rides inside [`crate::oavi::OaviConfig`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreMode {
    /// Owned in-memory blocks (default; bitwise-identical legacy path).
    Memory,
    /// File-backed segments with an LRU resident pool capped at
    /// `budget_bytes`.  The spill directory is an ephemeral per-process
    /// temp dir, cleaned up when the store drops.
    Spill {
        /// Resident-pool byte budget.  Honored as a hard cap on the
        /// pool's peak footprint whenever each individual block fits
        /// within it (a single over-budget block still loads — the
        /// alternative is refusing the fit).
        budget_bytes: usize,
    },
}

impl StoreMode {
    /// Spill mode with a budget in MiB (CLI surface).
    pub fn spill_mb(mb: usize) -> StoreMode {
        StoreMode::Spill { budget_bytes: mb.saturating_mul(1 << 20).max(1) }
    }

    pub fn is_spill(&self) -> bool {
        matches!(self, StoreMode::Spill { .. })
    }

    /// Stable name for reports/CLI (`mem` / `mmap`).
    pub fn as_str(&self) -> &'static str {
        match self {
            StoreMode::Memory => "mem",
            StoreMode::Spill { .. } => "mmap",
        }
    }
}

impl Default for StoreMode {
    fn default() -> Self {
        StoreMode::Memory
    }
}

/// One shard's owned in-memory column block (column-major, `rows` per
/// column).
#[derive(Clone, Debug)]
pub struct MemShard {
    pub(crate) rows: usize,
    pub(crate) data: Vec<f64>,
}

impl MemShard {
    pub(crate) fn new(rows: usize) -> MemShard {
        MemShard { rows, data: Vec::new() }
    }
}

/// Read guard over one shard's column block for one kernel pass.
///
/// See the module docs for lifetime rules.  `col(j)` is the only read
/// surface; it returns the same bits regardless of backing.
pub enum ShardLease<'a> {
    /// Borrowed in-memory block.
    Mem { data: &'a [f64], rows: usize },
    /// Pinned resident block (eviction can't free it while held).
    Spill { block: Arc<Vec<f64>>, rows: usize },
}

impl ShardLease<'_> {
    /// Column `j` of the leased block.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        match self {
            ShardLease::Mem { data, rows } => &data[j * rows..(j + 1) * rows],
            ShardLease::Spill { block, rows } => &block[j * rows..(j + 1) * rows],
        }
    }

    /// Rows in this shard.
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            ShardLease::Mem { rows, .. } => *rows,
            ShardLease::Spill { rows, .. } => *rows,
        }
    }
}

/// Snapshot of a spill backing's activity counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackingCounters {
    /// Disk→pool block loads (first loads + reloads).
    pub loads: u64,
    /// Loads of a block that had been resident before (evicted or
    /// invalidated by an append since).
    pub reloads: u64,
    /// LRU evictions under budget pressure.
    pub evictions: u64,
    /// Bytes currently charged to the resident pool.
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes`.
    pub peak_resident_bytes: u64,
    /// Configured budget.
    pub budget_bytes: u64,
}

/// LRU resident pool state (all under one mutex so evict-before-insert
/// accounting is atomic — the peak-≤-budget invariant depends on it).
#[derive(Debug, Default)]
struct ResidentPool {
    /// Per-shard resident block, `None` when spilled.
    blocks: Vec<Option<Arc<Vec<f64>>>>,
    /// Has shard `s` ever been loaded (distinguishes load vs reload)?
    ever_loaded: Vec<bool>,
    /// Shard ids, least-recently-used first.
    lru: Vec<usize>,
    /// Bytes held by `blocks` (pool's own references only).
    resident_bytes: usize,
    /// Reusable byte buffer for segment reads.
    scratch: Vec<u8>,
}

impl ResidentPool {
    fn touch(&mut self, s: usize) {
        if let Some(pos) = self.lru.iter().position(|&x| x == s) {
            self.lru.remove(pos);
        }
        self.lru.push(s);
    }
}

static SPILL_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// File-backed shard storage: one segment per shard plus the bounded
/// resident pool.  `Sync` (mutex + atomics + per-segment locks) so pool
/// workers can lease concurrently; shared via `Arc` inside
/// [`ShardBacking::Spill`], so cloning a spilled store shares segments
/// (clone-then-append would corrupt the sibling — working stores are
/// never cloned; manifest-opened stores are read-only).
#[derive(Debug)]
pub struct FileBacking {
    dir: PathBuf,
    /// Ephemeral spill dirs are removed on drop; manifest dirs are not.
    ephemeral: bool,
    /// Manifest-opened backings refuse appends (they would invalidate
    /// the recorded checksums).
    read_only: bool,
    budget_bytes: usize,
    /// Rows per shard (fixed at construction).
    rows: Vec<usize>,
    segs: Vec<Segment>,
    pool: Mutex<ResidentPool>,
    loads: AtomicU64,
    reloads: AtomicU64,
    evictions: AtomicU64,
    peak_resident: AtomicU64,
}

impl FileBacking {
    /// Create an ephemeral writable backing (working-store spill): fresh
    /// per-process temp dir, one empty segment per shard.
    pub fn create_ephemeral(shard_rows: &[usize], budget_bytes: usize) -> Result<FileBacking> {
        let dir = std::env::temp_dir().join(format!(
            "avi_spill_{}_{}",
            std::process::id(),
            SPILL_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        let mut segs = Vec::with_capacity(shard_rows.len());
        for s in 0..shard_rows.len() {
            segs.push(Segment::create(&dir.join(format!("seg_{s}.bin")))?);
        }
        Ok(Self::from_parts(dir, true, false, budget_bytes, shard_rows.to_vec(), segs))
    }

    /// Wrap already-opened segments (manifest path).  `read_only` stores
    /// refuse appends.
    pub fn from_segments(
        dir: PathBuf,
        shard_rows: Vec<usize>,
        segs: Vec<Segment>,
        budget_bytes: usize,
        read_only: bool,
    ) -> FileBacking {
        Self::from_parts(dir, false, read_only, budget_bytes, shard_rows, segs)
    }

    fn from_parts(
        dir: PathBuf,
        ephemeral: bool,
        read_only: bool,
        budget_bytes: usize,
        rows: Vec<usize>,
        segs: Vec<Segment>,
    ) -> FileBacking {
        let n = rows.len();
        FileBacking {
            dir,
            ephemeral,
            read_only,
            budget_bytes: budget_bytes.max(1),
            rows,
            segs,
            pool: Mutex::new(ResidentPool {
                blocks: vec![None; n],
                ever_loaded: vec![false; n],
                ..ResidentPool::default()
            }),
            loads: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            peak_resident: AtomicU64::new(0),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.rows.len()
    }

    pub fn shard_rows(&self, s: usize) -> usize {
        self.rows[s]
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Lease shard `s`'s block at the current store width `n_cols`,
    /// loading (and evicting under budget) as needed.
    ///
    /// Panics on segment IO failure: open-time checksum verification
    /// (manifest path) or our own writes (ephemeral path) make the
    /// segments trustworthy, so a mid-fit read error is an environment
    /// failure (disk pulled, tmp reaped) with no useful recovery —
    /// consistent with how the memory backing treats allocation failure.
    pub fn lease(&self, s: usize, n_cols: usize) -> ShardLease<'static> {
        ShardLease::Spill { rows: self.rows[s], block: self.load_block(s, n_cols) }
    }

    fn load_block(&self, s: usize, n_cols: usize) -> Arc<Vec<f64>> {
        let mut p = self.pool.lock().expect("resident pool lock poisoned");
        if let Some(b) = &p.blocks[s] {
            // resident hit — only valid at the current width (appends
            // invalidate, so a cached block always matches n_cols)
            debug_assert_eq!(b.len(), self.rows[s] * n_cols);
            let b = b.clone();
            p.touch(s);
            return b;
        }
        let want = self.rows[s] * n_cols;
        let incoming = want * 8;
        // Evict-before-insert: drop LRU blocks (oldest first, skipping
        // any pinned by outstanding leases) until the incoming block
        // fits, so the pool's footprint never exceeds budget + 0.
        let mut i = 0;
        while p.resident_bytes + incoming > self.budget_bytes && i < p.lru.len() {
            let victim = p.lru[i];
            let evictable = match &p.blocks[victim] {
                Some(b) => Arc::strong_count(b) == 1,
                None => {
                    p.lru.remove(i); // stale entry
                    continue;
                }
            };
            if evictable {
                let freed = p.blocks[victim].take().map(|b| b.len() * 8).unwrap_or(0);
                p.resident_bytes -= freed;
                p.lru.remove(i);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            } else {
                i += 1;
            }
        }
        // Load (under the pool lock: keeps the accounting + insert
        // atomic; resident hits above never touch disk or wait here
        // beyond the lock hand-off).
        let mut vals = Vec::new();
        let ResidentPool { scratch, .. } = &mut *p;
        self.segs[s]
            .read_f64s_at(0, want, scratch, &mut vals)
            .unwrap_or_else(|e| panic!("spill read failed on shard {s}: {e}"));
        self.loads.fetch_add(1, Ordering::Relaxed);
        if p.ever_loaded[s] {
            self.reloads.fetch_add(1, Ordering::Relaxed);
        }
        p.ever_loaded[s] = true;
        let block = Arc::new(vals);
        p.blocks[s] = Some(block.clone());
        p.resident_bytes += incoming;
        p.touch(s);
        self.peak_resident.fetch_max(p.resident_bytes as u64, Ordering::Relaxed);
        block
    }

    /// Append one column slice to shard `s` (store width was
    /// `n_cols_before`), invalidating the resident block so the next
    /// lease reloads at the new width (counted as a reload).
    ///
    /// Panics on read-only backings and on IO failure (see [`Self::lease`]).
    pub fn append_col(&self, s: usize, col: &[f64], n_cols_before: usize) {
        assert!(
            !self.read_only,
            "append on a read-only manifest-backed store (derive columns into a working store)"
        );
        debug_assert_eq!(col.len(), self.rows[s]);
        let off = (n_cols_before * self.rows[s] * 8) as u64;
        self.segs[s]
            .write_f64s_at(off, col)
            .unwrap_or_else(|e| panic!("spill write failed on shard {s}: {e}"));
        let mut p = self.pool.lock().expect("resident pool lock poisoned");
        if let Some(b) = p.blocks[s].take() {
            p.resident_bytes -= b.len() * 8;
            if let Some(pos) = p.lru.iter().position(|&x| x == s) {
                p.lru.remove(pos);
            }
        }
    }

    /// Activity counter snapshot.
    pub fn counters(&self) -> BackingCounters {
        let resident =
            self.pool.lock().expect("resident pool lock poisoned").resident_bytes as u64;
        BackingCounters {
            loads: self.loads.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: resident,
            peak_resident_bytes: self.peak_resident.load(Ordering::Relaxed),
            budget_bytes: self.budget_bytes as u64,
        }
    }
}

impl Drop for FileBacking {
    fn drop(&mut self) {
        if self.ephemeral {
            std::fs::remove_dir_all(&self.dir).ok();
        }
    }
}

/// The two physical homes for a store's shard blocks.  Cloning a
/// memory backing deep-copies; cloning a spill backing shares the
/// `Arc`'d segments + pool.
#[derive(Clone, Debug)]
pub enum ShardBacking {
    Memory(Vec<MemShard>),
    Spill(Arc<FileBacking>),
}

impl ShardBacking {
    /// Build a backing for the given shard partition.
    pub fn build(shard_rows: &[usize], mode: StoreMode) -> Result<ShardBacking> {
        match mode {
            StoreMode::Memory => {
                Ok(ShardBacking::Memory(shard_rows.iter().map(|&r| MemShard::new(r)).collect()))
            }
            StoreMode::Spill { budget_bytes } => Ok(ShardBacking::Spill(Arc::new(
                FileBacking::create_ephemeral(shard_rows, budget_bytes)?,
            ))),
        }
    }

    pub fn mode_str(&self) -> &'static str {
        match self {
            ShardBacking::Memory(_) => "mem",
            ShardBacking::Spill(_) => "mmap",
        }
    }

    /// Spill counters, if this backing spills.
    pub fn counters(&self) -> Option<BackingCounters> {
        match self {
            ShardBacking::Memory(_) => None,
            ShardBacking::Spill(fb) => Some(fb.counters()),
        }
    }
}

/// Validate a `StoreMode` (budget must be sane).
pub fn validate_store_mode(mode: StoreMode) -> Result<()> {
    if let StoreMode::Spill { budget_bytes } = mode {
        if budget_bytes == 0 {
            return Err(AviError::Config("spill budget_bytes must be > 0".into()));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backing(rows: &[usize], budget: usize) -> FileBacking {
        FileBacking::create_ephemeral(rows, budget).unwrap()
    }

    #[test]
    fn store_mode_surface() {
        assert_eq!(StoreMode::default(), StoreMode::Memory);
        assert!(!StoreMode::Memory.is_spill());
        assert_eq!(StoreMode::Memory.as_str(), "mem");
        let s = StoreMode::spill_mb(2);
        assert_eq!(s, StoreMode::Spill { budget_bytes: 2 << 20 });
        assert!(s.is_spill());
        assert_eq!(s.as_str(), "mmap");
        assert!(validate_store_mode(StoreMode::Spill { budget_bytes: 0 }).is_err());
        assert!(validate_store_mode(s).is_ok());
    }

    #[test]
    fn append_lease_roundtrips_bitwise() {
        let fb = backing(&[3, 2], 1 << 20);
        let col = [1.5, f64::NAN, -0.0, 7.25, 1e-300];
        fb.append_col(0, &col[..3], 0);
        fb.append_col(1, &col[3..], 0);
        let l0 = fb.lease(0, 1);
        let l1 = fb.lease(1, 1);
        assert_eq!(l0.rows(), 3);
        for (a, b) in l0.col(0).iter().zip(&col[..3]) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in l1.col(0).iter().zip(&col[3..]) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let c = fb.counters();
        assert_eq!(c.loads, 2);
        assert_eq!(c.reloads, 0);
        assert_eq!(c.evictions, 0);
        assert_eq!(c.resident_bytes, 5 * 8);
        assert_eq!(c.peak_resident_bytes, 5 * 8); // both blocks resident under budget
    }

    #[test]
    fn lru_eviction_stays_under_budget_and_counts_reloads() {
        // 4 shards × 8 rows × 1 col = 64 bytes/block; budget fits 2 blocks
        let fb = backing(&[8, 8, 8, 8], 160);
        for s in 0..4 {
            let col: Vec<f64> = (0..8).map(|i| (s * 10 + i) as f64).collect();
            fb.append_col(s, &col, 0);
        }
        // touch all shards twice; only 2 fit at once
        for _round in 0..2 {
            for s in 0..4 {
                let l = fb.lease(s, 1);
                assert_eq!(l.col(0)[0], (s * 10) as f64);
            }
        }
        let c = fb.counters();
        assert!(c.peak_resident_bytes <= c.budget_bytes, "{c:?}");
        assert!(c.evictions > 0, "{c:?}");
        assert!(c.reloads > 0, "{c:?}");
        assert_eq!(c.loads, c.reloads + 4, "every shard loaded once + reloads: {c:?}");
    }

    #[test]
    fn outstanding_lease_pins_block_across_eviction() {
        // budget of exactly one block
        let fb = backing(&[4, 4], 32);
        fb.append_col(0, &[1.0, 2.0, 3.0, 4.0], 0);
        fb.append_col(1, &[9.0, 8.0, 7.0, 6.0], 0);
        let pinned = fb.lease(0, 1);
        let other = fb.lease(1, 1); // forces shard 0 out of the pool
        assert_eq!(pinned.col(0), &[1.0, 2.0, 3.0, 4.0]); // still readable
        assert_eq!(other.col(0), &[9.0, 8.0, 7.0, 6.0]);
    }

    #[test]
    fn append_invalidates_resident_block() {
        let fb = backing(&[2], 1 << 20);
        fb.append_col(0, &[1.0, 2.0], 0);
        assert_eq!(fb.lease(0, 1).col(0), &[1.0, 2.0]);
        fb.append_col(0, &[5.0, 6.0], 1);
        let l = fb.lease(0, 2);
        assert_eq!(l.col(0), &[1.0, 2.0]);
        assert_eq!(l.col(1), &[5.0, 6.0]);
        let c = fb.counters();
        assert_eq!(c.reloads, 1, "{c:?}");
    }

    #[test]
    #[should_panic(expected = "read-only")]
    fn read_only_backing_refuses_append() {
        let fb = backing(&[2], 1 << 20);
        fb.append_col(0, &[1.0, 2.0], 0);
        let dir = fb.dir().to_path_buf();
        let seg = Segment::open(&dir.join("seg_0.bin")).unwrap();
        let ro = FileBacking::from_segments(dir, vec![2], vec![seg], 1 << 20, true);
        ro.append_col(0, &[3.0, 4.0], 1);
    }

    #[test]
    fn ephemeral_dir_removed_on_drop() {
        let fb = backing(&[2], 1 << 20);
        let dir = fb.dir().to_path_buf();
        assert!(dir.exists());
        drop(fb);
        assert!(!dir.exists());
    }
}

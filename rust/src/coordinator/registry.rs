//! The serving **registry** tier: fitted pipelines addressable as
//! `key@version`, loaded from the unified persistence envelope
//! ([`crate::estimator::persist`]) by path, by bytes, or by manifest.
//!
//! The registry is the control plane's source of truth for *what can be
//! served*; the [`crate::coordinator::router::ModelRouter`] decides *who
//! serves which traffic* (weighted A/B arms, shadows) and builds one
//! [`crate::coordinator::service::TransformService`] per registered
//! version.  Versions are kept in **insertion order** and the most
//! recently registered version of a key is its `latest` — so hot-swap is
//! "register the new version", and rollback is "register the old version
//! again" (both leave every previously handed-out `Arc` alive until its
//! in-flight requests drain).
//!
//! Every failure path is a typed [`AviError::Registry`] (malformed
//! `key@version` specs, manifests naming missing files) or the persist
//! layer's typed envelope errors (unknown format/version/kind) wrapped
//! with the registry context — corrupt inputs never panic.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::{AviError, Result};
use crate::estimator::persist;
use crate::estimator::plan::PlanPolicy;
use crate::pipeline::plan::TransformPlan;
use crate::pipeline::PipelineModel;

/// Manifest envelope format tag.
pub const FORMAT_MANIFEST: &str = "avi-scale-registry";
/// Current manifest version (bump on breaking changes).
pub const MANIFEST_VERSION: u64 = 1;

/// Version the bare-key form of a spec resolves to.
pub const DEFAULT_VERSION: &str = "v1";

/// One registered version: the model plus its content fingerprint
/// ([`crate::artifact::model_fingerprint`]), so re-registration can
/// distinguish a rollback (identical contents — always allowed) from a
/// silent replacement (different contents — refused without `force`).
#[derive(Clone, Debug)]
struct VersionEntry {
    version: String,
    model: Arc<PipelineModel>,
    fingerprint: u64,
    /// Transform plan compiled at registration (default dense policy),
    /// so activation/hot-swap adopts a pre-warmed plan instead of
    /// compiling on the serving path.
    plan: Arc<TransformPlan>,
}

/// Versions of one key, insertion-ordered (last = latest).
#[derive(Clone, Debug, Default)]
struct KeyEntry {
    versions: Vec<VersionEntry>,
}

/// A versioned collection of fitted pipelines keyed `key@version`.
#[derive(Clone, Debug, Default)]
pub struct ModelRegistry {
    keys: HashMap<String, KeyEntry>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of registered (key, version) pairs.
    pub fn len(&self) -> usize {
        self.keys.values().map(|e| e.versions.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Registered keys (sorted, deterministic).
    pub fn keys(&self) -> Vec<String> {
        let mut k: Vec<String> = self.keys.keys().cloned().collect();
        k.sort();
        k
    }

    /// Versions of `key` in registration order (last = latest).
    pub fn versions(&self, key: &str) -> Vec<String> {
        self.keys
            .get(key)
            .map(|e| e.versions.iter().map(|v| v.version.clone()).collect())
            .unwrap_or_default()
    }

    /// Register an in-memory pipeline under `key@version`.  Re-inserting
    /// an existing version with **identical contents** replaces it and
    /// promotes it to latest (which is exactly a rollback when the
    /// version is an older one).  Re-inserting with **different
    /// contents** is refused with a typed error — a version label must
    /// mean one model forever unless the caller says
    /// [`ModelRegistry::insert_force`].
    pub fn insert(
        &mut self,
        key: impl Into<String>,
        version: impl Into<String>,
        model: Arc<PipelineModel>,
    ) -> Result<()> {
        self.insert_inner(key.into(), version.into(), model, false)
    }

    /// [`ModelRegistry::insert`] without the conflict gate: explicitly
    /// replace whatever `key@version` currently means.
    pub fn insert_force(
        &mut self,
        key: impl Into<String>,
        version: impl Into<String>,
        model: Arc<PipelineModel>,
    ) {
        let _ = self.insert_inner(key.into(), version.into(), model, true);
    }

    fn insert_inner(
        &mut self,
        key: String,
        version: String,
        model: Arc<PipelineModel>,
        force: bool,
    ) -> Result<()> {
        let fingerprint = crate::artifact::model_fingerprint(&model);
        if !force {
            self.check_register(&key, &version, fingerprint, false)?;
        }
        // compile the transform plan once, at registration time, so the
        // serving tier adopts a ready plan at activation/hot-swap
        let plan = Arc::new(TransformPlan::build(model.clone(), &PlanPolicy::default()));
        let entry = self.keys.entry(key).or_default();
        entry.versions.retain(|v| v.version != version);
        entry.versions.push(VersionEntry { version, model, fingerprint, plan });
        Ok(())
    }

    /// The transform plan compiled for `key@version` at registration.
    pub fn plan_for(&self, key: &str, version: &str) -> Option<Arc<TransformPlan>> {
        self.keys
            .get(key)?
            .versions
            .iter()
            .find(|v| v.version == version)
            .map(|v| v.plan.clone())
    }

    /// Content fingerprint of a registered version, if present.
    pub fn fingerprint_of(&self, key: &str, version: &str) -> Option<u64> {
        self.keys
            .get(key)?
            .versions
            .iter()
            .find(|v| v.version == version)
            .map(|v| v.fingerprint)
    }

    /// Would registering contents with `fingerprint` as `key@version`
    /// succeed?  Lets callers (the push handler) refuse a conflict
    /// *before* writing anything to disk.
    pub fn check_register(
        &self,
        key: &str,
        version: &str,
        fingerprint: u64,
        force: bool,
    ) -> Result<()> {
        if force {
            return Ok(());
        }
        if let Some(existing) = self.fingerprint_of(key, version) {
            if existing != fingerprint {
                return Err(AviError::Registry(format!(
                    "{key}@{version} is already registered with different contents \
                     (fingerprint {existing:016x}, offered {fingerprint:016x}); \
                     pass force to replace it"
                )));
            }
        }
        Ok(())
    }

    /// Bound the retained versions of `key` to `max_retained`, never
    /// evicting the latest version or any version named in `pinned`
    /// (the router's live routes, the active version).  Oldest unpinned
    /// versions go first; returns the evicted labels so the caller can
    /// sweep its artifact store.  In-flight `Arc`s stay alive.
    pub fn evict(&mut self, key: &str, max_retained: usize, pinned: &[String]) -> Vec<String> {
        let max_retained = max_retained.max(1);
        let Some(entry) = self.keys.get_mut(key) else {
            return Vec::new();
        };
        let Some(latest) = entry.versions.last().map(|v| v.version.clone()) else {
            return Vec::new();
        };
        let mut evicted = Vec::new();
        let mut i = 0;
        while entry.versions.len() > max_retained && i < entry.versions.len() {
            let v = &entry.versions[i].version;
            if *v != latest && !pinned.contains(v) {
                evicted.push(entry.versions.remove(i).version);
            } else {
                i += 1;
            }
        }
        evicted
    }

    /// Load a pipeline from the persistence envelope at `path` and
    /// register it.  Missing files and corrupt envelopes surface as
    /// typed registry errors.
    pub fn load_path(
        &mut self,
        key: impl Into<String>,
        version: impl Into<String>,
        path: &Path,
    ) -> Result<Arc<PipelineModel>> {
        let (key, version) = (key.into(), version.into());
        let bytes = std::fs::read(path).map_err(|e| {
            AviError::Registry(format!("{key}@{version}: cannot read {}: {e}", path.display()))
        })?;
        self.load_any(key, version, &bytes)
    }

    /// Parse a JSON pipeline envelope from `text` and register it.
    pub fn load_bytes(
        &mut self,
        key: impl Into<String>,
        version: impl Into<String>,
        text: &str,
    ) -> Result<Arc<PipelineModel>> {
        self.load_any(key, version, text.as_bytes())
    }

    /// Parse a pipeline envelope — JSON or binary, sniffed by magic via
    /// [`persist::pipeline_from_bytes`] — and register it.
    pub fn load_any(
        &mut self,
        key: impl Into<String>,
        version: impl Into<String>,
        bytes: &[u8],
    ) -> Result<Arc<PipelineModel>> {
        let (key, version) = (key.into(), version.into());
        let model = persist::pipeline_from_bytes(bytes)
            .map(Arc::new)
            .map_err(|e| AviError::Registry(format!("{key}@{version}: {e}")))?;
        self.insert(key, version, model.clone())?;
        Ok(model)
    }

    /// The model registered under `key@version`.
    pub fn get(&self, key: &str, version: &str) -> Option<Arc<PipelineModel>> {
        self.keys
            .get(key)?
            .versions
            .iter()
            .find(|v| v.version == version)
            .map(|v| v.model.clone())
    }

    /// [`ModelRegistry::get`] with a typed error naming the miss.
    pub fn resolve(&self, key: &str, version: &str) -> Result<Arc<PipelineModel>> {
        self.get(key, version).ok_or_else(|| {
            AviError::Registry(format!(
                "unknown model '{key}@{version}' (registered: {})",
                self.describe()
            ))
        })
    }

    /// Latest (most recently registered) version of `key`.
    pub fn latest(&self, key: &str) -> Option<(String, Arc<PipelineModel>)> {
        self.keys
            .get(key)?
            .versions
            .last()
            .map(|v| (v.version.clone(), v.model.clone()))
    }

    /// Drop one version (in-flight `Arc`s stay alive).  Returns whether
    /// it existed.
    pub fn remove(&mut self, key: &str, version: &str) -> bool {
        let Some(entry) = self.keys.get_mut(key) else { return false };
        let before = entry.versions.len();
        entry.versions.retain(|v| v.version != version);
        let removed = entry.versions.len() != before;
        if entry.versions.is_empty() {
            self.keys.remove(key);
        }
        removed
    }

    fn describe(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for key in self.keys() {
            for v in self.versions(&key) {
                parts.push(format!("{key}@{v}"));
            }
        }
        if parts.is_empty() {
            "none".into()
        } else {
            parts.join(", ")
        }
    }

    // -----------------------------------------------------------------
    // Manifest
    // -----------------------------------------------------------------

    /// Load every model a manifest file names, resolving relative paths
    /// against the manifest's directory.  Returns the `(key, version)`
    /// pairs registered, in manifest order.
    pub fn load_manifest(&mut self, path: &Path) -> Result<Vec<(String, String)>> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            AviError::Registry(format!("cannot read manifest {}: {e}", path.display()))
        })?;
        let base = path.parent().map(Path::to_path_buf).unwrap_or_default();
        self.load_manifest_str(&text, &base)
    }

    /// [`ModelRegistry::load_manifest`] over in-memory text.
    pub fn load_manifest_str(&mut self, text: &str, base: &Path) -> Result<Vec<(String, String)>> {
        let format = persist::extract_str(text, "\"format\":")
            .map_err(|_| AviError::Registry("manifest: missing envelope header".into()))?;
        if format != FORMAT_MANIFEST {
            return Err(AviError::Registry(format!(
                "manifest: format '{format}', expected '{FORMAT_MANIFEST}'"
            )));
        }
        let version = persist::extract_f64(text, "\"version\":")
            .map_err(|e| AviError::Registry(format!("manifest: {e}")))?
            as u64;
        if version != MANIFEST_VERSION {
            return Err(AviError::Registry(format!(
                "manifest: unsupported version {version} (supported: {MANIFEST_VERSION})"
            )));
        }
        let models_src = persist::extract_array(text, "\"models\":")
            .map_err(|e| AviError::Registry(format!("manifest: {e}")))?;
        // load everything before registering anything, so a failure
        // mid-manifest cannot leave the registry half-updated
        let mut staged: Vec<(String, String, Arc<PipelineModel>)> = Vec::new();
        for obj in persist::split_objects(&models_src) {
            let key = persist::extract_str(obj, "\"key\":")
                .map_err(|e| AviError::Registry(format!("manifest entry: {e}")))?;
            let version = persist::extract_str(obj, "\"version\":")
                .map_err(|e| AviError::Registry(format!("manifest entry: {e}")))?;
            let rel = persist::extract_str(obj, "\"path\":")
                .map_err(|e| AviError::Registry(format!("manifest entry: {e}")))?;
            let mut full = PathBuf::from(&rel);
            if full.is_relative() {
                full = base.join(full);
            }
            let doc = std::fs::read(&full).map_err(|e| {
                AviError::Registry(format!(
                    "{key}@{version}: cannot read {}: {e}",
                    full.display()
                ))
            })?;
            let model = persist::pipeline_from_bytes(&doc)
                .map(Arc::new)
                .map_err(|e| AviError::Registry(format!("{key}@{version}: {e}")))?;
            staged.push((key, version, model));
        }
        if staged.is_empty() {
            return Err(AviError::Registry("manifest: no models listed".into()));
        }
        // conflict pre-check (against the registry and within the
        // manifest itself) before registering anything, so one refusal
        // cannot leave the registry half-updated
        let mut seen: HashMap<(String, String), u64> = HashMap::new();
        for (key, version, model) in &staged {
            let fp = crate::artifact::model_fingerprint(model);
            self.check_register(key, version, fp, false)
                .map_err(|e| AviError::Registry(format!("manifest: {e}")))?;
            if let Some(prev) = seen.insert((key.clone(), version.clone()), fp) {
                if prev != fp {
                    return Err(AviError::Registry(format!(
                        "manifest: {key}@{version} listed twice with different contents"
                    )));
                }
            }
        }
        let mut loaded = Vec::with_capacity(staged.len());
        for (key, version, model) in staged {
            self.insert_force(&key, &version, model); // pre-checked above
            loaded.push((key, version));
        }
        Ok(loaded)
    }

    /// Serialize a manifest document for `(key, version, path)` entries.
    pub fn manifest_json(entries: &[(String, String, String)]) -> String {
        use crate::util::json_escape;
        let mut out = format!(
            "{{\n\"format\": \"{FORMAT_MANIFEST}\",\n\"version\": {MANIFEST_VERSION},\n\"models\": [\n"
        );
        for (i, (key, version, path)) in entries.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "{{\"key\": \"{}\", \"version\": \"{}\", \"path\": \"{}\"}}",
                json_escape(key),
                json_escape(version),
                json_escape(path)
            ));
        }
        out.push_str("\n]\n}\n");
        out
    }
}

/// Parse a `key@version` spec (`key` alone resolves to
/// [`DEFAULT_VERSION`]).  Rejects empty parts, a second `@`, and
/// characters that would collide with the CLI spec/report syntax
/// (quotes, backslashes, `=`/`,`/`:` delimiters, whitespace, control
/// characters) with a typed error.
pub fn parse_spec(spec: &str) -> Result<(String, String)> {
    let (key, version) = match spec.split_once('@') {
        Some((k, v)) => (k, v),
        None => (spec, DEFAULT_VERSION),
    };
    let bad_part = |s: &str| {
        s.is_empty()
            || s.chars().any(|c| {
                c.is_whitespace()
                    || c.is_control()
                    || matches!(c, '@' | '"' | '\\' | '=' | ',' | ':')
            })
    };
    if bad_part(key) || bad_part(version) {
        return Err(AviError::Registry(format!(
            "malformed model spec '{spec}' (expected key or key@version; keys and \
             versions may not contain whitespace or @ \" \\ = , :)"
        )));
    }
    Ok((key.to_string(), version.to_string()))
}

/// Prefix a registry key with a tenant namespace: `tenant/key`.  `/` is
/// deliberately legal in [`parse_spec`] keys, so namespaced keys flow
/// through the registry, router, and wire protocol as plain keys — the
/// whole multi-tenant story is a naming convention, not a parallel
/// lookup path.  An empty tenant is the un-namespaced key.
pub fn namespaced(tenant: &str, key: &str) -> String {
    if tenant.is_empty() {
        key.to_string()
    } else {
        format!("{tenant}/{key}")
    }
}

/// Split a possibly-namespaced key into `(tenant, bare_key)`.  Only the
/// **first** `/` separates the tenant, so keys may themselves contain
/// `/` below the namespace.
pub fn split_namespace(key: &str) -> (Option<&str>, &str) {
    match key.split_once('/') {
        Some((tenant, bare)) if !tenant.is_empty() => (Some(tenant), bare),
        _ => (None, key),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::estimator::EstimatorConfig;
    use crate::oavi::OaviConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, PipelineConfig};
    use crate::svm::linear::LinearSvmConfig;

    fn model(psi: f64, seed: u64) -> Arc<PipelineModel> {
        let ds = synthetic_dataset(250, seed);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(psi)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        Arc::new(train_pipeline(&cfg, &ds).unwrap())
    }

    #[test]
    fn insert_get_latest_and_rollback_ordering() {
        let mut reg = ModelRegistry::new();
        let m1 = model(0.01, 1);
        let m2 = model(0.05, 2);
        reg.insert("champ", "v1", m1.clone()).unwrap();
        reg.insert("champ", "v2", m2.clone()).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.versions("champ"), vec!["v1", "v2"]);
        assert_eq!(reg.latest("champ").unwrap().0, "v2");
        assert!(Arc::ptr_eq(&reg.get("champ", "v1").unwrap(), &m1));
        // rollback: re-registering v1 (identical contents) promotes it
        // back to latest without needing force
        reg.insert("champ", "v1", m1.clone()).unwrap();
        assert_eq!(reg.latest("champ").unwrap().0, "v1");
        assert_eq!(reg.len(), 2, "rollback must not duplicate the version");
        assert!(reg.remove("champ", "v2"));
        assert!(!reg.remove("champ", "v2"));
        assert_eq!(reg.versions("champ"), vec!["v1"]);
    }

    #[test]
    fn conflicting_reregistration_is_refused_without_force() {
        let mut reg = ModelRegistry::new();
        let m1 = model(0.01, 21);
        let m2 = model(0.05, 22);
        reg.insert("champ", "v1", m1.clone()).unwrap();
        // different contents under the same label: typed refusal, and
        // the original stays registered
        let err = reg.insert("champ", "v1", m2.clone()).unwrap_err();
        assert!(matches!(err, AviError::Registry(_)), "{err}");
        assert!(err.to_string().contains("force"), "{err}");
        assert!(Arc::ptr_eq(&reg.get("champ", "v1").unwrap(), &m1));
        // a distinct Arc with identical contents is a rollback, not a
        // conflict (fingerprints are content-based, not pointer-based)
        let m1_clone = Arc::new(PipelineModel {
            perm: m1.perm.clone(),
            transformer: crate::pipeline::FittedTransformer {
                method_name: m1.transformer.method_name.clone(),
                per_class: m1
                    .transformer
                    .per_class
                    .iter()
                    .map(|m| m.clone_box())
                    .collect(),
            },
            svm: m1.svm.clone(),
            n_classes: m1.n_classes,
        });
        reg.insert("champ", "v1", m1_clone).unwrap();
        // force replaces explicitly
        reg.insert_force("champ", "v1", m2.clone());
        assert!(Arc::ptr_eq(&reg.get("champ", "v1").unwrap(), &m2));
        // check_register mirrors the gate without mutating
        let fp1 = crate::artifact::model_fingerprint(&m1);
        let fp2 = crate::artifact::model_fingerprint(&m2);
        assert!(reg.check_register("champ", "v1", fp2, false).is_ok());
        assert!(reg.check_register("champ", "v1", fp1, false).is_err());
        assert!(reg.check_register("champ", "v1", fp1, true).is_ok());
        assert!(reg.check_register("champ", "v9", fp1, false).is_ok());
        assert_eq!(reg.fingerprint_of("champ", "v1"), Some(fp2));
        assert_eq!(reg.fingerprint_of("champ", "v9"), None);
    }

    #[test]
    fn eviction_keeps_latest_and_pinned_versions() {
        let mut reg = ModelRegistry::new();
        let m = model(0.01, 23);
        for v in ["v1", "v2", "v3", "v4", "v5"] {
            reg.insert("champ", v, m.clone()).unwrap();
        }
        // pin v2 (say, the active route); cap at 3
        let evicted = reg.evict("champ", 3, &["v2".to_string()]);
        assert_eq!(evicted, vec!["v1".to_string(), "v3".to_string()]);
        assert_eq!(reg.versions("champ"), vec!["v2", "v4", "v5"]);
        // latest survives even a cap of 1 when pins force an overflow
        let evicted = reg.evict("champ", 1, &["v2".to_string()]);
        assert_eq!(evicted, vec!["v4".to_string()]);
        assert_eq!(reg.versions("champ"), vec!["v2", "v5"]);
        // already bounded: no-op
        assert!(reg.evict("champ", 3, &[]).is_empty());
        assert!(reg.evict("ghost", 3, &[]).is_empty());
        // cap of 0 is clamped to 1, and the latest is never evicted
        let evicted = reg.evict("champ", 0, &[]);
        assert_eq!(evicted, vec!["v2".to_string()]);
        assert_eq!(reg.versions("champ"), vec!["v5"]);
    }

    #[test]
    fn registration_compiles_a_transform_plan() {
        let mut reg = ModelRegistry::new();
        let m = model(0.01, 31);
        reg.insert("champ", "v1", m.clone()).unwrap();
        let plan = reg.plan_for("champ", "v1").unwrap();
        assert!(Arc::ptr_eq(plan.model(), &m));
        assert_eq!(plan.total_cols(), m.transformer.n_generators());
        assert!(reg.plan_for("champ", "v9").is_none());
        assert!(reg.plan_for("ghost", "v1").is_none());
    }

    #[test]
    fn resolve_names_the_miss_with_a_typed_error() {
        let mut reg = ModelRegistry::new();
        reg.insert("champ", "v1", model(0.01, 3)).unwrap();
        assert!(reg.resolve("champ", "v1").is_ok());
        let err = reg.resolve("champ", "v9").unwrap_err();
        assert!(matches!(err, AviError::Registry(_)), "{err}");
        assert!(err.to_string().contains("champ@v9"), "{err}");
        assert!(err.to_string().contains("champ@v1"), "{err}");
    }

    #[test]
    fn corrupt_envelopes_are_rejected_not_panicked() {
        let m = model(0.01, 4);
        let json = persist::pipeline_to_json(&m);
        let mut reg = ModelRegistry::new();
        // unknown envelope version
        let v99 = json.replace("\"version\": 1", "\"version\": 99");
        let err = reg.load_bytes("k", "v1", &v99).unwrap_err();
        assert!(matches!(err, AviError::Registry(_)), "{err}");
        // unknown payload kind
        let bad_kind = json.replace(persist::KIND_GENERATOR_SET, "alien-kind");
        assert!(reg.load_bytes("k", "v1", &bad_kind).is_err());
        // unknown format
        let bad_fmt = json.replace(persist::FORMAT_PIPELINE, "mystery");
        assert!(reg.load_bytes("k", "v1", &bad_fmt).is_err());
        assert!(reg.load_bytes("k", "v1", "not json").is_err());
        assert!(reg.is_empty(), "rejected loads must not register anything");
        // the pristine envelope still loads
        assert!(reg.load_bytes("k", "v1", &json).is_ok());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn load_path_missing_file_is_a_typed_error() {
        let mut reg = ModelRegistry::new();
        let err = reg
            .load_path("k", "v1", Path::new("/nonexistent/avi/model.json"))
            .unwrap_err();
        assert!(matches!(err, AviError::Registry(_)), "{err}");
        assert!(err.to_string().contains("model.json"), "{err}");
    }

    #[test]
    fn manifest_roundtrip_and_missing_file_rejection() {
        let dir = std::env::temp_dir().join("avi_scale_registry_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m1 = model(0.01, 5);
        let m2 = model(0.05, 6);
        persist::save(&m1, &dir.join("a.json")).unwrap();
        persist::save(&m2, &dir.join("b.json")).unwrap();
        let manifest = ModelRegistry::manifest_json(&[
            ("champ".into(), "v1".into(), "a.json".into()),
            ("champ".into(), "v2".into(), "b.json".into()),
        ]);
        let mpath = dir.join("manifest.json");
        std::fs::write(&mpath, &manifest).unwrap();

        let mut reg = ModelRegistry::new();
        let loaded = reg.load_manifest(&mpath).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(reg.versions("champ"), vec!["v1", "v2"]);

        // manifest naming a missing file: typed error naming the file,
        // and the load is atomic — nothing from the manifest registers
        let broken = ModelRegistry::manifest_json(&[
            ("champ".into(), "v1".into(), "a.json".into()),
            ("champ".into(), "v3".into(), "gone.json".into()),
        ]);
        std::fs::write(&mpath, &broken).unwrap();
        let mut reg2 = ModelRegistry::new();
        let err = reg2.load_manifest(&mpath).unwrap_err();
        assert!(matches!(err, AviError::Registry(_)), "{err}");
        assert!(err.to_string().contains("gone.json"), "{err}");
        assert!(reg2.is_empty(), "failed manifest load must not half-register");

        // unsupported manifest version / format
        let mut reg3 = ModelRegistry::new();
        let v9 = manifest.replace("\"version\": 1", "\"version\": 9");
        assert!(reg3.load_manifest_str(&v9, &dir).is_err());
        let badfmt = manifest.replace(FORMAT_MANIFEST, "mystery");
        assert!(reg3.load_manifest_str(&badfmt, &dir).is_err());
        assert!(reg3.load_manifest_str("{}", &dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(parse_spec("champ").unwrap(), ("champ".into(), "v1".into()));
        assert_eq!(parse_spec("champ@v7").unwrap(), ("champ".into(), "v7".into()));
        assert!(parse_spec("").is_err());
        assert!(parse_spec("@v1").is_err());
        assert!(parse_spec("k@").is_err());
        assert!(parse_spec("k@v@x").is_err());
        // delimiter/JSON-hostile characters are rejected up front
        for bad in ["a b", "a\"b", "a\\b", "a=b", "a,b", "a:b", "k@v 1"] {
            assert!(parse_spec(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn tenant_namespacing_round_trips_through_plain_keys() {
        assert_eq!(namespaced("acme", "champ"), "acme/champ");
        assert_eq!(namespaced("", "champ"), "champ");
        assert_eq!(split_namespace("acme/champ"), (Some("acme"), "champ"));
        assert_eq!(split_namespace("champ"), (None, "champ"));
        // only the first '/' is the namespace boundary
        assert_eq!(split_namespace("acme/models/champ"), (Some("acme"), "models/champ"));
        assert_eq!(split_namespace("/champ"), (None, "/champ"));
        // namespaced keys are valid specs end to end
        let (key, version) = parse_spec(&format!("{}@v2", namespaced("acme", "champ"))).unwrap();
        assert_eq!(key, "acme/champ");
        assert_eq!(version, "v2");
        // and resolve as ordinary registry keys
        let mut reg = ModelRegistry::new();
        reg.insert(namespaced("acme", "m"), "v1", model(0.01, 11)).unwrap();
        reg.insert(namespaced("globex", "m"), "v1", model(0.05, 12)).unwrap();
        assert!(reg.get("acme/m", "v1").is_some());
        assert!(reg.get("globex/m", "v1").is_some());
        assert!(reg.get("m", "v1").is_none(), "tenants must not leak into the bare key");
    }

    #[test]
    fn manifest_json_escapes_hostile_strings() {
        let doc = ModelRegistry::manifest_json(&[(
            "k\"ey".into(),
            "v\\1".into(),
            "dir/a.json".into(),
        )]);
        assert!(doc.contains("k\\\"ey"), "{doc}");
        assert!(doc.contains("v\\\\1"), "{doc}");
    }
}

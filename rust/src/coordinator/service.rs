//! The serving **service** tier: one batcher thread per served model
//! version, fed by a bounded request queue, answering the typed
//! [`ServeRequest`] → [`ServeReply`] protocol.
//!
//! Layering (control plane, top down): **registry → router → service →
//! backend**.  The [`crate::coordinator::registry::ModelRegistry`] owns
//! fitted pipelines by `key@version`, the
//! [`crate::coordinator::router::ModelRouter`] assigns traffic across
//! versions, and each (key, version) arm is one [`TransformService`]: a
//! batcher thread (vLLM-router style continuous batching) that groups
//! whatever requests are pending, runs the (FT) transform + SVM through
//! the fitted pipeline on the configured [`ServeBackend`], and answers
//! every admitted request exactly once.
//!
//! Everything is constructed through one builder-style [`ServeConfig`]
//! (backend choice, batch policy, queue bound, `key@version` stamp) —
//! the single constructor [`TransformService::start`] replaced the old
//! `start` / `start_sharded` / `start_pooled` trio.
//!
//! Admission control: the queue is a bounded `sync_channel`; a full
//! queue answers [`RejectReason::QueueFull`] synchronously instead of
//! blocking the client or growing without bound, and requests whose
//! [`ServeRequest::deadline`] has expired are rejected at dequeue time
//! ([`RejectReason::DeadlineExpired`]) instead of burning compute on an
//! answer nobody is waiting for.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{ComputeBackend, NativeBackend, ShardedBackend};
use crate::coordinator::pool::PoolHandle;
use crate::error::{AviError, Result};
use crate::estimator::plan::PlanPolicy;
use crate::linalg::dense::Matrix;
use crate::pipeline::plan::{TransformPlan, TransformScratch};
use crate::pipeline::PipelineModel;

// ---------------------------------------------------------------------
// Typed request/response protocol
// ---------------------------------------------------------------------

/// What a request carries: one feature row or a batch of rows.  A batch
/// is one protocol unit — it is admitted, batched, and answered as a
/// whole (never split across flushes), so per-model FIFO holds for
/// batches exactly as for rows.
#[derive(Clone, Debug)]
pub enum ServePayload {
    Row(Vec<f64>),
    Batch(Vec<Vec<f64>>),
}

/// One typed inference request.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub payload: ServePayload,
    /// Maximum time the request may wait in the queue; expired requests
    /// are rejected at dequeue instead of served late.
    pub deadline: Option<Duration>,
}

impl ServeRequest {
    /// Single-row request.
    pub fn row(row: Vec<f64>) -> Self {
        ServeRequest { payload: ServePayload::Row(row), deadline: None }
    }

    /// Row-batch request (answered as one unit).
    pub fn batch(rows: Vec<Vec<f64>>) -> Self {
        ServeRequest { payload: ServePayload::Batch(rows), deadline: None }
    }

    /// Attach a per-request queue deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Number of feature rows this request carries.
    pub fn n_rows(&self) -> usize {
        match &self.payload {
            ServePayload::Row(_) => 1,
            ServePayload::Batch(rows) => rows.len(),
        }
    }

    fn rows(&self) -> &[Vec<f64>] {
        match &self.payload {
            ServePayload::Row(row) => std::slice::from_ref(row),
            ServePayload::Batch(rows) => rows,
        }
    }
}

/// One row's prediction: the label plus the per-class decision scores it
/// was derived from (binary models expose the single one-vs-rest score).
#[derive(Clone, Debug)]
pub struct Prediction {
    pub label: usize,
    pub scores: Vec<f64>,
}

/// A successful answer: one [`Prediction`] per request row, stamped with
/// the model that served it and the latency split.
#[derive(Clone, Debug)]
pub struct ServeAnswer {
    pub predictions: Vec<Prediction>,
    /// Registry key of the model that served this request.
    pub model_key: String,
    /// Registry version of the model that served this request.
    pub model_version: String,
    /// Time spent waiting in the queue (enqueue → flush start).
    pub queue_latency: Duration,
    /// Time spent in the (FT) transform + SVM for the flush that served
    /// this request (shared across the flush's requests).
    pub compute_latency: Duration,
    /// How many rows shared the flush.
    pub batch_rows: usize,
}

impl ServeAnswer {
    /// First (or only) row's label — the single-row convenience.
    pub fn label(&self) -> usize {
        self.predictions[0].label
    }
}

/// Why a request was turned away without being served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded queue was full at admission.
    QueueFull { capacity: usize },
    /// The request's deadline expired before it was dequeued.
    DeadlineExpired { waited: Duration },
    /// A row's feature length does not match the model (or the batch was
    /// empty).
    BadShape { got: usize, want: usize },
    /// A row carries a NaN or infinity — rejected at admission so a
    /// poisoned value can never reach the transform (where it would
    /// propagate through every score in the flush) or panic a worker.
    NonFinite { row: usize, col: usize },
    /// The service has shut down.
    Stopped,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull { capacity } => {
                write!(f, "queue full (capacity {capacity})")
            }
            RejectReason::DeadlineExpired { waited } => {
                write!(f, "deadline expired after {:.1}ms in queue", waited.as_secs_f64() * 1e3)
            }
            RejectReason::BadShape { got, want } => {
                write!(f, "bad shape: {got} features, model wants {want}")
            }
            RejectReason::NonFinite { row, col } => {
                write!(f, "non-finite value at row {row}, col {col}")
            }
            RejectReason::Stopped => write!(f, "service stopped"),
        }
    }
}

/// The answer to a [`ServeRequest`]: served, or rejected with a typed
/// reason.  Every admitted request receives exactly one reply.
#[derive(Clone, Debug)]
pub enum ServeReply {
    Answered(ServeAnswer),
    Rejected(RejectReason),
}

impl ServeReply {
    /// Borrow the answer if the request was served.
    pub fn as_answer(&self) -> Option<&ServeAnswer> {
        match self {
            ServeReply::Answered(a) => Some(a),
            ServeReply::Rejected(_) => None,
        }
    }

    /// Unwrap into an answer, converting a rejection into a typed error.
    pub fn answer(self) -> Result<ServeAnswer> {
        match self {
            ServeReply::Answered(a) => Ok(a),
            ServeReply::Rejected(r) => Err(AviError::Coordinator(format!("rejected: {r}"))),
        }
    }

    pub fn is_rejected(&self) -> bool {
        matches!(self, ServeReply::Rejected(_))
    }
}

// ---------------------------------------------------------------------
// Histograms + metrics
// ---------------------------------------------------------------------

/// End-to-end latency buckets (µs, `le` upper bounds + overflow).
pub const LATENCY_BUCKETS_US: &[u64] =
    &[100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 1_000_000];

/// Flush batch-size buckets (rows, `le` upper bounds + overflow).
pub const BATCH_BUCKETS: &[u64] = &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];

/// Lock-free fixed-bucket histogram (`le` semantics, last bucket is the
/// overflow), snapshotted into the [`RouterReport`] JSON.
///
/// [`RouterReport`]: crate::coordinator::router::RouterReport
#[derive(Debug)]
pub struct Histogram {
    bounds: &'static [u64],
    counts: Vec<AtomicU64>,
}

impl Histogram {
    pub fn new(bounds: &'static [u64]) -> Self {
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, counts }
    }

    /// Count `v` in the first bucket with bound ≥ v (overflow otherwise).
    pub fn record(&self, v: u64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Upper bounds (the final overflow bucket is implicit).
    pub fn bounds(&self) -> &'static [u64] {
        self.bounds
    }

    /// Current per-bucket counts (bounds + one overflow slot).
    pub fn snapshot(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Total observations.
    pub fn total(&self) -> u64 {
        self.snapshot().iter().sum()
    }

    /// Add another histogram's counts into this one (same bounds).
    pub fn absorb(&self, other: &Histogram) {
        debug_assert_eq!(self.bounds, other.bounds);
        for (slot, count) in self.counts.iter().zip(other.snapshot()) {
            slot.fetch_add(count, Ordering::Relaxed);
        }
    }

    /// `{"le": [...], "counts": [...]}` with `"+inf"` as the last bound —
    /// the one histogram serialization, shared with the router's report.
    pub fn json_parts(bounds: &[u64], counts: &[u64]) -> String {
        let les: Vec<String> = bounds
            .iter()
            .map(|b| b.to_string())
            .chain(std::iter::once("\"+inf\"".to_string()))
            .collect();
        let cs: Vec<String> = counts.iter().map(|c| c.to_string()).collect();
        format!("{{\"le\": [{}], \"counts\": [{}]}}", les.join(","), cs.join(","))
    }

    /// [`Histogram::json_parts`] over this histogram's current state.
    pub fn to_json(&self) -> String {
        Self::json_parts(self.bounds, &self.snapshot())
    }
}

/// Per-service counters — one set per (key, version) arm, aggregated by
/// the router into its [`RouterReport`].
///
/// [`RouterReport`]: crate::coordinator::router::RouterReport
#[derive(Debug)]
pub struct ServeMetrics {
    /// Requests answered (protocol units, not rows).
    pub requests: AtomicU64,
    /// Feature rows served.
    pub rows: AtomicU64,
    /// Flushes executed.
    pub batches: AtomicU64,
    /// Largest flush, in rows.
    pub max_batch: AtomicU64,
    /// Admission rejections: queue full.
    pub rejected_full: AtomicU64,
    /// Dequeue rejections: deadline expired.
    pub rejected_deadline: AtomicU64,
    /// Admission rejections: feature-length mismatch / empty batch.
    pub rejected_shape: AtomicU64,
    /// Admission rejections: NaN/∞ in a feature row.
    pub rejected_value: AtomicU64,
    /// Σ queue latency over answered requests (µs) — mean = /requests.
    pub queue_us: AtomicU64,
    /// Σ compute latency over answered requests (µs).
    pub compute_us: AtomicU64,
    /// Transform plans compiled or adopted by this arm (one per start).
    pub plan_builds: AtomicU64,
    /// Σ plan compile time (µs) across builds/adoptions.
    pub plan_build_us: AtomicU64,
    /// Flushes served from the compiled plan (vs the legacy backend
    /// path, which large batches still take for shard parallelism).
    pub plan_hits: AtomicU64,
    /// Plan flushes served by the packed sparse kernel.
    pub plan_sparse_hits: AtomicU64,
    /// Σ multiply-adds skipped by the packed sparse kernel.
    pub plan_flops_saved: AtomicU64,
    /// Flush-size histogram (rows).
    pub batch_rows_hist: Histogram,
    /// End-to-end latency histogram over answered requests (µs).
    pub latency_us_hist: Histogram,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            requests: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            rejected_full: AtomicU64::new(0),
            rejected_deadline: AtomicU64::new(0),
            rejected_shape: AtomicU64::new(0),
            rejected_value: AtomicU64::new(0),
            queue_us: AtomicU64::new(0),
            compute_us: AtomicU64::new(0),
            plan_builds: AtomicU64::new(0),
            plan_build_us: AtomicU64::new(0),
            plan_hits: AtomicU64::new(0),
            plan_sparse_hits: AtomicU64::new(0),
            plan_flops_saved: AtomicU64::new(0),
            batch_rows_hist: Histogram::new(BATCH_BUCKETS),
            latency_us_hist: Histogram::new(LATENCY_BUCKETS_US),
        }
    }
}

impl ServeMetrics {
    /// Total rejections across all reasons.
    pub fn rejected(&self) -> u64 {
        self.rejected_full.load(Ordering::Relaxed)
            + self.rejected_deadline.load(Ordering::Relaxed)
            + self.rejected_shape.load(Ordering::Relaxed)
            + self.rejected_value.load(Ordering::Relaxed)
    }

    /// Add another metrics set into this one — the router folds retired
    /// arms' metrics into bounded accumulators with this.
    pub fn absorb(&self, other: &ServeMetrics) {
        let add = |into: &AtomicU64, from: &AtomicU64| {
            into.fetch_add(from.load(Ordering::Relaxed), Ordering::Relaxed);
        };
        add(&self.requests, &other.requests);
        add(&self.rows, &other.rows);
        add(&self.batches, &other.batches);
        add(&self.rejected_full, &other.rejected_full);
        add(&self.rejected_deadline, &other.rejected_deadline);
        add(&self.rejected_shape, &other.rejected_shape);
        add(&self.rejected_value, &other.rejected_value);
        add(&self.queue_us, &other.queue_us);
        add(&self.compute_us, &other.compute_us);
        add(&self.plan_builds, &other.plan_builds);
        add(&self.plan_build_us, &other.plan_build_us);
        add(&self.plan_hits, &other.plan_hits);
        add(&self.plan_sparse_hits, &other.plan_sparse_hits);
        add(&self.plan_flops_saved, &other.plan_flops_saved);
        self.max_batch
            .fetch_max(other.max_batch.load(Ordering::Relaxed), Ordering::Relaxed);
        self.batch_rows_hist.absorb(&other.batch_rows_hist);
        self.latency_us_hist.absorb(&other.latency_us_hist);
    }
}

// ---------------------------------------------------------------------
// ServeConfig
// ---------------------------------------------------------------------

/// Which compute backend the batcher runs the (FT) transform through.
/// The `ComputeBackend` trait itself is `!Send` by design, so only this
/// `Send` description crosses into the batcher thread, which constructs
/// the backend locally.
#[derive(Clone)]
pub enum ServeBackend {
    /// Sequential reference — bit-identical everywhere.
    Native,
    /// Private shard pool with `workers` threads.
    Sharded { workers: usize },
    /// Shard workers drawn from a **shared** process pool with an
    /// `inner_workers` budget, so serving composes with training load.
    Pooled { handle: PoolHandle, inner_workers: usize },
}

impl std::fmt::Debug for ServeBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeBackend::Native => write!(f, "Native"),
            ServeBackend::Sharded { workers } => write!(f, "Sharded({workers})"),
            ServeBackend::Pooled { inner_workers, .. } => write!(f, "Pooled({inner_workers})"),
        }
    }
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// flush when this many rows are pending…
    pub max_batch: usize,
    /// …and this is the idle recv pacing: how long the batcher blocks
    /// for the next request before re-checking `stop` (continuous
    /// batching flushes whatever accumulated as soon as the queue
    /// drains, so arrivals are never delayed by this — but shutdown can
    /// lag by up to one interval).
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 256, max_wait: Duration::from_millis(2) }
    }
}

/// Default bound on the per-service request queue.
pub const DEFAULT_QUEUE_CAPACITY: usize = 1024;

/// Shard floor for serving batches: per-row transform work (ℓ·g fused
/// multiply-adds across every class block) is much heavier than the
/// training dot products, so sharding pays off at smaller row counts
/// than training's `MIN_ROWS_PER_SHARD`.
pub const SERVE_MIN_ROWS_PER_SHARD: usize = 1024;

/// Builder-style construction surface for the whole serving path: the
/// backend choice, batching policy, queue bound, and the `key@version`
/// stamp replies carry.  [`TransformService::start`] consumes it — the
/// one constructor that replaced `start` / `start_sharded` /
/// `start_pooled`.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub backend: ServeBackend,
    pub policy: BatchPolicy,
    /// Bounded queue capacity; admission past it rejects synchronously.
    pub queue_capacity: usize,
    /// Registry key stamped onto every answer.
    pub key: String,
    /// Registry version stamped onto every answer.
    pub version: String,
    /// Pre-compiled transform plan to adopt (the router passes the plan
    /// the registry compiled at insert).  When absent, the batcher
    /// compiles one under `plan_policy` before taking traffic.
    pub plan: Option<Arc<TransformPlan>>,
    /// Policy for plans compiled by the service itself (dense exact by
    /// default; sparse opt-in mirrors `NumericsMode::Fast` gating).
    pub plan_policy: PlanPolicy,
    /// Test hook: while `true`, the batcher sleeps without draining the
    /// queue, making admission control deterministic to exercise.
    #[doc(hidden)]
    pub hold_gate: Option<Arc<AtomicBool>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            backend: ServeBackend::Native,
            policy: BatchPolicy::default(),
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            key: "default".into(),
            version: "v1".into(),
            plan: None,
            plan_policy: PlanPolicy::default(),
            hold_gate: None,
        }
    }
}

impl ServeConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sequential reference backend (the default).
    pub fn native(mut self) -> Self {
        self.backend = ServeBackend::Native;
        self
    }

    /// Private shard pool with `workers` threads.
    pub fn sharded(mut self, workers: usize) -> Self {
        self.backend = ServeBackend::Sharded { workers };
        self
    }

    /// Draw shard workers from a shared pool with an `inner_workers`
    /// budget.
    pub fn pooled(mut self, handle: PoolHandle, inner_workers: usize) -> Self {
        self.backend = ServeBackend::Pooled { handle, inner_workers };
        self
    }

    /// Batching policy.
    pub fn batch(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Bound the request queue (0 is clamped to 1).
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = capacity.max(1);
        self
    }

    /// `key@version` stamp replies carry (the router sets this when it
    /// builds arms from the registry).
    pub fn stamp(mut self, key: impl Into<String>, version: impl Into<String>) -> Self {
        self.key = key.into();
        self.version = version.into();
        self
    }

    /// Adopt a pre-compiled transform plan (the registry compiles one at
    /// insert; the router threads it through so activation serves from a
    /// warmed plan instead of compiling on the serving path).
    pub fn with_plan(mut self, plan: Arc<TransformPlan>) -> Self {
        self.plan = Some(plan);
        self
    }

    /// Opt service-compiled plans into the packed sparse kernel (engages
    /// per class past the measured zero-fraction threshold; dense exact
    /// stays the default).
    pub fn sparse_plans(mut self) -> Self {
        self.plan_policy = PlanPolicy::sparse_enabled();
        self
    }
}

// ---------------------------------------------------------------------
// The service
// ---------------------------------------------------------------------

/// One queued request: rows + deadline + the oneshot reply channel.
struct Request {
    req: ServeRequest,
    enqueued: Instant,
    respond: Sender<ServeReply>,
}

/// A reply that may already be available (synchronous rejection) or
/// still in flight.  [`Pending::wait`] blocks until it resolves.
pub enum Pending {
    Ready(ServeReply),
    Waiting(Receiver<ServeReply>),
}

impl Pending {
    /// Block until the reply arrives (a dropped service answers
    /// [`RejectReason::Stopped`] rather than hanging).
    pub fn wait(self) -> ServeReply {
        match self {
            Pending::Ready(reply) => reply,
            Pending::Waiting(rx) => rx
                .recv()
                .unwrap_or_else(|_| ServeReply::Rejected(RejectReason::Stopped)),
        }
    }
}

/// Batched transform/predict service over one fitted pipeline version.
pub struct TransformService {
    tx: SyncSender<Request>,
    handle: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<ServeMetrics>,
    n_features: usize,
    queue_capacity: usize,
    key: String,
    version: String,
}

impl TransformService {
    /// Spawn the batcher thread over a trained pipeline — the single
    /// constructor for every backend / queueing / batching combination.
    pub fn start(model: Arc<PipelineModel>, cfg: ServeConfig) -> Self {
        let ServeConfig {
            backend,
            policy,
            queue_capacity,
            key,
            version,
            plan,
            plan_policy,
            hold_gate,
        } = cfg;
        let queue_capacity = queue_capacity.max(1);
        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(queue_capacity);
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(ServeMetrics::default());
        let n_features = model.perm.len();
        let stop_c = stop.clone();
        let metrics_c = metrics.clone();
        let stamp = (key.clone(), version.clone());
        let handle = std::thread::spawn(move || {
            // the backend is constructed inside the batcher thread: the
            // ComputeBackend trait is !Send by design, only the Send
            // ServeBackend description crosses
            let backend: Box<dyn ComputeBackend> = match backend {
                ServeBackend::Native => Box::new(NativeBackend),
                ServeBackend::Sharded { workers } => {
                    ShardedBackend::boxed_with_min_rows(workers, SERVE_MIN_ROWS_PER_SHARD)
                }
                ServeBackend::Pooled { handle, inner_workers } => {
                    ShardedBackend::boxed_with_handle(
                        handle,
                        inner_workers,
                        SERVE_MIN_ROWS_PER_SHARD,
                    )
                }
            };
            // adopt the registry-compiled plan or compile one now — either
            // way the arm counts exactly one build, and warmup grows every
            // scratch slab to steady-state size before the first request
            let plan = plan
                .unwrap_or_else(|| Arc::new(TransformPlan::build(model.clone(), &plan_policy)));
            metrics_c.plan_builds.fetch_add(1, Ordering::Relaxed);
            metrics_c.plan_build_us.fetch_add(plan.build_micros(), Ordering::Relaxed);
            let mut scratch = TransformScratch::new();
            plan.warm(&mut scratch);
            if let Some(gate) = hold_gate {
                // stop must still end the spin, or dropping a gated
                // service would join a thread that never exits
                while gate.load(Ordering::SeqCst) && !stop_c.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            let mut arm = ArmState { model, plan, scratch };
            batcher_loop(&mut arm, rx, policy, stop_c, metrics_c, backend.as_ref(), &stamp)
        });
        TransformService {
            tx,
            handle: Some(handle),
            stop,
            metrics,
            n_features,
            queue_capacity,
            key,
            version,
        }
    }

    /// Registry key this service answers under.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Registry version this service answers under.
    pub fn version(&self) -> &str {
        &self.version
    }

    /// Feature length the model expects.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Admit a request without waiting for the answer.  Shape errors and
    /// a full queue resolve synchronously ([`Pending::Ready`]); admitted
    /// requests resolve when the batcher answers.
    pub fn enqueue(&self, req: ServeRequest) -> Pending {
        let rows = req.rows();
        if rows.is_empty() {
            self.metrics.rejected_shape.fetch_add(1, Ordering::Relaxed);
            return Pending::Ready(ServeReply::Rejected(RejectReason::BadShape {
                got: 0,
                want: self.n_features,
            }));
        }
        for (i, row) in rows.iter().enumerate() {
            if row.len() != self.n_features {
                self.metrics.rejected_shape.fetch_add(1, Ordering::Relaxed);
                return Pending::Ready(ServeReply::Rejected(RejectReason::BadShape {
                    got: row.len(),
                    want: self.n_features,
                }));
            }
            // NaN/∞ gate at admission: a non-finite value would poison
            // every score in the flush it shares (and historically could
            // panic NaN-unsafe comparisons downstream)
            if let Some(j) = row.iter().position(|v| !v.is_finite()) {
                self.metrics.rejected_value.fetch_add(1, Ordering::Relaxed);
                return Pending::Ready(ServeReply::Rejected(RejectReason::NonFinite {
                    row: i,
                    col: j,
                }));
            }
        }
        let (rtx, rrx) = channel();
        match self.tx.try_send(Request { req, enqueued: Instant::now(), respond: rtx }) {
            Ok(()) => Pending::Waiting(rrx),
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected_full.fetch_add(1, Ordering::Relaxed);
                Pending::Ready(ServeReply::Rejected(RejectReason::QueueFull {
                    capacity: self.queue_capacity,
                }))
            }
            Err(TrySendError::Disconnected(_)) => {
                Pending::Ready(ServeReply::Rejected(RejectReason::Stopped))
            }
        }
    }

    /// Submit a request and block for its reply.
    pub fn submit(&self, req: ServeRequest) -> ServeReply {
        self.enqueue(req).wait()
    }

    /// Single-row convenience: submit and unwrap (rejections become
    /// typed errors).
    pub fn predict_blocking(&self, row: Vec<f64>) -> Result<ServeAnswer> {
        self.submit(ServeRequest::row(row)).answer()
    }

    /// Fire-and-collect helper used by the demo/benches: submit many
    /// single-row requests from this thread, answers in submission order.
    /// Keeps at most `queue_capacity` requests in flight so its own
    /// traffic can never trip the bounded queue's admission control.
    pub fn predict_many(&self, rows: Vec<Vec<f64>>) -> Result<Vec<ServeAnswer>> {
        let mut out = Vec::with_capacity(rows.len());
        let mut pendings: Vec<Pending> = Vec::with_capacity(self.queue_capacity);
        for row in rows {
            pendings.push(self.enqueue(ServeRequest::row(row)));
            if pendings.len() == self.queue_capacity {
                for p in pendings.drain(..) {
                    out.push(p.wait().answer()?);
                }
            }
        }
        for p in pendings {
            out.push(p.wait().answer()?);
        }
        Ok(out)
    }

    /// Graceful shutdown (drains and answers pending requests first).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TransformService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Per-arm serving state threaded through the batcher: the fitted model
/// (the legacy path large sharded batches still take), its compiled
/// transform plan, and the reusable per-worker scratch slabs.
struct ArmState {
    model: Arc<PipelineModel>,
    plan: Arc<TransformPlan>,
    scratch: TransformScratch,
}

fn batcher_loop(
    arm: &mut ArmState,
    rx: Receiver<Request>,
    policy: BatchPolicy,
    stop: Arc<AtomicBool>,
    metrics: Arc<ServeMetrics>,
    backend: &dyn ComputeBackend,
    stamp: &(String, String),
) {
    let mut pending: Vec<Request> = Vec::new();
    let mut pending_rows = 0usize;
    loop {
        // drain whatever is available without blocking
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    pending_rows += req.req.n_rows();
                    pending.push(req);
                    if pending_rows >= policy.max_batch {
                        break;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    flush(arm, &mut pending, &metrics, backend, stamp);
                    return;
                }
            }
        }
        // Perf pass #1 (EXPERIMENTS.md §Perf): continuous batching.  Once
        // the channel is drained, flush whatever accumulated — under
        // sustained load the batch naturally grows to what arrived during
        // the previous flush's processing; waiting out `max_wait` only
        // added latency (p50 was pinned at the deadline).  `max_wait`
        // remains as the recv_timeout pacing below.
        if !pending.is_empty() {
            pending_rows = 0;
            flush(arm, &mut pending, &metrics, backend, stamp);
            continue;
        }
        if stop.load(Ordering::SeqCst) {
            // drain everything still queued so a request in flight on a
            // hot-swapped-out version still gets its (old-version) reply
            while let Ok(req) = rx.try_recv() {
                pending.push(req);
            }
            flush(arm, &mut pending, &metrics, backend, stamp);
            return;
        }
        // block for the next request, up to the configured pacing
        match rx.recv_timeout(policy.max_wait) {
            Ok(req) => {
                pending_rows += req.req.n_rows();
                pending.push(req);
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                flush(arm, &mut pending, &metrics, backend, stamp);
                return;
            }
        }
    }
}

fn flush(
    arm: &mut ArmState,
    pending: &mut Vec<Request>,
    metrics: &ServeMetrics,
    backend: &dyn ComputeBackend,
    stamp: &(String, String),
) {
    if pending.is_empty() {
        return;
    }
    let flush_start = Instant::now();
    let batch: Vec<Request> = std::mem::take(pending);
    // deadline check at dequeue: expired requests are rejected before
    // any compute is spent on them
    let mut alive: Vec<Request> = Vec::with_capacity(batch.len());
    for req in batch {
        if let Some(deadline) = req.req.deadline {
            let waited = flush_start.saturating_duration_since(req.enqueued);
            if waited > deadline {
                metrics.rejected_deadline.fetch_add(1, Ordering::Relaxed);
                let _ = req
                    .respond
                    .send(ServeReply::Rejected(RejectReason::DeadlineExpired { waited }));
                continue;
            }
        }
        alive.push(req);
    }
    if alive.is_empty() {
        return;
    }
    let rows: Vec<Vec<f64>> =
        alive.iter().flat_map(|r| r.req.rows().iter().cloned()).collect();
    let n_rows = rows.len();
    let x = Matrix::from_rows(&rows).expect("uniform rows");
    let t_compute = Instant::now();
    // plan path whenever the backend would not shard this batch anyway;
    // large sharded batches keep the legacy backend fan-out.  The dense
    // plan is bitwise identical to the legacy path, so routing never
    // changes answers.
    let (labels, scores) = if backend.preferred_shards(n_rows) <= 1 {
        metrics.plan_hits.fetch_add(1, Ordering::Relaxed);
        if arm.plan.sparse_engaged() {
            metrics.plan_sparse_hits.fetch_add(1, Ordering::Relaxed);
            metrics.plan_flops_saved.fetch_add(
                arm.plan.flops_saved_per_row() * n_rows as u64,
                Ordering::Relaxed,
            );
        }
        arm.plan.predict_scores(&x, &mut arm.scratch)
    } else {
        arm.model.predict_scores_with_backend(&x, backend)
    };
    let compute = t_compute.elapsed();
    metrics.requests.fetch_add(alive.len() as u64, Ordering::Relaxed);
    metrics.rows.fetch_add(n_rows as u64, Ordering::Relaxed);
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.max_batch.fetch_max(n_rows as u64, Ordering::Relaxed);
    metrics.batch_rows_hist.record(n_rows as u64);
    metrics.compute_us.fetch_add(
        compute.as_micros() as u64 * alive.len() as u64,
        Ordering::Relaxed,
    );
    let mut off = 0usize;
    for req in alive {
        let k = req.req.n_rows();
        let predictions = (off..off + k)
            .map(|i| Prediction { label: labels[i], scores: scores[i].clone() })
            .collect();
        off += k;
        let queue_latency = flush_start.saturating_duration_since(req.enqueued);
        metrics.queue_us.fetch_add(queue_latency.as_micros() as u64, Ordering::Relaxed);
        metrics
            .latency_us_hist
            .record(req.enqueued.elapsed().as_micros() as u64);
        let _ = req.respond.send(ServeReply::Answered(ServeAnswer {
            predictions,
            model_key: stamp.0.clone(),
            model_version: stamp.1.clone(),
            queue_latency,
            compute_latency: compute,
            batch_rows: n_rows,
        }));
    }
}

/// Latency summary helper for the demo/benches.
pub fn latency_percentiles(mut lat_us: Vec<f64>) -> (f64, f64, f64) {
    if lat_us.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    lat_us.sort_by(f64::total_cmp);
    let pick = |q: f64| lat_us[((lat_us.len() - 1) as f64 * q).round() as usize];
    (pick(0.5), pick(0.95), pick(0.99))
}

/// Shared-state stress helper used by tests: submit from several threads.
pub fn stress(service: &TransformService, rows: Vec<Vec<f64>>, threads: usize) -> Vec<usize> {
    let rows = Arc::new(Mutex::new(rows));
    let out = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let rows = rows.clone();
            let out = out.clone();
            let svc = &*service;
            scope.spawn(move || loop {
                let row = rows.lock().unwrap().pop();
                match row {
                    Some(r) => {
                        let resp = svc.predict_blocking(r).expect("predict");
                        out.lock().unwrap().push(resp.label());
                    }
                    None => break,
                }
            });
        }
    });
    Arc::try_unwrap(out).unwrap().into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::estimator::EstimatorConfig;
    use crate::oavi::OaviConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, PipelineConfig};
    use crate::svm::linear::LinearSvmConfig;

    fn trained_model() -> Arc<PipelineModel> {
        let ds = synthetic_dataset(300, 21);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        Arc::new(train_pipeline(&cfg, &ds).unwrap())
    }

    #[test]
    fn serves_predictions_matching_offline_path() {
        let model = trained_model();
        let ds = synthetic_dataset(64, 22);
        let offline = model.predict(&ds.x);
        let svc = TransformService::start(model.clone(), ServeConfig::default());
        let rows: Vec<Vec<f64>> = (0..64).map(|i| ds.x.row(i).to_vec()).collect();
        let responses = svc.predict_many(rows).unwrap();
        let online: Vec<usize> = responses.iter().map(|r| r.label()).collect();
        assert_eq!(online, offline);
        assert!(svc.metrics.requests.load(Ordering::Relaxed) == 64);
        assert!(svc.metrics.batches.load(Ordering::Relaxed) >= 1);
        assert_eq!(svc.metrics.batch_rows_hist.total(),
                   svc.metrics.batches.load(Ordering::Relaxed));
        svc.shutdown();
    }

    #[test]
    fn replies_carry_stamp_scores_and_latency_split() {
        let model = trained_model();
        let ds = synthetic_dataset(8, 23);
        let svc = TransformService::start(
            model.clone(),
            ServeConfig::new().stamp("champ", "v7"),
        );
        let ans = svc.predict_blocking(ds.x.row(0).to_vec()).unwrap();
        assert_eq!(ans.model_key, "champ");
        assert_eq!(ans.model_version, "v7");
        assert_eq!(ans.predictions.len(), 1);
        // scores agree with the offline decision path bit-for-bit
        let (labels, scores) =
            model.predict_scores_with_backend(&ds.x, &crate::backend::NativeBackend);
        assert_eq!(ans.label(), labels[0]);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&ans.predictions[0].scores), bits(&scores[0]));
        assert!(ans.compute_latency > Duration::ZERO);
        svc.shutdown();
    }

    #[test]
    fn batch_payload_is_answered_as_one_unit() {
        let model = trained_model();
        let ds = synthetic_dataset(20, 24);
        let offline = model.predict(&ds.x);
        let svc = TransformService::start(model, ServeConfig::default());
        let rows: Vec<Vec<f64>> = (0..20).map(|i| ds.x.row(i).to_vec()).collect();
        let reply = svc.submit(ServeRequest::batch(rows));
        let ans = reply.answer().unwrap();
        assert_eq!(ans.predictions.len(), 20);
        let labels: Vec<usize> = ans.predictions.iter().map(|p| p.label).collect();
        assert_eq!(labels, offline);
        assert_eq!(svc.metrics.rows.load(Ordering::Relaxed), 20);
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn sharded_and_pooled_configs_match_offline_path() {
        use crate::coordinator::pool::ThreadPool;
        let model = trained_model();
        let ds = synthetic_dataset(48, 25);
        let offline = model.predict(&ds.x);
        let rows = |n: usize| -> Vec<Vec<f64>> {
            (0..n).map(|i| ds.x.row(i).to_vec()).collect()
        };
        let svc = TransformService::start(model.clone(), ServeConfig::new().sharded(3));
        let online: Vec<usize> =
            svc.predict_many(rows(48)).unwrap().iter().map(|r| r.label()).collect();
        assert_eq!(online, offline);
        svc.shutdown();

        let pool = ThreadPool::new(3);
        let svc = TransformService::start(
            model.clone(),
            ServeConfig::new().pooled(pool.handle(), pool.workers()),
        );
        let online: Vec<usize> =
            svc.predict_many(rows(48)).unwrap().iter().map(|r| r.label()).collect();
        assert_eq!(online, offline);
        svc.shutdown();
        // the shared pool survives the service and stays usable
        let jobs: Vec<crate::coordinator::pool::Job<'static, u32>> =
            vec![Box::new(|| 1), Box::new(|| 2)];
        assert_eq!(pool.run_all(jobs), vec![1, 2]);
    }

    #[test]
    fn plan_counters_track_builds_and_hits() {
        let model = trained_model();
        let ds = synthetic_dataset(16, 30);
        let svc = TransformService::start(model.clone(), ServeConfig::default());
        let rows: Vec<Vec<f64>> = (0..16).map(|i| ds.x.row(i).to_vec()).collect();
        svc.predict_many(rows).unwrap();
        assert_eq!(svc.metrics.plan_builds.load(Ordering::Relaxed), 1);
        assert!(svc.metrics.plan_hits.load(Ordering::Relaxed) >= 1);
        // the dense default never engages the packed kernel
        assert_eq!(svc.metrics.plan_sparse_hits.load(Ordering::Relaxed), 0);
        assert_eq!(svc.metrics.plan_flops_saved.load(Ordering::Relaxed), 0);
        svc.shutdown();

        // an adopted pre-compiled plan still counts as this arm's build
        let plan = Arc::new(TransformPlan::build(model.clone(), &PlanPolicy::default()));
        let svc = TransformService::start(model, ServeConfig::new().with_plan(plan));
        let ans = svc.predict_blocking(ds.x.row(0).to_vec()).unwrap();
        assert_eq!(ans.predictions.len(), 1);
        assert_eq!(svc.metrics.plan_builds.load(Ordering::Relaxed), 1);
        assert!(svc.metrics.plan_hits.load(Ordering::Relaxed) >= 1);
        svc.shutdown();
    }

    #[test]
    fn batches_respect_cap() {
        let model = trained_model();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) };
        let svc = TransformService::start(model, ServeConfig::new().batch(policy));
        let ds = synthetic_dataset(40, 23);
        let rows: Vec<Vec<f64>> = (0..40).map(|i| ds.x.row(i).to_vec()).collect();
        let responses = svc.predict_many(rows).unwrap();
        for r in &responses {
            assert!(r.batch_rows <= 8, "batch {}", r.batch_rows);
        }
        assert!(svc.metrics.max_batch.load(Ordering::Relaxed) <= 8);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let model = trained_model();
        let svc = TransformService::start(model, ServeConfig::default());
        let ds = synthetic_dataset(60, 24);
        let rows: Vec<Vec<f64>> = (0..60).map(|i| ds.x.row(i).to_vec()).collect();
        let labels = stress(&svc, rows, 4);
        assert_eq!(labels.len(), 60);
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 60);
        svc.shutdown();
    }

    #[test]
    fn rejects_bad_feature_length_synchronously() {
        let model = trained_model();
        let svc = TransformService::start(model, ServeConfig::default());
        let reply = svc.submit(ServeRequest::row(vec![0.0; 99]));
        match reply {
            ServeReply::Rejected(RejectReason::BadShape { got: 99, .. }) => {}
            other => panic!("expected BadShape, got {other:?}"),
        }
        assert!(svc.submit(ServeRequest::batch(vec![])).is_rejected());
        assert!(svc.predict_blocking(vec![0.0; 99]).is_err());
        assert_eq!(svc.metrics.rejected_shape.load(Ordering::Relaxed), 3);
        svc.shutdown();
    }

    #[test]
    fn non_finite_rows_reject_without_poisoning_the_service() {
        let model = trained_model();
        let ds = synthetic_dataset(10, 29);
        let n = model.perm.len();
        let svc = TransformService::start(model, ServeConfig::default());
        for (poison, col) in [(f64::NAN, 0), (f64::INFINITY, n - 1), (f64::NEG_INFINITY, 1)] {
            let mut row = ds.x.row(0).to_vec();
            row[col] = poison;
            match svc.submit(ServeRequest::row(row)) {
                ServeReply::Rejected(RejectReason::NonFinite { row: 0, col: c }) => {
                    assert_eq!(c, col);
                }
                other => panic!("expected NonFinite, got {other:?}"),
            }
        }
        // a batch reports the offending (row, col) pair
        let mut bad = ds.x.row(1).to_vec();
        bad[2] = f64::NAN;
        let batch = vec![ds.x.row(0).to_vec(), bad];
        match svc.submit(ServeRequest::batch(batch)) {
            ServeReply::Rejected(RejectReason::NonFinite { row: 1, col: 2 }) => {}
            other => panic!("expected NonFinite at (1,2), got {other:?}"),
        }
        assert_eq!(svc.metrics.rejected_value.load(Ordering::Relaxed), 4);
        assert_eq!(svc.metrics.rejected(), 4);
        // the service keeps serving clean rows after every rejection
        let ans = svc.predict_blocking(ds.x.row(0).to_vec()).unwrap();
        assert!(ans.predictions[0].scores.iter().all(|s| s.is_finite()));
        svc.shutdown();
    }

    #[test]
    fn bounded_queue_rejects_instead_of_blocking_or_dropping() {
        let model = trained_model();
        let ds = synthetic_dataset(10, 26);
        let gate = Arc::new(AtomicBool::new(true));
        let svc = TransformService::start(model, ServeConfig {
            queue_capacity: 2,
            hold_gate: Some(gate.clone()),
            ..ServeConfig::default()
        });
        // batcher is held: exactly `capacity` admissions, then sync rejects
        let row = || ds.x.row(0).to_vec();
        let p1 = svc.enqueue(ServeRequest::row(row()));
        let p2 = svc.enqueue(ServeRequest::row(row()));
        let t0 = Instant::now();
        let p3 = svc.enqueue(ServeRequest::row(row()));
        assert!(t0.elapsed() < Duration::from_millis(100), "rejection must not block");
        match p3 {
            Pending::Ready(ServeReply::Rejected(RejectReason::QueueFull { capacity: 2 })) => {}
            _ => panic!("expected synchronous QueueFull"),
        }
        assert_eq!(svc.metrics.rejected_full.load(Ordering::Relaxed), 1);
        // release the batcher: the two admitted requests are answered
        gate.store(false, Ordering::SeqCst);
        assert!(p1.wait().answer().is_ok());
        assert!(p2.wait().answer().is_ok());
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 2);
        svc.shutdown();
    }

    #[test]
    fn expired_deadlines_reject_at_dequeue() {
        let model = trained_model();
        let ds = synthetic_dataset(10, 27);
        let gate = Arc::new(AtomicBool::new(true));
        let svc = TransformService::start(model, ServeConfig {
            hold_gate: Some(gate.clone()),
            ..ServeConfig::default()
        });
        let expired = svc.enqueue(
            ServeRequest::row(ds.x.row(0).to_vec()).with_deadline(Duration::from_millis(1)),
        );
        let patient = svc.enqueue(
            ServeRequest::row(ds.x.row(1).to_vec()).with_deadline(Duration::from_secs(60)),
        );
        std::thread::sleep(Duration::from_millis(20));
        gate.store(false, Ordering::SeqCst);
        match expired.wait() {
            ServeReply::Rejected(RejectReason::DeadlineExpired { waited }) => {
                assert!(waited >= Duration::from_millis(1));
            }
            other => panic!("expected DeadlineExpired, got {other:?}"),
        }
        assert!(patient.wait().answer().is_ok());
        assert_eq!(svc.metrics.rejected_deadline.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn shutdown_answers_queued_requests() {
        let model = trained_model();
        let ds = synthetic_dataset(10, 28);
        let gate = Arc::new(AtomicBool::new(true));
        let svc = TransformService::start(model, ServeConfig {
            hold_gate: Some(gate.clone()),
            ..ServeConfig::default()
        });
        let p = svc.enqueue(ServeRequest::row(ds.x.row(0).to_vec()));
        gate.store(false, Ordering::SeqCst);
        svc.shutdown(); // drain + join: the queued request must be answered
        assert!(p.wait().answer().is_ok());
    }

    #[test]
    fn percentiles() {
        let (p50, p95, p99) = latency_percentiles(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(p50, 3.0);
        assert_eq!(p95, 100.0);
        assert_eq!(p99, 100.0);
        assert_eq!(latency_percentiles(vec![]), (0.0, 0.0, 0.0));
    }

    #[test]
    fn histogram_buckets_and_json() {
        let h = Histogram::new(&[10, 100]);
        h.record(5);
        h.record(10);
        h.record(50);
        h.record(1000);
        assert_eq!(h.snapshot(), vec![2, 1, 1]);
        assert_eq!(h.total(), 4);
        let json = h.to_json();
        assert!(json.contains("\"+inf\""), "{json}");
        assert!(json.contains("[2,1,1]"), "{json}");
    }
}

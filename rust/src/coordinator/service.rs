//! Serving-style transform service: clients submit feature rows, a
//! batcher thread groups them (vLLM-router style — size- or
//! deadline-triggered), runs the (FT) transform + SVM through the fitted
//! pipeline, and answers each request exactly once.
//!
//! This is the request path the architecture contract cares about: the
//! pipeline model wraps AOT PJRT executables (or the native backend) and
//! no Python is anywhere near it.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{ComputeBackend, ShardedBackend};
use crate::coordinator::pool::PoolHandle;
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::pipeline::PipelineModel;

/// One inference request: a feature row + a oneshot response channel.
struct Request {
    row: Vec<f64>,
    enqueued: Instant,
    respond: Sender<Response>,
}

/// The answer to a request.
#[derive(Clone, Debug)]
pub struct Response {
    pub label: usize,
    /// end-to-end latency as observed by the service.
    pub latency: Duration,
    /// how many requests shared the batch.
    pub batch_size: usize,
}

/// Service counters.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub max_batch: AtomicU64,
}

/// Batched transform/predict service over a fitted pipeline.
pub struct TransformService {
    tx: Sender<Request>,
    handle: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<ServeMetrics>,
    n_features: usize,
}

/// Shard floor for serving batches: per-row transform work (ℓ·g fused
/// multiply-adds across every class block) is much heavier than the
/// training dot products, so sharding pays off at smaller row counts
/// than training's `MIN_ROWS_PER_SHARD`.
pub const SERVE_MIN_ROWS_PER_SHARD: usize = 1024;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// flush when this many requests are pending…
    pub max_batch: usize,
    /// …or when the oldest pending request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 256, max_wait: Duration::from_millis(2) }
    }
}

impl TransformService {
    /// Spawn the batcher thread over a trained pipeline (single-threaded
    /// transform — the seed behavior).
    pub fn start(model: Arc<PipelineModel>, policy: BatchPolicy) -> Self {
        Self::start_sharded(model, policy, 1)
    }

    /// Deprecated alias for [`TransformService::start_pooled`] that owns
    /// a private worker pool: the batcher runs the (FT) transform through
    /// a [`ShardedBackend`] with `intra_workers` shard workers, on top of
    /// the request-level batching.  Kept for the PR-1 call sites; new
    /// code shares the process pool via `start_pooled`.
    pub fn start_sharded(
        model: Arc<PipelineModel>,
        policy: BatchPolicy,
        intra_workers: usize,
    ) -> Self {
        let (tx, rx) = channel::<Request>();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(ServeMetrics::default());
        let n_features = model.perm.len();
        let stop_c = stop.clone();
        let metrics_c = metrics.clone();
        let handle = std::thread::spawn(move || {
            let backend =
                ShardedBackend::boxed_with_min_rows(intra_workers, SERVE_MIN_ROWS_PER_SHARD);
            batcher_loop(model, rx, policy, stop_c, metrics_c, backend.as_ref())
        });
        TransformService { tx, handle: Some(handle), stop, metrics, n_features }
    }

    /// [`TransformService::start`] drawing shard workers from a
    /// **shared** pool: the batcher's (FT) transform fans shards onto
    /// `pool` with an `inner_workers` budget, so serving composes with
    /// whatever else (grid search, per-class refits) the process runs on
    /// the same workers.  The persistent pool's cheap dispatch means the
    /// serving shard floor ([`SERVE_MIN_ROWS_PER_SHARD`]) — not thread
    /// spawn cost — is what gates small batches now.  The backend itself
    /// is still constructed inside the batcher thread (the
    /// `ComputeBackend` trait is `!Send` by design); only the `Send +
    /// Sync` [`PoolHandle`] crosses.
    pub fn start_pooled(
        model: Arc<PipelineModel>,
        policy: BatchPolicy,
        pool: PoolHandle,
        inner_workers: usize,
    ) -> Self {
        let (tx, rx) = channel::<Request>();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(ServeMetrics::default());
        let n_features = model.perm.len();
        let stop_c = stop.clone();
        let metrics_c = metrics.clone();
        let handle = std::thread::spawn(move || {
            let backend = ShardedBackend::boxed_with_handle(
                pool,
                inner_workers,
                SERVE_MIN_ROWS_PER_SHARD,
            );
            batcher_loop(model, rx, policy, stop_c, metrics_c, backend.as_ref())
        });
        TransformService { tx, handle: Some(handle), stop, metrics, n_features }
    }

    /// Submit a row; blocks until the prediction arrives.
    pub fn predict_blocking(&self, row: Vec<f64>) -> Result<Response> {
        if row.len() != self.n_features {
            return Err(AviError::Coordinator(format!(
                "feature length {} != {}",
                row.len(),
                self.n_features
            )));
        }
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { row, enqueued: Instant::now(), respond: rtx })
            .map_err(|_| AviError::Coordinator("service stopped".into()))?;
        rrx.recv().map_err(|_| AviError::Coordinator("response dropped".into()))
    }

    /// Fire-and-collect helper used by the demo/benches: submit many rows
    /// from this thread, return all responses.
    pub fn predict_many(&self, rows: Vec<Vec<f64>>) -> Result<Vec<Response>> {
        let mut rxs = Vec::with_capacity(rows.len());
        for row in rows {
            if row.len() != self.n_features {
                return Err(AviError::Coordinator("bad feature length".into()));
            }
            let (rtx, rrx) = channel();
            self.tx
                .send(Request { row, enqueued: Instant::now(), respond: rtx })
                .map_err(|_| AviError::Coordinator("service stopped".into()))?;
            rxs.push(rrx);
        }
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| AviError::Coordinator("response dropped".into())))
            .collect()
    }

    /// Graceful shutdown (drains pending requests first).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TransformService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn batcher_loop(
    model: Arc<PipelineModel>,
    rx: Receiver<Request>,
    policy: BatchPolicy,
    stop: Arc<AtomicBool>,
    metrics: Arc<ServeMetrics>,
    backend: &dyn ComputeBackend,
) {
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // drain whatever is available without blocking
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    pending.push(req);
                    if pending.len() >= policy.max_batch {
                        break;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    flush(&model, &mut pending, &metrics, backend);
                    return;
                }
            }
        }
        // Perf pass #1 (EXPERIMENTS.md §Perf): continuous batching.  Once
        // the channel is drained, flush whatever accumulated — under
        // sustained load the batch naturally grows to what arrived during
        // the previous flush's processing; waiting out `max_wait` only
        // added latency (p50 was pinned at the deadline).  `max_wait`
        // remains as the recv_timeout pacing below.
        if !pending.is_empty() {
            flush(&model, &mut pending, &metrics, backend);
            continue;
        }
        if stop.load(Ordering::SeqCst) {
            flush(&model, &mut pending, &metrics, backend);
            return;
        }
        if pending.is_empty() {
            // block briefly for the next request
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(req) => pending.push(req),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        } else {
            std::thread::yield_now();
        }
    }
}

fn flush(
    model: &PipelineModel,
    pending: &mut Vec<Request>,
    metrics: &ServeMetrics,
    backend: &dyn ComputeBackend,
) {
    if pending.is_empty() {
        return;
    }
    let batch: Vec<Request> = std::mem::take(pending);
    let rows: Vec<Vec<f64>> = batch.iter().map(|r| r.row.clone()).collect();
    let x = Matrix::from_rows(&rows).expect("uniform rows");
    let labels = model.predict_with_backend(&x, backend);
    let bsz = batch.len();
    metrics.requests.fetch_add(bsz as u64, Ordering::Relaxed);
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.max_batch.fetch_max(bsz as u64, Ordering::Relaxed);
    for (req, label) in batch.into_iter().zip(labels.into_iter()) {
        let _ = req.respond.send(Response {
            label,
            latency: req.enqueued.elapsed(),
            batch_size: bsz,
        });
    }
}

/// Latency summary helper for the demo/benches.
pub fn latency_percentiles(mut lat_us: Vec<f64>) -> (f64, f64, f64) {
    if lat_us.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| lat_us[((lat_us.len() - 1) as f64 * q).round() as usize];
    (pick(0.5), pick(0.95), pick(0.99))
}

/// Shared-state stress helper used by tests: submit from several threads.
pub fn stress(service: &TransformService, rows: Vec<Vec<f64>>, threads: usize) -> Vec<usize> {
    let rows = Arc::new(Mutex::new(rows));
    let out = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let rows = rows.clone();
            let out = out.clone();
            let svc = &*service;
            scope.spawn(move || loop {
                let row = rows.lock().unwrap().pop();
                match row {
                    Some(r) => {
                        let resp = svc.predict_blocking(r).expect("predict");
                        out.lock().unwrap().push(resp.label);
                    }
                    None => break,
                }
            });
        }
    });
    Arc::try_unwrap(out).unwrap().into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::estimator::EstimatorConfig;
    use crate::oavi::OaviConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, PipelineConfig};
    use crate::svm::linear::LinearSvmConfig;

    fn trained_model() -> Arc<PipelineModel> {
        let ds = synthetic_dataset(300, 21);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        Arc::new(train_pipeline(&cfg, &ds).unwrap())
    }

    #[test]
    fn serves_predictions_matching_offline_path() {
        let model = trained_model();
        let ds = synthetic_dataset(64, 22);
        let offline = model.predict(&ds.x);
        let svc = TransformService::start(model.clone(), BatchPolicy::default());
        let rows: Vec<Vec<f64>> = (0..64).map(|i| ds.x.row(i).to_vec()).collect();
        let responses = svc.predict_many(rows).unwrap();
        let online: Vec<usize> = responses.iter().map(|r| r.label).collect();
        assert_eq!(online, offline);
        assert!(svc.metrics.requests.load(Ordering::Relaxed) == 64);
        assert!(svc.metrics.batches.load(Ordering::Relaxed) >= 1);
        svc.shutdown();
    }

    #[test]
    fn sharded_service_matches_offline_path() {
        let model = trained_model();
        let ds = synthetic_dataset(48, 25);
        let offline = model.predict(&ds.x);
        let svc = TransformService::start_sharded(model.clone(), BatchPolicy::default(), 3);
        let rows: Vec<Vec<f64>> = (0..48).map(|i| ds.x.row(i).to_vec()).collect();
        let responses = svc.predict_many(rows).unwrap();
        let online: Vec<usize> = responses.iter().map(|r| r.label).collect();
        assert_eq!(online, offline);
        svc.shutdown();
    }

    #[test]
    fn pooled_service_matches_offline_path() {
        use crate::coordinator::pool::ThreadPool;
        let model = trained_model();
        let ds = synthetic_dataset(52, 26);
        let offline = model.predict(&ds.x);
        let pool = ThreadPool::new(3);
        let svc = TransformService::start_pooled(
            model.clone(),
            BatchPolicy::default(),
            pool.handle(),
            pool.workers(),
        );
        let rows: Vec<Vec<f64>> = (0..52).map(|i| ds.x.row(i).to_vec()).collect();
        let responses = svc.predict_many(rows).unwrap();
        let online: Vec<usize> = responses.iter().map(|r| r.label).collect();
        assert_eq!(online, offline);
        svc.shutdown();
        // the shared pool survives the service and stays usable
        let jobs: Vec<crate::coordinator::pool::Job<'static, u32>> =
            vec![Box::new(|| 1), Box::new(|| 2)];
        assert_eq!(pool.run_all(jobs), vec![1, 2]);
    }

    #[test]
    fn batches_respect_cap() {
        let model = trained_model();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) };
        let svc = TransformService::start(model, policy);
        let ds = synthetic_dataset(40, 23);
        let rows: Vec<Vec<f64>> = (0..40).map(|i| ds.x.row(i).to_vec()).collect();
        let responses = svc.predict_many(rows).unwrap();
        for r in &responses {
            assert!(r.batch_size <= 8, "batch {}", r.batch_size);
        }
        assert!(svc.metrics.max_batch.load(Ordering::Relaxed) <= 8);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let model = trained_model();
        let svc = TransformService::start(model, BatchPolicy::default());
        let ds = synthetic_dataset(60, 24);
        let rows: Vec<Vec<f64>> = (0..60).map(|i| ds.x.row(i).to_vec()).collect();
        let labels = stress(&svc, rows, 4);
        assert_eq!(labels.len(), 60);
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 60);
        svc.shutdown();
    }

    #[test]
    fn rejects_bad_feature_length() {
        let model = trained_model();
        let svc = TransformService::start(model, BatchPolicy::default());
        assert!(svc.predict_blocking(vec![0.0; 99]).is_err());
        svc.shutdown();
    }

    #[test]
    fn percentiles() {
        let (p50, p95, p99) = latency_percentiles(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(p50, 3.0);
        assert_eq!(p95, 100.0);
        assert_eq!(p99, 100.0);
        assert_eq!(latency_percentiles(vec![]), (0.0, 0.0, 0.0));
    }
}

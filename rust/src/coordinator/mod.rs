//! L3 coordinator: the persistent work-stealing thread pool behind both
//! parallelism levels (per-class / per-fold / per-grid-point jobs above
//! the backend trait, shard kernels below it), and a serving-style
//! batched transform service.
//!
//! The paper's contribution is algorithmic, so the coordinator is a thin
//! but real runtime layer (per the architecture contract): it owns worker
//! lifecycles, request routing, batching, and metrics — Python never runs
//! here.

pub mod pool;
pub mod router;
pub mod service;

pub use pool::{PoolHandle, ThreadPool};
pub use router::ModelRouter;
pub use service::{ServeMetrics, TransformService};

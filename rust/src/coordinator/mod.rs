//! L3 coordinator: the serving **control plane** plus the persistent
//! work-stealing thread pool behind both training parallelism levels.
//!
//! # Control-plane layering (front door → registry → router → service → backend)
//!
//! The serving path is five tiers, each consuming only the one below:
//!
//! * **Front door** — [`frontdoor::FrontDoor`]: the std-only network
//!   edge, a thread-per-connection TCP server speaking the framed
//!   [`wire`] protocol (12-byte header — magic `AVIW`, version, kind,
//!   u32-LE length — followed by a JSON payload; see the [`wire`]
//!   module docs for the frame layout, version gate, error codes, and
//!   rejection codes).  It adds what a network edge needs and nothing
//!   else: per-route token-bucket rate limits checked *before*
//!   admission, per-connection read/write deadlines, a max-frame cap
//!   enforced from the header alone, typed error frames for every
//!   protocol fault (never a panic, never a hung socket), per-tenant
//!   namespacing as plain `tenant/key` registry keys
//!   ([`registry::namespaced`]), graceful shutdown that drains
//!   in-flight requests through the router, and wire counters
//!   ([`wire::WireStats`]) folded into the [`router::RouterReport`]
//!   JSON.  The network path is **bitwise identical** to in-process
//!   serving: scores travel as `{:?}`-formatted (shortest-round-trip)
//!   floats.
//!
//!   With a [`frontdoor::ModelControl`] attached, the front door also
//!   speaks the model control plane: `PushModel` lands a
//!   checksum-verified binary artifact ([`crate::artifact`]) in the
//!   durable [`crate::artifact::ArtifactStore`] and registers it,
//!   `ActivateModel` hot-swaps the route to a stored version without a
//!   restart (bounding retained versions per key, latest and live
//!   routes pinned), and `PullModel` hands the verified bytes back.
//!
//! * **Registry** — [`registry::ModelRegistry`]: fitted pipelines
//!   addressable as `key@version`, loaded from the unified persistence
//!   envelope ([`crate::estimator::persist`]) by path, bytes, or
//!   manifest.  The source of truth for *what can be served*; corrupt
//!   envelopes and manifests naming missing files fail with typed
//!   errors, never panics.
//! * **Router** — [`router::ModelRouter`]: traffic policy over
//!   registered versions.  Weighted A/B splits with deterministic seeded
//!   assignment, shadow routes (mirrored traffic, replies discarded,
//!   latency recorded), atomic hot-swap/rollback that lets the old
//!   version drain its in-flight requests, and per-route load reports
//!   exported as one [`router::RouterReport`] JSON document.
//! * **Service** — [`service::TransformService`]: one batcher thread per
//!   served version speaking the typed [`service::ServeRequest`] →
//!   [`service::ServeReply`] protocol (single row or row batch, optional
//!   per-request deadline; answers carry per-class scores, the
//!   `key@version` stamp, and a queue/compute latency split).  Admission
//!   control is a bounded queue: a full queue or an expired deadline
//!   answers a typed [`service::RejectReason`] instead of blocking or
//!   dropping.
//! * **Backend** — [`crate::backend::ComputeBackend`]: the (FT)
//!   transform executes on the sequential native reference, a private
//!   shard pool, or shard workers drawn from the shared process pool.
//!
//! Everything is constructed through one builder-style
//! [`service::ServeConfig`] (backend choice, batch policy, queue bound,
//! stamp) — the single `TransformService::start(model, cfg)` constructor
//! replaced the old `start` / `start_sharded` / `start_pooled` trio.
//!
//! # The pool
//!
//! [`pool::ThreadPool`] / [`pool::PoolHandle`] is the persistent
//! work-stealing pool behind both training parallelism levels
//! (per-class / per-fold / per-grid-point jobs above the backend trait,
//! shard kernels below it) and, through
//! [`service::ServeBackend::Pooled`], the serving shard axis — so
//! serving composes with whatever else the process runs on the same
//! workers.
//!
//! The paper's contribution is algorithmic, so the coordinator is a thin
//! but real runtime layer (per the architecture contract): it owns worker
//! lifecycles, request routing, batching, and metrics — Python never runs
//! here.

pub mod frontdoor;
pub mod pool;
pub mod registry;
pub mod router;
pub mod service;
pub mod wire;

pub use frontdoor::{FrontDoor, FrontDoorConfig, ModelControl, RateLimit};
pub use pool::{PoolHandle, ThreadPool};
pub use registry::ModelRegistry;
pub use router::{ModelRouter, RouterReport};
pub use service::{
    BatchPolicy, RejectReason, ServeAnswer, ServeConfig, ServeMetrics, ServeReply, ServeRequest,
    TransformService,
};
pub use wire::{
    ControlAck, ControlOutcome, PullOutcome, PulledModel, WireClient, WireOutcome,
    WireStats,
};

//! L3 coordinator: thread-pool job scheduling for per-class / per-fold /
//! per-grid-point fits, and a serving-style batched transform service.
//!
//! The paper's contribution is algorithmic, so the coordinator is a thin
//! but real runtime layer (per the architecture contract): it owns worker
//! lifecycles, request routing, batching, and metrics — Python never runs
//! here.

pub mod pool;
pub mod router;
pub mod service;

pub use pool::ThreadPool;
pub use router::ModelRouter;
pub use service::{ServeMetrics, TransformService};

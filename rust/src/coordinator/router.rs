//! Multi-model router: the vLLM-router-shaped piece of the coordinator.
//!
//! Production deployments serve *several* fitted pipelines at once (one
//! per dataset / ψ working point / estimator / A-B arm — the estimator
//! layer makes OAVI, ABM, and VCA routes interchangeable).  The router
//! owns one
//! [`TransformService`] per registered model, routes each request by
//! model key, and load-reports per model.  Routing invariants (pinned by
//! the property tests below):
//!
//! 1. every accepted request is answered exactly once,
//! 2. a request is only ever served by the model it named,
//! 3. unknown keys are rejected synchronously (no silent drops),
//! 4. per-model FIFO: two requests from one client to one model come
//!    back in submission order (batching never reorders within a batch).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::service::{BatchPolicy, Response, TransformService};
use crate::error::{AviError, Result};
use crate::pipeline::PipelineModel;

/// Per-model routing entry.
struct Route {
    service: TransformService,
    requests: AtomicU64,
}

/// A keyed collection of serving pipelines.
pub struct ModelRouter {
    routes: HashMap<String, Route>,
}

impl ModelRouter {
    pub fn new() -> Self {
        ModelRouter { routes: HashMap::new() }
    }

    /// Register a fitted pipeline under `key` (replaces an existing
    /// route with the same key; the old service drains on drop).
    pub fn register(
        &mut self,
        key: impl Into<String>,
        model: Arc<PipelineModel>,
        policy: BatchPolicy,
    ) {
        let service = TransformService::start(model, policy);
        self.routes
            .insert(key.into(), Route { service, requests: AtomicU64::new(0) });
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.routes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.routes.is_empty()
    }

    /// Registered keys (sorted, deterministic).
    pub fn keys(&self) -> Vec<String> {
        let mut k: Vec<String> = self.routes.keys().cloned().collect();
        k.sort();
        k
    }

    /// Route one request to the named model (blocking).
    pub fn predict(&self, key: &str, row: Vec<f64>) -> Result<Response> {
        let route = self
            .routes
            .get(key)
            .ok_or_else(|| AviError::Coordinator(format!("unknown model '{key}'")))?;
        route.requests.fetch_add(1, Ordering::Relaxed);
        route.service.predict_blocking(row)
    }

    /// Route a batch of (key, row) pairs; results come back in input
    /// order.  Rows for the same model are submitted together so the
    /// per-model batcher can coalesce them.
    pub fn predict_batch(&self, items: Vec<(String, Vec<f64>)>) -> Result<Vec<Response>> {
        // group by key, remembering original positions
        let mut by_key: HashMap<&str, Vec<(usize, Vec<f64>)>> = HashMap::new();
        for (i, (key, row)) in items.iter().enumerate() {
            by_key.entry(key.as_str()).or_default().push((i, row.clone()));
        }
        let mut out: Vec<Option<Response>> = vec![None; items.len()];
        for (key, group) in by_key {
            let route = self
                .routes
                .get(key)
                .ok_or_else(|| AviError::Coordinator(format!("unknown model '{key}'")))?;
            route
                .requests
                .fetch_add(group.len() as u64, Ordering::Relaxed);
            let (idxs, rows): (Vec<usize>, Vec<Vec<f64>>) = group.into_iter().unzip();
            let responses = route.service.predict_many(rows)?;
            for (idx, resp) in idxs.into_iter().zip(responses) {
                out[idx] = Some(resp);
            }
        }
        Ok(out.into_iter().map(|r| r.expect("answered")).collect())
    }

    /// (key, requests-served) load report.
    pub fn load_report(&self) -> Vec<(String, u64)> {
        let mut report: Vec<(String, u64)> = self
            .routes
            .iter()
            .map(|(k, r)| (k.clone(), r.requests.load(Ordering::Relaxed)))
            .collect();
        report.sort();
        report
    }
}

impl Default for ModelRouter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::estimator::EstimatorConfig;
    use crate::oavi::OaviConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, PipelineConfig};
    use crate::svm::linear::LinearSvmConfig;

    fn model(psi: f64, seed: u64) -> Arc<PipelineModel> {
        let ds = synthetic_dataset(300, seed);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(psi)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        Arc::new(train_pipeline(&cfg, &ds).unwrap())
    }

    #[test]
    fn routes_serve_every_estimator() {
        // per-estimator serving routes: one fitted pipeline per method
        // behind one router — the serving shape the estimator layer
        // enables (each route's model is a trait-object transformer)
        let ds = synthetic_dataset(240, 9);
        let mut r = ModelRouter::new();
        for est in EstimatorConfig::battery(0.01) {
            let cfg = PipelineConfig {
                estimator: est,
                svm: LinearSvmConfig::default(),
                ordering: FeatureOrdering::Pearson,
            };
            let m = Arc::new(train_pipeline(&cfg, &ds).unwrap());
            r.register(est.name(), m, BatchPolicy::default());
        }
        assert_eq!(r.len(), 4);
        let row = ds.x.row(0).to_vec();
        for key in r.keys() {
            assert!(r.predict(&key, row.clone()).is_ok(), "route {key}");
        }
    }

    fn router() -> ModelRouter {
        let mut r = ModelRouter::new();
        r.register("tight", model(0.001, 1), BatchPolicy::default());
        r.register("loose", model(0.05, 2), BatchPolicy::default());
        r
    }

    #[test]
    fn routes_by_key_and_rejects_unknown() {
        let r = router();
        assert_eq!(r.len(), 2);
        assert_eq!(r.keys(), vec!["loose".to_string(), "tight".to_string()]);
        let ds = synthetic_dataset(10, 3);
        let row = ds.x.row(0).to_vec();
        assert!(r.predict("tight", row.clone()).is_ok());
        assert!(r.predict("nope", row).is_err());
    }

    #[test]
    fn batch_preserves_input_order_across_models() {
        let r = router();
        let ds = synthetic_dataset(40, 4);
        // interleave models
        let items: Vec<(String, Vec<f64>)> = (0..40)
            .map(|i| {
                let key = if i % 2 == 0 { "tight" } else { "loose" };
                (key.to_string(), ds.x.row(i).to_vec())
            })
            .collect();
        let responses = r.predict_batch(items).unwrap();
        assert_eq!(responses.len(), 40);
        // per-model answers match direct submission
        let direct_tight = r.predict("tight", ds.x.row(0).to_vec()).unwrap();
        assert_eq!(responses[0].label, direct_tight.label);
        let report = r.load_report();
        // 20 batch + 1 direct for tight; 20 for loose
        assert_eq!(report[0], ("loose".to_string(), 20));
        assert_eq!(report[1], ("tight".to_string(), 21));
    }

    #[test]
    fn replacing_a_route_keeps_serving() {
        let mut r = router();
        let ds = synthetic_dataset(10, 5);
        let row = ds.x.row(0).to_vec();
        let before = r.predict("tight", row.clone()).unwrap();
        r.register("tight", model(0.001, 1), BatchPolicy::default());
        let after = r.predict("tight", row).unwrap();
        assert_eq!(before.label, after.label); // same training → same model
    }

    #[test]
    fn property_exactly_once_under_concurrency() {
        let r = std::sync::Arc::new(router());
        let ds = synthetic_dataset(64, 6);
        let answered = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for t in 0..4 {
                let r = r.clone();
                let ds = &ds;
                let answered = &answered;
                scope.spawn(move || {
                    for i in 0..16 {
                        let key = if (t + i) % 2 == 0 { "tight" } else { "loose" };
                        let row = ds.x.row((t * 16 + i) % 64).to_vec();
                        r.predict(key, row).unwrap();
                        answered.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(answered.load(std::sync::atomic::Ordering::SeqCst), 64);
        let total: u64 = r.load_report().iter().map(|(_, n)| n).sum();
        assert_eq!(total, 64);
    }
}

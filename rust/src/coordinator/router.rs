//! The serving **router** tier: traffic policies over registered model
//! versions.
//!
//! Production deployments serve *several* fitted pipelines at once (one
//! per dataset / ψ working point / estimator / A-B arm — the estimator
//! layer makes OAVI, ABM, and VCA routes interchangeable).  The router
//! owns one [`TransformService`] per (key, version) arm and decides who
//! serves each request:
//!
//! * **Weighted A/B splits** across versions of a key, with
//!   deterministic seeded assignment (`splitmix64(seed, seq)` over a
//!   per-key submission counter) so a replayed request sequence lands on
//!   the same arms.
//! * **Shadow routes**: traffic mirrored to one extra version whose
//!   replies are discarded — its latency and load are still recorded in
//!   its own metrics, so a candidate can be soak-tested on production
//!   traffic without affecting a single primary reply.
//! * **Hot swap / rollback**: [`ModelRouter::register`] atomically
//!   replaces a live route; requests already admitted to the old
//!   version still get replies stamped with the old version (the old
//!   service drains before it drops), and re-registering an older
//!   version is a rollback.
//! * **Per-route load reports**: request/reject counts, batch-size and
//!   latency histograms for every live and retired arm, exported as one
//!   [`RouterReport`] (JSON via [`RouterReport::to_json`]) the bench
//!   layer can consume.
//!
//! Routing invariants (pinned by the property tests below and
//! `tests/serve_control_plane.rs`):
//!
//! 1. every accepted request is answered exactly once,
//! 2. a request is only ever served by the key it named (and stamped
//!    with the version that served it),
//! 3. unknown keys are rejected synchronously (no silent drops),
//! 4. per-model FIFO: two requests from one client to one key come back
//!    in submission order (batching never reorders within a batch),
//! 5. shadow traffic never changes a primary reply.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::service::{
    Histogram, Pending, ServeAnswer, ServeConfig, ServeMetrics, ServeReply, ServeRequest,
    TransformService, BATCH_BUCKETS, LATENCY_BUCKETS_US,
};
use crate::error::{AviError, Result};
use crate::pipeline::PipelineModel;
use crate::util::json_escape;

/// splitmix64 finalizer over (seed, sequence) — the deterministic arm
/// assignment hash.
fn mix(seed: u64, seq: u64) -> u64 {
    let mut z = seed ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One weighted primary arm of a route.
struct Arm {
    version: String,
    weight: u32,
    service: Arc<TransformService>,
}

/// The mirrored (shadow) arm of a route.
struct ShadowArm {
    version: String,
    service: Arc<TransformService>,
    /// Requests mirrored so far (admitted or rejected by the shadow).
    mirrored: AtomicU64,
}

/// Immutable-per-generation route state; hot swap replaces the whole
/// `Arc` so in-flight requests keep the generation that admitted them.
struct RouteState {
    seed: u64,
    /// Per-key assignment counter.  Shared (`Arc`) across generations
    /// that keep the same arms (adding a shadow), so no sequence number
    /// is ever handed out twice and replays stay deterministic.
    seq: Arc<AtomicU64>,
    arms: Vec<Arm>,
    total_weight: u64,
    shadow: Option<ShadowArm>,
}

impl RouteState {
    /// Deterministic weighted arm choice for the next request.
    fn pick(&self) -> &Arm {
        if self.arms.len() == 1 {
            return &self.arms[0];
        }
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let mut r = mix(self.seed, seq) % self.total_weight;
        for arm in &self.arms {
            if u64::from(arm.weight) > r {
                return arm;
            }
            r -= u64::from(arm.weight);
        }
        self.arms.last().expect("non-empty arms")
    }
}

/// A reply still in flight through the router.  Holds the route
/// generation that admitted the request, so a hot swap cannot tear down
/// the serving version before this reply resolves.
pub struct RouterPending {
    reply: Pending,
    _route: Arc<RouteState>,
}

impl RouterPending {
    /// Block until the reply arrives.
    pub fn wait(self) -> ServeReply {
        self.reply.wait()
    }
}

/// Metrics of an arm that was hot-swapped out — kept so
/// [`RouterReport`] totals stay cumulative across swaps.
struct RetiredArm {
    version: String,
    role: &'static str,
    metrics: Arc<ServeMetrics>,
}

/// Live `Arc`s retained per key before the oldest fold into accumulators
/// (a just-retired arm may still flush in-flight requests; by the time
/// this many further swaps have happened it has long drained).
const MAX_RETIRED_PER_KEY: usize = 8;

/// Retired arms of one key: a bounded window of live metric `Arc`s plus
/// per-(version, role) fold-in accumulators, so unbounded swap/rollback
/// cycles cost O(versions) memory instead of O(swaps).
#[derive(Default)]
struct RetiredSet {
    recent: VecDeque<RetiredArm>,
    folded: Vec<RetiredArm>,
}

impl RetiredSet {
    fn push(&mut self, arm: RetiredArm) {
        self.recent.push_back(arm);
        while self.recent.len() > MAX_RETIRED_PER_KEY {
            // only fold arms that can no longer receive increments: the
            // service and its batcher each hold a metrics Arc clone
            // until the generation fully drains, so strong_count == 1
            // means the counters are final.  A still-draining arm stays
            // in the window (bounded by actual in-flight work).
            let Some(pos) = self
                .recent
                .iter()
                .position(|a| Arc::strong_count(&a.metrics) == 1)
            else {
                break;
            };
            let old = self.recent.remove(pos).expect("position valid");
            let slot = match self
                .folded
                .iter()
                .position(|f| f.version == old.version && f.role == old.role)
            {
                Some(i) => &self.folded[i],
                None => {
                    self.folded.push(RetiredArm {
                        version: old.version.clone(),
                        role: old.role,
                        metrics: Arc::new(ServeMetrics::default()),
                    });
                    self.folded.last().expect("just pushed")
                }
            };
            slot.metrics.absorb(&old.metrics);
        }
    }
}

/// A keyed collection of serving routes with traffic policies.
#[derive(Default)]
pub struct ModelRouter {
    routes: RwLock<HashMap<String, Arc<RouteState>>>,
    retired: Mutex<HashMap<String, RetiredSet>>,
}

impl ModelRouter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or hot-swap) `key` with a single version taking all
    /// traffic.  The previous generation, if any, keeps serving its
    /// in-flight requests and its metrics are retained for the report.
    pub fn register(
        &self,
        key: impl Into<String>,
        version: impl Into<String>,
        model: Arc<PipelineModel>,
        cfg: ServeConfig,
    ) {
        let (key, version) = (key.into(), version.into());
        self.register_split(&key, vec![(version, model, 100)], 0, &cfg)
            .expect("single-arm register cannot fail");
    }

    /// Register (or hot-swap) `key` with weighted A/B arms
    /// `(version, model, weight)`.  Assignment is deterministic for a
    /// fixed `seed` and submission order.  Clears any shadow set on the
    /// previous generation (set it again via [`ModelRouter::set_shadow`]).
    pub fn register_split(
        &self,
        key: &str,
        arms: Vec<(String, Arc<PipelineModel>, u32)>,
        seed: u64,
        cfg: &ServeConfig,
    ) -> Result<()> {
        self.register_split_planned(key, arms, seed, cfg, None)
    }

    /// [`ModelRouter::register_split`] with an optional registry: when
    /// given, each arm adopts the transform plan compiled at registry
    /// insert instead of compiling its own on the batcher thread.
    fn register_split_planned(
        &self,
        key: &str,
        arms: Vec<(String, Arc<PipelineModel>, u32)>,
        seed: u64,
        cfg: &ServeConfig,
        registry: Option<&ModelRegistry>,
    ) -> Result<()> {
        if arms.is_empty() {
            return Err(AviError::Registry(format!("route '{key}': no arms")));
        }
        let total_weight: u64 = arms.iter().map(|(_, _, w)| u64::from(*w)).sum();
        if total_weight == 0 {
            return Err(AviError::Registry(format!("route '{key}': all weights are zero")));
        }
        let arms: Vec<Arm> = arms
            .into_iter()
            .filter(|(_, _, w)| *w > 0)
            .map(|(version, model, weight)| {
                let mut arm_cfg = cfg.clone();
                if let Some(plan) =
                    registry.and_then(|reg| reg.plan_for(key, &version))
                {
                    arm_cfg = arm_cfg.with_plan(plan);
                }
                let service = Arc::new(TransformService::start(
                    model,
                    arm_cfg.stamp(key, &version),
                ));
                Arm { version, weight, service }
            })
            .collect();
        let state = Arc::new(RouteState {
            seed,
            seq: Arc::new(AtomicU64::new(0)),
            arms,
            total_weight,
            shadow: None,
        });
        let old = self.routes.write().expect("routes").insert(key.to_string(), state);
        self.retire(key, old);
        Ok(())
    }

    /// Register every key's latest version from a registry under one
    /// serve configuration.  Each arm adopts the transform plan the
    /// registry compiled at insert, so no route rebuilds operands before
    /// taking traffic.
    pub fn from_registry(registry: &ModelRegistry, cfg: &ServeConfig) -> Self {
        let router = ModelRouter::new();
        for key in registry.keys() {
            if let Some((version, model)) = registry.latest(&key) {
                let mut arm_cfg = cfg.clone();
                if let Some(plan) = registry.plan_for(&key, &version) {
                    arm_cfg = arm_cfg.with_plan(plan);
                }
                router.register(key, version, model, arm_cfg);
            }
        }
        router
    }

    /// Register (or hot-swap) `key` as a weighted split across registry
    /// versions `(version, weight)`.  Arms adopt the registry-compiled
    /// transform plans, so an `ActivateModel` hot-swap serves from a
    /// plan that was warmed before the swap became visible.
    pub fn register_ab(
        &self,
        registry: &ModelRegistry,
        key: &str,
        split: &[(String, u32)],
        seed: u64,
        cfg: &ServeConfig,
    ) -> Result<()> {
        let arms = split
            .iter()
            .map(|(version, weight)| {
                registry.resolve(key, version).map(|m| (version.clone(), m, *weight))
            })
            .collect::<Result<Vec<_>>>()?;
        self.register_split_planned(key, arms, seed, cfg, Some(registry))
    }

    /// Mirror `key`'s traffic to `version` as a shadow: every request is
    /// also enqueued there, the reply is discarded, and the shadow's own
    /// metrics record its latency and load.  Fails on unknown keys.
    pub fn set_shadow(
        &self,
        key: &str,
        version: impl Into<String>,
        model: Arc<PipelineModel>,
        cfg: ServeConfig,
    ) -> Result<()> {
        let version = version.into();
        let mut routes = self.routes.write().expect("routes");
        let old = routes
            .get(key)
            .ok_or_else(|| AviError::Registry(format!("unknown route '{key}'")))?;
        let service = Arc::new(TransformService::start(
            model,
            cfg.stamp(key, &version),
        ));
        // rebuild the state sharing the live arms and the assignment
        // counter itself, so adding a shadow is not a traffic-visible
        // swap and no sequence number is handed out twice
        let state = Arc::new(RouteState {
            seed: old.seed,
            seq: old.seq.clone(),
            arms: old
                .arms
                .iter()
                .map(|a| Arm {
                    version: a.version.clone(),
                    weight: a.weight,
                    service: a.service.clone(),
                })
                .collect(),
            total_weight: old.total_weight,
            shadow: Some(ShadowArm { version, service, mirrored: AtomicU64::new(0) }),
        });
        let old = routes.insert(key.to_string(), state);
        drop(routes);
        // primaries are shared with the new generation; only a replaced
        // shadow's metrics need retiring
        if let Some(old) = old {
            if let Some(sh) = &old.shadow {
                self.retired.lock().expect("retired").entry(key.to_string()).or_default().push(
                    RetiredArm {
                        version: sh.version.clone(),
                        role: "retired-shadow",
                        metrics: sh.service.metrics.clone(),
                    },
                );
            }
        }
        Ok(())
    }

    fn retire(&self, key: &str, old: Option<Arc<RouteState>>) {
        let Some(old) = old else { return };
        let mut retired = self.retired.lock().expect("retired");
        let slot = retired.entry(key.to_string()).or_default();
        for arm in &old.arms {
            slot.push(RetiredArm {
                version: arm.version.clone(),
                role: "retired",
                metrics: arm.service.metrics.clone(),
            });
        }
        if let Some(sh) = &old.shadow {
            slot.push(RetiredArm {
                version: sh.version.clone(),
                role: "retired-shadow",
                metrics: sh.service.metrics.clone(),
            });
        }
        // dropping `old` here only tears the services down once the last
        // in-flight RouterPending releases its generation Arc
    }

    /// Number of registered keys.
    pub fn len(&self) -> usize {
        self.routes.read().expect("routes").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered keys (sorted, deterministic).
    pub fn keys(&self) -> Vec<String> {
        let mut k: Vec<String> =
            self.routes.read().expect("routes").keys().cloned().collect();
        k.sort();
        k
    }

    /// Versions of `key` currently receiving traffic: every A/B arm
    /// plus the shadow, deduplicated, sorted.  Registry eviction pins
    /// these so a hot-swap can never tear a live route's model away.
    pub fn live_versions(&self, key: &str) -> Vec<String> {
        let routes = self.routes.read().expect("routes");
        let Some(state) = routes.get(key) else {
            return Vec::new();
        };
        let mut versions: Vec<String> =
            state.arms.iter().map(|a| a.version.clone()).collect();
        if let Some(shadow) = &state.shadow {
            versions.push(shadow.version.clone());
        }
        versions.sort();
        versions.dedup();
        versions
    }

    /// Admit one request to `key` without waiting for the answer.
    /// Unknown keys fail synchronously; shadow traffic is mirrored
    /// before the primary admission and can never affect it.
    pub fn enqueue(&self, key: &str, req: ServeRequest) -> Result<RouterPending> {
        let route = self
            .routes
            .read()
            .expect("routes")
            .get(key)
            .cloned()
            .ok_or_else(|| AviError::Registry(format!("unknown route '{key}'")))?;
        if let Some(shadow) = &route.shadow {
            shadow.mirrored.fetch_add(1, Ordering::Relaxed);
            // reply discarded; the shadow service still records latency
            // and load in its own metrics
            drop(shadow.service.enqueue(req.clone()));
        }
        let arm = route.pick();
        let reply = arm.service.enqueue(req);
        Ok(RouterPending { reply, _route: route })
    }

    /// Route one request to `key` and block for the reply.
    pub fn submit(&self, key: &str, req: ServeRequest) -> Result<ServeReply> {
        Ok(self.enqueue(key, req)?.wait())
    }

    /// Single-row convenience (rejections become typed errors).
    pub fn predict(&self, key: &str, row: Vec<f64>) -> Result<ServeAnswer> {
        self.submit(key, ServeRequest::row(row))?.answer()
    }

    /// Route a batch of (key, row) pairs; answers come back in input
    /// order.  All requests are admitted before any reply is awaited, so
    /// each key's batcher can coalesce them.
    pub fn predict_batch(&self, items: Vec<(String, Vec<f64>)>) -> Result<Vec<ServeAnswer>> {
        {
            let routes = self.routes.read().expect("routes");
            for (key, _) in &items {
                if !routes.contains_key(key.as_str()) {
                    return Err(AviError::Registry(format!("unknown route '{key}'")));
                }
            }
        }
        let pendings = items
            .into_iter()
            .map(|(key, row)| self.enqueue(&key, ServeRequest::row(row)))
            .collect::<Result<Vec<_>>>()?;
        pendings.into_iter().map(|p| p.wait().answer()).collect()
    }

    /// Snapshot every live and retired arm into one load report.
    pub fn report(&self) -> RouterReport {
        let mut routes: Vec<RouteLoad> = Vec::new();
        {
            let map = self.routes.read().expect("routes");
            for (key, state) in map.iter() {
                for arm in &state.arms {
                    routes.push(RouteLoad::snapshot(
                        key,
                        &arm.version,
                        "primary",
                        arm.weight,
                        &arm.service.metrics,
                        0,
                    ));
                }
                if let Some(sh) = &state.shadow {
                    routes.push(RouteLoad::snapshot(
                        key,
                        &sh.version,
                        "shadow",
                        0,
                        &sh.service.metrics,
                        sh.mirrored.load(Ordering::Relaxed),
                    ));
                }
            }
        }
        {
            // aggregate retired arms per (version, role): repeated swaps
            // of the same version report as one cumulative row
            let retired = self.retired.lock().expect("retired");
            for (key, set) in retired.iter() {
                let mut groups: Vec<(String, &'static str, ServeMetrics)> = Vec::new();
                for arm in set.recent.iter().chain(set.folded.iter()) {
                    let idx = match groups
                        .iter()
                        .position(|(v, r, _)| *v == arm.version && *r == arm.role)
                    {
                        Some(i) => i,
                        None => {
                            groups.push((arm.version.clone(), arm.role, ServeMetrics::default()));
                            groups.len() - 1
                        }
                    };
                    groups[idx].2.absorb(&arm.metrics);
                }
                for (version, role, metrics) in &groups {
                    routes.push(RouteLoad::snapshot(key, version, role, 0, metrics, 0));
                }
            }
        }
        routes.sort_by(|a, b| {
            (&a.key, &a.version, a.role).cmp(&(&b.key, &b.version, b.role))
        });
        let primary = |r: &&RouteLoad| r.role == "primary" || r.role == "retired";
        let total_requests =
            routes.iter().filter(primary).map(|r| r.requests + r.rejected).sum();
        let total_rejected = routes.iter().filter(primary).map(|r| r.rejected).sum();
        // wire counters exist only when a front door serves this router;
        // it attaches them after snapshotting (FrontDoor::shutdown)
        RouterReport { routes, total_requests, total_rejected, wire: None }
    }
}

// ---------------------------------------------------------------------
// Load reports
// ---------------------------------------------------------------------

/// One arm's load snapshot.
#[derive(Clone, Debug)]
pub struct RouteLoad {
    pub key: String,
    pub version: String,
    /// `primary`, `shadow`, `retired`, or `retired-shadow`.
    pub role: &'static str,
    /// A/B weight (0 for shadow/retired arms).
    pub weight: u32,
    /// Requests answered.
    pub requests: u64,
    /// Feature rows served.
    pub rows: u64,
    /// Requests rejected (queue full + deadline + shape).
    pub rejected: u64,
    /// Requests mirrored to this arm (shadow arms only).
    pub mirrored: u64,
    pub batches: u64,
    pub max_batch: u64,
    pub mean_queue_us: f64,
    pub mean_compute_us: f64,
    /// Transform plans compiled by this arm's batcher (1 per arm start
    /// whether self-compiled or adopted from the registry).
    pub plan_builds: u64,
    /// Microseconds spent compiling this arm's plan.
    pub plan_build_us: u64,
    /// Flushes served through the prepared plan path.
    pub plan_hits: u64,
    /// Plan-path flushes served by the packed sparse kernel.
    pub plan_sparse_hits: u64,
    /// Multiply-adds skipped by the sparse kernel, summed over rows.
    pub plan_flops_saved: u64,
    /// Flush-size histogram counts ([`BATCH_BUCKETS`] + overflow).
    pub batch_rows_hist: Vec<u64>,
    /// Latency histogram counts ([`LATENCY_BUCKETS_US`] + overflow).
    pub latency_us_hist: Vec<u64>,
}

impl RouteLoad {
    fn snapshot(
        key: &str,
        version: &str,
        role: &'static str,
        weight: u32,
        m: &ServeMetrics,
        mirrored: u64,
    ) -> Self {
        let requests = m.requests.load(Ordering::Relaxed);
        let div = requests.max(1) as f64;
        RouteLoad {
            key: key.to_string(),
            version: version.to_string(),
            role,
            weight,
            requests,
            rows: m.rows.load(Ordering::Relaxed),
            rejected: m.rejected(),
            mirrored,
            batches: m.batches.load(Ordering::Relaxed),
            max_batch: m.max_batch.load(Ordering::Relaxed),
            mean_queue_us: m.queue_us.load(Ordering::Relaxed) as f64 / div,
            mean_compute_us: m.compute_us.load(Ordering::Relaxed) as f64 / div,
            plan_builds: m.plan_builds.load(Ordering::Relaxed),
            plan_build_us: m.plan_build_us.load(Ordering::Relaxed),
            plan_hits: m.plan_hits.load(Ordering::Relaxed),
            plan_sparse_hits: m.plan_sparse_hits.load(Ordering::Relaxed),
            plan_flops_saved: m.plan_flops_saved.load(Ordering::Relaxed),
            batch_rows_hist: m.batch_rows_hist.snapshot(),
            latency_us_hist: m.latency_us_hist.snapshot(),
        }
    }
}

/// The router's exportable load report: one entry per live/retired arm
/// plus totals over primary traffic (shadow arms report separately and
/// never count toward totals).
#[derive(Clone, Debug)]
pub struct RouterReport {
    pub routes: Vec<RouteLoad>,
    /// Requests submitted to primary arms (answered + rejected).
    pub total_requests: u64,
    /// Requests rejected by primary arms.
    pub total_rejected: u64,
    /// Wire-level counters when the router is served by a
    /// [`crate::coordinator::frontdoor::FrontDoor`]; `None` for
    /// in-process serving.
    pub wire: Option<crate::coordinator::wire::WireStats>,
}

impl RouterReport {
    /// One JSON document the bench layer consumes.
    pub fn to_json(&self) -> String {
        let hist_json = Histogram::json_parts;
        let mut out = String::from("{\n\"routes\": [\n");
        for (i, r) in self.routes.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "{{\"key\": \"{}\", \"version\": \"{}\", \"role\": \"{}\", \
                 \"weight\": {}, \"requests\": {}, \"rows\": {}, \"rejected\": {}, \
                 \"mirrored\": {}, \"batches\": {}, \"max_batch\": {}, \
                 \"mean_queue_us\": {:.1}, \"mean_compute_us\": {:.1}, \
                 \"plan_builds\": {}, \"plan_build_us\": {}, \"plan_hits\": {}, \
                 \"plan_sparse_hits\": {}, \"plan_flops_saved\": {}, \
                 \"batch_rows\": {}, \"latency_us\": {}}}",
                json_escape(&r.key),
                json_escape(&r.version),
                r.role,
                r.weight,
                r.requests,
                r.rows,
                r.rejected,
                r.mirrored,
                r.batches,
                r.max_batch,
                r.mean_queue_us,
                r.mean_compute_us,
                r.plan_builds,
                r.plan_build_us,
                r.plan_hits,
                r.plan_sparse_hits,
                r.plan_flops_saved,
                hist_json(BATCH_BUCKETS, &r.batch_rows_hist),
                hist_json(LATENCY_BUCKETS_US, &r.latency_us_hist),
            ));
        }
        out.push_str(&format!(
            "\n],\n\"total_requests\": {},\n\"total_rejected\": {}",
            self.total_requests, self.total_rejected
        ));
        if let Some(wire) = &self.wire {
            out.push_str(&format!(",\n\"wire\": {}", wire.to_json()));
        }
        out.push_str("\n}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::estimator::EstimatorConfig;
    use crate::oavi::OaviConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, PipelineConfig};
    use crate::svm::linear::LinearSvmConfig;
    use std::time::{Duration, Instant};

    fn model(psi: f64, seed: u64) -> Arc<PipelineModel> {
        let ds = synthetic_dataset(300, seed);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(psi)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        Arc::new(train_pipeline(&cfg, &ds).unwrap())
    }

    #[test]
    fn routes_serve_every_estimator() {
        // per-estimator serving routes: one fitted pipeline per method
        // behind one router — the serving shape the estimator layer
        // enables (each route's model is a trait-object transformer)
        let ds = synthetic_dataset(240, 9);
        let r = ModelRouter::new();
        for est in EstimatorConfig::battery(0.01) {
            let cfg = PipelineConfig {
                estimator: est,
                svm: LinearSvmConfig::default(),
                ordering: FeatureOrdering::Pearson,
            };
            let m = Arc::new(train_pipeline(&cfg, &ds).unwrap());
            r.register(est.name(), "v1", m, ServeConfig::default());
        }
        assert_eq!(r.len(), 4);
        let row = ds.x.row(0).to_vec();
        for key in r.keys() {
            let ans = r.predict(&key, row.clone()).unwrap();
            assert_eq!(ans.model_key, key);
            assert_eq!(ans.model_version, "v1");
        }
    }

    fn router() -> ModelRouter {
        let r = ModelRouter::new();
        r.register("tight", "v1", model(0.001, 1), ServeConfig::default());
        r.register("loose", "v1", model(0.05, 2), ServeConfig::default());
        r
    }

    #[test]
    fn routes_by_key_and_rejects_unknown() {
        let r = router();
        assert_eq!(r.len(), 2);
        assert_eq!(r.keys(), vec!["loose".to_string(), "tight".to_string()]);
        let ds = synthetic_dataset(10, 3);
        let row = ds.x.row(0).to_vec();
        assert!(r.predict("tight", row.clone()).is_ok());
        let err = r.predict("nope", row).unwrap_err();
        assert!(matches!(err, AviError::Registry(_)), "{err}");
    }

    #[test]
    fn batch_preserves_input_order_across_models() {
        let r = router();
        let ds = synthetic_dataset(40, 4);
        // interleave models
        let items: Vec<(String, Vec<f64>)> = (0..40)
            .map(|i| {
                let key = if i % 2 == 0 { "tight" } else { "loose" };
                (key.to_string(), ds.x.row(i).to_vec())
            })
            .collect();
        let answers = r.predict_batch(items).unwrap();
        assert_eq!(answers.len(), 40);
        for (i, ans) in answers.iter().enumerate() {
            let expect = if i % 2 == 0 { "tight" } else { "loose" };
            assert_eq!(ans.model_key, expect, "answer {i} from wrong model");
        }
        // per-model answers match direct submission
        let direct_tight = r.predict("tight", ds.x.row(0).to_vec()).unwrap();
        assert_eq!(answers[0].label(), direct_tight.label());
        let report = r.report();
        // 20 batch + 1 direct for tight; 20 for loose
        assert_eq!(report.total_requests, 41);
        let by_key = |k: &str| {
            report
                .routes
                .iter()
                .filter(|r| r.key == k)
                .map(|r| r.requests)
                .sum::<u64>()
        };
        assert_eq!(by_key("loose"), 20);
        assert_eq!(by_key("tight"), 21);
    }

    #[test]
    fn replacing_a_route_keeps_serving() {
        let r = router();
        let ds = synthetic_dataset(10, 5);
        let row = ds.x.row(0).to_vec();
        let before = r.predict("tight", row.clone()).unwrap();
        assert_eq!(before.model_version, "v1");
        r.register("tight", "v2", model(0.001, 1), ServeConfig::default());
        let after = r.predict("tight", row).unwrap();
        assert_eq!(after.model_version, "v2");
        assert_eq!(before.label(), after.label()); // same training → same model
        // the retired arm's traffic still counts in the report
        let report = r.report();
        assert_eq!(report.total_requests, 2);
        assert!(report.routes.iter().any(|l| l.role == "retired" && l.requests == 1));
    }

    #[test]
    fn in_flight_request_is_answered_by_the_old_version() {
        let r = ModelRouter::new();
        let ds = synthetic_dataset(10, 6);
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(true));
        let held = ServeConfig { hold_gate: Some(gate.clone()), ..ServeConfig::default() };
        r.register("m", "v1", model(0.01, 1), held);
        // admitted to v1, not yet served
        let pending = r.enqueue("m", ServeRequest::row(ds.x.row(0).to_vec())).unwrap();
        // hot swap while the request is in flight
        r.register("m", "v2", model(0.01, 1), ServeConfig::default());
        let fresh = r.predict("m", ds.x.row(1).to_vec()).unwrap();
        assert_eq!(fresh.model_version, "v2");
        // release the old batcher: the in-flight request must be answered
        // by (and stamped with) the version that admitted it
        gate.store(false, std::sync::atomic::Ordering::SeqCst);
        let ans = pending.wait().answer().unwrap();
        assert_eq!(ans.model_version, "v1");
        assert_eq!(r.report().total_requests, 2);
    }

    #[test]
    fn weighted_ab_assignment_is_deterministic_for_a_fixed_seed() {
        let ds = synthetic_dataset(64, 7);
        let make = |seed: u64| {
            let r = ModelRouter::new();
            r.register_split(
                "m",
                vec![
                    ("v1".into(), model(0.01, 1), 70),
                    ("v2".into(), model(0.05, 2), 30),
                ],
                seed,
                &ServeConfig::default(),
            )
            .unwrap();
            r
        };
        let assignment = |r: &ModelRouter| -> Vec<String> {
            (0..64)
                .map(|i| r.predict("m", ds.x.row(i).to_vec()).unwrap().model_version)
                .collect()
        };
        let a = assignment(&make(42));
        let b = assignment(&make(42));
        assert_eq!(a, b, "same seed must replay identically");
        let n1 = a.iter().filter(|v| *v == "v1").count();
        assert!(n1 > 32 && n1 < 64, "70/30 split landed {n1}/64 on v1");
        // a different seed produces a different (but internally valid)
        // assignment sequence
        let c = assignment(&make(43));
        assert_ne!(a, c, "different seeds should reshuffle assignment");
        // every reply still came from a registered arm
        assert!(c.iter().all(|v| v == "v1" || v == "v2"));
    }

    #[test]
    fn zero_weight_and_empty_splits_are_rejected() {
        let r = ModelRouter::new();
        assert!(r.register_split("m", vec![], 0, &ServeConfig::default()).is_err());
        let err = r
            .register_split(
                "m",
                vec![("v1".into(), model(0.01, 1), 0)],
                0,
                &ServeConfig::default(),
            )
            .unwrap_err();
        assert!(matches!(err, AviError::Registry(_)), "{err}");
        assert!(r.is_empty());
    }

    #[test]
    fn shadow_routes_never_affect_primary_replies() {
        let ds = synthetic_dataset(48, 8);
        let rows: Vec<Vec<f64>> = (0..48).map(|i| ds.x.row(i).to_vec()).collect();

        // reference: primary only
        let plain = ModelRouter::new();
        plain.register("m", "v1", model(0.01, 1), ServeConfig::default());
        let want: Vec<usize> =
            rows.iter().map(|r| plain.predict("m", r.clone()).unwrap().label()).collect();

        // same primary + a very different shadow model
        let shadowed = ModelRouter::new();
        shadowed.register("m", "v1", model(0.01, 1), ServeConfig::default());
        shadowed
            .set_shadow("m", "cand", model(0.05, 2), ServeConfig::default())
            .unwrap();
        let got: Vec<ServeAnswer> =
            rows.iter().map(|r| shadowed.predict("m", r.clone()).unwrap()).collect();
        assert_eq!(got.iter().map(ServeAnswer::label).collect::<Vec<_>>(), want);
        assert!(got.iter().all(|a| a.model_version == "v1"));

        // the shadow saw the traffic and recorded its own load
        let report = shadowed.report();
        let shadow = report.routes.iter().find(|l| l.role == "shadow").unwrap();
        assert_eq!(shadow.version, "cand");
        assert_eq!(shadow.mirrored, 48);
        // shadow replies are discarded but its service still answers and
        // records latency; wait briefly for the async flushes to land
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_secs(5) {
            let l = shadowed.report();
            let s = l.routes.iter().find(|l| l.role == "shadow").unwrap().clone();
            if s.requests + s.rejected >= 48 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let l = shadowed.report();
        let s = l.routes.iter().find(|l| l.role == "shadow").unwrap().clone();
        assert!(s.requests + s.rejected >= 48, "shadow served {}", s.requests);
        // shadow traffic never counts toward primary totals
        assert_eq!(l.total_requests, 48);
        // unknown key can't take a shadow
        assert!(shadowed
            .set_shadow("nope", "x", model(0.05, 2), ServeConfig::default())
            .is_err());
    }

    #[test]
    fn repeated_swaps_fold_into_one_cumulative_retired_row() {
        let r = ModelRouter::new();
        let m = model(0.01, 1);
        let ds = synthetic_dataset(8, 11);
        r.register("m", "v1", m.clone(), ServeConfig::default());
        // 12 swap cycles of the same version: more than the retained
        // window, so the fold-in accumulator path runs too
        for _ in 0..12 {
            r.predict("m", ds.x.row(0).to_vec()).unwrap();
            r.register("m", "v1", m.clone(), ServeConfig::default());
        }
        let report = r.report();
        let retired: Vec<_> =
            report.routes.iter().filter(|l| l.role == "retired").collect();
        assert_eq!(retired.len(), 1, "same-version swaps must aggregate: {:#?}", report.routes);
        assert_eq!(retired[0].requests, 12);
        assert_eq!(report.total_requests, 12);
    }

    #[test]
    fn report_json_escapes_hostile_keys() {
        let r = ModelRouter::new();
        r.register("k\"ey", "v\\1", model(0.01, 1), ServeConfig::default());
        let json = r.report().to_json();
        assert!(json.contains("k\\\"ey"), "{json}");
        assert!(json.contains("v\\\\1"), "{json}");
    }

    #[test]
    fn property_exactly_once_under_concurrency() {
        let r = std::sync::Arc::new(router());
        let ds = synthetic_dataset(64, 6);
        let answered = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for t in 0..4 {
                let r = r.clone();
                let ds = &ds;
                let answered = &answered;
                scope.spawn(move || {
                    for i in 0..16 {
                        let key = if (t + i) % 2 == 0 { "tight" } else { "loose" };
                        let row = ds.x.row((t * 16 + i) % 64).to_vec();
                        let ans = r.predict(key, row).unwrap();
                        assert_eq!(ans.model_key, key);
                        answered.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(answered.load(std::sync::atomic::Ordering::SeqCst), 64);
        assert_eq!(r.report().total_requests, 64);
    }

    #[test]
    fn registry_routes_adopt_precompiled_plans_and_report_counters() {
        let mut registry = ModelRegistry::new();
        registry.insert("m", "v1", model(0.01, 1)).unwrap();
        registry.insert("m", "v2", model(0.01, 1)).unwrap();
        let r = ModelRouter::new();
        r.register_ab(
            &registry,
            "m",
            &[("v1".into(), 50), ("v2".into(), 50)],
            42,
            &ServeConfig::default(),
        )
        .unwrap();
        let ds = synthetic_dataset(32, 12);
        for i in 0..32 {
            r.predict("m", ds.x.row(i).to_vec()).unwrap();
        }
        let report = r.report();
        let primaries: Vec<_> =
            report.routes.iter().filter(|l| l.role == "primary").collect();
        assert_eq!(primaries.len(), 2);
        for arm in &primaries {
            // each arm counts exactly one plan start (adopted from the
            // registry, not recompiled) and serves through it
            assert_eq!(arm.plan_builds, 1, "{}@{}", arm.key, arm.version);
            assert!(arm.plan_hits > 0, "{}@{} never hit its plan", arm.key, arm.version);
            assert_eq!(arm.plan_sparse_hits, 0, "dense default must not engage sparse");
            assert_eq!(arm.plan_flops_saved, 0);
        }
        let json = report.to_json();
        assert!(json.contains("\"plan_builds\": 1"), "{json}");
        assert!(json.contains("\"plan_hits\""), "{json}");
        assert!(json.contains("\"plan_flops_saved\": 0"), "{json}");
    }

    #[test]
    fn report_json_is_well_formed_enough_for_the_bench_layer() {
        let r = router();
        let ds = synthetic_dataset(8, 10);
        for i in 0..8 {
            r.predict("tight", ds.x.row(i).to_vec()).unwrap();
        }
        let json = r.report().to_json();
        assert!(json.contains("\"total_requests\": 8"), "{json}");
        assert!(json.contains("\"key\": \"tight\""), "{json}");
        assert!(json.contains("\"latency_us\""), "{json}");
        assert!(json.contains("\"+inf\""), "{json}");
        // counts in the report survive a JSON round-trip through the
        // persist helpers the bench layer uses
        let total = crate::estimator::persist::extract_f64(&json, "\"total_requests\":").unwrap();
        assert_eq!(total as u64, 8);
    }

    #[test]
    fn report_json_emits_wire_block_only_when_served_over_the_network() {
        let r = router();
        let mut report = r.report();
        assert!(report.wire.is_none());
        assert!(!report.to_json().contains("\"wire\""));
        report.wire = Some(crate::coordinator::wire::WireStats {
            accepted: 5,
            bytes_in: 123,
            ..Default::default()
        });
        let json = report.to_json();
        assert!(json.contains("\"wire\": {\"connections\": 0, \"accepted\": 5"), "{json}");
        assert!(json.contains("\"bytes_in\": 123"), "{json}");
        assert!(json.trim_end().ends_with('}'), "{json}");
    }
}

//! A small scoped thread pool (tokio/rayon are unavailable offline; the
//! std::thread::scope pattern is all the paper's workloads need).
//!
//! Jobs are `FnOnce() -> T`; results come back **in submission order**
//! regardless of completion order — the invariant the coordinator property
//! tests pin down (every job runs exactly once, order preserved).

use std::sync::Mutex;

/// Fixed-size scoped thread pool.
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// `workers` ≥ 1 (clamped).
    pub fn new(workers: usize) -> Self {
        ThreadPool { workers: workers.max(1) }
    }

    /// Reasonable default: available parallelism − 1, at least 1.
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        ThreadPool::new(n.saturating_sub(1).max(1))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run all jobs, returning results in submission order.
    pub fn run_all<T: Send>(&self, jobs: Vec<Box<dyn FnOnce() -> T + Send>>) -> Vec<T> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        // single worker or single job: run inline (no thread overhead)
        if self.workers == 1 || n == 1 {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let queue: Mutex<Vec<(usize, Box<dyn FnOnce() -> T + Send>)>> =
            Mutex::new(jobs.into_iter().enumerate().rev().collect());
        let results: Mutex<Vec<Option<T>>> =
            Mutex::new((0..n).map(|_| None).collect());
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(n) {
                scope.spawn(|| loop {
                    let job = queue.lock().expect("queue poisoned").pop();
                    match job {
                        Some((idx, f)) => {
                            let out = f();
                            results.lock().expect("results poisoned")[idx] = Some(out);
                        }
                        None => break,
                    }
                });
            }
        });
        results
            .into_inner()
            .expect("results poisoned")
            .into_iter()
            .map(|r| r.expect("job dropped without result"))
            .collect()
    }

    /// Map a slice through a function in parallel (convenience wrapper).
    pub fn map<I: Sync, T: Send>(&self, items: &[I], f: impl Fn(&I) -> T + Sync) -> Vec<T> {
        if items.is_empty() {
            return Vec::new();
        }
        let n = items.len();
        let next = std::sync::atomic::AtomicUsize::new(0);
        let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(n) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(&items[i]);
                    results.lock().expect("poisoned")[i] = Some(out);
                });
            }
        });
        results
            .into_inner()
            .expect("poisoned")
            .into_iter()
            .map(|r| r.expect("missing result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn results_in_submission_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..64usize)
            .map(|i| {
                Box::new(move || {
                    // stagger completion order
                    std::thread::sleep(std::time::Duration::from_micros((64 - i) as u64));
                    i * i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<usize>>());
    }

    #[test]
    fn single_worker_runs_inline() {
        let pool = ThreadPool::new(1);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> =
            vec![Box::new(|| 1), Box::new(|| 2)];
        assert_eq!(pool.run_all(jobs), vec![1, 2]);
    }

    #[test]
    fn empty_jobs_ok() {
        let pool = ThreadPool::new(3);
        let out: Vec<u32> = pool.run_all(vec![]);
        assert!(out.is_empty());
    }

    #[test]
    fn map_matches_serial() {
        let pool = ThreadPool::new(3);
        let items: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let par = pool.map(&items, |x| x * 2.0);
        let ser: Vec<f64> = items.iter().map(|x| x * 2.0).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn property_every_job_runs_exactly_once() {
        property(10, |rng| {
            let n = rng.below(40) + 1;
            let workers = rng.below(6) + 1;
            let counter = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
            let pool = ThreadPool::new(workers);
            let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..n)
                .map(|i| {
                    let c = counter.clone();
                    Box::new(move || {
                        c.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        i
                    }) as Box<dyn FnOnce() -> usize + Send>
                })
                .collect();
            let out = pool.run_all(jobs);
            if counter.load(std::sync::atomic::Ordering::SeqCst) != n {
                return Err("some job ran != 1 times".into());
            }
            if out != (0..n).collect::<Vec<usize>>() {
                return Err("order not preserved".into());
            }
            Ok(())
        });
    }
}

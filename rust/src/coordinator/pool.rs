//! Persistent work-stealing thread pool — the coordinator's job engine.
//!
//! The PR-1 pool spawned and joined scoped threads on **every call**
//! (tens of µs per `gram_stats`), which forced `ShardedBackend` behind a
//! large hard-coded work threshold and left small-batch serving traffic
//! single-threaded.  This pool spawns its workers **once** at
//! construction and feeds them jobs over an MPMC queue
//! (`Mutex<VecDeque>` + `Condvar`; tokio/rayon/crossbeam are unavailable
//! offline):
//!
//! * **In-submission-order results** — [`ThreadPool::run_all`] /
//!   [`PoolHandle::try_run_all`] return results indexed by submission
//!   position regardless of completion order: the deterministic-reduction
//!   contract the data plane and `rust/tests/pool_concurrency.rs` pin.
//! * **Work stealing / helping** — the submitting thread does not idle
//!   while its batch runs: it pops *its own batch's* queued jobs and
//!   executes them in place.  This is also what makes **nested
//!   submission** (a job submitting a sub-batch through a
//!   [`PoolHandle`]) deadlock-free: even with every worker busy running
//!   outer jobs, each nested submitter drains its own inner jobs itself.
//! * **Panic containment** — each job runs under `catch_unwind`; a
//!   panicking job poisons only its own result slot
//!   ([`PoolHandle::try_run_all`] reports it as `Err(message)`), the
//!   remaining jobs complete, and the workers survive.
//! * **Graceful shutdown** — dropping the [`ThreadPool`] drains queued
//!   jobs, then joins every worker.  [`PoolHandle`]s that outlive the
//!   pool degrade gracefully: their submissions execute inline on the
//!   submitting thread via the helping loop.
//!
//! [`PoolHandle`] (cheaply clonable, `Send + Sync`) is the sharing
//! surface for **two-level parallelism**: grid-search / per-class fit
//! jobs (outer axis) and `ShardedBackend` shard kernels (inner axis)
//! draw from one pool.  [`PoolHandle::budget_split`] divides the worker
//! budget (`outer × inner ≤ workers`) and
//! [`PoolHandle::adaptive_min_work`] is the calibrated dispatch-overhead
//! threshold (measured per pool: job hand-off cost vs. multiply-add
//! throughput) below which handing a shard to a worker cannot pay.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A submission-order job: runs once on some thread, yields a `T`.
pub type Job<'env, T> = Box<dyn FnOnce() -> T + Send + 'env>;

/// Type-erased queue entry (lifetime erased — see `extend_task_lifetime`).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Clamp range for the calibrated per-shard work threshold, in
/// multiply-add units.  The floor keeps degenerate measurements from
/// sharding trivial inputs; the ceiling keeps a noisy calibration from
/// re-serializing genuinely large shards (the old hard-coded constant
/// was 256·1024).
const ADAPTIVE_MIN_WORK_FLOOR: usize = 1 << 12;
const ADAPTIVE_MIN_WORK_CEIL: usize = 1 << 20;

struct QueueState {
    /// `(batch token, task)` in FIFO order across all batches.
    tasks: VecDeque<(u64, Task)>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    task_ready: Condvar,
    next_batch: AtomicU64,
    live_workers: AtomicUsize,
    workers: usize,
    /// memoized [`PoolHandle::adaptive_min_work`] (calibrated once per pool).
    min_work: Mutex<Option<usize>>,
}

/// Per-batch result collection: slots in submission order + completion
/// count, guarded by one mutex so the waiter cannot miss the last
/// completion (the classic condvar pattern).
struct Batch<T> {
    slots: Mutex<BatchSlots<T>>,
    done_cv: Condvar,
}

struct BatchSlots<T> {
    results: Vec<Option<Result<T, String>>>,
    completed: usize,
}

impl<T> Batch<T> {
    fn complete(&self, idx: usize, out: Result<T, String>) {
        let mut s = self.slots.lock().expect("pool batch slots");
        debug_assert!(s.results[idx].is_none(), "job {idx} completed twice");
        s.results[idx] = Some(out);
        s.completed += 1;
        if s.completed == s.results.len() {
            self.done_cv.notify_all();
        }
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked (non-string payload)".to_string()
    }
}

/// Extend a task's lifetime so it can sit in the `'static` worker queue.
///
/// # Safety
/// The caller must not return until the task has been executed (or
/// dropped) — `try_run_all` guarantees this by blocking until every slot
/// of its batch is complete, so no borrow captured by the task can be
/// outlived by the task itself.
unsafe fn extend_task_lifetime<'env>(task: Box<dyn FnOnce() + Send + 'env>) -> Task {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(task)
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut st = shared.state.lock().expect("pool queue");
            loop {
                if let Some((_, task)) = st.tasks.pop_front() {
                    break Some(task);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.task_ready.wait(st).expect("pool queue wait");
            }
        };
        match task {
            Some(task) => task(), // panic-contained inside the task wrapper
            None => {
                shared.live_workers.fetch_sub(1, Ordering::SeqCst);
                return;
            }
        }
    }
}

/// Machine-level multiply-add throughput (ns per fused multiply-add),
/// measured once per process — it is a hardware property, not a pool
/// property, so every pool shares the sample.
fn madd_ns_per_op() -> f64 {
    static MADD_NS: Mutex<Option<f64>> = Mutex::new(None);
    let mut cached = MADD_NS.lock().expect("madd calibration");
    if let Some(v) = *cached {
        return v;
    }
    const ITERS: usize = 200_000;
    let t = Instant::now();
    let mut acc = 0.0f64;
    let mut x = 1.000_000_1f64;
    for _ in 0..ITERS {
        acc += x * 1.000_000_3;
        x *= 0.999_999_9;
    }
    let mut ns = t.elapsed().as_nanos() as f64 / ITERS as f64;
    // keep the loop observable so the optimizer cannot elide it
    if !acc.is_finite() {
        ns += 1.0;
    }
    let v = ns.max(0.05);
    *cached = Some(v);
    v
}

/// Cheaply clonable, `Send + Sync` handle onto a [`ThreadPool`]'s queue —
/// the object that grid-search jobs, per-class fits, and
/// `ShardedBackend`s share so both parallelism levels draw from one
/// worker budget.
#[derive(Clone)]
pub struct PoolHandle {
    shared: Arc<Shared>,
}

impl PoolHandle {
    /// Worker-thread count the pool was built with.
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// Workers currently alive (0 after the owning pool is dropped).
    pub fn live_workers(&self) -> usize {
        self.shared.live_workers.load(Ordering::SeqCst)
    }

    /// Multi-job batches dispatched through this pool so far (single-job
    /// submissions run inline on the caller and are not counted; the
    /// one-time calibration's hand-off probes are).  The panel data
    /// plane's "one dispatch per (degree, panel chunk)" contract is
    /// asserted against deltas of this counter in
    /// `tests/runtime_parity.rs` and reported by
    /// `benches/micro_gram_panel.rs`.
    pub fn batches_dispatched(&self) -> u64 {
        self.shared.next_batch.load(Ordering::Relaxed)
    }

    /// Split the worker budget between `outer_jobs` outer jobs and the
    /// per-job inner (shard) axis: `(outer, inner)` with
    /// `outer × inner ≤ workers` and both ≥ 1.  Few outer jobs on a wide
    /// pool get a wide inner budget; more outer jobs than workers get
    /// `inner = 1`.
    pub fn budget_split(&self, outer_jobs: usize) -> (usize, usize) {
        let w = self.workers().max(1);
        let outer = outer_jobs.clamp(1, w);
        let inner = (w / outer).max(1);
        (outer, inner)
    }

    /// Run all jobs, returning results in submission order; a panicking
    /// job yields `Err(panic message)` in its own slot while every other
    /// job still runs and the workers survive.
    pub fn try_run_all<'env, T: Send + 'env>(
        &self,
        jobs: Vec<Job<'env, T>>,
    ) -> Vec<Result<T, String>> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        if n == 1 {
            // single job: no dispatch, same containment semantics
            let job = jobs.into_iter().next().expect("len checked");
            return vec![catch_unwind(AssertUnwindSafe(job)).map_err(panic_message)];
        }
        let batch: Arc<Batch<T>> = Arc::new(Batch {
            slots: Mutex::new(BatchSlots {
                results: (0..n).map(|_| None).collect(),
                completed: 0,
            }),
            done_cv: Condvar::new(),
        });
        let token = self.shared.next_batch.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.shared.state.lock().expect("pool queue");
            for (idx, job) in jobs.into_iter().enumerate() {
                let b = Arc::clone(&batch);
                let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                    let out = catch_unwind(AssertUnwindSafe(job)).map_err(panic_message);
                    b.complete(idx, out);
                });
                // SAFETY: this function blocks below until every slot of
                // `batch` is complete, i.e. until every task has run, so
                // the 'env borrows cannot dangle while a task is alive.
                st.tasks.push_back((token, unsafe { extend_task_lifetime(task) }));
            }
        }
        self.shared.task_ready.notify_all();
        // Helping loop: execute this batch's still-queued jobs on the
        // submitting thread.  Guarantees progress even with zero free
        // workers (nested submission, dropped pool) — the deadlock-freedom
        // property `tests/pool_concurrency.rs` pins.
        self.drain_own_batch(token);
        // Wait for jobs stolen by workers; completion is signalled under
        // the slots mutex, so the last wakeup cannot be missed.
        let mut slots = batch.slots.lock().expect("pool batch slots");
        while slots.completed < n {
            slots = batch.done_cv.wait(slots).expect("pool batch wait");
        }
        let results = std::mem::take(&mut slots.results);
        drop(slots);
        results
            .into_iter()
            .map(|r| r.expect("pool: job dropped without completing"))
            .collect()
    }

    /// Execute every queued task belonging to `token` on the calling
    /// thread — the work-stealing half of the pool, shared by the
    /// `try_run_all` helping loop and the calibration fallback.
    ///
    /// Steals in chunks of up to `STEAL_CHUNK` per lock acquisition so
    /// interleaved batches don't degenerate into a scan-per-task
    /// quadratic under the global queue lock, while workers can still
    /// take the tasks left behind.  LIFO back-stealing is fine — results
    /// land in submission-order slots regardless of execution order.
    fn drain_own_batch(&self, token: u64) {
        /// Per-lock steal bound: large enough to amortize a queue sweep,
        /// small enough that workers freed mid-batch still find work.
        const STEAL_CHUNK: usize = 32;
        loop {
            let mut stolen: Vec<Task> = Vec::new();
            {
                let mut st = self.shared.state.lock().expect("pool queue");
                // O(1) fast path: the draining batch was usually pushed
                // most recently, so its tasks sit at the back (workers
                // pop from the front)
                while stolen.len() < STEAL_CHUNK {
                    let back_is_ours = matches!(st.tasks.back(), Some((t, _)) if *t == token);
                    if !back_is_ours {
                        break;
                    }
                    if let Some((_, task)) = st.tasks.pop_back() {
                        stolen.push(task);
                    }
                }
                if stolen.is_empty() && st.tasks.iter().any(|(t, _)| *t == token) {
                    // interleaved batches: sweep own tasks out in ONE
                    // pass instead of a scan-per-task
                    let mut rest = VecDeque::with_capacity(st.tasks.len());
                    for (t, task) in st.tasks.drain(..) {
                        if t == token && stolen.len() < STEAL_CHUNK {
                            stolen.push(task);
                        } else {
                            rest.push_back((t, task));
                        }
                    }
                    st.tasks = rest;
                }
            }
            if stolen.is_empty() {
                return;
            }
            for task in stolen {
                task();
            }
        }
    }

    /// [`PoolHandle::try_run_all`] that re-raises the first contained
    /// panic on the submitting thread (after every job has finished).
    pub fn run_all<'env, T: Send + 'env>(&self, jobs: Vec<Job<'env, T>>) -> Vec<T> {
        self.try_run_all(jobs)
            .into_iter()
            .map(|r| match r {
                Ok(v) => v,
                Err(msg) => panic!("pool job panicked: {msg}"),
            })
            .collect()
    }

    /// Map a slice through a function in parallel (convenience wrapper,
    /// submission order preserved).
    pub fn map<I: Sync, T: Send>(&self, items: &[I], f: impl Fn(&I) -> T + Sync) -> Vec<T> {
        if items.is_empty() {
            return Vec::new();
        }
        let fr = &f;
        let jobs: Vec<Job<'_, T>> =
            items.iter().map(|item| Box::new(move || fr(item)) as Job<'_, T>).collect();
        self.run_all(jobs)
    }

    /// The calibrated per-shard work threshold (in multiply-add units)
    /// below which dispatching a shard to this pool costs more than the
    /// arithmetic it parallelizes.  Measured once per pool — per-job
    /// hand-off time over the live queue vs. the machine's multiply-add
    /// throughput — then memoized; clamped to
    /// `[2^12, 2^20]` so a noisy sample cannot produce a degenerate
    /// threshold.  Replaces PR 1's hard-coded `MIN_WORK_PER_SHARD`.
    pub fn adaptive_min_work(&self) -> usize {
        let mut cached = self.shared.min_work.lock().expect("pool calibration");
        if let Some(v) = *cached {
            return v;
        }
        let v = self.calibrate_min_work();
        *cached = Some(v);
        v
    }

    /// Dispatch `jobs` no-op tasks and wait for **workers** to run them,
    /// WITHOUT the helping loop — `try_run_all` would let the submitting
    /// thread drain its own batch in ~100 ns/job and the calibration
    /// would measure that fast path instead of the cross-thread hand-off
    /// (push → condvar wakeup → pop → complete → notify) that a real
    /// shard job pays.  Falls back to draining inline only if the
    /// workers are gone or saturated (bounded wait, no hang).  Public
    /// for benches/diagnostics that want to time the true hand-off.
    pub fn dispatch_to_workers(&self, jobs: usize) {
        let batch: Arc<Batch<()>> = Arc::new(Batch {
            slots: Mutex::new(BatchSlots {
                results: (0..jobs).map(|_| None).collect(),
                completed: 0,
            }),
            done_cv: Condvar::new(),
        });
        let token = self.shared.next_batch.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.shared.state.lock().expect("pool queue");
            for idx in 0..jobs {
                let b = Arc::clone(&batch);
                // 'static closure: no transmute needed on this path
                let task: Task = Box::new(move || b.complete(idx, Ok(())));
                st.tasks.push_back((token, task));
            }
        }
        self.shared.task_ready.notify_all();
        let mut slots = batch.slots.lock().expect("pool batch slots");
        while slots.completed < jobs {
            // 10 ms is orders of magnitude above a healthy wakeup, so the
            // timeout only fires when the workers are gone or saturated
            let (guard, timeout) = batch
                .done_cv
                .wait_timeout(slots, std::time::Duration::from_millis(10))
                .expect("pool batch wait");
            slots = guard;
            if timeout.timed_out() && slots.completed < jobs {
                // workers gone or saturated: drain our own tasks inline
                drop(slots);
                self.drain_own_batch(token);
                slots = batch.slots.lock().expect("pool batch slots");
            }
        }
    }

    fn calibrate_min_work(&self) -> usize {
        if self.live_workers() == 0 {
            // no workers to hand off to (pool already dropped): every
            // submission runs inline, so the cheapest threshold applies
            return ADAPTIVE_MIN_WORK_FLOOR;
        }
        const ROUNDS: usize = 4;
        const JOBS_PER_ROUND: usize = 16;
        // warm-up round: first wakeups bill thread-start latency
        self.dispatch_to_workers(JOBS_PER_ROUND);
        let t = Instant::now();
        for _ in 0..ROUNDS {
            self.dispatch_to_workers(JOBS_PER_ROUND);
        }
        let dispatch_ns = t.elapsed().as_nanos() as f64 / (ROUNDS * JOBS_PER_ROUND) as f64;
        // a shard pays off once its multiply-adds dwarf the hand-off; the
        // 2× margin covers reduction + cache effects the model ignores
        let per_shard = (2.0 * dispatch_ns / madd_ns_per_op()) as usize;
        per_shard.clamp(ADAPTIVE_MIN_WORK_FLOOR, ADAPTIVE_MIN_WORK_CEIL)
    }
}

/// Persistent fixed-size thread pool.  Workers are spawned once here and
/// joined on drop; all submission goes through the queue shared with
/// every [`PoolHandle`].
pub struct ThreadPool {
    shared: Arc<Shared>,
    joins: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `workers` long-lived workers (clamped to ≥ 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState { tasks: VecDeque::new(), shutdown: false }),
            task_ready: Condvar::new(),
            next_batch: AtomicU64::new(0),
            live_workers: AtomicUsize::new(workers),
            workers,
            min_work: Mutex::new(None),
        });
        let joins = (0..workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("avi-pool-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn pool worker")
            })
            .collect();
        let pool = ThreadPool { shared, joins };
        // Calibrate the dispatch threshold EAGERLY, while the pool is
        // guaranteed idle: a lazy calibration under load (every worker
        // busy with outer jobs) would measure wait_timeout stalls
        // instead of hand-off cost and memoize a uselessly high
        // threshold for the pool's whole lifetime.
        pool.adaptive_min_work();
        pool
    }

    /// Reasonable default: available parallelism − 1, at least 1.
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        ThreadPool::new(n.saturating_sub(1).max(1))
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// A clonable, `Send + Sync` handle sharing this pool's queue.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle { shared: Arc::clone(&self.shared) }
    }

    /// See [`PoolHandle::run_all`].
    pub fn run_all<'env, T: Send + 'env>(&self, jobs: Vec<Job<'env, T>>) -> Vec<T> {
        self.handle().run_all(jobs)
    }

    /// See [`PoolHandle::try_run_all`].
    pub fn try_run_all<'env, T: Send + 'env>(
        &self,
        jobs: Vec<Job<'env, T>>,
    ) -> Vec<Result<T, String>> {
        self.handle().try_run_all(jobs)
    }

    /// See [`PoolHandle::map`].
    pub fn map<I: Sync, T: Send>(&self, items: &[I], f: impl Fn(&I) -> T + Sync) -> Vec<T> {
        self.handle().map(items, f)
    }

    /// See [`PoolHandle::adaptive_min_work`].
    pub fn adaptive_min_work(&self) -> usize {
        self.handle().adaptive_min_work()
    }

    /// See [`PoolHandle::batches_dispatched`].
    pub fn batches_dispatched(&self) -> u64 {
        self.handle().batches_dispatched()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // poison-proof: a worker cannot poison this lock (user code
            // runs under catch_unwind), but stay robust anyway
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
        }
        self.shared.task_ready.notify_all();
        for join in self.joins.drain(..) {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn results_in_submission_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Job<'static, usize>> = (0..64usize)
            .map(|i| {
                Box::new(move || {
                    // stagger completion order
                    std::thread::sleep(std::time::Duration::from_micros((64 - i) as u64));
                    i * i
                }) as Job<'static, usize>
            })
            .collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<usize>>());
    }

    #[test]
    fn single_worker_pool_completes_batches() {
        let pool = ThreadPool::new(1);
        let jobs: Vec<Job<'static, u32>> = vec![Box::new(|| 1), Box::new(|| 2)];
        assert_eq!(pool.run_all(jobs), vec![1, 2]);
    }

    #[test]
    fn empty_jobs_ok() {
        let pool = ThreadPool::new(3);
        let out: Vec<u32> = pool.run_all(vec![]);
        assert!(out.is_empty());
    }

    #[test]
    fn map_matches_serial_and_borrows_locals() {
        let pool = ThreadPool::new(3);
        let items: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let offset = 1.5; // borrowed by the closure: non-'static jobs work
        let par = pool.map(&items, |x| x * 2.0 + offset);
        let ser: Vec<f64> = items.iter().map(|x| x * 2.0 + offset).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn property_every_job_runs_exactly_once() {
        property(10, |rng| {
            let n = rng.below(40) + 1;
            let workers = rng.below(6) + 1;
            let counter = std::sync::Arc::new(AtomicUsize::new(0));
            let pool = ThreadPool::new(workers);
            let jobs: Vec<Job<'static, usize>> = (0..n)
                .map(|i| {
                    let c = counter.clone();
                    Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                        i
                    }) as Job<'static, usize>
                })
                .collect();
            let out = pool.run_all(jobs);
            if counter.load(Ordering::SeqCst) != n {
                return Err("some job ran != 1 times".into());
            }
            if out != (0..n).collect::<Vec<usize>>() {
                return Err("order not preserved".into());
            }
            Ok(())
        });
    }

    #[test]
    fn panic_poisons_only_its_slot() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Job<'static, u32>> = vec![
            Box::new(|| 10),
            Box::new(|| panic!("boom-42")),
            Box::new(|| 30),
        ];
        let out = pool.try_run_all(jobs);
        assert_eq!(out[0].as_ref().unwrap(), &10);
        assert!(out[1].as_ref().unwrap_err().contains("boom-42"));
        assert_eq!(out[2].as_ref().unwrap(), &30);
        // workers survive: the pool is still usable
        let more: Vec<Job<'static, u32>> = vec![Box::new(|| 7), Box::new(|| 8)];
        assert_eq!(pool.run_all(more), vec![7, 8]);
    }

    #[test]
    #[should_panic(expected = "pool job panicked")]
    fn run_all_reraises_contained_panic() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Job<'static, u32>> =
            vec![Box::new(|| 1), Box::new(|| panic!("surface me")), Box::new(|| 3)];
        let _ = pool.run_all(jobs);
    }

    #[test]
    fn handle_outlives_pool_gracefully() {
        let pool = ThreadPool::new(2);
        let handle = pool.handle();
        drop(pool);
        assert_eq!(handle.live_workers(), 0);
        // submissions now execute inline via the helping loop
        let jobs: Vec<Job<'static, u32>> = vec![Box::new(|| 5), Box::new(|| 6)];
        assert_eq!(handle.run_all(jobs), vec![5, 6]);
    }

    #[test]
    fn budget_split_never_oversubscribes() {
        let pool = ThreadPool::new(8);
        let h = pool.handle();
        for jobs in [1usize, 2, 3, 7, 8, 9, 100] {
            let (outer, inner) = h.budget_split(jobs);
            assert!(outer >= 1 && inner >= 1, "jobs={jobs}");
            assert!(outer * inner <= 8, "jobs={jobs}: {outer}×{inner}");
            assert!(outer <= jobs.max(1), "jobs={jobs}");
        }
        assert_eq!(h.budget_split(2), (2, 4));
        assert_eq!(h.budget_split(0), (1, 8));
        assert_eq!(ThreadPool::new(1).handle().budget_split(5), (1, 1));
    }

    #[test]
    fn adaptive_min_work_is_clamped_and_memoized() {
        let pool = ThreadPool::new(2);
        let v = pool.adaptive_min_work();
        assert!((ADAPTIVE_MIN_WORK_FLOOR..=ADAPTIVE_MIN_WORK_CEIL).contains(&v));
        assert_eq!(pool.adaptive_min_work(), v, "memoized value must be stable");
        assert_eq!(pool.handle().adaptive_min_work(), v);
    }
}

//! The serving **wire protocol**: length-prefixed frames carrying the
//! typed [`ServeRequest`] → [`ServeReply`] protocol over a byte stream.
//!
//! ## Frame layout
//!
//! Every frame is a fixed 12-byte header followed by a JSON payload:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "AVIW"
//! 4       1     protocol version (currently 1)
//! 5       1     frame kind (1 request, 2 reply, 3 error, 4 shutdown,
//!               5 push-model, 6 pull-model, 7 activate-model)
//! 6       2     reserved (zero)
//! 8       4     payload length, u32 little-endian
//! 12      len   payload (UTF-8 JSON, or a hybrid envelope — below)
//! ```
//!
//! The header is validated *before* the payload is read: a bad magic or
//! unknown kind is [`WireFault::Malformed`], a version mismatch is
//! [`WireFault::Version`], and a length beyond the receiver's cap is
//! [`WireFault::Oversized`] — all surfaced without allocating the
//! payload, so an adversarial length can never balloon memory.
//!
//! ## Payloads
//!
//! * request — `{"kind":"row"|"batch","route":"key","deadline_ms":N,`
//!   `"rows":[[...]]}` (`deadline_ms` optional).
//! * reply (ok) — `{"status":"ok","key":..,"version":..,"batch_rows":N,`
//!   `"queue_us":N,"compute_us":N,"predictions":[{"label":N,`
//!   `"scores":[...]}]}`.
//! * reply (rejected) — `{"status":"rejected","reason":"<code>",`
//!   `"detail":".."}` with codes `queue_full`, `deadline_expired`,
//!   `bad_shape`, `non_finite`, `stopped`, `rate_limited`,
//!   `unknown_route`.
//! * error — `{"error":"malformed"|"oversized"|"bad_version"|`
//!   `"internal"|"busy","detail":".."}` — protocol-level faults; the
//!   server closes the connection after sending one.
//!
//! ## Model-control payloads
//!
//! The control plane moves binary model artifacts, which JSON cannot
//! carry.  `PushModel` requests and `PullModel` replies therefore use a
//! **hybrid envelope**: `"AVIM"` magic, a u32-LE header length, a JSON
//! header, then the raw artifact bytes:
//!
//! ```text
//! 0   4           magic "AVIM"
//! 4   4           header length, u32 little-endian
//! 8   hdr_len     UTF-8 JSON header
//! ..  rest        artifact bytes (binary or JSON envelope, opaque here)
//! ```
//!
//! * push header — `{"key":..,"version":..,"checksum":"<16-hex fnv64>",`
//!   `"force":true|false}`; the server re-hashes the artifact and
//!   refuses a mismatch before anything touches disk.
//! * pull / activate request — plain JSON `{"key":..,"version":..}`
//!   (`version` omitted on pull = latest).
//! * control ack — `{"status":"ok","op":"push"|"pull"|"activate",`
//!   `"key":..,"version":..,"checksum":"<hex>","bytes":N}`; control
//!   rejections reuse the `"status":"rejected"` shape with codes
//!   `checksum_mismatch`, `version_conflict`, `bad_artifact`,
//!   `unknown_model`, `push_disabled`, `rate_limited`.
//!
//! Checksums travel as 16-digit hex *strings* — a u64 exceeds the
//! integer range a JSON number (f64) can represent exactly.
//!
//! Scores are serialized with Rust's `{:?}` float formatting (shortest
//! round-trip) and parsed with `f64::from_str`, which reproduces every
//! bit pattern — the network path is **bitwise identical** to calling
//! the in-process [`TransformService`].
//!
//! [`TransformService`]: crate::coordinator::service::TransformService

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::coordinator::service::{
    Prediction, RejectReason, ServePayload, ServeReply, ServeRequest,
};
use crate::error::{AviError, Result};
use crate::util::json_escape;

/// Frame magic: every frame starts with these four bytes.
pub const MAGIC: [u8; 4] = *b"AVIW";

/// Current protocol version; the server rejects any other.
pub const WIRE_VERSION: u8 = 1;

/// Header size in bytes (magic + version + kind + reserved + length).
pub const HEADER_LEN: usize = 12;

/// Default payload cap: 1 MiB ≈ 16k rows of 8 features — far above any
/// sane request, far below a memory-exhaustion vector.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 20;

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    Request = 1,
    Reply = 2,
    Error = 3,
    Shutdown = 4,
    /// Upload a model artifact to the server's store (hybrid payload).
    PushModel = 5,
    /// Download a stored artifact (JSON request, hybrid reply).
    PullModel = 6,
    /// Register + hot-swap routes to a stored `key@version`.
    ActivateModel = 7,
}

impl FrameKind {
    fn from_u8(b: u8) -> Option<FrameKind> {
        match b {
            1 => Some(FrameKind::Request),
            2 => Some(FrameKind::Reply),
            3 => Some(FrameKind::Error),
            4 => Some(FrameKind::Shutdown),
            5 => Some(FrameKind::PushModel),
            6 => Some(FrameKind::PullModel),
            7 => Some(FrameKind::ActivateModel),
            _ => None,
        }
    }
}

/// One decoded frame.
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Total bytes this frame occupied on the wire.
    pub fn wire_len(&self) -> u64 {
        (HEADER_LEN + self.payload.len()) as u64
    }
}

/// Why a frame could not be read.  Every variant maps to a defined
/// behaviour — a typed error frame, a counter, or a closed connection —
/// never a panic and never a hung peer.
#[derive(Debug)]
pub enum WireFault {
    /// Bad magic, unknown kind, truncated bytes, or unparsable payload.
    Malformed(String),
    /// Declared payload length beyond the receiver's cap.
    Oversized { got: usize, max: usize },
    /// Protocol version this endpoint does not speak.
    Version { got: u8 },
    /// Clean end-of-stream at a frame boundary (peer closed).
    Eof,
    /// The read/write deadline expired mid-frame.
    Timeout,
    /// Any other I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for WireFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireFault::Malformed(m) => write!(f, "malformed frame: {m}"),
            WireFault::Oversized { got, max } => {
                write!(f, "frame too large: {got} bytes (cap {max})")
            }
            WireFault::Version { got } => {
                write!(f, "unsupported protocol version {got} (speaking {WIRE_VERSION})")
            }
            WireFault::Eof => write!(f, "connection closed"),
            WireFault::Timeout => write!(f, "connection timed out"),
            WireFault::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl From<WireFault> for AviError {
    fn from(fault: WireFault) -> Self {
        AviError::Net(fault.to_string())
    }
}

fn classify_io(e: std::io::Error) -> WireFault {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => WireFault::Timeout,
        std::io::ErrorKind::UnexpectedEof => {
            WireFault::Malformed("truncated frame".into())
        }
        _ => WireFault::Io(e),
    }
}

/// Read one frame, enforcing `max_payload`.  A clean close at a frame
/// boundary is [`WireFault::Eof`]; a close mid-frame is `Malformed`.
pub fn read_frame<R: Read>(
    r: &mut R,
    max_payload: usize,
) -> std::result::Result<Frame, WireFault> {
    let mut header = [0u8; HEADER_LEN];
    // first byte read separately so a peer closing between frames is a
    // clean Eof, not a truncation error
    match r.read_exact(&mut header[..1]) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Err(WireFault::Eof)
        }
        Err(e) => return Err(classify_io(e)),
    }
    r.read_exact(&mut header[1..]).map_err(classify_io)?;
    if header[..4] != MAGIC {
        return Err(WireFault::Malformed(format!("bad magic {:02x?}", &header[..4])));
    }
    if header[4] != WIRE_VERSION {
        return Err(WireFault::Version { got: header[4] });
    }
    let kind = FrameKind::from_u8(header[5])
        .ok_or_else(|| WireFault::Malformed(format!("unknown frame kind {}", header[5])))?;
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    if len > max_payload {
        return Err(WireFault::Oversized { got: len, max: max_payload });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(classify_io)?;
    Ok(Frame { kind, payload })
}

/// Write one frame; returns the bytes put on the wire.
pub fn write_frame<W: Write>(
    w: &mut W,
    kind: FrameKind,
    payload: &[u8],
) -> std::io::Result<u64> {
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = WIRE_VERSION;
    header[5] = kind as u8;
    header[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok((HEADER_LEN + payload.len()) as u64)
}

// ---------------------------------------------------------------------
// Payload codecs
// ---------------------------------------------------------------------

/// `{:?}` float formatting: Rust's shortest-round-trip representation,
/// the same convention the persist layer relies on for bitwise fidelity.
fn fmt_f64(v: f64) -> String {
    format!("{v:?}")
}

fn fmt_rows(rows: &[Vec<f64>]) -> String {
    let lists: Vec<String> = rows
        .iter()
        .map(|r| {
            let vals: Vec<String> = r.iter().map(|&v| fmt_f64(v)).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    format!("[{}]", lists.join(","))
}

/// Encode a routed request payload.
pub fn encode_request(route: &str, req: &ServeRequest) -> Vec<u8> {
    let (kind, rows): (&str, &[Vec<f64>]) = match &req.payload {
        ServePayload::Row(row) => ("row", std::slice::from_ref(row)),
        ServePayload::Batch(rows) => ("batch", rows),
    };
    let deadline = match req.deadline {
        Some(d) => format!(",\"deadline_ms\":{}", d.as_millis()),
        None => String::new(),
    };
    format!(
        "{{\"kind\":\"{kind}\",\"route\":\"{}\"{deadline},\"rows\":{}}}",
        json_escape(route),
        fmt_rows(rows)
    )
    .into_bytes()
}

/// Decode a request payload into its route and typed request.
pub fn decode_request(
    payload: &[u8],
) -> std::result::Result<(String, ServeRequest), WireFault> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| WireFault::Malformed("request payload is not UTF-8".into()))?;
    let kind = get_str(text, "kind")?;
    let route = get_str(text, "route")?;
    let rows = get_rows(text, "rows")?;
    let payload = match kind.as_str() {
        "row" => {
            if rows.len() != 1 {
                return Err(WireFault::Malformed(format!(
                    "row request carries {} rows",
                    rows.len()
                )));
            }
            ServePayload::Row(rows.into_iter().next().unwrap_or_default())
        }
        "batch" => ServePayload::Batch(rows),
        other => {
            return Err(WireFault::Malformed(format!("unknown request kind '{other}'")))
        }
    };
    let deadline = opt_u64(text, "deadline_ms")?.map(Duration::from_millis);
    Ok((route, ServeRequest { payload, deadline }))
}

/// Stable wire code for a service rejection.
pub fn reject_code(r: &RejectReason) -> &'static str {
    match r {
        RejectReason::QueueFull { .. } => "queue_full",
        RejectReason::DeadlineExpired { .. } => "deadline_expired",
        RejectReason::BadShape { .. } => "bad_shape",
        RejectReason::NonFinite { .. } => "non_finite",
        RejectReason::Stopped => "stopped",
    }
}

/// Encode a service reply (answered or rejected).
pub fn encode_reply(reply: &ServeReply) -> Vec<u8> {
    match reply {
        ServeReply::Answered(a) => {
            let preds: Vec<String> = a
                .predictions
                .iter()
                .map(|p| {
                    let scores: Vec<String> =
                        p.scores.iter().map(|&s| fmt_f64(s)).collect();
                    format!(
                        "{{\"label\":{},\"scores\":[{}]}}",
                        p.label,
                        scores.join(",")
                    )
                })
                .collect();
            format!(
                "{{\"status\":\"ok\",\"key\":\"{}\",\"version\":\"{}\",\
                 \"batch_rows\":{},\"queue_us\":{},\"compute_us\":{},\
                 \"predictions\":[{}]}}",
                json_escape(&a.model_key),
                json_escape(&a.model_version),
                a.batch_rows,
                a.queue_latency.as_micros(),
                a.compute_latency.as_micros(),
                preds.join(",")
            )
            .into_bytes()
        }
        ServeReply::Rejected(r) => encode_rejection(reject_code(r), &r.to_string()),
    }
}

/// Encode a rejection the wire layer itself produced (`rate_limited`,
/// `unknown_route`) or a service rejection by code.
pub fn encode_rejection(code: &str, detail: &str) -> Vec<u8> {
    format!(
        "{{\"status\":\"rejected\",\"reason\":\"{}\",\"detail\":\"{}\"}}",
        json_escape(code),
        json_escape(detail)
    )
    .into_bytes()
}

/// A successful network answer (mirror of
/// [`crate::coordinator::service::ServeAnswer`] minus live `Duration`s).
#[derive(Clone, Debug)]
pub struct WireAnswer {
    pub key: String,
    pub version: String,
    pub batch_rows: usize,
    pub queue_us: u64,
    pub compute_us: u64,
    pub predictions: Vec<Prediction>,
}

/// What a request frame came back as.
#[derive(Clone, Debug)]
pub enum WireOutcome {
    Answered(WireAnswer),
    Rejected { reason: String, detail: String },
}

impl WireOutcome {
    pub fn answer(self) -> Result<WireAnswer> {
        match self {
            WireOutcome::Answered(a) => Ok(a),
            WireOutcome::Rejected { reason, detail } => {
                Err(AviError::Coordinator(format!("rejected ({reason}): {detail}")))
            }
        }
    }
}

/// Decode a reply payload.
pub fn decode_reply(payload: &[u8]) -> std::result::Result<WireOutcome, WireFault> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| WireFault::Malformed("reply payload is not UTF-8".into()))?;
    match get_str(text, "status")?.as_str() {
        "ok" => {
            let preds_src = get_array(text, "predictions")?;
            let mut predictions = Vec::new();
            for obj in split_objects(&preds_src) {
                let label = get_u64(obj, "label")? as usize;
                let scores_src = get_array(obj, "scores")?;
                let scores = parse_f64_list(&scores_src)?;
                predictions.push(Prediction { label, scores });
            }
            Ok(WireOutcome::Answered(WireAnswer {
                key: get_str(text, "key")?,
                version: get_str(text, "version")?,
                batch_rows: get_u64(text, "batch_rows")? as usize,
                queue_us: get_u64(text, "queue_us")?,
                compute_us: get_u64(text, "compute_us")?,
                predictions,
            }))
        }
        "rejected" => Ok(WireOutcome::Rejected {
            reason: get_str(text, "reason")?,
            detail: get_str(text, "detail").unwrap_or_default(),
        }),
        other => Err(WireFault::Malformed(format!("unknown reply status '{other}'"))),
    }
}

/// Encode a protocol-level error payload.
pub fn encode_wire_error(code: &str, detail: &str) -> Vec<u8> {
    format!(
        "{{\"error\":\"{}\",\"detail\":\"{}\"}}",
        json_escape(code),
        json_escape(detail)
    )
    .into_bytes()
}

/// Decode a protocol-level error payload into (code, detail); tolerant
/// of garbage (both default to empty).
pub fn decode_wire_error(payload: &[u8]) -> (String, String) {
    let text = std::str::from_utf8(payload).unwrap_or("");
    (
        get_str(text, "error").unwrap_or_default(),
        get_str(text, "detail").unwrap_or_default(),
    )
}

// ---------------------------------------------------------------------
// Model-control payload codecs
// ---------------------------------------------------------------------

/// Magic opening a hybrid (JSON header + raw artifact bytes) payload.
pub const HYBRID_MAGIC: [u8; 4] = *b"AVIM";

fn encode_hybrid(header: &str, artifact: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + header.len() + artifact.len());
    out.extend_from_slice(&HYBRID_MAGIC);
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(artifact);
    out
}

/// Split a hybrid payload into its JSON header and artifact bytes.  The
/// declared header length is validated against the bytes present before
/// any slicing — same discipline as the frame header itself.
fn decode_hybrid(payload: &[u8]) -> std::result::Result<(&str, &[u8]), WireFault> {
    if payload.len() < 8 || payload[..4] != HYBRID_MAGIC {
        return Err(WireFault::Malformed("not a hybrid model payload".into()));
    }
    let hdr_len =
        u32::from_le_bytes([payload[4], payload[5], payload[6], payload[7]]) as usize;
    if hdr_len > payload.len() - 8 {
        return Err(WireFault::Malformed(format!(
            "hybrid header claims {hdr_len} bytes, {} present",
            payload.len() - 8
        )));
    }
    let header = std::str::from_utf8(&payload[8..8 + hdr_len])
        .map_err(|_| WireFault::Malformed("hybrid header is not UTF-8".into()))?;
    Ok((header, &payload[8 + hdr_len..]))
}

fn parse_checksum(text: &str, key: &str) -> std::result::Result<u64, WireFault> {
    let hex = get_str(text, key)?;
    u64::from_str_radix(hex.trim(), 16)
        .map_err(|_| WireFault::Malformed(format!("bad checksum literal '{hex}'")))
}

fn get_bool(text: &str, key: &str) -> std::result::Result<bool, WireFault> {
    match after_key(text, key) {
        None => Ok(false),
        Some(rest) if rest.starts_with("true") => Ok(true),
        Some(rest) if rest.starts_with("false") => Ok(false),
        Some(_) => Err(WireFault::Malformed(format!("\"{key}\" is not a bool"))),
    }
}

/// Declared metadata of a pushed artifact.
#[derive(Clone, Debug)]
pub struct PushHeader {
    pub key: String,
    pub version: String,
    /// FNV-1a-64 the sender computed; the receiver re-hashes and
    /// refuses a mismatch with `checksum_mismatch`.
    pub checksum: u64,
    /// Allow replacing an existing `key@version` with different bytes.
    pub force: bool,
}

/// Encode a `PushModel` payload (checksum computed here, over exactly
/// the bytes shipped).
pub fn encode_push_model(key: &str, version: &str, artifact: &[u8], force: bool) -> Vec<u8> {
    let header = format!(
        "{{\"key\":\"{}\",\"version\":\"{}\",\"checksum\":\"{:016x}\",\"force\":{force}}}",
        json_escape(key),
        json_escape(version),
        crate::artifact::fnv64(artifact),
    );
    encode_hybrid(&header, artifact)
}

/// Decode a `PushModel` payload into its header and artifact bytes.
pub fn decode_push_model(
    payload: &[u8],
) -> std::result::Result<(PushHeader, &[u8]), WireFault> {
    let (header, artifact) = decode_hybrid(payload)?;
    Ok((
        PushHeader {
            key: get_str(header, "key")?,
            version: get_str(header, "version")?,
            checksum: parse_checksum(header, "checksum")?,
            force: get_bool(header, "force")?,
        },
        artifact,
    ))
}

/// Encode a `PullModel` request (`version: None` = latest).
pub fn encode_pull_model(key: &str, version: Option<&str>) -> Vec<u8> {
    match version {
        Some(v) => format!(
            "{{\"key\":\"{}\",\"version\":\"{}\"}}",
            json_escape(key),
            json_escape(v)
        ),
        None => format!("{{\"key\":\"{}\"}}", json_escape(key)),
    }
    .into_bytes()
}

/// Decode a `PullModel` request into `(key, version)`.
pub fn decode_pull_model(
    payload: &[u8],
) -> std::result::Result<(String, Option<String>), WireFault> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| WireFault::Malformed("pull payload is not UTF-8".into()))?;
    let key = get_str(text, "key")?;
    let version = get_str(text, "version").ok();
    Ok((key, version))
}

/// Encode an `ActivateModel` request.
pub fn encode_activate_model(key: &str, version: &str) -> Vec<u8> {
    format!(
        "{{\"key\":\"{}\",\"version\":\"{}\"}}",
        json_escape(key),
        json_escape(version)
    )
    .into_bytes()
}

/// Decode an `ActivateModel` request into `(key, version)`.
pub fn decode_activate_model(
    payload: &[u8],
) -> std::result::Result<(String, String), WireFault> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| WireFault::Malformed("activate payload is not UTF-8".into()))?;
    Ok((get_str(text, "key")?, get_str(text, "version")?))
}

/// Successful control-plane acknowledgement.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ControlAck {
    /// `"push"`, `"pull"`, or `"activate"`.
    pub op: String,
    pub key: String,
    pub version: String,
    pub checksum: u64,
    /// Artifact size on the server, in bytes.
    pub bytes: u64,
}

/// What a control frame (push/activate) came back as.
#[derive(Clone, Debug)]
pub enum ControlOutcome {
    Ok(ControlAck),
    Rejected { reason: String, detail: String },
}

impl ControlOutcome {
    /// Unwrap the ack or surface the rejection as a typed error.
    pub fn ack(self) -> Result<ControlAck> {
        match self {
            ControlOutcome::Ok(a) => Ok(a),
            ControlOutcome::Rejected { reason, detail } => Err(AviError::Artifact(
                format!("control rejected ({reason}): {detail}"),
            )),
        }
    }
}

/// Encode a control-plane acknowledgement reply.
pub fn encode_control_ok(
    op: &str,
    key: &str,
    version: &str,
    checksum: u64,
    bytes: u64,
) -> Vec<u8> {
    format!(
        "{{\"status\":\"ok\",\"op\":\"{}\",\"key\":\"{}\",\"version\":\"{}\",\
         \"checksum\":\"{checksum:016x}\",\"bytes\":{bytes}}}",
        json_escape(op),
        json_escape(key),
        json_escape(version)
    )
    .into_bytes()
}

/// Decode a push/activate reply payload.
pub fn decode_control_reply(
    payload: &[u8],
) -> std::result::Result<ControlOutcome, WireFault> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| WireFault::Malformed("control reply is not UTF-8".into()))?;
    match get_str(text, "status")?.as_str() {
        "ok" => Ok(ControlOutcome::Ok(ControlAck {
            op: get_str(text, "op")?,
            key: get_str(text, "key")?,
            version: get_str(text, "version")?,
            checksum: parse_checksum(text, "checksum")?,
            bytes: get_u64(text, "bytes")?,
        })),
        "rejected" => Ok(ControlOutcome::Rejected {
            reason: get_str(text, "reason")?,
            detail: get_str(text, "detail").unwrap_or_default(),
        }),
        other => {
            Err(WireFault::Malformed(format!("unknown control status '{other}'")))
        }
    }
}

/// A pulled artifact: metadata + the verified bytes.
#[derive(Clone, Debug)]
pub struct PulledModel {
    pub key: String,
    pub version: String,
    pub checksum: u64,
    pub artifact: Vec<u8>,
}

/// What a `PullModel` frame came back as.
#[derive(Clone, Debug)]
pub enum PullOutcome {
    Pulled(PulledModel),
    Rejected { reason: String, detail: String },
}

impl PullOutcome {
    /// Unwrap the artifact or surface the rejection as a typed error.
    pub fn model(self) -> Result<PulledModel> {
        match self {
            PullOutcome::Pulled(m) => Ok(m),
            PullOutcome::Rejected { reason, detail } => Err(AviError::Artifact(
                format!("pull rejected ({reason}): {detail}"),
            )),
        }
    }
}

/// Encode a successful `PullModel` reply: hybrid ack header + artifact.
pub fn encode_pull_reply(key: &str, version: &str, artifact: &[u8]) -> Vec<u8> {
    let header = format!(
        "{{\"status\":\"ok\",\"op\":\"pull\",\"key\":\"{}\",\"version\":\"{}\",\
         \"checksum\":\"{:016x}\",\"bytes\":{}}}",
        json_escape(key),
        json_escape(version),
        crate::artifact::fnv64(artifact),
        artifact.len()
    );
    encode_hybrid(&header, artifact)
}

/// Decode a `PullModel` reply: hybrid = artifact, plain JSON = rejection.
/// The pulled bytes are re-hashed against the declared checksum, so a
/// corrupted transfer is refused client-side too.
pub fn decode_pull_reply(payload: &[u8]) -> std::result::Result<PullOutcome, WireFault> {
    if payload.len() >= 4 && payload[..4] == HYBRID_MAGIC {
        let (header, artifact) = decode_hybrid(payload)?;
        let checksum = parse_checksum(header, "checksum")?;
        if crate::artifact::fnv64(artifact) != checksum {
            return Err(WireFault::Malformed(
                "pulled artifact does not match its declared checksum".into(),
            ));
        }
        if get_u64(header, "bytes")? != artifact.len() as u64 {
            return Err(WireFault::Malformed(
                "pulled artifact does not match its declared length".into(),
            ));
        }
        return Ok(PullOutcome::Pulled(PulledModel {
            key: get_str(header, "key")?,
            version: get_str(header, "version")?,
            checksum,
            artifact: artifact.to_vec(),
        }));
    }
    let text = std::str::from_utf8(payload)
        .map_err(|_| WireFault::Malformed("pull reply is not UTF-8".into()))?;
    match get_str(text, "status")?.as_str() {
        "rejected" => Ok(PullOutcome::Rejected {
            reason: get_str(text, "reason")?,
            detail: get_str(text, "detail").unwrap_or_default(),
        }),
        other => Err(WireFault::Malformed(format!("unknown pull status '{other}'"))),
    }
}

// ---------------------------------------------------------------------
// Wire-level counters
// ---------------------------------------------------------------------

/// Snapshot of the front door's wire counters, embedded in
/// [`RouterReport::to_json`] under `"wire"`.  Lives here (not in the
/// front door) so the router can carry it without depending on the
/// server layer above it.
///
/// [`RouterReport::to_json`]: crate::coordinator::router::RouterReport::to_json
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// TCP connections accepted.
    pub connections: u64,
    /// Request frames answered by the router (any [`ServeReply`]).
    pub accepted: u64,
    /// Request frames turned away by a route's token bucket.
    pub rejected_limit: u64,
    /// Request frames naming a route the router does not serve.
    pub rejected_route: u64,
    /// Connections reaped by the read deadline.
    pub timed_out: u64,
    /// Frames with bad magic/kind/version or unparsable payloads.
    pub malformed: u64,
    /// Frames whose declared length exceeded the cap.
    pub oversized: u64,
    /// Bytes read off the wire (complete frames).
    pub bytes_in: u64,
    /// Bytes written to the wire.
    pub bytes_out: u64,
    /// Model artifacts accepted through `PushModel`.
    pub model_pushes: u64,
    /// Artifacts served through `PullModel`.
    pub model_pulls: u64,
    /// Successful `ActivateModel` hot-swaps.
    pub model_activations: u64,
}

impl WireStats {
    /// One JSON object, same flat style as the rest of the report.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"connections\": {}, \"accepted\": {}, \"rejected_limit\": {}, \
             \"rejected_route\": {}, \"timed_out\": {}, \"malformed\": {}, \
             \"oversized\": {}, \"bytes_in\": {}, \"bytes_out\": {}, \
             \"model_pushes\": {}, \"model_pulls\": {}, \"model_activations\": {}}}",
            self.connections,
            self.accepted,
            self.rejected_limit,
            self.rejected_route,
            self.timed_out,
            self.malformed,
            self.oversized,
            self.bytes_in,
            self.bytes_out,
            self.model_pushes,
            self.model_pulls,
            self.model_activations
        )
    }
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// Blocking client for the framed protocol — one TCP connection,
/// request/reply in lockstep.
pub struct WireClient {
    stream: TcpStream,
    max_frame: usize,
}

impl WireClient {
    /// Connect to a front door.
    pub fn connect(addr: &str) -> Result<WireClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| AviError::Net(format!("connect {addr}: {e}")))?;
        Ok(WireClient { stream, max_frame: DEFAULT_MAX_FRAME_BYTES })
    }

    /// Set read/write deadlines on the connection.
    pub fn with_timeouts(
        self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<WireClient> {
        self.stream
            .set_read_timeout(read)
            .and_then(|()| self.stream.set_write_timeout(write))
            .map_err(|e| AviError::Net(format!("set timeouts: {e}")))?;
        Ok(self)
    }

    /// Raise/lower the reply-size cap.
    pub fn max_frame(mut self, bytes: usize) -> WireClient {
        self.max_frame = bytes;
        self
    }

    /// Send one request and block for its outcome.  Rejections (rate
    /// limits included) come back as [`WireOutcome::Rejected`];
    /// protocol-level error frames surface as typed [`AviError::Net`].
    pub fn request(&mut self, route: &str, req: &ServeRequest) -> Result<WireOutcome> {
        let payload = encode_request(route, req);
        write_frame(&mut self.stream, FrameKind::Request, &payload)
            .map_err(|e| AviError::Net(format!("send request: {e}")))?;
        let frame = read_frame(&mut self.stream, self.max_frame)?;
        match frame.kind {
            FrameKind::Reply => Ok(decode_reply(&frame.payload)?),
            FrameKind::Error => {
                let (code, detail) = decode_wire_error(&frame.payload);
                Err(AviError::Net(format!("server error ({code}): {detail}")))
            }
            other => Err(AviError::Net(format!("unexpected frame kind {other:?}"))),
        }
    }

    /// Push a model artifact to the server's store as `key@version`.
    /// `force` permits replacing an existing version with different
    /// bytes (rollback to identical bytes never needs it).
    pub fn push_model(
        &mut self,
        key: &str,
        version: &str,
        artifact: &[u8],
        force: bool,
    ) -> Result<ControlOutcome> {
        let payload = encode_push_model(key, version, artifact, force);
        self.control(FrameKind::PushModel, &payload)
    }

    /// Pull an artifact back out of the server's store
    /// (`version: None` = latest).  Bytes are checksum-verified before
    /// this returns.
    pub fn pull_model(&mut self, key: &str, version: Option<&str>) -> Result<PullOutcome> {
        let payload = encode_pull_model(key, version);
        write_frame(&mut self.stream, FrameKind::PullModel, &payload)
            .map_err(|e| AviError::Net(format!("send pull: {e}")))?;
        let frame = read_frame(&mut self.stream, self.max_frame)?;
        match frame.kind {
            FrameKind::Reply => Ok(decode_pull_reply(&frame.payload)?),
            FrameKind::Error => {
                let (code, detail) = decode_wire_error(&frame.payload);
                Err(AviError::Net(format!("server error ({code}): {detail}")))
            }
            other => Err(AviError::Net(format!("unexpected frame kind {other:?}"))),
        }
    }

    /// Register + hot-swap routes to a stored `key@version`.
    pub fn activate_model(&mut self, key: &str, version: &str) -> Result<ControlOutcome> {
        let payload = encode_activate_model(key, version);
        self.control(FrameKind::ActivateModel, &payload)
    }

    fn control(&mut self, kind: FrameKind, payload: &[u8]) -> Result<ControlOutcome> {
        write_frame(&mut self.stream, kind, payload)
            .map_err(|e| AviError::Net(format!("send {kind:?}: {e}")))?;
        let frame = read_frame(&mut self.stream, self.max_frame)?;
        match frame.kind {
            FrameKind::Reply => Ok(decode_control_reply(&frame.payload)?),
            FrameKind::Error => {
                let (code, detail) = decode_wire_error(&frame.payload);
                Err(AviError::Net(format!("server error ({code}): {detail}")))
            }
            other => Err(AviError::Net(format!("unexpected frame kind {other:?}"))),
        }
    }

    /// Ask the server to shut down gracefully; consumes the client.
    pub fn shutdown_server(mut self) -> Result<()> {
        write_frame(&mut self.stream, FrameKind::Shutdown, b"{}")
            .map_err(|e| AviError::Net(format!("send shutdown: {e}")))?;
        // best effort: wait for the ack so the caller knows the server
        // heard us, but a racing close is not an error
        let _ = read_frame(&mut self.stream, self.max_frame);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Minimal JSON readers (wire payloads only — flat objects, nested
// numeric arrays, no objects-in-strings; the container has no serde)
// ---------------------------------------------------------------------

fn after_key<'a>(text: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\"");
    let pos = text.find(&pat)?;
    let rest = text[pos + pat.len()..].trim_start();
    Some(rest.strip_prefix(':')?.trim_start())
}

fn get_str(text: &str, key: &str) -> std::result::Result<String, WireFault> {
    let rest = after_key(text, key)
        .ok_or_else(|| WireFault::Malformed(format!("missing \"{key}\"")))?;
    let rest = rest
        .strip_prefix('"')
        .ok_or_else(|| WireFault::Malformed(format!("\"{key}\" is not a string")))?;
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Ok(out),
            '\\' => match chars.next() {
                Some('n') => out.push('\n'),
                Some('r') => out.push('\r'),
                Some('t') => out.push('\t'),
                Some('u') => {
                    let hex: String = chars.by_ref().take(4).collect();
                    let cp = u32::from_str_radix(&hex, 16).map_err(|_| {
                        WireFault::Malformed(format!("bad \\u escape in \"{key}\""))
                    })?;
                    out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                }
                Some(e) => out.push(e),
                None => {
                    return Err(WireFault::Malformed(format!(
                        "unterminated escape in \"{key}\""
                    )))
                }
            },
            c => out.push(c),
        }
    }
    Err(WireFault::Malformed(format!("unterminated string for \"{key}\"")))
}

fn get_u64(text: &str, key: &str) -> std::result::Result<u64, WireFault> {
    let rest = after_key(text, key)
        .ok_or_else(|| WireFault::Malformed(format!("missing \"{key}\"")))?;
    let end = rest
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(rest.len());
    rest[..end]
        .parse::<u64>()
        .map_err(|_| WireFault::Malformed(format!("\"{key}\" is not an integer")))
}

fn opt_u64(text: &str, key: &str) -> std::result::Result<Option<u64>, WireFault> {
    if after_key(text, key).is_none() {
        return Ok(None);
    }
    get_u64(text, key).map(Some)
}

/// Contents of the depth-matched `[…]` after `"key":` (brackets
/// stripped).
fn get_array(text: &str, key: &str) -> std::result::Result<String, WireFault> {
    let rest = after_key(text, key)
        .ok_or_else(|| WireFault::Malformed(format!("missing \"{key}\"")))?;
    if !rest.starts_with('[') {
        return Err(WireFault::Malformed(format!("\"{key}\" is not an array")));
    }
    let mut depth = 0usize;
    for (i, ch) in rest.char_indices() {
        match ch {
            '[' => depth += 1,
            ']' => {
                depth -= 1;
                if depth == 0 {
                    return Ok(rest[1..i].to_string());
                }
            }
            _ => {}
        }
    }
    Err(WireFault::Malformed(format!("unbalanced array for \"{key}\"")))
}

/// Top-level `{…}` objects of an array body.
fn split_objects(src: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in src.char_indices() {
        match ch {
            '{' => {
                if depth == 0 {
                    start = i;
                }
                depth += 1;
            }
            '}' => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    out.push(&src[start..i + 1]);
                }
            }
            _ => {}
        }
    }
    out
}

fn parse_f64_list(src: &str) -> std::result::Result<Vec<f64>, WireFault> {
    if src.trim().is_empty() {
        return Ok(Vec::new());
    }
    src.split(',')
        .map(|t| {
            t.trim()
                .parse::<f64>()
                .map_err(|e| WireFault::Malformed(format!("bad float '{}': {e}", t.trim())))
        })
        .collect()
}

fn get_rows(text: &str, key: &str) -> std::result::Result<Vec<Vec<f64>>, WireFault> {
    let body = get_array(text, key)?;
    let mut out = Vec::new();
    let mut rest = body.as_str();
    while let Some(start) = rest.find('[') {
        let end = rest[start..]
            .find(']')
            .ok_or_else(|| WireFault::Malformed(format!("unbalanced rows in \"{key}\"")))?
            + start;
        out.push(parse_f64_list(&rest[start + 1..end])?);
        rest = &rest[end + 1..];
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::ServeAnswer;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, FrameKind::Request, b"{\"x\":1}").unwrap();
        assert_eq!(n, (HEADER_LEN + 7) as u64);
        let frame = read_frame(&mut Cursor::new(&buf), DEFAULT_MAX_FRAME_BYTES).unwrap();
        assert_eq!(frame.kind, FrameKind::Request);
        assert_eq!(frame.payload, b"{\"x\":1}");
        assert_eq!(frame.wire_len(), n);
    }

    #[test]
    fn clean_close_is_eof_truncation_is_malformed() {
        let fault = read_frame(&mut Cursor::new(&[][..]), 64).unwrap_err();
        assert!(matches!(fault, WireFault::Eof), "{fault:?}");
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Reply, b"12345678").unwrap();
        buf.truncate(buf.len() - 3);
        let fault = read_frame(&mut Cursor::new(&buf), 64).unwrap_err();
        assert!(matches!(fault, WireFault::Malformed(_)), "{fault:?}");
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Request, b"{}").unwrap();
        let mut bad = buf.clone();
        bad[0] = b'X';
        let fault = read_frame(&mut Cursor::new(&bad), 64).unwrap_err();
        assert!(matches!(fault, WireFault::Malformed(_)), "{fault:?}");
        let mut bad = buf.clone();
        bad[4] = 9;
        let fault = read_frame(&mut Cursor::new(&bad), 64).unwrap_err();
        assert!(matches!(fault, WireFault::Version { got: 9 }), "{fault:?}");
        let mut bad = buf;
        bad[5] = 200;
        let fault = read_frame(&mut Cursor::new(&bad), 64).unwrap_err();
        assert!(matches!(fault, WireFault::Malformed(_)), "{fault:?}");
    }

    #[test]
    fn oversized_rejects_on_header_before_allocating() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Request, &[b' '; 100]).unwrap();
        let fault = read_frame(&mut Cursor::new(&buf), 64).unwrap_err();
        match fault {
            WireFault::Oversized { got: 100, max: 64 } => {}
            other => panic!("expected Oversized, got {other:?}"),
        }
        // a declared length with no bytes behind it still rejects on the
        // header alone
        let mut lying = Vec::new();
        write_frame(&mut lying, FrameKind::Request, b"").unwrap();
        lying[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let fault = read_frame(&mut Cursor::new(&lying), 1 << 20).unwrap_err();
        assert!(matches!(fault, WireFault::Oversized { .. }), "{fault:?}");
    }

    #[test]
    fn request_codec_roundtrips_bitwise() {
        let rows = vec![
            vec![1.5, -0.0, f64::MIN_POSITIVE, 0.1 + 0.2],
            vec![f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 3.141592653589793],
        ];
        let req = ServeRequest::batch(rows.clone())
            .with_deadline(Duration::from_millis(250));
        let payload = encode_request("tenant-a/model", &req);
        let (route, back) = decode_request(&payload).unwrap();
        assert_eq!(route, "tenant-a/model");
        assert_eq!(back.deadline, Some(Duration::from_millis(250)));
        match back.payload {
            ServePayload::Batch(got) => {
                for (a, b) in rows.iter().flatten().zip(got.iter().flatten()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("expected batch, got {other:?}"),
        }
        // single-row kind survives
        let (_, back) =
            decode_request(&encode_request("m", &ServeRequest::row(vec![1.0]))).unwrap();
        assert!(matches!(back.payload, ServePayload::Row(_)));
        assert_eq!(back.deadline, None);
    }

    #[test]
    fn reply_codec_roundtrips_bitwise() {
        let answer = ServeAnswer {
            predictions: vec![
                Prediction { label: 2, scores: vec![0.1 + 0.2, -1.5e-300] },
                Prediction { label: 0, scores: vec![f64::MAX, f64::MIN] },
            ],
            model_key: "m".into(),
            model_version: "v1".into(),
            queue_latency: Duration::from_micros(12),
            compute_latency: Duration::from_micros(345),
            batch_rows: 2,
        };
        let payload = encode_reply(&ServeReply::Answered(answer));
        let out = decode_reply(&payload).unwrap();
        match out {
            WireOutcome::Answered(a) => {
                assert_eq!(a.key, "m");
                assert_eq!(a.version, "v1");
                assert_eq!(a.batch_rows, 2);
                assert_eq!(a.queue_us, 12);
                assert_eq!(a.compute_us, 345);
                assert_eq!(a.predictions.len(), 2);
                assert_eq!(a.predictions[0].label, 2);
                assert_eq!(a.predictions[0].scores[0].to_bits(), (0.1 + 0.2).to_bits());
                assert_eq!(a.predictions[1].scores[0].to_bits(), f64::MAX.to_bits());
            }
            other => panic!("expected answer, got {other:?}"),
        }
    }

    #[test]
    fn rejection_codec_carries_code_and_detail() {
        let reply =
            ServeReply::Rejected(RejectReason::NonFinite { row: 3, col: 7 });
        let payload = encode_reply(&reply);
        match decode_reply(&payload).unwrap() {
            WireOutcome::Rejected { reason, detail } => {
                assert_eq!(reason, "non_finite");
                assert!(detail.contains("row 3"), "{detail}");
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        match decode_reply(&encode_rejection("rate_limited", "route 'm'")).unwrap() {
            WireOutcome::Rejected { reason, .. } => assert_eq!(reason, "rate_limited"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wire_error_codec() {
        let payload = encode_wire_error("oversized", "got 9999");
        let (code, detail) = decode_wire_error(&payload);
        assert_eq!(code, "oversized");
        assert_eq!(detail, "got 9999");
        assert_eq!(decode_wire_error(b"garbage").0, "");
    }

    #[test]
    fn malformed_payloads_are_typed_not_panics() {
        for bad in [
            &b"not json at all"[..],
            b"{\"route\":\"m\"}",
            b"{\"kind\":\"row\",\"route\":\"m\",\"rows\":[[1],[2]]}",
            b"{\"kind\":\"warp\",\"route\":\"m\",\"rows\":[[1]]}",
            b"{\"kind\":\"row\",\"route\":\"m\",\"rows\":[[oops]]}",
            b"\xff\xfe",
        ] {
            let err = decode_request(bad).unwrap_err();
            assert!(matches!(err, WireFault::Malformed(_)), "{err:?}");
        }
    }

    #[test]
    fn wire_stats_json_has_every_counter() {
        let stats = WireStats {
            connections: 1,
            accepted: 2,
            rejected_limit: 3,
            rejected_route: 4,
            timed_out: 5,
            malformed: 6,
            oversized: 7,
            bytes_in: 8,
            bytes_out: 9,
            model_pushes: 10,
            model_pulls: 11,
            model_activations: 12,
        };
        let json = stats.to_json();
        for cell in [
            "\"connections\": 1",
            "\"accepted\": 2",
            "\"rejected_limit\": 3",
            "\"rejected_route\": 4",
            "\"timed_out\": 5",
            "\"malformed\": 6",
            "\"oversized\": 7",
            "\"bytes_in\": 8",
            "\"bytes_out\": 9",
            "\"model_pushes\": 10",
            "\"model_pulls\": 11",
            "\"model_activations\": 12",
        ] {
            assert!(json.contains(cell), "{json}");
        }
    }

    #[test]
    fn push_model_codec_roundtrips_and_verifies() {
        let artifact: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let payload = encode_push_model("acme/m", "v2", &artifact, true);
        let (header, bytes) = decode_push_model(&payload).unwrap();
        assert_eq!(header.key, "acme/m");
        assert_eq!(header.version, "v2");
        assert!(header.force);
        assert_eq!(bytes, &artifact[..]);
        assert_eq!(header.checksum, crate::artifact::fnv64(&artifact));
        // force defaults to false
        let payload = encode_push_model("m", "v1", b"abc", false);
        let (header, _) = decode_push_model(&payload).unwrap();
        assert!(!header.force);
    }

    #[test]
    fn hybrid_envelope_rejects_lies_without_panicking() {
        // header length claiming more bytes than present
        let mut bad = encode_push_model("m", "v1", b"artifact", false);
        let lie = (bad.len() as u32) * 2;
        bad[4..8].copy_from_slice(&lie.to_le_bytes());
        assert!(matches!(
            decode_push_model(&bad).unwrap_err(),
            WireFault::Malformed(_)
        ));
        // not hybrid at all / too short
        assert!(decode_push_model(b"{}").is_err());
        assert!(decode_push_model(b"AVIM").is_err());
        assert!(decode_push_model(b"").is_err());
        // truncation anywhere is typed
        let good = encode_push_model("m", "v1", b"artifact-bytes", false);
        for cut in 0..good.len().min(16) {
            let _ = decode_push_model(&good[..cut]);
        }
    }

    #[test]
    fn pull_and_activate_request_codecs_roundtrip() {
        let (key, version) = decode_pull_model(&encode_pull_model("t/m", Some("v3"))).unwrap();
        assert_eq!(key, "t/m");
        assert_eq!(version.as_deref(), Some("v3"));
        let (key, version) = decode_pull_model(&encode_pull_model("t/m", None)).unwrap();
        assert_eq!(key, "t/m");
        assert!(version.is_none());
        let (key, version) =
            decode_activate_model(&encode_activate_model("t/m", "v3")).unwrap();
        assert_eq!((key.as_str(), version.as_str()), ("t/m", "v3"));
        assert!(decode_activate_model(b"{\"key\":\"m\"}").is_err());
    }

    #[test]
    fn control_reply_codec_roundtrips_ok_and_rejected() {
        let payload = encode_control_ok("push", "acme/m", "v2", u64::MAX - 5, 4096);
        match decode_control_reply(&payload).unwrap() {
            ControlOutcome::Ok(ack) => {
                assert_eq!(ack.op, "push");
                assert_eq!(ack.key, "acme/m");
                assert_eq!(ack.version, "v2");
                assert_eq!(ack.checksum, u64::MAX - 5);
                assert_eq!(ack.bytes, 4096);
            }
            other => panic!("{other:?}"),
        }
        match decode_control_reply(&encode_rejection("checksum_mismatch", "boom")).unwrap() {
            ControlOutcome::Rejected { reason, detail } => {
                assert_eq!(reason, "checksum_mismatch");
                assert_eq!(detail, "boom");
            }
            other => panic!("{other:?}"),
        }
        // rejection unwrap is a typed artifact error
        let e = decode_control_reply(&encode_rejection("version_conflict", "m@v1"))
            .unwrap()
            .ack()
            .unwrap_err();
        assert!(matches!(e, AviError::Artifact(_)), "{e}");
    }

    #[test]
    fn pull_reply_codec_verifies_checksum_client_side() {
        let artifact = b"pretend-artifact-bytes".to_vec();
        let payload = encode_pull_reply("m", "v1", &artifact);
        match decode_pull_reply(&payload).unwrap() {
            PullOutcome::Pulled(m) => {
                assert_eq!(m.key, "m");
                assert_eq!(m.version, "v1");
                assert_eq!(m.artifact, artifact);
                assert_eq!(m.checksum, crate::artifact::fnv64(&artifact));
            }
            other => panic!("{other:?}"),
        }
        // a flipped artifact byte no longer matches the declared digest
        let mut bad = encode_pull_reply("m", "v1", &artifact);
        let n = bad.len();
        bad[n - 1] ^= 0xFF;
        assert!(matches!(
            decode_pull_reply(&bad).unwrap_err(),
            WireFault::Malformed(_)
        ));
        // rejection path
        match decode_pull_reply(&encode_rejection("unknown_model", "m@v9")).unwrap() {
            PullOutcome::Rejected { reason, .. } => assert_eq!(reason, "unknown_model"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fault_display_and_error_mapping() {
        let e: AviError = WireFault::Oversized { got: 10, max: 5 }.into();
        assert!(e.to_string().contains("frame too large"), "{e}");
        assert!(WireFault::Version { got: 3 }.to_string().contains("version 3"));
        assert!(WireFault::Eof.to_string().contains("closed"));
    }
}

//! The serving **front door**: a std-only TCP server speaking the
//! framed [`crate::coordinator::wire`] protocol over the
//! [`ModelRouter`].
//!
//! Design: thread-per-connection with a bounded handler count.  The
//! accept loop runs on its own thread; each connection gets a handler
//! thread that reads frames in lockstep (one request, one reply).  The
//! concurrency story stays the same as in-process serving — handlers
//! funnel into each route's bounded-queue batcher — the front door only
//! adds the protections a network edge needs:
//!
//! * **Rate limiting** — an optional per-route token bucket checked
//!   *before* admission, so a hot client is turned away with a typed
//!   `rate_limited` rejection instead of starving the queue.
//! * **Deadlines** — per-connection read/write timeouts; a silent peer
//!   is reaped (counted in `timed_out`), never waited on forever.
//! * **Frame caps** — oversized frames are rejected from the header
//!   alone with a typed `oversized` error frame; the payload is never
//!   allocated.
//! * **Graceful shutdown** — a `Shutdown` frame (or
//!   [`FrontDoor::shutdown`]) stops the accept loop, joins every
//!   handler, and lets in-flight requests drain through the router's
//!   existing drain path before the final report is cut.
//! * **Model control plane** — when started with a [`ModelControl`],
//!   the server also speaks `PushModel` / `PullModel` / `ActivateModel`
//!   frames: a pushed artifact is checksum-verified, decoded, conflict-
//!   checked, and landed in the checksummed
//!   [`crate::artifact::ArtifactStore`]; activation hot-swaps the route
//!   atomically through [`ModelRouter::register`] — all without a
//!   restart, all rate-limited per tenant-namespaced key under separate
//!   `model-control/<key>` buckets so control traffic cannot starve (or
//!   be starved by) the data plane.
//!
//! Every failure mode ends in a typed frame or a closed socket — the
//! front door never panics a worker and never leaves a peer hanging.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::artifact::{self, ArtifactStore};
use crate::coordinator::registry::{self, ModelRegistry};
use crate::coordinator::router::ModelRouter;
use crate::coordinator::service::ServeConfig;
use crate::coordinator::wire::{
    self, FrameKind, WireFault, WireStats, DEFAULT_MAX_FRAME_BYTES,
};
use crate::error::{AviError, Result};
use crate::estimator::persist;

// ---------------------------------------------------------------------
// Rate limiting
// ---------------------------------------------------------------------

/// Token-bucket parameters: `burst` tokens cap, refilled at `per_sec`.
/// `per_sec = 0` never refills — handy for deterministic tests and for
/// hard request quotas.
#[derive(Clone, Copy, Debug)]
pub struct RateLimit {
    pub per_sec: f64,
    pub burst: f64,
}

struct TokenBucket {
    tokens: f64,
    last: Instant,
}

/// Per-route token buckets.  One bucket per route key, created on first
/// sight; the map only ever holds as many entries as there are routes
/// named by clients.
pub struct RateLimiter {
    limit: RateLimit,
    buckets: Mutex<HashMap<String, TokenBucket>>,
}

impl RateLimiter {
    pub fn new(limit: RateLimit) -> RateLimiter {
        RateLimiter { limit, buckets: Mutex::new(HashMap::new()) }
    }

    /// Take one token for `route`; `false` means rate-limited.
    pub fn try_acquire(&self, route: &str) -> bool {
        let now = Instant::now();
        // bucket state is self-healing (recomputed from `last` each
        // call), so a poisoned lock is safe to recover
        let mut buckets =
            self.buckets.lock().unwrap_or_else(PoisonError::into_inner);
        let b = buckets.entry(route.to_string()).or_insert(TokenBucket {
            tokens: self.limit.burst,
            last: now,
        });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.last = now;
        b.tokens = (b.tokens + dt * self.limit.per_sec).min(self.limit.burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

// ---------------------------------------------------------------------
// Wire metrics (atomic mirror of WireStats)
// ---------------------------------------------------------------------

#[derive(Debug, Default)]
struct WireMetrics {
    connections: AtomicU64,
    accepted: AtomicU64,
    rejected_limit: AtomicU64,
    rejected_route: AtomicU64,
    timed_out: AtomicU64,
    malformed: AtomicU64,
    oversized: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    model_pushes: AtomicU64,
    model_pulls: AtomicU64,
    model_activations: AtomicU64,
}

impl WireMetrics {
    fn snapshot(&self) -> WireStats {
        WireStats {
            connections: self.connections.load(Ordering::Relaxed),
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected_limit: self.rejected_limit.load(Ordering::Relaxed),
            rejected_route: self.rejected_route.load(Ordering::Relaxed),
            timed_out: self.timed_out.load(Ordering::Relaxed),
            malformed: self.malformed.load(Ordering::Relaxed),
            oversized: self.oversized.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            model_pushes: self.model_pushes.load(Ordering::Relaxed),
            model_pulls: self.model_pulls.load(Ordering::Relaxed),
            model_activations: self.model_activations.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------
// Model control plane
// ---------------------------------------------------------------------

/// Versions retained per key unless overridden: the active/latest pair
/// plus a couple of rollback candidates.
pub const DEFAULT_MAX_RETAINED: usize = 4;

/// State behind the `PushModel` / `PullModel` / `ActivateModel` frames:
/// the registry of decodable models, the durable artifact store, and
/// the [`ServeConfig`] used to build hot-swapped services.  Without one
/// of these, control frames get a typed `push_disabled` rejection.
#[derive(Debug)]
pub struct ModelControl {
    registry: Mutex<ModelRegistry>,
    store: Mutex<ArtifactStore>,
    serve_cfg: ServeConfig,
    tenant: String,
    max_retained: usize,
}

impl ModelControl {
    /// Wrap a registry (usually the one the router was built from) and
    /// an opened store.
    pub fn new(registry: ModelRegistry, store: ArtifactStore, serve_cfg: ServeConfig) -> Self {
        ModelControl {
            registry: Mutex::new(registry),
            store: Mutex::new(store),
            serve_cfg,
            tenant: String::new(),
            max_retained: DEFAULT_MAX_RETAINED,
        }
    }

    /// Namespace every pushed/pulled/activated key under `tenant`
    /// (mirrors how `serve --tenant` namespaces `--model` keys).
    pub fn with_tenant(mut self, tenant: &str) -> Self {
        self.tenant = tenant.to_string();
        self
    }

    /// Bound the versions retained per key (clamped to ≥ 1; the latest
    /// and every live route are always pinned regardless).
    pub fn with_max_retained(mut self, n: usize) -> Self {
        self.max_retained = n.max(1);
        self
    }

    /// Registered versions of the tenant-namespaced `key` (test and
    /// report surface).
    pub fn versions(&self, key: &str) -> Vec<String> {
        let key = registry::namespaced(&self.tenant, key);
        self.registry
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .versions(&key)
    }
}

// ---------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------

/// Front-door knobs (CLI surface of `serve --listen`).
#[derive(Clone, Debug)]
pub struct FrontDoorConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`FrontDoor::local_addr`]).
    pub addr: String,
    /// Per-connection read deadline (a silent peer is reaped after this).
    pub read_timeout: Duration,
    /// Per-connection write deadline.
    pub write_timeout: Duration,
    /// Payload cap; larger frames get a typed `oversized` error.
    pub max_frame_bytes: usize,
    /// Optional per-route token bucket.
    pub rate_limit: Option<RateLimit>,
    /// Handler-thread cap; connections beyond it get a `busy` error.
    pub max_connections: usize,
    /// Model control plane; `None` rejects push/pull/activate frames
    /// with a typed `push_disabled` error.
    pub model_control: Option<Arc<ModelControl>>,
}

impl Default for FrontDoorConfig {
    fn default() -> Self {
        FrontDoorConfig {
            addr: "127.0.0.1:0".into(),
            read_timeout: Duration::from_millis(5_000),
            write_timeout: Duration::from_millis(5_000),
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            rate_limit: None,
            max_connections: 256,
            model_control: None,
        }
    }
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

struct Shared {
    router: Arc<ModelRouter>,
    metrics: WireMetrics,
    limiter: Option<RateLimiter>,
    stop: AtomicBool,
    /// (flag, condvar): set + notified when a peer requests shutdown.
    shutdown: (Mutex<bool>, Condvar),
    read_timeout: Duration,
    write_timeout: Duration,
    max_frame_bytes: usize,
    model_control: Option<Arc<ModelControl>>,
}

/// A running front door.  Dropping it without [`FrontDoor::shutdown`]
/// stops the server but discards the final report.
pub struct FrontDoor {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept_handle: Option<JoinHandle<()>>,
}

impl FrontDoor {
    /// Bind and start serving `router` — returns once the listener is
    /// live (the bound address is [`FrontDoor::local_addr`]).
    pub fn start(router: Arc<ModelRouter>, cfg: FrontDoorConfig) -> Result<FrontDoor> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| AviError::Net(format!("bind {}: {e}", cfg.addr)))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| AviError::Net(format!("local_addr: {e}")))?;
        let shared = Arc::new(Shared {
            router,
            metrics: WireMetrics::default(),
            limiter: cfg.rate_limit.map(RateLimiter::new),
            stop: AtomicBool::new(false),
            shutdown: (Mutex::new(false), Condvar::new()),
            read_timeout: cfg.read_timeout,
            write_timeout: cfg.write_timeout,
            max_frame_bytes: cfg.max_frame_bytes,
            model_control: cfg.model_control,
        });
        let accept_shared = shared.clone();
        let max_connections = cfg.max_connections.max(1);
        let accept_handle = std::thread::spawn(move || {
            accept_loop(&listener, &accept_shared, max_connections)
        });
        Ok(FrontDoor { shared, local_addr, accept_handle: Some(accept_handle) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Block until a peer sends a `Shutdown` frame (or
    /// [`FrontDoor::shutdown`] is called from another thread).
    pub fn wait_shutdown(&self) {
        let (flag, cv) = &self.shared.shutdown;
        let mut requested = flag.lock().unwrap_or_else(PoisonError::into_inner);
        while !*requested {
            requested = cv
                .wait(requested)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Wire counters so far.
    pub fn wire_stats(&self) -> WireStats {
        self.shared.metrics.snapshot()
    }

    /// Stop accepting, join every handler (in-flight requests drain
    /// through the router), and cut the final report with the wire
    /// counters attached.
    pub fn shutdown(mut self) -> crate::coordinator::router::RouterReport {
        self.stop_and_join();
        let mut report = self.shared.router.report();
        report.wire = Some(self.shared.metrics.snapshot());
        report
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        signal_shutdown(&self.shared);
        // the accept loop blocks in accept(); poke it with a throwaway
        // connection so it observes the stop flag
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FrontDoor {
    fn drop(&mut self) {
        if self.accept_handle.is_some() {
            self.stop_and_join();
        }
    }
}

fn signal_shutdown(shared: &Shared) {
    let (flag, cv) = &shared.shutdown;
    *flag.lock().unwrap_or_else(PoisonError::into_inner) = true;
    cv.notify_all();
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>, max_connections: usize) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            // the shutdown poke (or a raced client); close and leave
            drop(stream);
            break;
        }
        shared.metrics.connections.fetch_add(1, Ordering::Relaxed);
        handlers.retain(|h| !h.is_finished());
        if handlers.len() >= max_connections {
            // typed busy error, then close — never a silent drop
            let mut stream = stream;
            let _ = stream.set_write_timeout(Some(shared.write_timeout));
            let payload = wire::encode_wire_error(
                "busy",
                &format!("connection limit {max_connections} reached"),
            );
            if let Ok(n) = wire::write_frame(&mut stream, FrameKind::Error, &payload) {
                shared.metrics.bytes_out.fetch_add(n, Ordering::Relaxed);
            }
            continue;
        }
        let conn_shared = shared.clone();
        handlers.push(std::thread::spawn(move || handle_conn(stream, &conn_shared)));
    }
    // graceful drain: every handler finishes its in-flight request (the
    // router's batcher answers it) before the front door reports
    for h in handlers {
        let _ = h.join();
    }
}

/// Send a frame, counting bytes; `false` means the connection is dead.
fn send(
    stream: &mut TcpStream,
    shared: &Shared,
    kind: FrameKind,
    payload: &[u8],
) -> bool {
    match wire::write_frame(stream, kind, payload) {
        Ok(n) => {
            shared.metrics.bytes_out.fetch_add(n, Ordering::Relaxed);
            true
        }
        Err(_) => false,
    }
}

fn handle_conn(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(shared.read_timeout));
    let _ = stream.set_write_timeout(Some(shared.write_timeout));
    loop {
        let frame = match wire::read_frame(&mut stream, shared.max_frame_bytes) {
            Ok(frame) => frame,
            Err(WireFault::Eof) => break,
            Err(WireFault::Timeout) => {
                // during shutdown the reap is expected — only count
                // peers that actually went silent on a live server
                if !shared.stop.load(Ordering::SeqCst) {
                    shared.metrics.timed_out.fetch_add(1, Ordering::Relaxed);
                }
                break;
            }
            Err(WireFault::Oversized { got, max }) => {
                shared.metrics.oversized.fetch_add(1, Ordering::Relaxed);
                let payload = wire::encode_wire_error(
                    "oversized",
                    &format!("{got} bytes (cap {max})"),
                );
                send(&mut stream, shared, FrameKind::Error, &payload);
                break; // unread payload bytes follow; resync is impossible
            }
            Err(WireFault::Version { got }) => {
                shared.metrics.malformed.fetch_add(1, Ordering::Relaxed);
                let payload = wire::encode_wire_error(
                    "bad_version",
                    &format!("got {got}, speaking {}", wire::WIRE_VERSION),
                );
                send(&mut stream, shared, FrameKind::Error, &payload);
                break;
            }
            Err(WireFault::Malformed(m)) => {
                shared.metrics.malformed.fetch_add(1, Ordering::Relaxed);
                let payload = wire::encode_wire_error("malformed", &m);
                send(&mut stream, shared, FrameKind::Error, &payload);
                break; // byte stream is out of sync past a bad header
            }
            Err(WireFault::Io(_)) => break,
        };
        shared.metrics.bytes_in.fetch_add(frame.wire_len(), Ordering::Relaxed);
        match frame.kind {
            FrameKind::Request => {
                // a bad payload inside a well-framed request keeps the
                // stream in sync — answer the error and keep serving
                let (route, req) = match wire::decode_request(&frame.payload) {
                    Ok(parts) => parts,
                    Err(fault) => {
                        shared.metrics.malformed.fetch_add(1, Ordering::Relaxed);
                        let payload =
                            wire::encode_wire_error("malformed", &fault.to_string());
                        if !send(&mut stream, shared, FrameKind::Error, &payload) {
                            break;
                        }
                        continue;
                    }
                };
                let payload = answer_request(shared, &route, req);
                if !send(&mut stream, shared, FrameKind::Reply, &payload) {
                    break;
                }
            }
            FrameKind::PushModel | FrameKind::PullModel | FrameKind::ActivateModel => {
                // same contract as Request: a bad payload inside a
                // well-framed control frame keeps the stream in sync
                let result = match frame.kind {
                    FrameKind::PushModel => control_push(shared, &frame.payload),
                    FrameKind::PullModel => control_pull(shared, &frame.payload),
                    _ => control_activate(shared, &frame.payload),
                };
                match result {
                    Ok(payload) => {
                        if !send(&mut stream, shared, FrameKind::Reply, &payload) {
                            break;
                        }
                    }
                    Err(fault) => {
                        shared.metrics.malformed.fetch_add(1, Ordering::Relaxed);
                        let payload =
                            wire::encode_wire_error("malformed", &fault.to_string());
                        if !send(&mut stream, shared, FrameKind::Error, &payload) {
                            break;
                        }
                        continue;
                    }
                }
            }
            FrameKind::Shutdown => {
                shared.stop.store(true, Ordering::SeqCst);
                signal_shutdown(shared);
                let ack = wire::encode_rejection("stopped", "shutting down");
                send(&mut stream, shared, FrameKind::Reply, &ack);
                break;
            }
            FrameKind::Reply | FrameKind::Error => {
                shared.metrics.malformed.fetch_add(1, Ordering::Relaxed);
                let payload = wire::encode_wire_error(
                    "malformed",
                    "unexpected reply/error frame from client",
                );
                send(&mut stream, shared, FrameKind::Error, &payload);
                break;
            }
        }
    }
}

/// Rate-limit gate → router admission → encoded reply payload.
fn answer_request(
    shared: &Shared,
    route: &str,
    req: crate::coordinator::service::ServeRequest,
) -> Vec<u8> {
    if let Some(limiter) = &shared.limiter {
        if !limiter.try_acquire(route) {
            shared.metrics.rejected_limit.fetch_add(1, Ordering::Relaxed);
            return wire::encode_rejection("rate_limited", &format!("route '{route}'"));
        }
    }
    match shared.router.enqueue(route, req) {
        Ok(pending) => {
            // wait() resolves through the service's existing reply path:
            // admitted requests drain even across shutdown
            let reply = pending.wait();
            shared.metrics.accepted.fetch_add(1, Ordering::Relaxed);
            wire::encode_reply(&reply)
        }
        Err(e) => {
            shared.metrics.rejected_route.fetch_add(1, Ordering::Relaxed);
            wire::encode_rejection("unknown_route", &e.to_string())
        }
    }
}

// ---------------------------------------------------------------------
// Control-plane handlers
// ---------------------------------------------------------------------

/// `Ok(..)` is the reply payload (a control ack or a typed rejection
/// the peer can act on); `Err(..)` means the payload itself could not
/// be decoded and the caller counts it as malformed.
type ControlReply = std::result::Result<Vec<u8>, WireFault>;

fn control_disabled() -> Vec<u8> {
    wire::encode_rejection(
        "push_disabled",
        "server started without an artifact store (serve --artifact-dir)",
    )
}

/// Control ops share the front door's limiter but under their own
/// `model-control/<key>` buckets, so a chatty deployer cannot starve
/// the data plane (or vice versa).
fn control_limited(shared: &Shared, key: &str) -> bool {
    if let Some(limiter) = &shared.limiter {
        if !limiter.try_acquire(&format!("model-control/{key}")) {
            shared.metrics.rejected_limit.fetch_add(1, Ordering::Relaxed);
            return true;
        }
    }
    false
}

/// `PushModel`: verify the declared checksum, decode (a corrupt
/// artifact must never become durable or routable), conflict-check the
/// version label, land the bytes in the store, then register.
fn control_push(shared: &Shared, payload: &[u8]) -> ControlReply {
    let Some(mc) = &shared.model_control else {
        return Ok(control_disabled());
    };
    let (header, artifact) = wire::decode_push_model(payload)?;
    let key = registry::namespaced(&mc.tenant, &header.key);
    if control_limited(shared, &key) {
        return Ok(wire::encode_rejection(
            "rate_limited",
            &format!("route 'model-control/{key}'"),
        ));
    }
    let digest = artifact::fnv64(artifact);
    if digest != header.checksum {
        return Ok(wire::encode_rejection(
            "checksum_mismatch",
            &format!(
                "declared {:016x}, artifact hashes to {digest:016x}",
                header.checksum
            ),
        ));
    }
    let model = match persist::pipeline_from_bytes(artifact) {
        Ok(m) => Arc::new(m),
        Err(e) => {
            return Ok(wire::encode_rejection("bad_artifact", &e.to_string()));
        }
    };
    let fingerprint = artifact::model_fingerprint(&model);
    {
        let reg = mc.registry.lock().unwrap_or_else(PoisonError::into_inner);
        if let Err(e) =
            reg.check_register(&key, &header.version, fingerprint, header.force)
        {
            return Ok(wire::encode_rejection("version_conflict", &e.to_string()));
        }
    }
    if let Err(e) = mc
        .store
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .put(&key, &header.version, artifact)
    {
        return Ok(wire::encode_rejection("store_failed", &e.to_string()));
    }
    let landed = {
        let mut reg = mc.registry.lock().unwrap_or_else(PoisonError::into_inner);
        if header.force {
            reg.insert_force(&key, &header.version, model);
            Ok(())
        } else {
            reg.insert(&key, &header.version, model)
        }
    };
    if let Err(e) = landed {
        // a conflicting register raced in between the pre-check and the
        // store write; sweep the orphaned bytes back out
        let _ = mc
            .store
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .remove(&key, &header.version);
        return Ok(wire::encode_rejection("version_conflict", &e.to_string()));
    }
    shared.metrics.model_pushes.fetch_add(1, Ordering::Relaxed);
    Ok(wire::encode_control_ok(
        "push",
        &key,
        &header.version,
        digest,
        artifact.len() as u64,
    ))
}

/// `PullModel`: serve the stored bytes (re-verified against the
/// manifest checksum on read); models that were loaded at startup and
/// never pushed are re-encoded through the binary codec on the fly.
fn control_pull(shared: &Shared, payload: &[u8]) -> ControlReply {
    let Some(mc) = &shared.model_control else {
        return Ok(control_disabled());
    };
    let (raw_key, version) = wire::decode_pull_model(payload)?;
    let key = registry::namespaced(&mc.tenant, &raw_key);
    if control_limited(shared, &key) {
        return Ok(wire::encode_rejection(
            "rate_limited",
            &format!("route 'model-control/{key}'"),
        ));
    }
    let version = match version {
        Some(v) => v,
        None => {
            let stored = mc
                .store
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .latest_version(&key);
            match stored {
                Some(v) => v,
                None => {
                    let reg =
                        mc.registry.lock().unwrap_or_else(PoisonError::into_inner);
                    match reg.latest(&key) {
                        Some((v, _)) => v,
                        None => {
                            return Ok(wire::encode_rejection(
                                "unknown_model",
                                &format!("no versions of '{key}'"),
                            ));
                        }
                    }
                }
            }
        }
    };
    let stored = mc
        .store
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .get(&key, &version);
    let artifact = match stored {
        Ok(bytes) => bytes,
        Err(_) => {
            let model = {
                let reg = mc.registry.lock().unwrap_or_else(PoisonError::into_inner);
                reg.get(&key, &version)
            };
            match model {
                Some(m) => match artifact::encode_pipeline(&m) {
                    Ok(bytes) => bytes,
                    Err(e) => {
                        return Ok(wire::encode_rejection(
                            "bad_artifact",
                            &e.to_string(),
                        ));
                    }
                },
                None => {
                    return Ok(wire::encode_rejection(
                        "unknown_model",
                        &format!("'{key}@{version}' is neither stored nor registered"),
                    ));
                }
            }
        }
    };
    shared.metrics.model_pulls.fetch_add(1, Ordering::Relaxed);
    Ok(wire::encode_pull_reply(&key, &version, &artifact))
}

/// `ActivateModel`: resolve `key@version` (registry first, store bytes
/// as fallback), hot-swap the route through [`ModelRouter::register`],
/// then bound retained versions — the latest and every live route stay
/// pinned, evicted versions are swept from the store.
fn control_activate(shared: &Shared, payload: &[u8]) -> ControlReply {
    let Some(mc) = &shared.model_control else {
        return Ok(control_disabled());
    };
    let (raw_key, version) = wire::decode_activate_model(payload)?;
    let key = registry::namespaced(&mc.tenant, &raw_key);
    if control_limited(shared, &key) {
        return Ok(wire::encode_rejection(
            "rate_limited",
            &format!("route 'model-control/{key}'"),
        ));
    }
    let registered = {
        let reg = mc.registry.lock().unwrap_or_else(PoisonError::into_inner);
        reg.get(&key, &version)
    };
    let model = match registered {
        Some(m) => m,
        None => {
            // not in memory — fall back to the store (bytes re-verified
            // against the manifest checksum by `get`)
            let bytes = mc
                .store
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .get(&key, &version);
            let bytes = match bytes {
                Ok(b) => b,
                Err(_) => {
                    return Ok(wire::encode_rejection(
                        "unknown_model",
                        &format!("'{key}@{version}' is neither registered nor stored"),
                    ));
                }
            };
            match persist::pipeline_from_bytes(&bytes) {
                Ok(m) => {
                    let m = Arc::new(m);
                    mc.registry
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner)
                        .insert_force(&key, &version, m.clone());
                    m
                }
                Err(e) => {
                    return Ok(wire::encode_rejection("bad_artifact", &e.to_string()));
                }
            }
        }
    };
    // adopt the transform plan compiled at registration — the hot-swap
    // goes live with a warmed plan instead of rebuilding operands on
    // the first request (both resolve branches leave one: `get` hits a
    // registered entry, the store fallback just ran `insert_force`)
    let mut cfg = mc.serve_cfg.clone();
    if let Some(plan) = mc
        .registry
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .plan_for(&key, &version)
    {
        cfg = cfg.with_plan(plan);
    }
    shared.router.register(key.clone(), version.clone(), model, cfg);
    let mut pinned = shared.router.live_versions(&key);
    pinned.push(version.clone());
    let evicted = mc
        .registry
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .evict(&key, mc.max_retained, &pinned);
    if !evicted.is_empty() {
        let mut store = mc.store.lock().unwrap_or_else(PoisonError::into_inner);
        for v in &evicted {
            let _ = store.remove(&key, v);
        }
    }
    let (checksum, bytes) = {
        let store = mc.store.lock().unwrap_or_else(PoisonError::into_inner);
        match store.list().iter().find(|e| e.key == key && e.version == version) {
            Some(e) => (e.checksum, e.bytes),
            None => (0, 0),
        }
    };
    shared.metrics.model_activations.fetch_add(1, Ordering::Relaxed);
    Ok(wire::encode_control_ok("activate", &key, &version, checksum, bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::ModelRegistry;
    use crate::coordinator::service::{ServeConfig, ServeRequest};
    use crate::coordinator::wire::{
        ControlOutcome, PullOutcome, WireClient, WireOutcome,
    };
    use crate::data::synthetic::synthetic_dataset;
    use crate::estimator::EstimatorConfig;
    use crate::oavi::OaviConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, PipelineConfig, PipelineModel};
    use crate::svm::linear::LinearSvmConfig;

    fn trained_model(seed: u64) -> Arc<PipelineModel> {
        let ds = synthetic_dataset(300, seed);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        Arc::new(train_pipeline(&cfg, &ds).unwrap())
    }

    fn served_router(seed: u64) -> Arc<ModelRouter> {
        let mut registry = ModelRegistry::new();
        registry.insert("m", "v1", trained_model(seed)).unwrap();
        Arc::new(ModelRouter::from_registry(&registry, &ServeConfig::default()))
    }

    fn start(cfg: FrontDoorConfig, seed: u64) -> FrontDoor {
        FrontDoor::start(served_router(seed), cfg).unwrap()
    }

    #[test]
    fn network_scores_are_bitwise_identical_to_in_process() {
        let model = trained_model(31);
        let mut registry = ModelRegistry::new();
        registry.insert("m", "v1", model.clone()).unwrap();
        let router =
            Arc::new(ModelRouter::from_registry(&registry, &ServeConfig::default()));
        let fd = FrontDoor::start(router.clone(), FrontDoorConfig::default()).unwrap();

        let ds = synthetic_dataset(16, 32);
        let rows: Vec<Vec<f64>> = (0..16).map(|i| ds.x.row(i).to_vec()).collect();
        let reference = router
            .submit("m", ServeRequest::batch(rows.clone()))
            .unwrap()
            .answer()
            .unwrap();

        let mut client =
            WireClient::connect(&fd.local_addr().to_string()).unwrap();
        let answer = client
            .request("m", &ServeRequest::batch(rows))
            .unwrap()
            .answer()
            .unwrap();
        assert_eq!(answer.key, "m");
        assert_eq!(answer.version, "v1");
        assert_eq!(answer.predictions.len(), 16);
        for (a, b) in answer.predictions.iter().zip(&reference.predictions) {
            assert_eq!(a.label, b.label);
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a.scores), bits(&b.scores));
        }
        let report = fd.shutdown();
        let wire = report.wire.expect("wire stats attached");
        assert_eq!(wire.accepted, 1);
        assert!(wire.bytes_in > 0 && wire.bytes_out > 0);
    }

    #[test]
    fn rate_limit_rejects_typed_and_recovers_nothing_at_rate_zero() {
        // burst 2, no refill: exactly two requests pass, forever
        let cfg = FrontDoorConfig {
            rate_limit: Some(RateLimit { per_sec: 0.0, burst: 2.0 }),
            ..FrontDoorConfig::default()
        };
        let fd = start(cfg, 33);
        let ds = synthetic_dataset(8, 34);
        let mut client =
            WireClient::connect(&fd.local_addr().to_string()).unwrap();
        let row = || ServeRequest::row(ds.x.row(0).to_vec());
        assert!(client.request("m", &row()).unwrap().answer().is_ok());
        assert!(client.request("m", &row()).unwrap().answer().is_ok());
        for _ in 0..3 {
            match client.request("m", &row()).unwrap() {
                WireOutcome::Rejected { reason, .. } => {
                    assert_eq!(reason, "rate_limited")
                }
                other => panic!("expected rate_limited, got {other:?}"),
            }
        }
        let report = fd.shutdown();
        let wire = report.wire.unwrap();
        assert_eq!(wire.accepted, 2);
        assert_eq!(wire.rejected_limit, 3);
    }

    #[test]
    fn token_bucket_refills_at_rate() {
        let limiter = RateLimiter::new(RateLimit { per_sec: 1000.0, burst: 1.0 });
        assert!(limiter.try_acquire("r"));
        assert!(!limiter.try_acquire("r"));
        std::thread::sleep(Duration::from_millis(5));
        assert!(limiter.try_acquire("r"), "bucket should refill at 1000/s");
        // buckets are per-route
        assert!(limiter.try_acquire("other"));
    }

    #[test]
    fn unknown_route_and_nan_rows_reject_without_killing_the_server() {
        let fd = start(FrontDoorConfig::default(), 35);
        let ds = synthetic_dataset(8, 36);
        let mut client =
            WireClient::connect(&fd.local_addr().to_string()).unwrap();
        match client
            .request("nope", &ServeRequest::row(ds.x.row(0).to_vec()))
            .unwrap()
        {
            WireOutcome::Rejected { reason, .. } => assert_eq!(reason, "unknown_route"),
            other => panic!("{other:?}"),
        }
        let mut bad = ds.x.row(0).to_vec();
        bad[0] = f64::NAN;
        match client.request("m", &ServeRequest::row(bad)).unwrap() {
            WireOutcome::Rejected { reason, detail } => {
                assert_eq!(reason, "non_finite");
                assert!(detail.contains("col 0"), "{detail}");
            }
            other => panic!("{other:?}"),
        }
        // same connection still serves clean rows
        assert!(client
            .request("m", &ServeRequest::row(ds.x.row(1).to_vec()))
            .unwrap()
            .answer()
            .is_ok());
        let report = fd.shutdown();
        let wire = report.wire.unwrap();
        assert_eq!(wire.rejected_route, 1);
        assert_eq!(wire.accepted, 2); // NaN reject is an answered admission
    }

    #[test]
    fn malformed_and_oversized_frames_get_typed_errors() {
        use std::io::{Read, Write};
        let cfg = FrontDoorConfig {
            max_frame_bytes: 256,
            ..FrontDoorConfig::default()
        };
        let fd = start(cfg, 37);
        let addr = fd.local_addr().to_string();

        // raw garbage: typed malformed error, then close — never a hang
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        raw.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        let frame = wire::read_frame(&mut raw, 1 << 16).unwrap();
        assert_eq!(frame.kind, FrameKind::Error);
        assert_eq!(wire::decode_wire_error(&frame.payload).0, "malformed");
        let mut rest = Vec::new();
        raw.read_to_end(&mut rest).unwrap(); // server closed
        assert!(rest.is_empty());

        // oversized: rejected from the header, typed error, close
        let mut big = TcpStream::connect(&addr).unwrap();
        big.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        wire::write_frame(&mut big, FrameKind::Request, &[b'x'; 4096]).unwrap();
        let frame = wire::read_frame(&mut big, 1 << 16).unwrap();
        assert_eq!(frame.kind, FrameKind::Error);
        assert_eq!(wire::decode_wire_error(&frame.payload).0, "oversized");

        // well-framed junk payload: error reply, connection stays usable
        let ds = synthetic_dataset(8, 38);
        let mut mixed = WireClient::connect(&addr).unwrap();
        {
            // reach inside: send a valid frame with a junk payload
            let mut s = TcpStream::connect(&addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            wire::write_frame(&mut s, FrameKind::Request, b"{\"nope\":1}").unwrap();
            let frame = wire::read_frame(&mut s, 1 << 16).unwrap();
            assert_eq!(frame.kind, FrameKind::Error);
            // the same connection still answers a valid request
            let payload =
                wire::encode_request("m", &ServeRequest::row(ds.x.row(0).to_vec()));
            wire::write_frame(&mut s, FrameKind::Request, &payload).unwrap();
            let frame = wire::read_frame(&mut s, 1 << 16).unwrap();
            assert_eq!(frame.kind, FrameKind::Reply);
        }
        assert!(mixed
            .request("m", &ServeRequest::row(ds.x.row(1).to_vec()))
            .unwrap()
            .answer()
            .is_ok());

        let report = fd.shutdown();
        let wire_stats = report.wire.unwrap();
        assert!(wire_stats.malformed >= 2, "{wire_stats:?}");
        assert_eq!(wire_stats.oversized, 1);
    }

    #[test]
    fn silent_peer_is_reaped_by_read_timeout() {
        let cfg = FrontDoorConfig {
            read_timeout: Duration::from_millis(50),
            ..FrontDoorConfig::default()
        };
        let fd = start(cfg, 39);
        let stream = TcpStream::connect(fd.local_addr()).unwrap();
        // say nothing; the server must reap us rather than wait forever
        let deadline = Instant::now() + Duration::from_secs(5);
        while fd.wire_stats().timed_out == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        drop(stream);
        let report = fd.shutdown();
        assert_eq!(report.wire.unwrap().timed_out, 1);
    }

    #[test]
    fn deadline_expired_propagates_over_the_wire() {
        let fd = start(FrontDoorConfig::default(), 40);
        let ds = synthetic_dataset(8, 41);
        let mut client =
            WireClient::connect(&fd.local_addr().to_string()).unwrap();
        // deadline 0: any queue wait exceeds it → deterministic expiry
        let req = ServeRequest::row(ds.x.row(0).to_vec())
            .with_deadline(Duration::ZERO);
        match client.request("m", &req).unwrap() {
            WireOutcome::Rejected { reason, .. } => {
                assert_eq!(reason, "deadline_expired")
            }
            other => panic!("expected deadline_expired, got {other:?}"),
        }
        fd.shutdown();
    }

    #[test]
    fn shutdown_frame_drains_in_flight_requests() {
        let fd = start(FrontDoorConfig::default(), 42);
        let addr = fd.local_addr().to_string();
        let ds = synthetic_dataset(64, 43);
        let rows: Vec<Vec<f64>> = (0..64).map(|i| ds.x.row(i).to_vec()).collect();
        // conn A is established (one answered warm-up) before B races a
        // shutdown against A's big in-flight batch
        let mut a = WireClient::connect(&addr).unwrap();
        assert!(a
            .request("m", &ServeRequest::row(ds.x.row(0).to_vec()))
            .unwrap()
            .answer()
            .is_ok());
        let in_flight = std::thread::spawn(move || {
            a.request("m", &ServeRequest::batch(rows)).unwrap().answer()
        });
        std::thread::sleep(Duration::from_millis(10));
        let b = WireClient::connect(&addr).unwrap();
        b.shutdown_server().unwrap();
        fd.wait_shutdown(); // returns because B's frame signalled it
        let answer = in_flight.join().unwrap().expect("in-flight batch answered");
        assert_eq!(answer.predictions.len(), 64);
        let report = fd.shutdown();
        let wire = report.wire.unwrap();
        assert_eq!(wire.accepted, 2);
        // the reaped-during-shutdown poke is not a client timeout
        assert_eq!(wire.timed_out, 0);
    }

    #[test]
    fn connection_cap_answers_busy() {
        let cfg = FrontDoorConfig {
            max_connections: 1,
            ..FrontDoorConfig::default()
        };
        let fd = start(cfg, 44);
        let addr = fd.local_addr().to_string();
        let hold = TcpStream::connect(&addr).unwrap(); // occupies the only slot
        let mut second = TcpStream::connect(&addr).unwrap();
        second.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let frame = wire::read_frame(&mut second, 1 << 16).unwrap();
        assert_eq!(frame.kind, FrameKind::Error);
        assert_eq!(wire::decode_wire_error(&frame.payload).0, "busy");
        drop(hold);
        fd.shutdown();
    }

    // -- model control plane ------------------------------------------

    fn control_tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "avi-frontdoor-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// A front door whose router serves `m@v1` and whose control plane
    /// is live (store at a fresh temp dir, default serve config).
    fn start_with_control(
        tag: &str,
        seed: u64,
        max_retained: usize,
    ) -> (FrontDoor, Arc<ModelControl>, std::path::PathBuf) {
        let dir = control_tmpdir(tag);
        let mut registry = ModelRegistry::new();
        registry.insert("m", "v1", trained_model(seed)).unwrap();
        let router =
            Arc::new(ModelRouter::from_registry(&registry, &ServeConfig::default()));
        let store = crate::artifact::ArtifactStore::open(&dir).unwrap();
        let control = Arc::new(
            ModelControl::new(registry, store, ServeConfig::default())
                .with_max_retained(max_retained),
        );
        let cfg = FrontDoorConfig {
            model_control: Some(control.clone()),
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::start(router, cfg).unwrap();
        (fd, control, dir)
    }

    #[test]
    fn control_frames_without_store_get_push_disabled() {
        let fd = start(FrontDoorConfig::default(), 50);
        let mut client = WireClient::connect(&fd.local_addr().to_string()).unwrap();
        let artifact = crate::artifact::encode_pipeline(&trained_model(50)).unwrap();
        match client.push_model("m2", "v1", &artifact, false).unwrap() {
            ControlOutcome::Rejected { reason, .. } => {
                assert_eq!(reason, "push_disabled")
            }
            other => panic!("{other:?}"),
        }
        match client.pull_model("m", None).unwrap() {
            PullOutcome::Rejected { reason, .. } => assert_eq!(reason, "push_disabled"),
            other => panic!("{other:?}"),
        }
        match client.activate_model("m", "v1").unwrap() {
            ControlOutcome::Rejected { reason, .. } => {
                assert_eq!(reason, "push_disabled")
            }
            other => panic!("{other:?}"),
        }
        let wire = fd.shutdown().wire.unwrap();
        assert_eq!(wire.model_pushes, 0);
        assert_eq!(wire.model_pulls, 0);
        assert_eq!(wire.model_activations, 0);
    }

    #[test]
    fn push_activate_serve_pull_roundtrip_is_bitwise() {
        let (fd, _control, dir) = start_with_control("roundtrip", 51, 4);
        let model = trained_model(52);
        let artifact = crate::artifact::encode_pipeline(&model).unwrap();
        let mut client = WireClient::connect(&fd.local_addr().to_string()).unwrap();

        let ack = client
            .push_model("m2", "v1", &artifact, false)
            .unwrap()
            .ack()
            .unwrap();
        assert_eq!(ack.op, "push");
        assert_eq!(ack.key, "m2");
        assert_eq!(ack.bytes, artifact.len() as u64);
        assert_eq!(ack.checksum, crate::artifact::fnv64(&artifact));

        let ack = client
            .activate_model("m2", "v1")
            .unwrap()
            .ack()
            .unwrap();
        assert_eq!(ack.op, "activate");

        // served scores are bitwise identical to predicting in-process
        // with the model the artifact was encoded from
        let ds = synthetic_dataset(12, 53);
        let rows: Vec<Vec<f64>> = (0..12).map(|i| ds.x.row(i).to_vec()).collect();
        let answer = client
            .request("m2", &ServeRequest::batch(rows))
            .unwrap()
            .answer()
            .unwrap();
        let (labels, scores) = model.predict_scores_with_backend(
            &ds.x,
            &crate::backend::NativeBackend,
        );
        for (i, p) in answer.predictions.iter().enumerate() {
            assert_eq!(p.label, labels[i]);
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&p.scores), bits(&scores[i]));
        }

        // pulling hands back the exact bytes that were pushed
        let pulled = client.pull_model("m2", None).unwrap().model().unwrap();
        assert_eq!(pulled.version, "v1");
        assert_eq!(pulled.artifact, artifact);
        // pulling a never-pushed startup model re-encodes on the fly
        let boot = client.pull_model("m", None).unwrap().model().unwrap();
        assert!(crate::artifact::codec::is_binary(&boot.artifact));

        let wire = fd.shutdown().wire.unwrap();
        assert_eq!(wire.model_pushes, 1);
        assert_eq!(wire.model_pulls, 2);
        assert_eq!(wire.model_activations, 1);
        assert_eq!(wire.accepted, 1);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn corrupt_and_conflicting_pushes_are_refused_and_never_routable() {
        let (fd, control, dir) = start_with_control("refuse", 54, 4);
        let mut client = WireClient::connect(&fd.local_addr().to_string()).unwrap();
        let artifact = crate::artifact::encode_pipeline(&trained_model(55)).unwrap();

        // flip a byte in the artifact tail after the header committed to
        // a checksum: the server must refuse before anything lands
        let mut lying = wire::encode_push_model("m2", "v1", &artifact, false);
        *lying.last_mut().unwrap() ^= 0xff;
        let mut raw = TcpStream::connect(fd.local_addr()).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        wire::write_frame(&mut raw, FrameKind::PushModel, &lying).unwrap();
        let frame = wire::read_frame(&mut raw, 1 << 20).unwrap();
        assert_eq!(frame.kind, FrameKind::Reply);
        match wire::decode_control_reply(&frame.payload).unwrap() {
            ControlOutcome::Rejected { reason, .. } => {
                assert_eq!(reason, "checksum_mismatch")
            }
            other => panic!("{other:?}"),
        }

        // garbage with an honest checksum decodes as no model at all
        match client
            .push_model("g", "v1", b"definitely not a model", false)
            .unwrap()
        {
            ControlOutcome::Rejected { reason, .. } => assert_eq!(reason, "bad_artifact"),
            other => panic!("{other:?}"),
        }
        // ...and is not activatable (nothing was stored or registered)
        match client.activate_model("g", "v1").unwrap() {
            ControlOutcome::Rejected { reason, .. } => {
                assert_eq!(reason, "unknown_model")
            }
            other => panic!("{other:?}"),
        }
        assert!(control.versions("g").is_empty());

        // a version label means one model forever — unless forced
        client
            .push_model("m2", "v1", &artifact, false)
            .unwrap()
            .ack()
            .unwrap();
        let different = crate::artifact::encode_pipeline(&trained_model(56)).unwrap();
        match client.push_model("m2", "v1", &different, false).unwrap() {
            ControlOutcome::Rejected { reason, detail } => {
                assert_eq!(reason, "version_conflict");
                assert!(detail.contains("force"), "{detail}");
            }
            other => panic!("{other:?}"),
        }
        // identical bytes re-push is a no-op rollback, still allowed
        client
            .push_model("m2", "v1", &artifact, false)
            .unwrap()
            .ack()
            .unwrap();
        // force replaces
        client
            .push_model("m2", "v1", &different, true)
            .unwrap()
            .ack()
            .unwrap();

        let wire = fd.shutdown().wire.unwrap();
        assert_eq!(wire.model_pushes, 3);
        assert_eq!(wire.malformed, 0, "rejections are typed, not malformed");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn activation_evicts_old_versions_but_pins_latest_and_live() {
        let (fd, control, dir) = start_with_control("evict", 57, 2);
        let mut client = WireClient::connect(&fd.local_addr().to_string()).unwrap();
        for v in ["v1", "v2", "v3", "v4"] {
            let artifact =
                crate::artifact::encode_pipeline(&trained_model(58)).unwrap();
            client.push_model("m2", v, &artifact, false).unwrap().ack().unwrap();
        }
        // activating v2 hot-swaps the route; retention 2 must keep the
        // live v2 and the latest v4, dropping v1/v3
        client.activate_model("m2", "v2").unwrap().ack().unwrap();
        let kept = control.versions("m2");
        assert_eq!(kept, vec!["v2".to_string(), "v4".to_string()], "{kept:?}");
        // the route answers with the activated version
        let ds = synthetic_dataset(4, 59);
        let answer = client
            .request("m2", &ServeRequest::row(ds.x.row(0).to_vec()))
            .unwrap()
            .answer()
            .unwrap();
        assert_eq!(answer.version, "v2");
        // evicted versions are gone from the store too
        match client.pull_model("m2", Some("v1")).unwrap() {
            PullOutcome::Rejected { reason, .. } => assert_eq!(reason, "unknown_model"),
            other => panic!("{other:?}"),
        }
        let wire = fd.shutdown().wire.unwrap();
        assert_eq!(wire.model_pushes, 4);
        assert_eq!(wire.model_activations, 1);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn control_ops_are_rate_limited_under_their_own_bucket() {
        let dir = control_tmpdir("ratelimit");
        let mut registry = ModelRegistry::new();
        registry.insert("m", "v1", trained_model(60)).unwrap();
        let router =
            Arc::new(ModelRouter::from_registry(&registry, &ServeConfig::default()));
        let store = crate::artifact::ArtifactStore::open(&dir).unwrap();
        let control = Arc::new(ModelControl::new(
            registry,
            store,
            ServeConfig::default(),
        ));
        let cfg = FrontDoorConfig {
            rate_limit: Some(RateLimit { per_sec: 0.0, burst: 2.0 }),
            model_control: Some(control),
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::start(router, cfg).unwrap();
        let mut client = WireClient::connect(&fd.local_addr().to_string()).unwrap();
        let artifact = crate::artifact::encode_pipeline(&trained_model(61)).unwrap();
        // burst 2 on the control bucket: two pushes pass, the third is
        // refused — without having spent the data plane's own budget
        client.push_model("m2", "v1", &artifact, false).unwrap().ack().unwrap();
        client.push_model("m2", "v2", &artifact, false).unwrap().ack().unwrap();
        match client.push_model("m2", "v3", &artifact, false).unwrap() {
            ControlOutcome::Rejected { reason, .. } => assert_eq!(reason, "rate_limited"),
            other => panic!("{other:?}"),
        }
        let ds = synthetic_dataset(4, 62);
        assert!(client
            .request("m", &ServeRequest::row(ds.x.row(0).to_vec()))
            .unwrap()
            .answer()
            .is_ok());
        let wire = fd.shutdown().wire.unwrap();
        assert_eq!(wire.model_pushes, 2);
        assert_eq!(wire.rejected_limit, 1);
        assert_eq!(wire.accepted, 1);
        let _ = std::fs::remove_dir_all(dir);
    }
}

//! Out-of-core dataset storage: manifest-backed shard-segment
//! directories, chunked CSV ingestion, and the open path that turns a
//! dataset directory into a spill-capable [`ColumnStore`].
//!
//! Layering (bottom up):
//!
//! * [`segment`] — raw per-shard segment files: column-major le-f64
//!   blocks, positioned reads into reusable buffers, FNV-1a-64
//!   checksumming.  Knows nothing about datasets.
//! * [`manifest`] — the checksummed `manifest.json` describing a
//!   dataset directory: rows, columns, shard partition, per-segment
//!   byte sizes + checksums.
//! * [`ingest`] — single-pass chunked CSV ingestion: `RowGroupReader`
//!   (the shared `BufRead` line-streaming loop) feeding `SegmentSink`
//!   (one row-group → one checksummed shard segment).  Peak memory is
//!   one row-group, independent of m.
//! * this module — the trust boundary: [`verify_segments`] checks
//!   existence, geometry, and checksums of every segment and refuses
//!   corrupt data with a typed [`AviError::Storage`] *before* any fit
//!   touches it; [`open_store`] then wraps the verified segments in a
//!   read-only [`FileBacking`] under a resident-byte budget.  A store
//!   that opens is trustworthy — that is what licenses the backing to
//!   panic on mid-fit IO errors.
//!
//! The le-f64 codec round-trips every bit pattern and the per-shard
//! kernels are backing-agnostic, so an exact-mode fit over an opened
//! store is bitwise identical to the same fit over an in-memory store
//! with the same shard partition.

pub mod ingest;
pub mod manifest;
pub mod segment;

use std::path::Path;

use crate::backend::{ColumnStore, FileBacking, ShardBacking};
use crate::data::scaling::minmax_scale_in_place;
use crate::data::Dataset;
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::util::rng::Rng;

pub use ingest::{ingest_csv, IngestOptions, RowGroupReader, SegmentSink, DEFAULT_ROWS_PER_SHARD};
pub use manifest::{DatasetManifest, SegmentMeta, DATASET_FORMAT, DATASET_VERSION};
pub use segment::{checksum_file, Segment};

use std::sync::Arc;

/// Default resident budget when the caller gives none: 256 MiB.
pub const DEFAULT_BUDGET_BYTES: usize = 256 << 20;

/// Verify every segment of `man` under `dir`: the file must exist, its
/// length must match both the recorded byte count and the manifest
/// geometry, and its FNV-1a-64 checksum must match the recorded one.
///
/// Any mismatch is a typed [`AviError::Storage`] naming the segment —
/// raised before any fit touches the data.
pub fn verify_segments(dir: &Path, man: &DatasetManifest) -> Result<()> {
    for seg in &man.segments {
        let path = dir.join(&seg.file);
        let len = std::fs::metadata(&path)
            .map_err(|e| {
                AviError::Storage(format!("segment {} missing under {}: {e}", seg.file, dir.display()))
            })?
            .len();
        if len != seg.bytes {
            return Err(AviError::Storage(format!(
                "segment {}: {len} bytes on disk, manifest records {}",
                seg.file, seg.bytes
            )));
        }
        let sum = checksum_file(&path)?;
        if sum != seg.checksum {
            return Err(AviError::Storage(format!(
                "segment {}: checksum {sum:016x} != manifest {:016x} (corrupt or tampered)",
                seg.file, seg.checksum
            )));
        }
    }
    Ok(())
}

/// Open a dataset directory as a read-only spill-backed [`ColumnStore`]
/// (columns = features + label, in manifest order) after verifying
/// every segment checksum.  `budget_bytes` bounds resident shard bytes;
/// 0 means [`DEFAULT_BUDGET_BYTES`].
pub fn open_store(dir: &Path, budget_bytes: usize) -> Result<(DatasetManifest, ColumnStore)> {
    let man = DatasetManifest::load(dir)?;
    verify_segments(dir, &man)?;
    let shard_rows = man.shard_rows();
    let mut segs = Vec::with_capacity(man.segments.len());
    for seg in &man.segments {
        segs.push(Segment::open(&dir.join(&seg.file))?);
    }
    let budget = if budget_bytes == 0 { DEFAULT_BUDGET_BYTES } else { budget_bytes };
    let backing = ShardBacking::Spill(Arc::new(FileBacking::from_segments(
        dir.to_path_buf(),
        shard_rows.clone(),
        segs,
        budget,
        true,
    )));
    let mut offsets = Vec::with_capacity(shard_rows.len() + 1);
    offsets.push(0usize);
    for r in &shard_rows {
        offsets.push(offsets.last().unwrap() + r);
    }
    let store = ColumnStore::from_backing_parts(man.rows, man.cols, offsets, backing);
    Ok((man, store))
}

impl ColumnStore {
    /// Open a manifest-backed dataset directory as a read-only store —
    /// see [`open_store`].
    pub fn open_manifest(dir: &Path, budget_bytes: usize) -> Result<(DatasetManifest, ColumnStore)> {
        open_store(dir, budget_bytes)
    }
}

/// Load a dataset directory as an in-RAM [`Dataset`] (min-max scaled,
/// labels remapped to `0..k`), streaming shard-by-shard under
/// `budget_bytes`.
///
/// Runs the identical remap + [`minmax_scale_in_place`] path as
/// [`crate::data::csvio::load_csv_dataset`], and raw values round-trip
/// the le-f64 segment codec bitwise — so the result is bitwise equal to
/// loading the original CSV directly.
pub fn open_dataset(dir: &Path, budget_bytes: usize) -> Result<Dataset> {
    let (man, store) = open_store(dir, budget_bytes)?;
    let feats = man.n_features();
    let mut data = vec![0.0f64; man.rows * feats];
    let mut labels = vec![0i64; man.rows];
    for s in 0..store.n_shards() {
        let range = store.shard_range(s);
        let lease = store.lease(s);
        for j in 0..feats {
            let col = lease.col(j);
            for (i, &v) in col.iter().enumerate() {
                data[(range.start + i) * feats + j] = v;
            }
        }
        for (i, &v) in lease.col(feats).iter().enumerate() {
            labels[range.start + i] = v.round() as i64;
        }
    }
    let mut uniq = labels.clone();
    uniq.sort_unstable();
    uniq.dedup();
    let y: Vec<usize> = labels.iter().map(|l| uniq.binary_search(l).unwrap()).collect();
    let mut x = Matrix::from_flat(man.rows, feats, data)?;
    minmax_scale_in_place(&mut x);
    Dataset::new(&man.name, x, y, uniq.len())
}

/// Streaming per-column statistics over a store (raw, unscaled values).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColStats {
    pub min: f64,
    pub max: f64,
    pub mean: f64,
}

/// Per-column min/max/mean, computed shard-outer so a spilled store
/// loads each shard block exactly once per call.
pub fn column_stats(store: &ColumnStore) -> Vec<ColStats> {
    let n = store.len();
    let m = store.rows();
    let mut stats = vec![
        ColStats { min: f64::INFINITY, max: f64::NEG_INFINITY, mean: 0.0 };
        n
    ];
    for s in 0..store.n_shards() {
        let lease = store.lease(s);
        for (j, st) in stats.iter_mut().enumerate() {
            for &v in lease.col(j) {
                st.min = st.min.min(v);
                st.max = st.max.max(v);
                st.mean += v;
            }
        }
    }
    if m > 0 {
        for st in &mut stats {
            st.mean /= m as f64;
        }
    }
    stats
}

/// Split a dataset directory into train/test dataset directories by a
/// per-row Bernoulli draw (`uniform() < test_frac`, seeded — stable
/// across runs).  Streams shard-by-shard; rows keep their raw values.
pub fn split_dataset(
    dir: &Path,
    out_train: &Path,
    out_test: &Path,
    test_frac: f64,
    seed: u64,
) -> Result<(DatasetManifest, DatasetManifest)> {
    if !(0.0..1.0).contains(&test_frac) || test_frac <= 0.0 {
        return Err(AviError::Storage(format!(
            "split: test fraction must be in (0, 1), got {test_frac}"
        )));
    }
    let (man, store) = open_store(dir, DEFAULT_BUDGET_BYTES)?;
    let group = man.segments.iter().map(|s| s.rows).max().unwrap_or(1);
    let mut train = SegmentSink::create(out_train, group)?;
    let mut test = SegmentSink::create(out_test, group)?;
    let mut rng = Rng::new(seed);
    let mut row = vec![0.0f64; man.cols];
    for s in 0..store.n_shards() {
        let rows = store.shard_range(s).len();
        let lease = store.lease(s);
        for i in 0..rows {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = lease.col(j)[i];
            }
            if rng.uniform() < test_frac {
                test.push_row(&row)?;
            } else {
                train.push_row(&row)?;
            }
        }
    }
    let man_train = train.finish(&format!("{}_train", man.name))?;
    let man_test = test.finish(&format!("{}_test", man.name))?;
    Ok((man_train, man_test))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csvio::load_csv_dataset;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("avi_storage_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_toy_csv(dir: &Path, rows: usize) -> std::path::PathBuf {
        let csv = dir.join("toy.csv");
        let mut body = String::from("x0,x1,x2,label\n");
        for i in 0..rows {
            // non-trivial fractions so bitwise comparisons mean something
            body.push_str(&format!(
                "{},{},{},{}\n",
                i as f64 / 7.0,
                (i * i) as f64 / 3.0,
                1.0 - i as f64 / 11.0,
                i % 3
            ));
        }
        std::fs::write(&csv, body).unwrap();
        csv
    }

    #[test]
    fn open_dataset_is_bitwise_equal_to_csv_loader() {
        let dir = tmp("roundtrip");
        let csv = write_toy_csv(&dir, 23);
        let ds_direct = load_csv_dataset(&csv, "toy").unwrap();
        let out = dir.join("ds");
        ingest_csv(&csv, &out, &IngestOptions { name: "toy".into(), rows_per_shard: 5 }).unwrap();
        let ds_store = open_dataset(&out, 0).unwrap();
        assert_eq!(ds_direct.len(), ds_store.len());
        assert_eq!(ds_direct.y, ds_store.y);
        assert_eq!(ds_direct.n_classes, ds_store.n_classes);
        for (a, b) in ds_direct.x.data().iter().zip(ds_store.x.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_segment_is_refused_before_any_read() {
        let dir = tmp("corrupt");
        let csv = write_toy_csv(&dir, 12);
        let out = dir.join("ds");
        let man =
            ingest_csv(&csv, &out, &IngestOptions { name: "toy".into(), rows_per_shard: 4 }).unwrap();
        // flip one byte in the middle segment
        let victim = out.join(&man.segments[1].file);
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes[8] ^= 0x01;
        std::fs::write(&victim, &bytes).unwrap();
        let err = open_store(&out, 0).unwrap_err();
        match err {
            AviError::Storage(m) => {
                assert!(m.contains("seg_1.bin"), "error should name the segment: {m}");
                assert!(m.contains("checksum"), "{m}");
            }
            other => panic!("expected Storage error, got {other:?}"),
        }
        // restore seg_1, then truncation is also refused with the segment named
        bytes[8] ^= 0x01;
        std::fs::write(&victim, &bytes).unwrap();
        let seg0 = out.join(&man.segments[0].file);
        let full = std::fs::read(&seg0).unwrap();
        std::fs::write(&seg0, &full[..full.len() - 8]).unwrap();
        let err = open_store(&out, 0).unwrap_err();
        assert!(matches!(&err, AviError::Storage(m) if m.contains("seg_0.bin")), "{err:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_store_exposes_columns_and_counters() {
        let dir = tmp("open");
        let csv = write_toy_csv(&dir, 10);
        let out = dir.join("ds");
        ingest_csv(&csv, &out, &IngestOptions { name: "toy".into(), rows_per_shard: 4 }).unwrap();
        let (man, store) = ColumnStore::open_manifest(&out, 0).unwrap();
        assert_eq!(store.rows(), 10);
        assert_eq!(store.len(), man.cols);
        assert_eq!(store.n_shards(), 3);
        assert!(store.is_spilled());
        assert_eq!(store.mode_str(), "mmap");
        // column 0 of shard 1 starts at global row 4
        let lease = store.lease(1);
        assert_eq!(lease.col(0)[0], 4.0 / 7.0);
        drop(lease);
        let c = store.backing_counters().unwrap();
        assert!(c.loads >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn column_stats_stream_matches_manifest_extrema() {
        let dir = tmp("stats");
        let csv = write_toy_csv(&dir, 9);
        let out = dir.join("ds");
        let man =
            ingest_csv(&csv, &out, &IngestOptions { name: "toy".into(), rows_per_shard: 2 }).unwrap();
        let (_, store) = open_store(&out, 0).unwrap();
        let stats = column_stats(&store);
        assert_eq!(stats.len(), man.cols);
        for j in 0..man.cols {
            assert_eq!(stats[j].min, man.col_min[j]);
            assert_eq!(stats[j].max, man.col_max[j]);
        }
        let mean0: f64 = (0..9).map(|i| i as f64 / 7.0).sum::<f64>() / 9.0;
        assert!((stats[0].mean - mean0).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn split_partitions_every_row_exactly_once() {
        let dir = tmp("split");
        let csv = write_toy_csv(&dir, 40);
        let out = dir.join("ds");
        let man =
            ingest_csv(&csv, &out, &IngestOptions { name: "toy".into(), rows_per_shard: 16 }).unwrap();
        let (tr, te) =
            split_dataset(&out, &dir.join("train"), &dir.join("test"), 0.3, 7).unwrap();
        assert_eq!(tr.rows + te.rows, man.rows);
        assert!(tr.rows > 0 && te.rows > 0);
        assert_eq!(tr.cols, man.cols);
        // both outputs reopen cleanly (checksums valid)
        open_store(&dir.join("train"), 0).unwrap();
        open_store(&dir.join("test"), 0).unwrap();
        // deterministic across runs
        let (tr2, _) =
            split_dataset(&out, &dir.join("train2"), &dir.join("test2"), 0.3, 7).unwrap();
        assert_eq!(tr.rows, tr2.rows);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn split_rejects_degenerate_fractions() {
        let dir = tmp("splitbad");
        let csv = write_toy_csv(&dir, 4);
        let out = dir.join("ds");
        ingest_csv(&csv, &out, &IngestOptions::default()).unwrap();
        for bad in [0.0, 1.0, -0.2, 1.5] {
            assert!(matches!(
                split_dataset(&out, &dir.join("a"), &dir.join("b"), bad, 1),
                Err(AviError::Storage(_))
            ));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

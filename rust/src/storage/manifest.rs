//! The checksummed on-disk dataset manifest (`manifest.json`).
//!
//! Records everything needed to reopen an ingested dataset without
//! rescanning it: row count, column count (label = last column), the
//! shard partition (each segment's row count, in order), per-segment
//! byte sizes + FNV-1a-64 checksums, the sorted unique raw labels, and
//! per-column raw min/max (for `dataset inspect`/`stats` display — the
//! scaled load path recomputes them from data so scaling stays bitwise
//! identical to the direct CSV loader).
//!
//! Hand-rolled JSON, same discipline as `estimator::persist` (no serde
//! in the offline container): a versioned envelope, `{:?}`-formatted
//! floats (shortest round-trip — parse returns identical bits), and
//! checksums as fixed-width hex strings (u64 doesn't survive an f64
//! number cell).  Corruption of the manifest itself surfaces as a typed
//! [`AviError::Storage`] at open.

use std::path::Path;

use crate::error::{AviError, Result};
use crate::estimator::persist::{extract_array, extract_f64, extract_str, split_objects};
use crate::util::json_escape;

/// Envelope header of every dataset manifest.
pub const DATASET_FORMAT: &str = "avi-scale.dataset";
/// Manifest schema version.
pub const DATASET_VERSION: u64 = 1;

/// One shard segment's identity + integrity record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentMeta {
    /// File name relative to the dataset directory.
    pub file: String,
    /// Rows in this shard.
    pub rows: usize,
    /// Expected file size in bytes (`rows × cols × 8`).
    pub bytes: u64,
    /// FNV-1a-64 of the file contents.
    pub checksum: u64,
}

/// The dataset directory's self-description.
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetManifest {
    pub name: String,
    /// Total rows m across all segments.
    pub rows: usize,
    /// Columns per row, label included (= features + 1).
    pub cols: usize,
    /// Sorted unique raw labels (last column, rounded to integer).
    pub labels_uniq: Vec<i64>,
    /// Raw per-column minima (display/stats only).
    pub col_min: Vec<f64>,
    /// Raw per-column maxima (display/stats only).
    pub col_max: Vec<f64>,
    /// Shard segments in shard order.
    pub segments: Vec<SegmentMeta>,
}

impl DatasetManifest {
    /// The shard partition: rows per segment, in order.
    pub fn shard_rows(&self) -> Vec<usize> {
        self.segments.iter().map(|s| s.rows).collect()
    }

    /// Feature count (columns minus the label).
    pub fn n_features(&self) -> usize {
        self.cols.saturating_sub(1)
    }

    /// Serialize (one segment object per line — greppable, like every
    /// other artifact in this crate).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"format\": \"{DATASET_FORMAT}\",\n"));
        s.push_str(&format!("  \"version\": {DATASET_VERSION},\n"));
        s.push_str(&format!("  \"name\": \"{}\",\n", json_escape(&self.name)));
        s.push_str(&format!("  \"rows\": {},\n", self.rows));
        s.push_str(&format!("  \"cols\": {},\n", self.cols));
        s.push_str(&format!("  \"labels_uniq\": [{}],\n", join_i64(&self.labels_uniq)));
        s.push_str(&format!("  \"col_min\": [{}],\n", join_f64(&self.col_min)));
        s.push_str(&format!("  \"col_max\": [{}],\n", join_f64(&self.col_max)));
        s.push_str("  \"segments\": [\n");
        for (i, seg) in self.segments.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"file\": \"{}\", \"rows\": {}, \"bytes\": {}, \"checksum\": \"{:016x}\"}}{}\n",
                json_escape(&seg.file),
                seg.rows,
                seg.bytes,
                seg.checksum,
                if i + 1 < self.segments.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Parse, validating the envelope and internal consistency.
    pub fn from_json(text: &str) -> Result<Self> {
        let storage_err = |m: String| AviError::Storage(m);
        let format = extract_str(text, "\"format\":")
            .map_err(|_| storage_err("manifest: missing format header".into()))?;
        if format != DATASET_FORMAT {
            return Err(storage_err(format!(
                "manifest: format '{format}', expected '{DATASET_FORMAT}'"
            )));
        }
        let version = extract_f64(text, "\"version\":")? as u64;
        if version != DATASET_VERSION {
            return Err(storage_err(format!(
                "manifest: unsupported version {version} (supported: {DATASET_VERSION})"
            )));
        }
        let name = extract_str(text, "\"name\":")?;
        let rows = extract_f64(text, "\"rows\":")? as usize;
        let cols = extract_f64(text, "\"cols\":")? as usize;
        let labels_uniq = parse_i64_list(&extract_array(text, "\"labels_uniq\":")?)?;
        let col_min = parse_f64_list(&extract_array(text, "\"col_min\":")?)?;
        let col_max = parse_f64_list(&extract_array(text, "\"col_max\":")?)?;
        let mut segments = Vec::new();
        for obj in split_objects(&extract_array(text, "\"segments\":")?) {
            let checksum_hex = extract_str(obj, "\"checksum\":")?;
            let checksum = u64::from_str_radix(&checksum_hex, 16).map_err(|e| {
                storage_err(format!("manifest: bad checksum '{checksum_hex}': {e}"))
            })?;
            segments.push(SegmentMeta {
                file: extract_str(obj, "\"file\":")?,
                rows: extract_f64(obj, "\"rows\":")? as usize,
                bytes: extract_f64(obj, "\"bytes\":")? as u64,
                checksum,
            });
        }
        let man =
            DatasetManifest { name, rows, cols, labels_uniq, col_min, col_max, segments };
        man.validate()?;
        Ok(man)
    }

    /// Internal-consistency checks (before any segment is touched).
    fn validate(&self) -> Result<()> {
        if self.cols < 2 {
            return Err(AviError::Storage(format!(
                "manifest '{}': need >= 2 columns, got {}",
                self.name, self.cols
            )));
        }
        if self.segments.is_empty() {
            return Err(AviError::Storage(format!("manifest '{}': no segments", self.name)));
        }
        let seg_rows: usize = self.segments.iter().map(|s| s.rows).sum();
        if seg_rows != self.rows {
            return Err(AviError::Storage(format!(
                "manifest '{}': segment rows sum to {seg_rows}, manifest says {}",
                self.name, self.rows
            )));
        }
        for seg in &self.segments {
            let want = (seg.rows * self.cols * 8) as u64;
            if seg.bytes != want {
                return Err(AviError::Storage(format!(
                    "manifest '{}': segment {} records {} bytes, geometry implies {want}",
                    self.name, seg.file, seg.bytes
                )));
            }
        }
        if self.col_min.len() != self.cols || self.col_max.len() != self.cols {
            return Err(AviError::Storage(format!(
                "manifest '{}': col stats length mismatch",
                self.name
            )));
        }
        Ok(())
    }

    /// Write `manifest.json` into `dir`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("manifest.json"), self.to_json())?;
        Ok(())
    }

    /// Read and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            AviError::Storage(format!("no dataset manifest at {}: {e}", path.display()))
        })?;
        Self::from_json(&text)
    }
}

fn join_i64(vals: &[i64]) -> String {
    vals.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
}

fn join_f64(vals: &[f64]) -> String {
    vals.iter().map(|v| format!("{v:?}")).collect::<Vec<_>>().join(", ")
}

fn parse_f64_list(src: &str) -> Result<Vec<f64>> {
    if src.trim().is_empty() {
        return Ok(Vec::new());
    }
    src.split(',')
        .map(|t| {
            t.trim()
                .parse::<f64>()
                .map_err(|e| AviError::Storage(format!("manifest: number list: {e}")))
        })
        .collect()
}

fn parse_i64_list(src: &str) -> Result<Vec<i64>> {
    if src.trim().is_empty() {
        return Ok(Vec::new());
    }
    src.split(',')
        .map(|t| {
            t.trim()
                .parse::<i64>()
                .map_err(|e| AviError::Storage(format!("manifest: label list: {e}")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DatasetManifest {
        DatasetManifest {
            name: "toy".into(),
            rows: 5,
            cols: 3,
            labels_uniq: vec![-1, 0, 4],
            col_min: vec![0.1, -2.5, 0.0],
            col_max: vec![0.9, 3.25, 4.0],
            segments: vec![
                SegmentMeta { file: "seg_0.bin".into(), rows: 3, bytes: 72, checksum: 0xdead_beef },
                SegmentMeta {
                    file: "seg_1.bin".into(),
                    rows: 2,
                    bytes: 48,
                    checksum: u64::MAX, // must survive the codec (not an f64)
                },
            ],
        }
    }

    #[test]
    fn manifest_roundtrips_including_u64_checksums() {
        let man = sample();
        let back = DatasetManifest::from_json(&man.to_json()).unwrap();
        assert_eq!(man, back);
        assert_eq!(back.shard_rows(), vec![3, 2]);
        assert_eq!(back.n_features(), 2);
    }

    #[test]
    fn float_stats_roundtrip_bitwise() {
        let mut man = sample();
        man.col_min = vec![0.1 + 0.2, f64::MIN_POSITIVE, -0.0];
        man.col_max = vec![1.0 / 3.0, 1e308, 2.0_f64.powi(-40)];
        let back = DatasetManifest::from_json(&man.to_json()).unwrap();
        for (a, b) in man.col_min.iter().zip(&back.col_min) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in man.col_max.iter().zip(&back.col_max) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_wrong_header_and_inconsistencies() {
        let man = sample();
        let wrong = man.to_json().replace(DATASET_FORMAT, "something.else");
        assert!(matches!(
            DatasetManifest::from_json(&wrong),
            Err(AviError::Storage(_))
        ));
        let mut bad_rows = sample();
        bad_rows.rows = 99;
        assert!(matches!(
            DatasetManifest::from_json(&bad_rows.to_json()),
            Err(AviError::Storage(_))
        ));
        let mut bad_bytes = sample();
        bad_bytes.segments[0].bytes = 7;
        assert!(matches!(
            DatasetManifest::from_json(&bad_bytes.to_json()),
            Err(AviError::Storage(_))
        ));
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("avi_manifest_test_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let man = sample();
        man.save(&dir).unwrap();
        let back = DatasetManifest::load(&dir).unwrap();
        assert_eq!(man, back);
        assert!(matches!(
            DatasetManifest::load(&dir.join("missing")),
            Err(AviError::Storage(_))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! On-disk shard segments: the byte-level substrate of the file-backed
//! store.
//!
//! One segment file holds one shard's column block in **column-major**
//! little-endian `f64` layout: column `j` of an `rows × n_cols` block
//! lives at byte offset `j · rows · 8`.  Because every shard gets its
//! own file, each block starts page-aligned at offset 0; columns inside
//! it are 8-byte aligned.  The encoding is bitwise-lossless
//! (`f64::to_le_bytes` / `from_le_bytes` round-trip every bit pattern,
//! NaNs included), which is what makes the file-backed store's exact
//! path *bitwise identical* to the in-memory store: the kernels see the
//! same `f64` values, only the bytes' residence differs.
//!
//! Concurrency: reads and writes go through a per-segment `Mutex<File>`
//! (seek + read/write under the lock).  A segment maps 1:1 to a shard
//! and the resident pool serializes loads per shard anyway, so the lock
//! is uncontended across shards — pool workers touching *different*
//! shards never share a segment lock.
//!
//! Integrity: segments are checksummed with FNV-1a 64 (streamed, no
//! allocation proportional to file size).  The dataset manifest records
//! the expected checksum; [`crate::storage`] refuses mismatches with a
//! typed [`crate::error::AviError::Storage`] before any fit runs.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Streaming FNV-1a 64-bit hasher (the container has no hash crates;
/// FNV-1a is 6 lines and good enough for corruption detection, which is
/// the only job here — this is not a cryptographic integrity claim).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64 {
    h: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Self {
        Fnv64 { h: Self::OFFSET }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.h;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(Self::PRIME);
        }
        self.h = h;
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

/// Encode `vals` as little-endian bytes into `out` (cleared first).
pub fn f64s_to_le(vals: &[f64], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Decode little-endian bytes into `out` (cleared first).  `bytes.len()`
/// must be a multiple of 8.
pub fn le_to_f64s(bytes: &[u8], out: &mut Vec<f64>) {
    debug_assert_eq!(bytes.len() % 8, 0);
    out.clear();
    out.reserve(bytes.len() / 8);
    for chunk in bytes.chunks_exact(8) {
        let mut b = [0u8; 8];
        b.copy_from_slice(chunk);
        out.push(f64::from_le_bytes(b));
    }
}

/// One shard's on-disk column block.
#[derive(Debug)]
pub struct Segment {
    path: PathBuf,
    file: Mutex<File>,
}

impl Segment {
    /// Create (truncating) a writable segment.
    pub fn create(path: &Path) -> std::io::Result<Segment> {
        let file =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(path)?;
        Ok(Segment { path: path.to_path_buf(), file: Mutex::new(file) })
    }

    /// Open an existing segment read-only.
    pub fn open(path: &Path) -> std::io::Result<Segment> {
        let file = OpenOptions::new().read(true).open(path)?;
        Ok(Segment { path: path.to_path_buf(), file: Mutex::new(file) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Lock the file handle, recovering from a poisoned mutex.  The only
    /// guarded state is a file cursor, and every operation re-seeks to an
    /// absolute offset before touching it — a panic mid-operation on
    /// another thread leaves nothing inconsistent to inherit, so
    /// propagating the poison (and panicking every later reader) would
    /// turn one crashed worker into a crashed store.
    fn lock_file(&self) -> std::sync::MutexGuard<'_, File> {
        self.file.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Read `count` f64s starting at `byte_off` into `out` (cleared
    /// first).  Short files surface as `UnexpectedEof`.
    pub fn read_f64s_at(
        &self,
        byte_off: u64,
        count: usize,
        scratch: &mut Vec<u8>,
        out: &mut Vec<f64>,
    ) -> std::io::Result<()> {
        scratch.clear();
        scratch.resize(count * 8, 0);
        {
            let mut f = self.lock_file();
            f.seek(SeekFrom::Start(byte_off))?;
            f.read_exact(scratch)?;
        }
        le_to_f64s(scratch, out);
        Ok(())
    }

    /// Write `vals` at `byte_off` (overwriting or appending).
    pub fn write_f64s_at(&self, byte_off: u64, vals: &[f64]) -> std::io::Result<()> {
        let mut bytes = Vec::new();
        f64s_to_le(vals, &mut bytes);
        let mut f = self.lock_file();
        f.seek(SeekFrom::Start(byte_off))?;
        f.write_all(&bytes)?;
        f.flush()
    }

    /// File length in bytes.
    pub fn len_bytes(&self) -> std::io::Result<u64> {
        let f = self.lock_file();
        Ok(f.metadata()?.len())
    }
}

/// Checksum a whole file with a bounded (64 KiB) buffer.
pub fn checksum_file(path: &Path) -> std::io::Result<u64> {
    let mut f = File::open(path)?;
    let mut buf = vec![0u8; 64 * 1024];
    let mut h = Fnv64::new();
    loop {
        let n = f.read(&mut buf)?;
        if n == 0 {
            break;
        }
        h.update(&buf[..n]);
    }
    Ok(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("avi_seg_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // standard FNV-1a test vectors
        let mut h = Fnv64::new();
        assert_eq!(h.finish(), 0xcbf29ce484222325);
        h.update(b"a");
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
        let mut h2 = Fnv64::new();
        h2.update(b"foobar");
        assert_eq!(h2.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn le_roundtrip_is_bitwise_nan_included() {
        let vals = [1.5, -0.0, f64::NAN, f64::INFINITY, 3.141592653589793, f64::MIN_POSITIVE];
        let mut bytes = Vec::new();
        f64s_to_le(&vals, &mut bytes);
        let mut back = Vec::new();
        le_to_f64s(&bytes, &mut back);
        assert_eq!(vals.len(), back.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn segment_write_read_roundtrips_columns() {
        let path = tmp("roundtrip.bin");
        let seg = Segment::create(&path).unwrap();
        let rows = 7;
        let col0: Vec<f64> = (0..rows).map(|i| i as f64 * 0.25).collect();
        let col1: Vec<f64> = (0..rows).map(|i| -(i as f64)).collect();
        seg.write_f64s_at(0, &col0).unwrap();
        seg.write_f64s_at((rows * 8) as u64, &col1).unwrap();
        let (mut scratch, mut out) = (Vec::new(), Vec::new());
        seg.read_f64s_at((rows * 8) as u64, rows, &mut scratch, &mut out).unwrap();
        assert_eq!(out, col1);
        seg.read_f64s_at(0, rows, &mut scratch, &mut out).unwrap();
        assert_eq!(out, col0);
        assert_eq!(seg.len_bytes().unwrap(), (2 * rows * 8) as u64);
        // streamed file checksum == streamed in-memory checksum
        let mut bytes = Vec::new();
        f64s_to_le(&col0, &mut bytes);
        let mut h = Fnv64::new();
        h.update(&bytes);
        f64s_to_le(&col1, &mut bytes);
        h.update(&bytes);
        assert_eq!(checksum_file(&path).unwrap(), h.finish());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn poisoned_lock_does_not_cascade() {
        // a worker panicking while holding the segment lock poisons the
        // mutex; later readers must recover (every op re-seeks, so there
        // is no inconsistent state to fear) instead of panicking too
        let path = tmp("poison.bin");
        let seg = std::sync::Arc::new(Segment::create(&path).unwrap());
        seg.write_f64s_at(0, &[1.0, 2.0, 3.0]).unwrap();
        let seg2 = seg.clone();
        let _ = std::thread::spawn(move || {
            let _guard = seg2.file.lock().unwrap();
            panic!("poison the segment lock");
        })
        .join();
        assert!(seg.file.lock().is_err(), "lock should be poisoned");
        let (mut scratch, mut out) = (Vec::new(), Vec::new());
        seg.read_f64s_at(0, 3, &mut scratch, &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
        seg.write_f64s_at(24, &[4.0]).unwrap();
        assert_eq!(seg.len_bytes().unwrap(), 32);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn short_read_is_unexpected_eof() {
        let path = tmp("short.bin");
        let seg = Segment::create(&path).unwrap();
        seg.write_f64s_at(0, &[1.0, 2.0]).unwrap();
        let (mut scratch, mut out) = (Vec::new(), Vec::new());
        let err = seg.read_f64s_at(0, 5, &mut scratch, &mut out).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
        std::fs::remove_file(&path).ok();
    }
}

//! Chunked ingestion: stream CSV row-groups straight into shard
//! segments without ever materializing the full m×n matrix.
//!
//! Two pieces:
//!
//! * [`RowGroupReader`] — the `BufRead` line-streaming CSV loop, shared
//!   with [`crate::data::csvio::load_csv_dataset`]: one reusable line
//!   buffer, typed per-line errors with 1-based line numbers, header
//!   auto-detection (an unparsable *first* line is skipped, matching
//!   the historical loader).  Yields row-major groups of at most
//!   `group_rows` rows, so peak ingest memory is one group buffer —
//!   independent of m.
//! * [`SegmentSink`] — the write side: accumulates rows, and flushes
//!   each full group as one shard segment (column-major transpose →
//!   le-bytes → FNV-1a checksum → `seg_<s>.bin`), tracking per-column
//!   min/max and the raw label set along the way.  `finish` writes the
//!   checksummed [`DatasetManifest`].
//!
//! Each row-group becomes one shard, which is what makes ingestion
//! single-pass: the shard partition is discovered as rows stream by, no
//! up-front row count needed.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};

use crate::error::{AviError, Result};
use crate::storage::manifest::{DatasetManifest, SegmentMeta};
use crate::storage::segment::{f64s_to_le, Fnv64};

/// Default rows per group/shard: 64k rows × n cols × 8 B keeps the
/// transpose buffer in the tens of MB for realistic widths.
pub const DEFAULT_ROWS_PER_SHARD: usize = 65_536;

/// Streaming CSV reader yielding row-major groups of parsed rows.
pub struct RowGroupReader<R: BufRead> {
    reader: R,
    /// Display name for error messages (the file path).
    source: String,
    /// 0-based index of the next line to read.
    lineno: usize,
    /// Field count fixed by the first accepted row.
    n_fields: Option<usize>,
    group_rows: usize,
    line: String,
    done: bool,
}

impl RowGroupReader<BufReader<File>> {
    /// Open a CSV file for streaming.
    pub fn open(path: &Path, group_rows: usize) -> Result<Self> {
        let file = File::open(path)?;
        Ok(Self::from_reader(BufReader::new(file), &path.display().to_string(), group_rows))
    }
}

impl<R: BufRead> RowGroupReader<R> {
    /// Stream from any `BufRead` (tests; in-memory sources).
    pub fn from_reader(reader: R, source: &str, group_rows: usize) -> Self {
        RowGroupReader {
            reader,
            source: source.to_string(),
            lineno: 0,
            n_fields: None,
            group_rows: group_rows.max(1),
            line: String::new(),
            done: false,
        }
    }

    /// Field count per row (known after the first accepted row).
    pub fn n_fields(&self) -> Option<usize> {
        self.n_fields
    }

    /// Read the next group into `buf` (cleared first; row-major,
    /// `n_fields` values per row).  Returns the number of rows read —
    /// 0 at end of input.
    pub fn next_group(&mut self, buf: &mut Vec<f64>) -> Result<usize> {
        buf.clear();
        let mut got = 0usize;
        while got < self.group_rows && !self.done {
            self.line.clear();
            if self.reader.read_line(&mut self.line)? == 0 {
                self.done = true;
                break;
            }
            let lineno = self.lineno;
            self.lineno += 1;
            let line = self.line.trim();
            if line.is_empty() {
                continue;
            }
            let before = buf.len();
            let mut fields = 0usize;
            let mut bad = false;
            for f in line.split(',') {
                match f.trim().parse::<f64>() {
                    Ok(v) => {
                        buf.push(v);
                        fields += 1;
                    }
                    Err(_) => {
                        bad = true;
                        break;
                    }
                }
            }
            // a row needs features + label; a 1-field line is treated
            // like a parse failure (header if first, error otherwise) —
            // same contract as the historical whole-file loader
            if bad || fields < 2 {
                buf.truncate(before);
                if lineno == 0 {
                    continue; // header row
                }
                return Err(AviError::Data(format!(
                    "{}: unparsable line {}",
                    self.source,
                    lineno + 1
                )));
            }
            match self.n_fields {
                None => self.n_fields = Some(fields),
                Some(n) if n != fields => {
                    return Err(AviError::Data(format!(
                        "{}: line {}: expected {} fields, got {}",
                        self.source,
                        lineno + 1,
                        n,
                        fields
                    )));
                }
                Some(_) => {}
            }
            got += 1;
        }
        Ok(got)
    }
}

/// Write side of ingestion: rows in, checksummed shard segments +
/// manifest out.
pub struct SegmentSink {
    out_dir: PathBuf,
    rows_per_shard: usize,
    n_fields: Option<usize>,
    /// Pending rows, row-major.
    pending: Vec<f64>,
    pending_rows: usize,
    total_rows: usize,
    segments: Vec<SegmentMeta>,
    col_min: Vec<f64>,
    col_max: Vec<f64>,
    /// Raw (rounded) labels seen in the last column.
    labels: Vec<i64>,
    /// Reusable transpose + encode buffers.
    colmaj: Vec<f64>,
    bytes: Vec<u8>,
}

impl SegmentSink {
    /// Start a sink writing into `out_dir` (created if missing).
    pub fn create(out_dir: &Path, rows_per_shard: usize) -> Result<SegmentSink> {
        std::fs::create_dir_all(out_dir)?;
        Ok(SegmentSink {
            out_dir: out_dir.to_path_buf(),
            rows_per_shard: rows_per_shard.max(1),
            n_fields: None,
            pending: Vec::new(),
            pending_rows: 0,
            total_rows: 0,
            segments: Vec::new(),
            col_min: Vec::new(),
            col_max: Vec::new(),
            labels: Vec::new(),
            colmaj: Vec::new(),
            bytes: Vec::new(),
        })
    }

    /// Append one row (label = last value), flushing a segment when the
    /// group fills.
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        match self.n_fields {
            None => {
                if row.len() < 2 {
                    return Err(AviError::Data(
                        "ingest: rows need >= 2 columns (features + label)".into(),
                    ));
                }
                self.n_fields = Some(row.len());
                self.col_min = vec![f64::INFINITY; row.len()];
                self.col_max = vec![f64::NEG_INFINITY; row.len()];
            }
            Some(n) if n != row.len() => {
                return Err(AviError::Data(format!(
                    "ingest: row width changed from {n} to {}",
                    row.len()
                )));
            }
            Some(_) => {}
        }
        for (j, &v) in row.iter().enumerate() {
            self.col_min[j] = self.col_min[j].min(v);
            self.col_max[j] = self.col_max[j].max(v);
        }
        self.labels.push(row[row.len() - 1].round() as i64);
        self.pending.extend_from_slice(row);
        self.pending_rows += 1;
        self.total_rows += 1;
        if self.pending_rows == self.rows_per_shard {
            self.flush_group()?;
        }
        Ok(())
    }

    /// Transpose the pending row-major group to column-major, checksum,
    /// and write it as the next shard segment.
    fn flush_group(&mut self) -> Result<()> {
        if self.pending_rows == 0 {
            return Ok(());
        }
        // pending_rows > 0 implies push_row ran, which sets n_fields —
        // but storage never panics on its own invariants: surface a
        // typed error instead
        let n = self.n_fields.ok_or_else(|| {
            AviError::Storage("ingest: flush with rows pending but no field count".into())
        })?;
        let rows = self.pending_rows;
        self.colmaj.clear();
        self.colmaj.resize(rows * n, 0.0);
        for i in 0..rows {
            for j in 0..n {
                self.colmaj[j * rows + i] = self.pending[i * n + j];
            }
        }
        f64s_to_le(&self.colmaj, &mut self.bytes);
        let mut h = Fnv64::new();
        h.update(&self.bytes);
        let file = format!("seg_{}.bin", self.segments.len());
        std::fs::write(self.out_dir.join(&file), &self.bytes)?;
        self.segments.push(SegmentMeta {
            file,
            rows,
            bytes: self.bytes.len() as u64,
            checksum: h.finish(),
        });
        self.pending.clear();
        self.pending_rows = 0;
        Ok(())
    }

    /// Flush the tail group and write `manifest.json`.  Errors when no
    /// rows were pushed.
    pub fn finish(mut self, name: &str) -> Result<DatasetManifest> {
        self.flush_group()?;
        if self.total_rows == 0 {
            return Err(AviError::Storage(format!("ingest '{name}': no rows")));
        }
        // total_rows > 0 implies n_fields is set; typed error, not a panic
        let cols = self.n_fields.ok_or_else(|| {
            AviError::Storage(format!("ingest '{name}': rows counted but no field count"))
        })?;
        let mut uniq = self.labels.clone();
        uniq.sort_unstable();
        uniq.dedup();
        let manifest = DatasetManifest {
            name: name.to_string(),
            rows: self.total_rows,
            cols,
            labels_uniq: uniq,
            col_min: self.col_min,
            col_max: self.col_max,
            segments: self.segments,
        };
        manifest.save(&self.out_dir)?;
        Ok(manifest)
    }
}

/// Ingestion knobs (CLI surface).
#[derive(Clone, Debug)]
pub struct IngestOptions {
    /// Dataset name recorded in the manifest.
    pub name: String,
    /// Rows per shard segment (= per row-group).
    pub rows_per_shard: usize,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions { name: "ingested".into(), rows_per_shard: DEFAULT_ROWS_PER_SHARD }
    }
}

/// Stream `csv` (label = last column) into a manifest-backed dataset
/// directory.  Single pass; peak memory is one row-group.
pub fn ingest_csv(csv: &Path, out_dir: &Path, opts: &IngestOptions) -> Result<DatasetManifest> {
    let mut rdr = RowGroupReader::open(csv, opts.rows_per_shard)?;
    let mut sink = SegmentSink::create(out_dir, opts.rows_per_shard)?;
    let mut buf = Vec::new();
    loop {
        let got = rdr.next_group(&mut buf)?;
        if got == 0 {
            break;
        }
        let n = rdr.n_fields().ok_or_else(|| {
            AviError::Storage(format!(
                "ingest '{}': non-empty group with unknown field count",
                csv.display()
            ))
        })?;
        for r in 0..got {
            sink.push_row(&buf[r * n..(r + 1) * n])?;
        }
    }
    sink.finish(&opts.name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn row_group_reader_streams_groups_and_skips_header() {
        let src = "a,b,label\n1,2,0\n3,4,1\n\n5,6,0\n";
        let mut rdr = RowGroupReader::from_reader(Cursor::new(src), "mem", 2);
        let mut buf = Vec::new();
        assert_eq!(rdr.next_group(&mut buf).unwrap(), 2);
        assert_eq!(buf, vec![1.0, 2.0, 0.0, 3.0, 4.0, 1.0]);
        assert_eq!(rdr.n_fields(), Some(3));
        assert_eq!(rdr.next_group(&mut buf).unwrap(), 1);
        assert_eq!(buf, vec![5.0, 6.0, 0.0]);
        assert_eq!(rdr.next_group(&mut buf).unwrap(), 0);
    }

    #[test]
    fn row_group_reader_reports_line_numbers() {
        let src = "h,h,h\n1,2,0\nbad,row,here\n";
        let mut rdr = RowGroupReader::from_reader(Cursor::new(src), "mem", 8);
        let mut buf = Vec::new();
        let err = rdr.next_group(&mut buf).unwrap_err();
        assert_eq!(err.to_string(), "data error: mem: unparsable line 3");
        let src = "1,2,0\n3,4\n";
        let mut rdr = RowGroupReader::from_reader(Cursor::new(src), "mem", 8);
        let err = rdr.next_group(&mut buf).unwrap_err();
        assert!(err.to_string().contains("line 2: expected 3 fields, got 2"), "{err}");
    }

    #[test]
    fn ingest_partitions_rows_into_segments() {
        let dir = std::env::temp_dir().join(format!("avi_ingest_test_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let csv = dir.join("toy.csv");
        std::fs::create_dir_all(&dir).unwrap();
        let mut body = String::from("x0,x1,label\n");
        for i in 0..7 {
            body.push_str(&format!("{}.5,{},{}\n", i, i * 2, i % 2));
        }
        std::fs::write(&csv, body).unwrap();
        let out = dir.join("ds");
        let man = ingest_csv(
            &csv,
            &out,
            &IngestOptions { name: "toy".into(), rows_per_shard: 3 },
        )
        .unwrap();
        assert_eq!(man.rows, 7);
        assert_eq!(man.cols, 3);
        assert_eq!(man.shard_rows(), vec![3, 3, 1]);
        assert_eq!(man.labels_uniq, vec![0, 1]);
        assert_eq!(man.col_min[0], 0.5);
        assert_eq!(man.col_max[1], 12.0);
        for seg in &man.segments {
            let len = std::fs::metadata(out.join(&seg.file)).unwrap().len();
            assert_eq!(len, (seg.rows * man.cols * 8) as u64);
            assert_eq!(len, seg.bytes);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ingest_rejects_empty_input() {
        let dir = std::env::temp_dir().join(format!("avi_ingest_empty_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("empty.csv");
        std::fs::write(&csv, "just,a,header\n").unwrap();
        let err = ingest_csv(&csv, &dir.join("ds"), &IngestOptions::default()).unwrap_err();
        assert!(err.to_string().contains("no rows"), "{err}");
        assert!(matches!(err, AviError::Storage(_)), "{err:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ingest_zero_row_inputs_are_typed_errors_not_panics() {
        // header-only, fully empty, and whitespace-only sources all reach
        // finish() with zero rows through slightly different paths — each
        // must surface a typed Storage error, never an unwrap panic
        let dir = std::env::temp_dir().join(format!("avi_ingest_zero_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        for (name, body) in [
            ("header_only.csv", "x0,x1,label\n"),
            ("empty.csv", ""),
            ("blank_lines.csv", "\n\n   \n\n"),
        ] {
            let csv = dir.join(name);
            std::fs::write(&csv, body).unwrap();
            let err = ingest_csv(
                &csv,
                &dir.join(format!("ds_{name}")),
                &IngestOptions::default(),
            )
            .unwrap_err();
            assert!(matches!(err, AviError::Storage(_)), "{name}: {err:?}");
            assert!(err.to_string().contains("no rows"), "{name}: {err}");
        }
        // a sink finished with no pushed rows takes the direct path
        let sink = SegmentSink::create(&dir.join("ds_direct"), 4).unwrap();
        let err = sink.finish("direct").unwrap_err();
        assert!(matches!(err, AviError::Storage(_)), "{err:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! The Approximate Buchberger–Möller algorithm (Limbeck 2013), with the
//! paper's §6.1 modification: the smallest singular pair of `[A b]` is
//! obtained from the eigendecomposition of the bordered Gram matrix
//! `[A b]ᵀ[A b]` (cheaper whenever m > ℓ, which is always the case here).
//!
//! ABM walks the same DegLex border as OAVI but decides vanishing via the
//! smallest eigenvalue: for the unit-norm coefficient vector v of
//! `[A b]`'s smallest singular direction, `MSE = λ_min/m`; if ≤ ψ the
//! polynomial (rescaled to LTC = 1) becomes a generator, otherwise the
//! term joins O.  Note ABM's criterion normalizes by ‖v‖₂ = 1, *not*
//! LTC = 1 — the paper's Remark 4.4 uses exactly this to transfer the
//! Theorem 4.3 bound to ABM.
//!
//! Data flow: ABM rides OAVI's degree-batched candidate panels — one
//! `gram_panel` pass per (degree, chunk) supplies every bordered-Gram
//! column, with the within-degree dependence resolved from the cached
//! panel cross entries (bitwise identical to the per-candidate
//! reference, [`Abm::fit_with_backend_per_candidate`]).

use crate::backend::{
    CandidatePanel, ColumnStore, ComputeBackend, CrossMode, NativeBackend, NumericsMode,
    PanelRecipe,
};
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::linalg::eigen::smallest_eigenpair;
use crate::linalg::gram::GramState;
use crate::oavi::driver::FitStats;
use crate::poly::border::compute_border;
use crate::poly::eval::TermSet;
use crate::poly::poly::{Generator, GeneratorSet};

/// ABM configuration.
#[derive(Clone, Copy, Debug)]
pub struct AbmConfig {
    /// vanishing parameter ψ (on the unit-norm MSE λ_min/m).
    pub psi: f64,
    pub max_degree: u32,
    pub max_o_terms: usize,
    /// |LTC| below this rejects the polynomial as spurious (the leading
    /// coefficient is numerically zero ⇒ rescaling to LTC = 1 explodes).
    pub ltc_floor: f64,
    /// Column cap per candidate-panel chunk (see
    /// `OaviConfig::panel_budget_cols` — same semantics, bitwise-neutral).
    pub panel_budget_cols: usize,
}

impl AbmConfig {
    pub fn new(psi: f64) -> Self {
        AbmConfig {
            psi,
            max_degree: 12,
            max_o_terms: 5_000,
            ltc_floor: 1e-10,
            panel_budget_cols: 512,
        }
    }
}

/// Fitted ABM output (same shape as OAVI's).
#[derive(Clone, Debug)]
pub struct AbmModel {
    pub generators: Vec<Generator>,
    pub o_terms: TermSet,
    pub stats: FitStats,
}

impl AbmModel {
    pub fn generator_set(&self) -> GeneratorSet {
        GeneratorSet { o_terms: self.o_terms.clone(), generators: self.generators.clone() }
    }

    pub fn total_size(&self) -> usize {
        self.generators.len() + self.o_terms.len()
    }
}

/// The ABM algorithm.
pub struct Abm {
    config: AbmConfig,
}

impl Abm {
    pub fn new(config: AbmConfig) -> Self {
        Abm { config }
    }

    pub fn config(&self) -> &AbmConfig {
        &self.config
    }

    /// Fit with the native streaming backend.
    pub fn fit(&self, x: &Matrix) -> Result<AbmModel> {
        self.fit_with_backend(x, &NativeBackend)
    }

    /// Fit with an explicit streaming backend through the degree-batched
    /// candidate-panel path (the default) — ABM shares OAVI's
    /// `gram_panel` kernel (the O(mℓk) bordered-Gram batch), so it
    /// shards and accelerates the same way.
    pub fn fit_with_backend(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> Result<AbmModel> {
        self.fit_impl(x, backend, true)
    }

    /// Legacy correctness reference: one `gram_stats` pass per border
    /// term.  Bitwise identical to [`Abm::fit_with_backend`] (pinned in
    /// `tests/runtime_parity.rs`).
    pub fn fit_with_backend_per_candidate(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> Result<AbmModel> {
        self.fit_impl(x, backend, false)
    }

    fn fit_impl(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
        panels: bool,
    ) -> Result<AbmModel> {
        let cfg = self.config;
        let m = x.rows();
        let n = x.cols();
        if m == 0 || n == 0 {
            return Err(AviError::Data("ABM fit: empty data".into()));
        }
        let mut o = TermSet::with_one(n);
        let mut cols = ColumnStore::with_ones(m, backend.preferred_shards(m));
        let mut gram = GramState::new_ones_b_only(m);
        let mut generators = Vec::new();
        let mut stats = FitStats::default();

        if panels {
            let budget = CandidatePanel::budget_cols(cfg.panel_budget_cols, m);
            let mut atb_buf: Vec<f64> = Vec::new();
            'degrees: for d in 1..=cfg.max_degree {
                let border = compute_border(&o, d);
                if border.is_empty() {
                    break;
                }
                stats.degree_reached = d;
                let mut start = 0usize;
                while start < border.len() {
                    let end = (start + budget).min(border.len());
                    let chunk = &border[start..end];
                    let recipes: Vec<PanelRecipe> = chunk
                        .iter()
                        .map(|bt| PanelRecipe { parent: bt.parent, var: bt.var })
                        .collect();
                    let panel = CandidatePanel::from_recipes(&cols, x, &recipes);
                    // ABM reads cross entries for rejected candidates too
                    // (bordered-Gram eigenproblems), so the eager triangle
                    // is the right shape here; exact numerics always
                    let pstats =
                        backend.gram_panel(&cols, &panel, CrossMode::Eager, NumericsMode::Exact);
                    stats.panel_passes += 1;
                    stats.panel_cols += chunk.len();
                    let mut accepted: Vec<usize> = Vec::new();
                    for (ci, bt) in chunk.iter().enumerate() {
                        atb_buf.clear();
                        atb_buf.extend_from_slice(pstats.atb_col(ci));
                        for &ai in &accepted {
                            atb_buf.push(pstats.cross_at(ai, ci));
                        }
                        stats.cross_cache_hits += accepted.len();
                        let btb = pstats.btb(ci);
                        stats.oracle_calls += 1;
                        match self.eigen_step(&gram, &atb_buf, btb, m)? {
                            Some((coeffs, mse)) => generators.push(Generator {
                                coeffs,
                                leading: bt.term.clone(),
                                leading_parent: bt.parent,
                                leading_var: bt.var,
                                mse,
                            }),
                            None => {
                                gram.append(&atb_buf, btb)?;
                                cols.push_col_from_panel(&panel, ci);
                                o.push_product(bt.parent, bt.var)?;
                                accepted.push(ci);
                                if o.len() >= cfg.max_o_terms {
                                    break 'degrees;
                                }
                            }
                        }
                    }
                    start = end;
                }
            }
        } else {
            let mut b_col = vec![0.0f64; m];
            'degrees_legacy: for d in 1..=cfg.max_degree {
                let border = compute_border(&o, d);
                if border.is_empty() {
                    break;
                }
                stats.degree_reached = d;
                for bt in &border {
                    cols.fill_product(bt.parent, x, bt.var, &mut b_col);
                    let (atb, btb) = backend.gram_stats(&cols, &b_col);
                    stats.oracle_calls += 1;
                    match self.eigen_step(&gram, &atb, btb, m)? {
                        Some((coeffs, mse)) => generators.push(Generator {
                            coeffs,
                            leading: bt.term.clone(),
                            leading_parent: bt.parent,
                            leading_var: bt.var,
                            mse,
                        }),
                        None => {
                            gram.append(&atb, btb)?;
                            cols.push_col(&b_col); // copy into shard blocks
                            o.push_product(bt.parent, bt.var)?;
                            if o.len() >= cfg.max_o_terms {
                                break 'degrees_legacy;
                            }
                        }
                    }
                }
            }
        }
        Ok(AbmModel { generators, o_terms: o, stats })
    }

    /// The §6.1 decision: eigendecompose the bordered Gram `[A b]ᵀ[A b]`
    /// (assembled from the maintained B plus the cached `Aᵀb`/`bᵀb`) and
    /// return `Some((coeffs, mse))` when the smallest singular direction
    /// vanishes with a usable leading coefficient, `None` when the term
    /// belongs in O.
    fn eigen_step(
        &self,
        gram: &GramState,
        atb: &[f64],
        btb: f64,
        m: usize,
    ) -> Result<Option<(Vec<f64>, f64)>> {
        let cfg = &self.config;
        let ell = gram.len();
        // bordered Gram [A b]ᵀ[A b]
        let mut bt_gram = Matrix::zeros(ell + 1, ell + 1);
        for i in 0..ell {
            bt_gram.row_mut(i)[..ell].copy_from_slice(&gram.b().row(i)[..ell]);
            bt_gram.set(i, ell, atb[i]);
            bt_gram.set(ell, i, atb[i]);
        }
        bt_gram.set(ell, ell, btb);

        let (lam, v) = smallest_eigenpair(&bt_gram)?;
        let unit_mse = lam.max(0.0) / m as f64;
        let ltc = v[ell];

        if unit_mse <= cfg.psi && ltc.abs() >= cfg.ltc_floor {
            // rescale to LTC = 1 (paper Definition 2.2) for the shared
            // Generator representation
            let coeffs: Vec<f64> = v[..ell].iter().map(|c| c / ltc).collect();
            Ok(Some((coeffs, unit_mse / (ltc * ltc))))
        } else {
            Ok(None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn parabola(m: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        for i in 0..m {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, t * t);
        }
        x
    }

    #[test]
    fn finds_exact_structure() {
        let x = parabola(120, 1);
        let model = Abm::new(AbmConfig::new(1e-10)).fit(&x).unwrap();
        assert!(!model.generators.is_empty());
        let gs = model.generator_set();
        // generators must vanish out-of-sample on the same variety
        let fresh = parabola(60, 2);
        for mse in gs.mse_on(&fresh) {
            assert!(mse < 1e-6, "out-sample mse {mse}");
        }
    }

    #[test]
    fn unit_norm_criterion_bounds_reported_mse() {
        // accepted generators have unit-norm MSE ≤ ψ; the LTC=1 rescaled
        // MSE can be larger but must stay finite and consistent
        let x = parabola(100, 3);
        let model = Abm::new(AbmConfig::new(1e-6)).fit(&x).unwrap();
        let gs = model.generator_set();
        let recomputed = gs.mse_on(&x);
        for (g, r) in model.generators.iter().zip(recomputed.iter()) {
            assert!((g.mse - r).abs() <= 1e-6 * (1.0 + r), "stored {} vs {}", g.mse, r);
        }
    }

    #[test]
    fn tracks_size_like_oavi_on_random_data() {
        // Remark 4.4: ABM obeys the same |G|+|O| bound
        let mut rng = Rng::new(5);
        let mut x = Matrix::zeros(80, 2);
        for i in 0..80 {
            for j in 0..2 {
                x.set(i, j, rng.uniform());
            }
        }
        let psi = 0.05;
        let cfg = crate::oavi::OaviConfig::cgavi_ihb(psi);
        let model = Abm::new(AbmConfig::new(psi)).fit(&x).unwrap();
        assert!((model.total_size() as f64) <= cfg.size_bound(2));
        assert!(model.stats.degree_reached <= cfg.theorem_degree());
    }

    #[test]
    fn empty_data_errors() {
        assert!(Abm::new(AbmConfig::new(0.1)).fit(&Matrix::zeros(0, 2)).is_err());
    }
}

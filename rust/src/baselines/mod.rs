//! Baseline generator-constructing algorithms from the paper's §1.2/§6:
//! ABM (monomial-aware, SVD-based) and VCA (monomial-agnostic).

pub mod abm;
pub mod vca;

pub use abm::{Abm, AbmConfig};
pub use vca::{Vca, VcaConfig, VcaModel};

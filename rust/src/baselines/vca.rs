//! Vanishing Component Analysis (Livni et al. 2013) — the paper's
//! monomial-agnostic baseline.
//!
//! VCA constructs polynomials as linear combinations of *polynomials*
//! (not monomials): per degree d, candidates are products of F₁ × F_{d−1}
//! entries, projected against the span of all non-vanishing polynomials
//! so far, then eigendecomposed; small-eigenvalue directions become
//! vanishing components, the rest are normalized to unit evaluation norm
//! and join F_d.  Polynomials are stored as an op-DAG ([`VcaNode`]) so
//! they can be evaluated on unseen data (transform/test time).
//!
//! The spurious-vanishing problem the paper discusses (§1.2, Table 3's
//! spam row) is inherent to this normalization and intentionally left in.

use crate::backend::ColumnStore;
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::linalg::dot;
use crate::linalg::eigen::sym_eig;
use crate::oavi::driver::FitStats;
use crate::util::timer::Timer;

/// One node of the polynomial DAG.
#[derive(Clone, Debug)]
pub enum VcaNode {
    /// constant-1 polynomial.
    One,
    /// input feature x_j.
    Feature(usize),
    /// pointwise product of two earlier nodes.
    Product(usize, usize),
    /// Σ w_i · node_i.
    LinComb(Vec<(f64, usize)>),
}

/// VCA configuration.
#[derive(Clone, Copy, Debug)]
pub struct VcaConfig {
    /// vanishing parameter ψ (MSE of the *unnormalized* component).
    pub psi: f64,
    pub max_degree: u32,
    /// cap on candidates per degree (guards the combinatorial blow-up the
    /// paper observes on spam; overflow is truncated deterministically).
    pub max_candidates: usize,
}

impl VcaConfig {
    pub fn new(psi: f64) -> Self {
        VcaConfig { psi, max_degree: 12, max_candidates: 3_000 }
    }
}

/// Fitted VCA model.
#[derive(Clone, Debug)]
pub struct VcaModel {
    nodes: Vec<VcaNode>,
    /// vanishing components (node ids) — the generators.
    pub vanishing: Vec<usize>,
    /// per-degree non-vanishing components (node ids) — the F sets.
    pub f_sets: Vec<Vec<usize>>,
    /// degree of each node (parallel to `nodes`).
    degrees: Vec<u32>,
    pub stats: FitStats,
}

impl VcaModel {
    /// |V| + Σ_d |F_d| — the paper's |G|+|O| analogue for VCA.
    pub fn total_size(&self) -> usize {
        self.vanishing.len() + self.f_sets.iter().map(|f| f.len()).sum::<usize>()
    }

    pub fn n_generators(&self) -> usize {
        self.vanishing.len()
    }

    /// Average degree of the vanishing components (Table 3 "Degree").
    pub fn avg_degree(&self) -> f64 {
        if self.vanishing.is_empty() {
            return 0.0;
        }
        self.vanishing.iter().map(|&i| self.degrees[i] as f64).sum::<f64>()
            / self.vanishing.len() as f64
    }

    /// (SPAR) over the LinComb coefficients of the vanishing components.
    pub fn sparsity(&self) -> f64 {
        let (mut gz, mut ge) = (0usize, 0usize);
        for &v in &self.vanishing {
            if let VcaNode::LinComb(terms) = &self.nodes[v] {
                ge += terms.len();
                gz += terms.iter().filter(|(w, _)| *w == 0.0).count();
            }
        }
        if ge == 0 {
            0.0
        } else {
            gz as f64 / ge as f64
        }
    }

    /// Evaluate every node over `x` (memoized DAG walk) into the shared
    /// column currency — one [`ColumnStore`] column per node, built
    /// through a single reused scratch buffer.
    fn eval_store(&self, x: &Matrix) -> ColumnStore {
        let m = x.rows();
        let mut store = ColumnStore::new(m, 1);
        let mut buf = vec![0.0f64; m];
        for node in &self.nodes {
            match node {
                VcaNode::One => buf.fill(1.0),
                VcaNode::Feature(j) => {
                    for (i, v) in buf.iter_mut().enumerate() {
                        *v = x.get(i, *j);
                    }
                }
                VcaNode::Product(a, b) => {
                    for s in 0..store.n_shards() {
                        let (va, vb) = (store.col_shard(*a, s), store.col_shard(*b, s));
                        for (k, i) in store.shard_range(s).enumerate() {
                            buf[i] = va[k] * vb[k];
                        }
                    }
                }
                VcaNode::LinComb(terms) => {
                    buf.fill(0.0);
                    for (w, idx) in terms {
                        if *w == 0.0 {
                            continue;
                        }
                        for s in 0..store.n_shards() {
                            let src = store.col_shard(*idx, s);
                            for (k, i) in store.shard_range(s).enumerate() {
                                buf[i] += w * src[k];
                            }
                        }
                    }
                }
            }
            store.push_col(&buf);
        }
        store
    }

    /// |g(x)| for every vanishing component — the (FT) feature block.
    pub fn transform(&self, x: &Matrix) -> Matrix {
        let store = self.eval_store(x);
        let m = x.rows();
        let mut out = Matrix::zeros(m, self.vanishing.len());
        for (gi, &nid) in self.vanishing.iter().enumerate() {
            for s in 0..store.n_shards() {
                let col = store.col_shard(nid, s);
                for (k, i) in store.shard_range(s).enumerate() {
                    out.set(i, gi, col[k].abs());
                }
            }
        }
        out
    }

    /// MSE of every vanishing component on `x`.
    pub fn mse_on(&self, x: &Matrix) -> Vec<f64> {
        let store = self.eval_store(x);
        let m = x.rows() as f64;
        self.vanishing
            .iter()
            .map(|&nid| store.dot_cols(nid, nid) / m)
            .collect()
    }
}

/// The VCA algorithm.
pub struct Vca {
    config: VcaConfig,
}

impl Vca {
    pub fn new(config: VcaConfig) -> Self {
        Vca { config }
    }

    pub fn fit(&self, x: &Matrix) -> Result<VcaModel> {
        let cfg = self.config;
        let timer = Timer::start();
        let m = x.rows();
        let n = x.cols();
        if m == 0 || n == 0 {
            return Err(AviError::Data("VCA fit: empty data".into()));
        }

        let mut nodes: Vec<VcaNode> = Vec::new();
        let mut degrees: Vec<u32> = Vec::new();
        let mut evals: Vec<Vec<f64>> = Vec::new(); // training evaluations per node
        let push =
            |nodes: &mut Vec<VcaNode>, degrees: &mut Vec<u32>, evals: &mut Vec<Vec<f64>>,
             node: VcaNode, deg: u32, ev: Vec<f64>| {
                nodes.push(node);
                degrees.push(deg);
                evals.push(ev);
                nodes.len() - 1
            };

        let one = push(&mut nodes, &mut degrees, &mut evals, VcaNode::One, 0, vec![1.0; m]);
        // f0 = 1/√m — unit-norm constant component
        let inv_sqrt_m = 1.0 / (m as f64).sqrt();
        let f0 = push(
            &mut nodes,
            &mut degrees,
            &mut evals,
            VcaNode::LinComb(vec![(inv_sqrt_m, one)]),
            0,
            vec![inv_sqrt_m; m],
        );

        // orthonormal basis of span(F): node ids whose eval vectors are
        // orthonormal (f0 plus everything appended below)
        let mut f_basis: Vec<usize> = vec![f0];
        let mut f_sets: Vec<Vec<usize>> = vec![vec![f0]];
        let mut vanishing: Vec<usize> = Vec::new();
        let mut stats = FitStats::default();

        for d in 1..=cfg.max_degree {
            // ---- candidates
            let mut cands: Vec<usize> = Vec::new();
            if d == 1 {
                for j in 0..n {
                    let ev = x.col(j);
                    let id = push(
                        &mut nodes,
                        &mut degrees,
                        &mut evals,
                        VcaNode::Feature(j),
                        1,
                        ev,
                    );
                    cands.push(id);
                }
            } else {
                let f1 = f_sets[1].clone();
                let fprev = f_sets[d as usize - 1].clone();
                'outer: for &a in &f1 {
                    for &b in &fprev {
                        let ev: Vec<f64> =
                            (0..m).map(|i| evals[a][i] * evals[b][i]).collect();
                        let id = push(
                            &mut nodes,
                            &mut degrees,
                            &mut evals,
                            VcaNode::Product(a, b),
                            d,
                            ev,
                        );
                        cands.push(id);
                        if cands.len() >= cfg.max_candidates {
                            break 'outer;
                        }
                    }
                }
            }
            if cands.is_empty() {
                break;
            }
            stats.degree_reached = d;
            stats.oracle_calls += 1; // one eigendecomposition per degree

            // ---- project against span(F)
            let mut proj_ids: Vec<usize> = Vec::with_capacity(cands.len());
            for &c in &cands {
                let mut terms = vec![(1.0, c)];
                let mut ev = evals[c].clone();
                for &f in &f_basis {
                    let w = dot(&evals[c], &evals[f]);
                    if w != 0.0 {
                        terms.push((-w, f));
                        for (e, fe) in ev.iter_mut().zip(evals[f].iter()) {
                            *e -= w * fe;
                        }
                    }
                }
                let id = push(
                    &mut nodes,
                    &mut degrees,
                    &mut evals,
                    VcaNode::LinComb(terms),
                    d,
                    ev,
                );
                proj_ids.push(id);
            }

            // ---- eigendecompose the candidate Gram
            let k = proj_ids.len();
            let mut gram = Matrix::zeros(k, k);
            for i in 0..k {
                for j in i..k {
                    let v = dot(&evals[proj_ids[i]], &evals[proj_ids[j]]);
                    gram.set(i, j, v);
                    gram.set(j, i, v);
                }
            }
            let eig = sym_eig(&gram, 40)?;

            let mut new_f: Vec<usize> = Vec::new();
            for (ei, &lam) in eig.values.iter().enumerate() {
                let lam = lam.max(0.0);
                let w_col = eig.vectors.col(ei);
                // component p = Σ_j w_j · proj_j ; ‖p(X)‖² = λ
                let mse = lam / m as f64;
                if mse <= cfg.psi {
                    let terms: Vec<(f64, usize)> = w_col
                        .iter()
                        .zip(proj_ids.iter())
                        .map(|(w, &id)| (*w, id))
                        .collect();
                    let mut ev = vec![0.0; m];
                    for (w, id) in &terms {
                        for (e, s) in ev.iter_mut().zip(evals[*id].iter()) {
                            *e += w * s;
                        }
                    }
                    let id = push(
                        &mut nodes,
                        &mut degrees,
                        &mut evals,
                        VcaNode::LinComb(terms),
                        d,
                        ev,
                    );
                    vanishing.push(id);
                } else {
                    // normalize to unit evaluation norm → joins F_d
                    let s = lam.sqrt();
                    let terms: Vec<(f64, usize)> = w_col
                        .iter()
                        .zip(proj_ids.iter())
                        .map(|(w, &id)| (*w / s, id))
                        .collect();
                    let mut ev = vec![0.0; m];
                    for (w, id) in &terms {
                        for (e, src) in ev.iter_mut().zip(evals[*id].iter()) {
                            *e += w * src;
                        }
                    }
                    let id = push(
                        &mut nodes,
                        &mut degrees,
                        &mut evals,
                        VcaNode::LinComb(terms),
                        d,
                        ev,
                    );
                    new_f.push(id);
                }
            }
            f_basis.extend(new_f.iter().copied());
            let stop = new_f.is_empty();
            f_sets.push(new_f);
            if stop {
                break;
            }
        }

        stats.wall_secs = timer.secs();
        Ok(VcaModel { nodes, vanishing, f_sets, degrees, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn circle(m: usize, seed: u64) -> Matrix {
        // unit circle scaled into [0,1]²: (x−.5)² + (y−.5)² = 0.16
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        for i in 0..m {
            let th = rng.uniform() * std::f64::consts::TAU;
            x.set(i, 0, 0.5 + 0.4 * th.cos());
            x.set(i, 1, 0.5 + 0.4 * th.sin());
        }
        x
    }

    #[test]
    fn finds_circle_generator() {
        let x = circle(200, 1);
        let model = Vca::new(VcaConfig::new(1e-6)).fit(&x).unwrap();
        assert!(!model.vanishing.is_empty());
        // must vanish out-of-sample
        let fresh = circle(100, 2);
        let best = model
            .mse_on(&fresh)
            .into_iter()
            .fold(f64::INFINITY, f64::min);
        assert!(best < 1e-6, "best out-sample mse {best}");
        // the circle relation is degree 2
        assert!(model.avg_degree() >= 2.0);
    }

    #[test]
    fn training_mse_respects_psi() {
        let mut rng = Rng::new(3);
        let mut x = Matrix::zeros(80, 3);
        for i in 0..80 {
            for j in 0..3 {
                x.set(i, j, rng.uniform());
            }
        }
        let psi = 0.02;
        let model = Vca::new(VcaConfig::new(psi)).fit(&x).unwrap();
        for mse in model.mse_on(&x) {
            assert!(mse <= psi * (1.0 + 1e-6) + 1e-12, "training mse {mse} > ψ");
        }
    }

    #[test]
    fn transform_columns_match_generator_count() {
        let x = circle(100, 4);
        let model = Vca::new(VcaConfig::new(1e-4)).fit(&x).unwrap();
        let t = model.transform(&x);
        assert_eq!(t.cols(), model.n_generators());
        assert_eq!(t.rows(), 100);
        for v in t.data() {
            assert!(*v >= 0.0);
        }
    }

    #[test]
    fn f_vectors_are_orthonormal_on_train() {
        let x = circle(150, 5);
        let model = Vca::new(VcaConfig::new(1e-5)).fit(&x).unwrap();
        let store = model.eval_store(&x);
        let basis: Vec<usize> = model.f_sets.iter().flatten().copied().collect();
        for (ai, &a) in basis.iter().enumerate() {
            for &b in basis.iter().skip(ai) {
                let d = store.dot_cols(a, b);
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!(
                    (d - expect).abs() < 1e-6,
                    "⟨f{a}, f{b}⟩ = {d}, expected {expect}"
                );
            }
        }
    }

    #[test]
    fn monomial_agnostic_feature_permutation_invariance() {
        // VCA's output sizes are invariant to feature permutation
        let x = circle(120, 6);
        let model_a = Vca::new(VcaConfig::new(1e-5)).fit(&x).unwrap();
        let mut xp = Matrix::zeros(120, 2);
        for i in 0..120 {
            xp.set(i, 0, x.get(i, 1));
            xp.set(i, 1, x.get(i, 0));
        }
        let model_b = Vca::new(VcaConfig::new(1e-5)).fit(&xp).unwrap();
        assert_eq!(model_a.n_generators(), model_b.n_generators());
        assert_eq!(model_a.total_size(), model_b.total_size());
    }

    #[test]
    fn empty_data_errors() {
        assert!(Vca::new(VcaConfig::new(0.1)).fit(&Matrix::zeros(0, 2)).is_err());
    }
}

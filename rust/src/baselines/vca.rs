//! Vanishing Component Analysis (Livni et al. 2013) — the paper's
//! monomial-agnostic baseline.
//!
//! VCA constructs polynomials as linear combinations of *polynomials*
//! (not monomials): per degree d, candidates are products of F₁ × F_{d−1}
//! entries, projected against the span of all non-vanishing polynomials
//! so far, then eigendecomposed; small-eigenvalue directions become
//! vanishing components, the rest are normalized to unit evaluation norm
//! and join F_d.  Polynomials are stored as an op-DAG ([`VcaNode`]) so
//! they can be evaluated on unseen data (transform/test time).
//!
//! Backend-generic like OAVI/ABM: the two O(m·k) hot spots — projecting
//! candidates against span(F) and the candidate Gram — are panel shapes,
//! so they run through [`ComputeBackend::gram_panel`] batches over
//! [`ColumnStore`]s sized by [`ComputeBackend::preferred_shards`]:
//! projections as chunked store-vs-panel blocks against the orthonormal
//! F basis (one backend call per chunk instead of one per candidate),
//! and the per-degree candidate Gram as ONE panel cross-Gram pass whose
//! upper triangle is mirrored (the per-shard kernels are
//! elementwise-commutative, so the mirror is bitwise exact).  The
//! pre-panel per-candidate flow survives as
//! [`Vca::fit_with_backend_per_candidate`] and is pinned bitwise equal
//! in `rust/tests/runtime_parity.rs`.  Results are deterministic per
//! shard count, and native ↔ sharded are bit-identical for a fixed
//! shard count (the data-plane contract).
//!
//! The spurious-vanishing problem the paper discusses (§1.2, Table 3's
//! spam row) is inherent to this normalization and intentionally left in.

use crate::backend::{
    CandidatePanel, ColumnStore, ComputeBackend, CrossMode, NativeBackend, NumericsMode,
};
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::linalg::eigen::sym_eig;
use crate::oavi::driver::FitStats;

/// Candidate columns per projection-panel chunk: bounds the transient
/// m×chunk panel copy while keeping per-chunk backend calls rare.
/// Chunking is bitwise-neutral (each candidate's projection weights are
/// an independent panel column).
const VCA_PANEL_CHUNK: usize = 512;

/// One node of the polynomial DAG.
#[derive(Clone, Debug)]
pub enum VcaNode {
    /// constant-1 polynomial.
    One,
    /// input feature x_j.
    Feature(usize),
    /// pointwise product of two earlier nodes.
    Product(usize, usize),
    /// Σ w_i · node_i.
    LinComb(Vec<(f64, usize)>),
}

/// VCA configuration.
#[derive(Clone, Copy, Debug)]
pub struct VcaConfig {
    /// vanishing parameter ψ (MSE of the *unnormalized* component).
    pub psi: f64,
    pub max_degree: u32,
    /// cap on candidates per degree (guards the combinatorial blow-up the
    /// paper observes on spam; overflow is truncated deterministically).
    pub max_candidates: usize,
}

impl VcaConfig {
    pub fn new(psi: f64) -> Self {
        VcaConfig { psi, max_degree: 12, max_candidates: 3_000 }
    }
}

/// Fitted VCA model.
#[derive(Clone, Debug)]
pub struct VcaModel {
    nodes: Vec<VcaNode>,
    /// vanishing components (node ids) — the generators.
    pub vanishing: Vec<usize>,
    /// per-degree non-vanishing components (node ids) — the F sets.
    pub f_sets: Vec<Vec<usize>>,
    /// degree of each node (parallel to `nodes`).
    degrees: Vec<u32>,
    /// input feature dimension the DAG was fitted against (bounds every
    /// `Feature` index; persisted so loads can validate).
    n_vars: usize,
    pub stats: FitStats,
}

impl VcaModel {
    /// Rebuild a model from persisted parts (the op-DAG, the component id
    /// lists, per-node degrees, and the input feature dimension),
    /// validating DAG well-formedness and feature-index bounds.
    pub fn from_parts(
        nodes: Vec<VcaNode>,
        vanishing: Vec<usize>,
        f_sets: Vec<Vec<usize>>,
        degrees: Vec<u32>,
        n_vars: usize,
    ) -> Result<VcaModel> {
        if nodes.len() != degrees.len() {
            return Err(AviError::Data(format!(
                "VCA model: {} nodes but {} degrees",
                nodes.len(),
                degrees.len()
            )));
        }
        if n_vars == 0 {
            return Err(AviError::Data("VCA model: n_vars must be ≥ 1".into()));
        }
        let n = nodes.len();
        for (i, node) in nodes.iter().enumerate() {
            let ok = match node {
                VcaNode::One => true,
                // bound feature reads so a loaded model can never index
                // past the data matrix at transform time
                VcaNode::Feature(j) => *j < n_vars,
                VcaNode::Product(a, b) => *a < i && *b < i,
                VcaNode::LinComb(terms) => terms.iter().all(|(_, id)| *id < i),
            };
            if !ok {
                return Err(AviError::Data(format!(
                    "VCA model: node {i} references a later node or an out-of-range feature"
                )));
            }
        }
        if vanishing.iter().any(|&v| v >= n)
            || f_sets.iter().flatten().any(|&f| f >= n)
        {
            return Err(AviError::Data("VCA model: component id out of range".into()));
        }
        Ok(VcaModel { nodes, vanishing, f_sets, degrees, n_vars, stats: FitStats::default() })
    }

    /// The polynomial op-DAG (persistence/introspection).
    pub fn nodes(&self) -> &[VcaNode] {
        &self.nodes
    }

    /// Input feature dimension the model was fitted against.
    pub fn n_vars(&self) -> usize {
        self.n_vars
    }

    /// Per-node degrees, parallel to [`VcaModel::nodes`].
    pub fn degrees(&self) -> &[u32] {
        &self.degrees
    }

    /// |V| + Σ_d |F_d| — the paper's |G|+|O| analogue for VCA.
    pub fn total_size(&self) -> usize {
        self.vanishing.len() + self.f_sets.iter().map(|f| f.len()).sum::<usize>()
    }

    pub fn n_generators(&self) -> usize {
        self.vanishing.len()
    }

    /// Average degree of the vanishing components (Table 3 "Degree").
    pub fn avg_degree(&self) -> f64 {
        if self.vanishing.is_empty() {
            return 0.0;
        }
        self.vanishing.iter().map(|&i| self.degrees[i] as f64).sum::<f64>()
            / self.vanishing.len() as f64
    }

    /// (SPAR) over the LinComb coefficients of the vanishing components.
    pub fn sparsity(&self) -> f64 {
        let (mut gz, mut ge) = (0usize, 0usize);
        for &v in &self.vanishing {
            if let VcaNode::LinComb(terms) = &self.nodes[v] {
                ge += terms.len();
                gz += terms.iter().filter(|(w, _)| *w == 0.0).count();
            }
        }
        if ge == 0 {
            0.0
        } else {
            gz as f64 / ge as f64
        }
    }

    /// Evaluate every node over `x` (memoized DAG walk) into the shared
    /// column currency — one [`ColumnStore`] column per node, built
    /// through a single reused scratch buffer.  Per-element accumulation
    /// order is shard-independent, so the evaluations are bitwise
    /// identical for every shard count.
    fn eval_store(&self, x: &Matrix, n_shards: usize) -> ColumnStore {
        let m = x.rows();
        let mut store = ColumnStore::new(m, n_shards);
        let mut buf = vec![0.0f64; m];
        for node in &self.nodes {
            match node {
                VcaNode::One => buf.fill(1.0),
                VcaNode::Feature(j) => {
                    for (i, v) in buf.iter_mut().enumerate() {
                        *v = x.get(i, *j);
                    }
                }
                VcaNode::Product(a, b) => {
                    for s in 0..store.n_shards() {
                        let lease = store.lease(s);
                        let (va, vb) = (lease.col(*a), lease.col(*b));
                        for (k, i) in store.shard_range(s).enumerate() {
                            buf[i] = va[k] * vb[k];
                        }
                    }
                }
                VcaNode::LinComb(terms) => {
                    buf.fill(0.0);
                    for (w, idx) in terms {
                        if *w == 0.0 {
                            continue;
                        }
                        for s in 0..store.n_shards() {
                            let lease = store.lease(s);
                            let src = lease.col(*idx);
                            for (k, i) in store.shard_range(s).enumerate() {
                                buf[i] += w * src[k];
                            }
                        }
                    }
                }
            }
            store.push_col(&buf);
        }
        store
    }

    /// |g(x)| for every vanishing component — the (FT) feature block —
    /// with the DAG evaluation store sharded to the backend's preference.
    pub fn transform_with(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
        self.transform_sharded(x, backend.preferred_shards(x.rows()))
    }

    /// [`VcaModel::transform_with`] on the native reference backend.
    pub fn transform(&self, x: &Matrix) -> Matrix {
        self.transform_sharded(x, 1)
    }

    /// [`VcaModel::transform_with`] written directly into a column range
    /// of the caller's concatenated m×`stride` feature slab — the
    /// per-class write path of the pipeline's (FT) concatenation.  The
    /// DAG evaluation is per-element shard-independent, so the written
    /// cells are bitwise identical to [`VcaModel::transform_with`]'s.
    pub fn transform_into(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        let store = self.eval_store(x, backend.preferred_shards(x.rows()));
        for (gi, &nid) in self.vanishing.iter().enumerate() {
            for s in 0..store.n_shards() {
                let lease = store.lease(s);
                let col = lease.col(nid);
                for (k, i) in store.shard_range(s).enumerate() {
                    out[i * stride + col_off + gi] = col[k].abs();
                }
            }
        }
    }

    fn transform_sharded(&self, x: &Matrix, n_shards: usize) -> Matrix {
        let store = self.eval_store(x, n_shards);
        let m = x.rows();
        let mut out = Matrix::zeros(m, self.vanishing.len());
        for (gi, &nid) in self.vanishing.iter().enumerate() {
            for s in 0..store.n_shards() {
                let lease = store.lease(s);
                let col = lease.col(nid);
                for (k, i) in store.shard_range(s).enumerate() {
                    out.set(i, gi, col[k].abs());
                }
            }
        }
        out
    }

    /// MSE of every vanishing component on `x`.
    pub fn mse_on(&self, x: &Matrix) -> Vec<f64> {
        let store = self.eval_store(x, 1);
        let m = x.rows() as f64;
        self.vanishing
            .iter()
            .map(|&nid| store.dot_cols(nid, nid) / m)
            .collect()
    }
}

/// The VCA algorithm.
pub struct Vca {
    config: VcaConfig,
}

impl Vca {
    pub fn new(config: VcaConfig) -> Self {
        Vca { config }
    }

    pub fn config(&self) -> &VcaConfig {
        &self.config
    }

    /// Fit with the native streaming backend.
    pub fn fit(&self, x: &Matrix) -> Result<VcaModel> {
        self.fit_with_backend(x, &NativeBackend)
    }

    /// Fit with an explicit streaming backend: candidate projections and
    /// the per-degree candidate Gram run through
    /// [`ComputeBackend::gram_panel`] batches, so `--backend sharded`
    /// accelerates VCA the same way it accelerates OAVI/ABM.
    pub fn fit_with_backend(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> Result<VcaModel> {
        self.fit_impl(x, backend, true)
    }

    /// Legacy correctness reference: one `gram_stats` call per candidate
    /// projection and per candidate-Gram row.  Bitwise identical to
    /// [`Vca::fit_with_backend`] (pinned in `tests/runtime_parity.rs`).
    pub fn fit_with_backend_per_candidate(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> Result<VcaModel> {
        self.fit_impl(x, backend, false)
    }

    fn fit_impl(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
        panels: bool,
    ) -> Result<VcaModel> {
        let cfg = self.config;
        let m = x.rows();
        let n = x.cols();
        if m == 0 || n == 0 {
            return Err(AviError::Data("VCA fit: empty data".into()));
        }
        let n_shards = backend.preferred_shards(m);

        let mut nodes: Vec<VcaNode> = Vec::new();
        let mut degrees: Vec<u32> = Vec::new();
        let mut evals: Vec<Vec<f64>> = Vec::new(); // training evaluations per node
        let push =
            |nodes: &mut Vec<VcaNode>, degrees: &mut Vec<u32>, evals: &mut Vec<Vec<f64>>,
             node: VcaNode, deg: u32, ev: Vec<f64>| {
                nodes.push(node);
                degrees.push(deg);
                evals.push(ev);
                nodes.len() - 1
            };

        let one = push(&mut nodes, &mut degrees, &mut evals, VcaNode::One, 0, vec![1.0; m]);
        // f0 = 1/√m — unit-norm constant component
        let inv_sqrt_m = 1.0 / (m as f64).sqrt();
        let f0 = push(
            &mut nodes,
            &mut degrees,
            &mut evals,
            VcaNode::LinComb(vec![(inv_sqrt_m, one)]),
            0,
            vec![inv_sqrt_m; m],
        );

        // orthonormal basis of span(F): node ids whose eval vectors are
        // orthonormal (f0 plus everything appended below).  `f_store`
        // mirrors `f_basis` as backend-ready columns for the projection
        // kernel.
        let mut f_basis: Vec<usize> = vec![f0];
        let mut f_store = ColumnStore::new(m, n_shards);
        f_store.push_col(&evals[f0]);
        let mut f_sets: Vec<Vec<usize>> = vec![vec![f0]];
        let mut vanishing: Vec<usize> = Vec::new();
        let mut stats = FitStats::default();

        for d in 1..=cfg.max_degree {
            // ---- candidates
            let mut cands: Vec<usize> = Vec::new();
            if d == 1 {
                for j in 0..n {
                    let ev = x.col(j);
                    let id = push(
                        &mut nodes,
                        &mut degrees,
                        &mut evals,
                        VcaNode::Feature(j),
                        1,
                        ev,
                    );
                    cands.push(id);
                }
            } else {
                let f1 = f_sets[1].clone();
                let fprev = f_sets[d as usize - 1].clone();
                'outer: for &a in &f1 {
                    for &b in &fprev {
                        let ev: Vec<f64> =
                            (0..m).map(|i| evals[a][i] * evals[b][i]).collect();
                        let id = push(
                            &mut nodes,
                            &mut degrees,
                            &mut evals,
                            VcaNode::Product(a, b),
                            d,
                            ev,
                        );
                        cands.push(id);
                        if cands.len() >= cfg.max_candidates {
                            break 'outer;
                        }
                    }
                }
            }
            if cands.is_empty() {
                break;
            }
            stats.degree_reached = d;
            stats.oracle_calls += 1; // one eigendecomposition per degree

            // ---- project against span(F): the weight vectors ⟨cand, f_k⟩
            // over the whole basis are store-vs-panel blocks (A = the
            // orthonormal-basis store) — the backend hot spot.  Panel
            // path: one gram_panel call per candidate chunk; legacy
            // path: one gram_stats call per candidate.
            fn project(
                c: usize,
                ws: &[f64],
                f_basis: &[usize],
                evals: &[Vec<f64>],
            ) -> (Vec<(f64, usize)>, Vec<f64>) {
                let mut terms = vec![(1.0, c)];
                let mut ev = evals[c].clone();
                for (&f, &w) in f_basis.iter().zip(ws.iter()) {
                    if w != 0.0 {
                        terms.push((-w, f));
                        for (e, fe) in ev.iter_mut().zip(evals[f].iter()) {
                            *e -= w * fe;
                        }
                    }
                }
                (terms, ev)
            }
            let mut proj_ids: Vec<usize> = Vec::with_capacity(cands.len());
            // projected columns mirror into a CandidatePanel (panel path:
            // feeds the one cross-Gram pass) or a ColumnStore (legacy
            // path: feeds the per-candidate Gram rows)
            let mut proj_panel = CandidatePanel::new_like(&f_store);
            let mut proj_store = ColumnStore::new(m, n_shards);
            if panels {
                // same memory clamp as OAVI/ABM: never let the transient
                // m×chunk panel copy exceed the ~256MB budget at large m
                let chunk_cols = CandidatePanel::budget_cols(VCA_PANEL_CHUNK, m);
                for chunk in cands.chunks(chunk_cols) {
                    let mut cand_panel = CandidatePanel::new_like(&f_store);
                    for &c in chunk {
                        cand_panel.push_col(&evals[c]);
                    }
                    // projections need no cross block — skip the k×k triangle
                    let ws_all = backend.gram_panel(
                        &f_store,
                        &cand_panel,
                        CrossMode::Skip,
                        NumericsMode::Exact,
                    );
                    stats.panel_passes += 1;
                    stats.panel_cols += chunk.len();
                    for (idx, &c) in chunk.iter().enumerate() {
                        let (terms, ev) = project(c, ws_all.atb_col(idx), &f_basis, &evals);
                        proj_panel.push_col(&ev);
                        let id = push(
                            &mut nodes,
                            &mut degrees,
                            &mut evals,
                            VcaNode::LinComb(terms),
                            d,
                            ev,
                        );
                        proj_ids.push(id);
                    }
                }
            } else {
                for &c in &cands {
                    let (ws, _btb) = backend.gram_stats(&f_store, &evals[c]);
                    let (terms, ev) = project(c, &ws, &f_basis, &evals);
                    proj_store.push_col(&ev);
                    let id = push(
                        &mut nodes,
                        &mut degrees,
                        &mut evals,
                        VcaNode::LinComb(terms),
                        d,
                        ev,
                    );
                    proj_ids.push(id);
                }
            }

            // ---- eigendecompose the candidate Gram.  Panel path: ONE
            // cross-Gram pass over the projection panel, upper triangle
            // mirrored (the per-shard kernels are elementwise-commutative
            // in their two operands, so the mirror carries exactly the
            // bits the legacy per-row computation produces — at half the
            // FLOPs and one backend call instead of k).
            let k = proj_ids.len();
            let mut gram = Matrix::zeros(k, k);
            if panels {
                let empty = ColumnStore::new(m, n_shards);
                let ps =
                    backend.gram_panel(&empty, &proj_panel, CrossMode::Eager, NumericsMode::Exact);
                stats.panel_passes += 1;
                stats.panel_cols += k;
                for i in 0..k {
                    for j in i..k {
                        let v = ps.cross_at(i, j);
                        gram.set(i, j, v);
                        gram.set(j, i, v);
                    }
                }
            } else {
                for (i, &pid) in proj_ids.iter().enumerate() {
                    let (row, _btb) = backend.gram_stats(&proj_store, &evals[pid]);
                    gram.row_mut(i).copy_from_slice(&row);
                }
            }
            let eig = sym_eig(&gram, 40)?;

            let mut new_f: Vec<usize> = Vec::new();
            for (ei, &lam) in eig.values.iter().enumerate() {
                let lam = lam.max(0.0);
                let w_col = eig.vectors.col(ei);
                // component p = Σ_j w_j · proj_j ; ‖p(X)‖² = λ
                let mse = lam / m as f64;
                if mse <= cfg.psi {
                    let terms: Vec<(f64, usize)> = w_col
                        .iter()
                        .zip(proj_ids.iter())
                        .map(|(w, &id)| (*w, id))
                        .collect();
                    let mut ev = vec![0.0; m];
                    for (w, id) in &terms {
                        for (e, s) in ev.iter_mut().zip(evals[*id].iter()) {
                            *e += w * s;
                        }
                    }
                    let id = push(
                        &mut nodes,
                        &mut degrees,
                        &mut evals,
                        VcaNode::LinComb(terms),
                        d,
                        ev,
                    );
                    vanishing.push(id);
                } else {
                    // normalize to unit evaluation norm → joins F_d
                    let s = lam.sqrt();
                    let terms: Vec<(f64, usize)> = w_col
                        .iter()
                        .zip(proj_ids.iter())
                        .map(|(w, &id)| (*w / s, id))
                        .collect();
                    let mut ev = vec![0.0; m];
                    for (w, id) in &terms {
                        for (e, src) in ev.iter_mut().zip(evals[*id].iter()) {
                            *e += w * src;
                        }
                    }
                    let id = push(
                        &mut nodes,
                        &mut degrees,
                        &mut evals,
                        VcaNode::LinComb(terms),
                        d,
                        ev,
                    );
                    new_f.push(id);
                }
            }
            for &id in &new_f {
                f_store.push_col(&evals[id]);
            }
            f_basis.extend(new_f.iter().copied());
            let stop = new_f.is_empty();
            f_sets.push(new_f);
            if stop {
                break;
            }
        }

        Ok(VcaModel { nodes, vanishing, f_sets, degrees, n_vars: n, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ShardedBackend;
    use crate::util::rng::Rng;

    fn circle(m: usize, seed: u64) -> Matrix {
        // unit circle scaled into [0,1]²: (x−.5)² + (y−.5)² = 0.16
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        for i in 0..m {
            let th = rng.uniform() * std::f64::consts::TAU;
            x.set(i, 0, 0.5 + 0.4 * th.cos());
            x.set(i, 1, 0.5 + 0.4 * th.sin());
        }
        x
    }

    #[test]
    fn finds_circle_generator() {
        let x = circle(200, 1);
        let model = Vca::new(VcaConfig::new(1e-6)).fit(&x).unwrap();
        assert!(!model.vanishing.is_empty());
        // must vanish out-of-sample
        let fresh = circle(100, 2);
        let best = model
            .mse_on(&fresh)
            .into_iter()
            .fold(f64::INFINITY, f64::min);
        assert!(best < 1e-6, "best out-sample mse {best}");
        // the circle relation is degree 2
        assert!(model.avg_degree() >= 2.0);
    }

    #[test]
    fn training_mse_respects_psi() {
        let mut rng = Rng::new(3);
        let mut x = Matrix::zeros(80, 3);
        for i in 0..80 {
            for j in 0..3 {
                x.set(i, j, rng.uniform());
            }
        }
        let psi = 0.02;
        let model = Vca::new(VcaConfig::new(psi)).fit(&x).unwrap();
        for mse in model.mse_on(&x) {
            assert!(mse <= psi * (1.0 + 1e-6) + 1e-12, "training mse {mse} > ψ");
        }
    }

    #[test]
    fn transform_columns_match_generator_count() {
        let x = circle(100, 4);
        let model = Vca::new(VcaConfig::new(1e-4)).fit(&x).unwrap();
        let t = model.transform(&x);
        assert_eq!(t.cols(), model.n_generators());
        assert_eq!(t.rows(), 100);
        for v in t.data() {
            assert!(*v >= 0.0);
        }
    }

    #[test]
    fn f_vectors_are_orthonormal_on_train() {
        let x = circle(150, 5);
        let model = Vca::new(VcaConfig::new(1e-5)).fit(&x).unwrap();
        let store = model.eval_store(&x, 1);
        let basis: Vec<usize> = model.f_sets.iter().flatten().copied().collect();
        for (ai, &a) in basis.iter().enumerate() {
            for &b in basis.iter().skip(ai) {
                let d = store.dot_cols(a, b);
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!(
                    (d - expect).abs() < 1e-6,
                    "⟨f{a}, f{b}⟩ = {d}, expected {expect}"
                );
            }
        }
    }

    #[test]
    fn monomial_agnostic_feature_permutation_invariance() {
        // VCA's output sizes are invariant to feature permutation
        let x = circle(120, 6);
        let model_a = Vca::new(VcaConfig::new(1e-5)).fit(&x).unwrap();
        let mut xp = Matrix::zeros(120, 2);
        for i in 0..120 {
            xp.set(i, 0, x.get(i, 1));
            xp.set(i, 1, x.get(i, 0));
        }
        let model_b = Vca::new(VcaConfig::new(1e-5)).fit(&xp).unwrap();
        assert_eq!(model_a.n_generators(), model_b.n_generators());
        assert_eq!(model_a.total_size(), model_b.total_size());
    }

    #[test]
    fn sharded_backend_fit_matches_native_statistics() {
        // same shard count ⇒ bitwise (pinned in runtime_parity.rs); here:
        // the structural outputs must agree across backends even when the
        // preferred shard counts differ
        let x = circle(300, 8);
        let native = Vca::new(VcaConfig::new(1e-5)).fit(&x).unwrap();
        let sharded_backend = ShardedBackend::with_min_rows(3, 32);
        let sharded =
            Vca::new(VcaConfig::new(1e-5)).fit_with_backend(&x, &sharded_backend).unwrap();
        assert_eq!(native.n_generators(), sharded.n_generators());
        assert_eq!(native.total_size(), sharded.total_size());
        let mn = native.mse_on(&x);
        let ms = sharded.mse_on(&x);
        for (a, b) in mn.iter().zip(ms.iter()) {
            assert!((a - b).abs() < 1e-9, "mse {a} vs {b}");
        }
    }

    #[test]
    fn from_parts_validates_dag_shape() {
        let x = circle(80, 9);
        let model = Vca::new(VcaConfig::new(1e-4)).fit(&x).unwrap();
        assert_eq!(model.n_vars(), 2);
        let rebuilt = VcaModel::from_parts(
            model.nodes().to_vec(),
            model.vanishing.clone(),
            model.f_sets.clone(),
            model.degrees().to_vec(),
            model.n_vars(),
        )
        .unwrap();
        assert_eq!(rebuilt.transform(&x).data(), model.transform(&x).data());
        // forward reference is rejected
        assert!(VcaModel::from_parts(
            vec![VcaNode::Product(0, 1), VcaNode::One],
            vec![],
            vec![],
            vec![0, 0],
            2,
        )
        .is_err());
        // feature index beyond the fitted dimension is rejected
        assert!(
            VcaModel::from_parts(vec![VcaNode::Feature(2)], vec![], vec![], vec![1], 2).is_err()
        );
        // out-of-range component id is rejected
        assert!(VcaModel::from_parts(vec![VcaNode::One], vec![3], vec![], vec![0], 2).is_err());
        // arity mismatch is rejected
        assert!(VcaModel::from_parts(vec![VcaNode::One], vec![], vec![], vec![], 2).is_err());
    }

    #[test]
    fn empty_data_errors() {
        assert!(Vca::new(VcaConfig::new(0.1)).fit(&Matrix::zeros(0, 2)).is_err());
    }
}

//! Hand-rolled property-testing harness (the `proptest` crate is not
//! available in this offline environment).
//!
//! Usage:
//! ```ignore
//! property(64, |rng| {
//!     let n = rng.below(10) + 1;
//!     // ... generate a case, return Err(msg) on violation
//!     Ok(())
//! });
//! ```
//! Each case gets an independently seeded [`Rng`]; on failure the seed is
//! reported so the case can be replayed deterministically.

use crate::util::rng::Rng;

/// Run `cases` randomized cases of `prop`; panic with the failing seed on
/// the first violation.
pub fn property(cases: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    property_seeded(0xA1B2_C3D4, cases, prop)
}

/// Like [`property`] but with an explicit base seed (for replaying).
pub fn property_seeded(
    base_seed: u64,
    cases: u64,
    prop: impl Fn(&mut Rng) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two floats are close (absolute + relative), returning a
/// property-friendly error.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol}, scale {scale})"))
    }
}

/// Assert slices are elementwise close.
pub fn all_close(a: &[f64], b: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        close(*x, *y, tol, &format!("{what}[{i}]"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        property(32, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        property(8, |_| Err("always fails".into()));
    }

    #[test]
    fn close_accepts_relative_tolerance() {
        close(1000.0, 1000.1, 1e-3, "x").unwrap();
        assert!(close(1.0, 2.0, 1e-3, "x").is_err());
    }

    #[test]
    fn all_close_checks_lengths() {
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-9, "v").is_err());
        all_close(&[1.0, 2.0], &[1.0, 2.0], 1e-9, "v").unwrap();
    }
}

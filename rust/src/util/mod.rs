//! Small shared utilities: deterministic RNG, timing, formatting, a
//! hand-rolled property-testing helper (proptest is unavailable offline).

pub mod proptest;
pub mod rng;
pub mod timer;

/// Format a float like the paper's tables (`3.1e+00` style).
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0.0e+00".to_string();
    }
    let sign = if x < 0.0 { "-" } else { "" };
    let ax = x.abs();
    let exp = ax.log10().floor() as i32;
    let mant = ax / 10f64.powi(exp);
    // rounding may push the mantissa to 10.0
    let (mant, exp) = if mant >= 9.95 { (1.0, exp + 1) } else { (mant, exp) };
    format!("{sign}{mant:.1}e{}{:02}", if exp < 0 { "-" } else { "+" }, exp.abs())
}

/// Escape a string for embedding inside a hand-rolled JSON document
/// (quotes, backslashes, and control characters).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let mu = mean(xs);
    (xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (copies and sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Binomial coefficient C(n, k) with saturation, in f64 (Theorem 4.3 bound
/// can overflow u64 for large n, D).
pub fn binomial_f64(n: u64, k: u64) -> f64 {
    let k = k.min(n - k.min(n));
    let mut acc = 1.0f64;
    for i in 0..k {
        acc *= (n - i) as f64 / (i + 1) as f64;
        if !acc.is_finite() {
            return f64::INFINITY;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_small() {
        assert_eq!(binomial_f64(5, 2), 10.0);
        assert_eq!(binomial_f64(10, 0), 1.0);
        assert_eq!(binomial_f64(10, 10), 1.0);
        assert_eq!(binomial_f64(6, 3), 20.0);
    }

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(0.0), "0.0e+00");
        assert_eq!(sci(3.1), "3.1e+00");
        assert_eq!(sci(160.0), "1.6e+02");
        assert_eq!(sci(0.0015), "1.5e-03");
        assert_eq!(sci(-0.0015), "-1.5e-03");
        assert_eq!(sci(9.99), "1.0e+01");
    }
}

//! Wall-clock timing helpers used by the pipeline and bench harness.

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed nanoseconds.
    pub fn nanos(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::hint::black_box((0..10_000).sum::<u64>());
        assert!(t.secs() >= 0.0);
        assert!(t.nanos() > 0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}

//! Deterministic, seedable RNG (xoshiro256**) — `rand` is unavailable in
//! this offline environment, and determinism across runs matters for the
//! paper-reproduction benches anyway.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction; any u64 seed is fine (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // take the top 53 bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (caches the second draw).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid log(0)
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A fresh RNG stream derived from this one (for per-worker seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_moments() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}

//! # avi-scale
//!
//! Production-grade reproduction of *“Approximate Vanishing Ideal
//! Computations at Scale”* (Wirth, Kera, Pokutta — ICLR 2023): the Oracle
//! Approximate Vanishing Ideal algorithm (OAVI) with Blended Pairwise
//! Conditional Gradients (BPCG) and Inverse Hessian Boosting (IHB/WIHB),
//! plus every substrate the paper depends on — convex solvers, baselines
//! (ABM, VCA), linear/kernel SVMs, dataset generators, Pearson ordering,
//! the Algorithm-2 classification pipeline, and a serving-style
//! coordinator.
//!
//! ## Architecture (store → backend → estimator → pipeline)
//!
//! The crate is four layers, each consuming only the one below:
//!
//! * **Store** — [`backend::ColumnStore`]: the row-sharded column-major
//!   evaluation store, the only column currency above `linalg`.  The
//!   per-shard kernels (`gram_partial`, `transform_block`) live next to
//!   it so every execution strategy runs identical per-shard code.
//!   Shard blocks live behind a pluggable [`backend::ShardBacking`]
//!   (in-memory by default, or spilled to checksummed on-disk segments
//!   under an LRU resident-byte budget — [`backend::StoreMode`]); the
//!   [`storage`] module adds chunked CSV ingestion into manifest-backed
//!   dataset directories for the m ≫ RAM regime.
//! * **Backend** — [`backend::ComputeBackend`]: the execution strategy
//!   over a store.  [`backend::NativeBackend`] (sequential reference),
//!   [`backend::ShardedBackend`] (thread-pool map-reduce, bit-identical
//!   to native per shard count), or [`runtime::XlaBackend`] (AOT
//!   JAX/Pallas artifacts through the PJRT C API; f32, padded shapes).
//! * **Estimator** — [`estimator::VanishingIdealEstimator`]: the unified
//!   fit/transform surface.  OAVI variants ([`oavi::Oavi`]), ABM
//!   ([`baselines::abm::Abm`]), and VCA ([`baselines::vca::Vca`]) all
//!   fit through any backend and return
//!   [`estimator::FittedModel`] trait objects with a uniform
//!   [`estimator::FitReport`]; the typed
//!   [`estimator::EstimatorConfig`] builds them, and
//!   [`estimator::persist`] round-trips every fitted model (and whole
//!   pipelines) through one versioned envelope — JSON or the compact
//!   [`artifact::codec`] binary form, selected by a magic sniff; the
//!   checksummed [`artifact::ArtifactStore`] gives envelopes a durable
//!   `key@version` home.
//! * **Pipeline & serving** — [`pipeline`] (Algorithm 2: per-class fits
//!   → (FT) transform → ℓ1 SVM, mixed-method grid search, Table-3
//!   reporting) and the [`coordinator`] serving control plane
//!   (**front door → registry → router → service → backend**: the
//!   std-only TCP [`coordinator::FrontDoor`] speaking the framed
//!   [`coordinator::wire`] protocol with rate limits, deadlines, and
//!   typed error frames; versioned [`coordinator::ModelRegistry`],
//!   weighted-A/B + shadow [`coordinator::ModelRouter`], batched
//!   [`coordinator::TransformService`] speaking the typed
//!   `ServeRequest`/`ServeReply` protocol, all built through one
//!   [`coordinator::ServeConfig`]) are estimator-agnostic: they hold
//!   trait objects and never branch on the algorithm.
//!
//! Numeric hot spots (Gram updates, IHB solve/append, the (FT)
//! transform) are authored in JAX + Pallas and AOT-lowered to
//! `artifacts/*.hlo.txt`, which [`runtime::PjrtRuntime`] loads; Python
//! never runs at request time.  The native Rust path is the bit-level
//! correctness reference for shapes beyond the padded artifacts.
//!
//! ## Quickstart
//!
//! ```no_run
//! use avi_scale::backend::NativeBackend;
//! use avi_scale::data::synthetic::synthetic_dataset;
//! use avi_scale::estimator::EstimatorConfig;
//!
//! let ds = synthetic_dataset(10_000, 42);
//! // any estimator by name: cgavi-ihb, bpcgavi-wihb, abm, vca, ...
//! let cfg = EstimatorConfig::parse("cgavi-ihb", 0.005).unwrap();
//! let model = cfg.fit(&ds.class_matrix(0), &NativeBackend).unwrap();
//! let report = model.report();
//! println!("{}: |G| = {}, |G|+|O| = {} in {:.3}s",
//!     report.name(), report.n_generators, report.total_size(), report.wall_secs);
//! ```

pub mod artifact;
pub mod backend;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod estimator;
pub mod linalg;
pub mod oavi;
pub mod ordering;
pub mod pipeline;
pub mod poly;
pub mod runtime;
pub mod solvers;
pub mod storage;
pub mod svm;
pub mod util;

pub use error::{AviError, Result};

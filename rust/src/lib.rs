//! # avi-scale
//!
//! Production-grade reproduction of *“Approximate Vanishing Ideal
//! Computations at Scale”* (Wirth, Kera, Pokutta — ICLR 2023): the Oracle
//! Approximate Vanishing Ideal algorithm (OAVI) with Blended Pairwise
//! Conditional Gradients (BPCG) and Inverse Hessian Boosting (IHB/WIHB),
//! plus every substrate the paper depends on — convex solvers, baselines
//! (ABM, VCA), linear/kernel SVMs, dataset generators, Pearson ordering,
//! the Algorithm-2 classification pipeline, and a serving-style
//! coordinator.
//!
//! ## Architecture (three layers, AOT via PJRT)
//!
//! * **L3 (this crate)** — the framework: algorithm drivers, scheduling,
//!   CLI, metrics.  Owns the event loop; Python never runs at request
//!   time.  The data plane is the row-sharded
//!   [`backend::ColumnStore`] (the only evaluation-column currency)
//!   executed by a [`backend::ComputeBackend`]:
//!   [`backend::NativeBackend`] (sequential reference),
//!   [`backend::ShardedBackend`] (map-reduce over shards, bit-identical
//!   to native per shard count), or the PJRT path below.
//! * **L2/L1 (python/compile)** — the numeric hot spots (Gram updates,
//!   IHB solve/append, the (FT) feature transform) authored in JAX +
//!   Pallas and AOT-lowered to `artifacts/*.hlo.txt`, which
//!   [`runtime::PjrtRuntime`] loads and executes through the PJRT C API.
//!   A bit-compatible native Rust path ([`backend::NativeBackend`]) covers
//!   shapes beyond the padded artifacts and is the correctness reference.
//!
//! ## Quickstart
//!
//! ```no_run
//! use avi_scale::data::synthetic::synthetic_dataset;
//! use avi_scale::oavi::{Oavi, OaviConfig};
//!
//! let ds = synthetic_dataset(10_000, 42);
//! let cfg = OaviConfig::cgavi_ihb(0.005);
//! let model = Oavi::new(cfg).fit(&ds.class_matrix(0)).unwrap();
//! println!("|G| = {}, |O| = {}", model.generators.len(), model.o_terms.len());
//! ```

pub mod backend;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod linalg;
pub mod oavi;
pub mod ordering;
pub mod pipeline;
pub mod poly;
pub mod runtime;
pub mod solvers;
pub mod svm;
pub mod util;

pub use error::{AviError, Result};

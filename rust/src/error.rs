//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by avi-scale.
#[derive(Debug, Error)]
pub enum AviError {
    /// A linear-algebra precondition failed (singular matrix, dimension
    /// mismatch, non-PSD Gram, …).
    #[error("linear algebra error: {0}")]
    Linalg(String),

    /// The IHB Schur complement was non-positive — the appended column is
    /// (numerically) in the span of the existing evaluation matrix.  OAVI
    /// recovers by rebuilding the inverse via Cholesky with jitter.
    #[error("IHB append failed: Schur complement {0:.3e} <= 0")]
    SchurNotPositive(f64),

    /// A convex solver failed to make progress / hit a numerical issue.
    #[error("solver error: {0}")]
    Solver(String),

    /// Invalid configuration.
    #[error("config error: {0}")]
    Config(String),

    /// Dataset construction/loading problem.
    #[error("data error: {0}")]
    Data(String),

    /// PJRT runtime problems (missing artifact, compile/execute failure).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator/service failure (channel closed, worker panicked).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// IO.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, AviError>;

impl From<anyhow::Error> for AviError {
    fn from(e: anyhow::Error) -> Self {
        AviError::Runtime(format!("{e:#}"))
    }
}

//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — `thiserror` is unavailable in
//! the offline build environment, and the enum is small enough that the
//! derive buys nothing.

use std::fmt;

/// Errors surfaced by avi-scale.
#[derive(Debug)]
pub enum AviError {
    /// A linear-algebra precondition failed (singular matrix, dimension
    /// mismatch, non-PSD Gram, …).
    Linalg(String),

    /// The IHB Schur complement was non-positive — the appended column is
    /// (numerically) in the span of the existing evaluation matrix.  OAVI
    /// recovers by rebuilding the inverse via Cholesky with jitter.
    SchurNotPositive(f64),

    /// A convex solver failed to make progress / hit a numerical issue.
    Solver(String),

    /// Invalid configuration.
    Config(String),

    /// Dataset construction/loading problem.
    Data(String),

    /// PJRT runtime problems (missing artifact, compile/execute failure).
    Runtime(String),

    /// Coordinator/service failure (channel closed, worker panicked).
    Coordinator(String),

    /// Model-registry failure (unknown key/version, malformed spec,
    /// manifest naming a missing file).
    Registry(String),

    /// Storage-plane failure: corrupt or truncated shard segment,
    /// checksum mismatch, malformed dataset manifest.  Raised *before*
    /// any fit touches the data — a store that opens is trustworthy.
    Storage(String),

    /// Model-artifact failure: malformed or truncated binary envelope,
    /// artifact checksum mismatch, corrupt artifact-store manifest.
    /// Raised *before* a pushed or loaded model can route traffic — an
    /// artifact that decodes is byte-verified.
    Artifact(String),

    /// Network front-door failure: bind/connect errors, malformed or
    /// oversized wire frames, protocol-version mismatches, connection
    /// timeouts.  Always a typed reply or a closed socket — never a
    /// panic, never a hung peer.
    Net(String),

    /// A per-route token bucket turned the request away; retry later.
    RateLimited(String),

    /// IO.
    Io(std::io::Error),
}

impl fmt::Display for AviError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AviError::Linalg(m) => write!(f, "linear algebra error: {m}"),
            AviError::SchurNotPositive(s) => {
                write!(f, "IHB append failed: Schur complement {s:.3e} <= 0")
            }
            AviError::Solver(m) => write!(f, "solver error: {m}"),
            AviError::Config(m) => write!(f, "config error: {m}"),
            AviError::Data(m) => write!(f, "data error: {m}"),
            AviError::Runtime(m) => write!(f, "runtime error: {m}"),
            AviError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            AviError::Registry(m) => write!(f, "registry error: {m}"),
            AviError::Storage(m) => write!(f, "storage error: {m}"),
            AviError::Artifact(m) => write!(f, "artifact error: {m}"),
            AviError::Net(m) => write!(f, "network error: {m}"),
            AviError::RateLimited(m) => write!(f, "rate limited: {m}"),
            AviError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for AviError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AviError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for AviError {
    fn from(e: std::io::Error) -> Self {
        AviError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, AviError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_variants() {
        assert_eq!(AviError::Config("bad psi".into()).to_string(), "config error: bad psi");
        assert_eq!(
            AviError::SchurNotPositive(-1.5e-3).to_string(),
            "IHB append failed: Schur complement -1.500e-3 <= 0"
        );
        let io: AviError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("io error"));
        assert_eq!(
            AviError::Net("frame too large".into()).to_string(),
            "network error: frame too large"
        );
        assert_eq!(
            AviError::RateLimited("route 'm'".into()).to_string(),
            "rate limited: route 'm'"
        );
        assert_eq!(
            AviError::Storage("seg_0.bin checksum mismatch".into()).to_string(),
            "storage error: seg_0.bin checksum mismatch"
        );
        assert_eq!(
            AviError::Artifact("truncated envelope".into()).to_string(),
            "artifact error: truncated envelope"
        );
    }
}

//! Data-driven feature ordering (paper §5, Algorithm 5).
//!
//! Monomial-aware algorithms (OAVI, ABM) depend on the order of the
//! features.  Pearson ordering sorts features *increasingly* by their
//! total absolute Pearson correlation with all features, making the
//! output invariant to the input feature permutation; reverse-Pearson is
//! the Table-1 ablation.

use crate::backend::ColumnStore;
use crate::linalg::dense::Matrix;

/// The orderings studied in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureOrdering {
    /// Keep the dataset's native order (not data-driven).
    Native,
    /// Algorithm 5: ascending Σ_j |r_ij|.
    Pearson,
    /// Table 1 ablation: descending Σ_j |r_ij|.
    ReversePearson,
}

/// Pearson correlation coefficient between two equal-length vectors
/// (Definition 5.1).  Returns 0 for constant vectors.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (x, y) in a.iter().zip(b.iter()) {
        let dx = x - ma;
        let dy = y - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Pearson correlation between two columns of a [`ColumnStore`]
/// (two centered passes over the shard slices — same arithmetic as
/// [`pearson`], accumulated in shard order).
pub fn pearson_cols(store: &ColumnStore, i: usize, j: usize) -> f64 {
    let ma = store.col_mean(i);
    let mb = store.col_mean(j);
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for s in 0..store.n_shards() {
        let lease = store.lease(s);
        let (ci, cj) = (lease.col(i), lease.col(j));
        for (x, y) in ci.iter().zip(cj.iter()) {
            let dx = x - ma;
            let dy = y - mb;
            cov += dx * dy;
            va += dx * dx;
            vb += dy * dy;
        }
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Algorithm 5: the permutation that sorts features by ascending
/// `p_i = Σ_j |r_{c_i c_j}|` (ties broken by original index → the output
/// is a well-defined function of the data).
pub fn pearson_permutation(x: &Matrix, reverse: bool) -> Vec<usize> {
    let n = x.cols();
    let store = ColumnStore::from_matrix(x, 1);
    let mut p = vec![0.0; n];
    for i in 0..n {
        for j in 0..n {
            p[i] += pearson_cols(&store, i, j).abs();
        }
    }
    let mut perm: Vec<usize> = (0..n).collect();
    // total_cmp: bitwise identical to partial_cmp on the finite sums this
    // produces, and still a total order if a pathological input sneaks a
    // NaN through — ordering must never panic a fit
    perm.sort_by(|&a, &b| {
        let ord = p[a].total_cmp(&p[b]);
        let ord = if reverse { ord.reverse() } else { ord };
        ord.then(a.cmp(&b))
    });
    perm
}

/// Apply an ordering to a feature matrix (returns the permutation used).
pub fn order_features(x: &Matrix, ordering: FeatureOrdering) -> Vec<usize> {
    match ordering {
        FeatureOrdering::Native => (0..x.cols()).collect(),
        FeatureOrdering::Pearson => pearson_permutation(x, false),
        FeatureOrdering::ReversePearson => pearson_permutation(x, true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pearson_known_values() {
        let a = [1.0, 2.0, 3.0];
        assert!((pearson(&a, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&a, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&a, &[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn ordering_puts_least_correlated_first() {
        // features: f0 and f1 perfectly correlated, f2 independent noise
        let mut rng = Rng::new(3);
        let m = 500;
        let mut x = Matrix::zeros(m, 3);
        for i in 0..m {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, 1.0 - t);
            x.set(i, 2, rng.uniform());
        }
        let perm = pearson_permutation(&x, false);
        assert_eq!(perm[0], 2, "independent feature must come first: {perm:?}");
        let rev = pearson_permutation(&x, true);
        assert_eq!(rev[2], 2);
    }

    #[test]
    fn permutation_invariance() {
        // Algorithm 5's point: the *ordered* dataset is invariant to a
        // pre-permutation of the features.
        let mut rng = Rng::new(5);
        let m = 200;
        let mut x = Matrix::zeros(m, 4);
        for i in 0..m {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, t * t + 0.1 * rng.uniform());
            x.set(i, 2, rng.uniform());
            x.set(i, 3, 0.5 * t + 0.5 * rng.uniform());
        }
        let ds = crate::data::Dataset::new("t", x, vec![0; m], 1).unwrap();
        let perm_pre = [2usize, 0, 3, 1];
        let shuffled = ds.permute_features(&perm_pre);

        let o1 = order_features(&ds.x, FeatureOrdering::Pearson);
        let o2 = order_features(&shuffled.x, FeatureOrdering::Pearson);
        let a = ds.permute_features(&o1);
        let b = shuffled.permute_features(&o2);
        for j in 0..4 {
            for i in 0..5 {
                assert!(
                    (a.x.get(i, j) - b.x.get(i, j)).abs() < 1e-12,
                    "column {j} differs after ordering"
                );
            }
        }
    }

    #[test]
    fn pearson_cols_matches_slice_pearson_across_shard_counts() {
        let mut rng = Rng::new(9);
        let m = 120;
        let a: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let expect = pearson(&a, &b);
        for k in [1usize, 2, 3, 7] {
            let store = crate::backend::ColumnStore::from_cols(&[a.clone(), b.clone()], k);
            let got = pearson_cols(&store, 0, 1);
            assert!((got - expect).abs() < 1e-12, "shards {k}: {got} vs {expect}");
        }
    }

    #[test]
    fn native_is_identity() {
        let x = Matrix::zeros(3, 5);
        assert_eq!(order_features(&x, FeatureOrdering::Native), vec![0, 1, 2, 3, 4]);
    }
}

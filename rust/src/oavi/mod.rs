//! The Oracle Approximate Vanishing Ideal algorithm (Algorithm 1) with
//! Inverse Hessian Boosting (§4.4) — the paper's core contribution.

pub mod config;
pub mod driver;

pub use config::{IhbMode, OaviConfig};
pub use driver::{FitStats, Oavi, OaviModel};

//! Model persistence: save/load fitted generator sets as a simple JSON
//! document (hand-rolled — serde is unavailable offline).
//!
//! The format stores the order ideal's recipes (not raw exponent vectors)
//! so a loaded model evaluates through exactly the same
//! one-multiply-per-term path as a freshly fitted one.

use std::fs;
use std::path::Path;

use crate::error::{AviError, Result};
use crate::poly::eval::{Recipe, TermSet};
use crate::poly::poly::{Generator, GeneratorSet};

/// Serialize a generator set to a JSON string.
pub fn to_json(gs: &GeneratorSet) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"n_vars\": {},\n", gs.o_terms.n_vars()));
    // recipes: [[-1,-1]] for One, [parent, var] otherwise
    out.push_str("  \"o_recipes\": [");
    for i in 0..gs.o_terms.len() {
        if i > 0 {
            out.push(',');
        }
        match gs.o_terms.recipe(i) {
            Recipe::One => out.push_str("[-1,-1]"),
            Recipe::Product { parent, var } => {
                out.push_str(&format!("[{parent},{var}]"))
            }
        }
    }
    out.push_str("],\n  \"generators\": [\n");
    for (gi, g) in gs.generators.iter().enumerate() {
        if gi > 0 {
            out.push_str(",\n");
        }
        let coeffs: Vec<String> = g.coeffs.iter().map(|c| format!("{c:e}")).collect();
        out.push_str(&format!(
            "    {{\"parent\": {}, \"var\": {}, \"mse\": {:e}, \"coeffs\": [{}]}}",
            g.leading_parent,
            g.leading_var,
            g.mse,
            coeffs.join(",")
        ));
    }
    out.push_str("\n  ]\n}\n");
    out
}

/// Parse a generator set back from [`to_json`] output.
pub fn from_json(text: &str) -> Result<GeneratorSet> {
    let n_vars = extract_usize(text, "\"n_vars\":")?;
    let recipes_src = extract_array(text, "\"o_recipes\":")?;
    let mut o = TermSet::with_one(n_vars);
    let pairs = parse_pairs(&recipes_src)?;
    if pairs.first() != Some(&(-1, -1)) {
        return Err(AviError::Data("persist: first recipe must be the One term".into()));
    }
    for (i, pair) in pairs.into_iter().enumerate() {
        match pair {
            (-1, -1) => {
                if i != 0 {
                    return Err(AviError::Data("persist: One recipe not first".into()));
                }
            }
            (p, v) => {
                if p < 0 || v < 0 {
                    return Err(AviError::Data("persist: bad recipe".into()));
                }
                o.push_product(p as usize, v as usize)?;
            }
        }
    }
    let mut generators = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find("{\"parent\":") {
        let obj_src = &rest[pos..];
        let end = obj_src
            .find('}')
            .ok_or_else(|| AviError::Data("persist: unterminated generator".into()))?;
        let obj = &obj_src[..=end];
        let parent = extract_usize(obj, "\"parent\":")?;
        let var = extract_usize(obj, "\"var\":")?;
        let mse = extract_f64(obj, "\"mse\":")?;
        let coeff_src = extract_array(obj, "\"coeffs\":")?;
        let coeffs: Vec<f64> = if coeff_src.trim().is_empty() {
            Vec::new()
        } else {
            coeff_src
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<f64>()
                        .map_err(|e| AviError::Data(format!("persist: coeff {e}")))
                })
                .collect::<Result<_>>()?
        };
        if parent >= o.len() || var >= n_vars {
            return Err(AviError::Data("persist: leading recipe out of range".into()));
        }
        let leading = o.terms()[parent].times_var(var);
        generators.push(Generator {
            coeffs,
            leading,
            leading_parent: parent,
            leading_var: var,
            mse,
        });
        rest = &rest[pos + end..];
    }
    Ok(GeneratorSet { o_terms: o, generators })
}

/// Save to a file.
pub fn save(gs: &GeneratorSet, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    fs::write(path, to_json(gs))?;
    Ok(())
}

/// Load from a file.
pub fn load(path: &Path) -> Result<GeneratorSet> {
    from_json(&fs::read_to_string(path)?)
}

fn extract_usize(text: &str, key: &str) -> Result<usize> {
    extract_f64(text, key).map(|v| v as usize)
}

fn extract_f64(text: &str, key: &str) -> Result<f64> {
    let pos = text
        .find(key)
        .ok_or_else(|| AviError::Data(format!("persist: missing {key}")))?;
    let rest = &text[pos + key.len()..];
    let end = rest
        .find([',', '}', '\n', ']'])
        .unwrap_or(rest.len());
    rest[..end]
        .trim()
        .parse::<f64>()
        .map_err(|e| AviError::Data(format!("persist: {key} parse: {e}")))
}

fn extract_array(text: &str, key: &str) -> Result<String> {
    let pos = text
        .find(key)
        .ok_or_else(|| AviError::Data(format!("persist: missing {key}")))?;
    let rest = &text[pos + key.len()..];
    let start = rest
        .find('[')
        .ok_or_else(|| AviError::Data("persist: missing [".to_string()))?;
    // match brackets (arrays may nest one level: recipes)
    let mut depth = 0usize;
    for (i, ch) in rest[start..].char_indices() {
        match ch {
            '[' => depth += 1,
            ']' => {
                depth -= 1;
                if depth == 0 {
                    return Ok(rest[start + 1..start + i].to_string());
                }
            }
            _ => {}
        }
    }
    Err(AviError::Data("persist: unbalanced array".into()))
}

fn parse_pairs(src: &str) -> Result<Vec<(i64, i64)>> {
    let mut out = Vec::new();
    let mut rest = src;
    while let Some(start) = rest.find('[') {
        let end = rest[start..]
            .find(']')
            .ok_or_else(|| AviError::Data("persist: unbalanced pair".into()))?
            + start;
        let inner = &rest[start + 1..end];
        let parts: Vec<&str> = inner.split(',').map(|p| p.trim()).collect();
        if parts.len() != 2 {
            return Err(AviError::Data("persist: pair arity".into()));
        }
        let a = parts[0]
            .parse::<i64>()
            .map_err(|e| AviError::Data(format!("persist: {e}")))?;
        let b = parts[1]
            .parse::<i64>()
            .map_err(|e| AviError::Data(format!("persist: {e}")))?;
        out.push((a, b));
        rest = &rest[end + 1..];
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Matrix;
    use crate::oavi::{Oavi, OaviConfig};
    use crate::util::rng::Rng;

    fn fitted() -> GeneratorSet {
        let mut rng = Rng::new(5);
        let mut x = Matrix::zeros(120, 2);
        for i in 0..120 {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, t * t);
        }
        Oavi::new(OaviConfig::cgavi_ihb(0.001)).fit(&x).unwrap().generator_set()
    }

    #[test]
    fn roundtrip_preserves_structure_and_numerics() {
        let gs = fitted();
        let json = to_json(&gs);
        let back = from_json(&json).unwrap();
        assert_eq!(back.o_terms.len(), gs.o_terms.len());
        assert_eq!(back.generators.len(), gs.generators.len());
        assert_eq!(back.o_terms.terms(), gs.o_terms.terms());
        // identical transforms on fresh data
        let mut rng = Rng::new(9);
        let mut z = Matrix::zeros(30, 2);
        for i in 0..30 {
            for j in 0..2 {
                z.set(i, j, rng.uniform());
            }
        }
        let a = gs.transform(&z);
        let b = back.transform(&z);
        for i in 0..30 {
            for j in 0..a.cols() {
                assert!((a.get(i, j) - b.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn file_roundtrip() {
        let gs = fitted();
        let path = std::env::temp_dir().join("avi_scale_persist/model.json");
        save(&gs, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.total_size(), gs.total_size());
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_json("{}").is_err());
        assert!(from_json("{\"n_vars\": 2, \"o_recipes\": [[0,0]]}").is_err()); // bad first recipe
        assert!(from_json("not json at all").is_err());
    }
}

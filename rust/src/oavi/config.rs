//! OAVI configuration: solver, IHB mode, vanishing parameter, safeguards.

use crate::backend::backing::validate_store_mode;
use crate::backend::{NumericsMode, StoreMode};
use crate::error::{AviError, Result};
use crate::solvers::SolverKind;

/// How Inverse Hessian Boosting is used (paper §4.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IhbMode {
    /// Pure solver from cold start (PCGAVI / BPCGAVI in Figures 2–3).
    None,
    /// Full IHB: closed-form `y0 = −(AᵀA)^{-1}Aᵀb` decides vanishing and
    /// supplies the coefficients; the solver is only a fallback
    /// (CGAVI-IHB, AGDAVI-IHB).
    Ihb,
    /// Weak IHB: the closed form decides *whether* a term vanishes, and
    /// each accepted generator is re-solved with BPCG from a vertex to
    /// obtain sparse coefficients (BPCGAVI-WIHB, §4.4.3).
    Wihb,
}

/// Full OAVI configuration.
#[derive(Clone, Copy, Debug)]
pub struct OaviConfig {
    /// Vanishing parameter ψ ≥ 0 (Definition 2.2).
    pub psi: f64,
    /// ℓ1 bound τ on generator coefficient vectors; (CCOP) radius is τ−1.
    /// Paper default: 1000.
    pub tau: f64,
    /// The convex oracle.
    pub solver: SolverKind,
    /// IHB mode.
    pub ihb: IhbMode,
    /// Use the ℓ1-constrained problem (CCOP)?  Forced false for AGD
    /// (the paper's AGDAVI solves the unconstrained Line-7 problem).
    pub constrained: bool,
    /// Solver accuracy factor: ε = `eps_factor`·ψ (paper: 0.01).
    pub eps_factor: f64,
    /// Solver iteration cap (paper: 10,000).
    pub max_solver_iters: usize,
    /// Safety cap on the border degree (Theorem 4.3 bounds the true
    /// termination degree at D = ⌈−log ψ / log 4⌉; this cap only guards
    /// pathological configs).
    pub max_degree: u32,
    /// Safety cap on |O| (memory guard for adversarial data).
    pub max_o_terms: usize,
    /// Column cap per candidate-panel chunk: each degree-d border is
    /// processed in chunks of at most this many candidates through one
    /// `gram_panel` pass (clamped to ≥ 1, and further capped by a ~256MB
    /// memory bound at large m — see
    /// `backend::CandidatePanel::budget_cols`).  Chunking changes
    /// dispatch granularity only; results are bitwise identical for any
    /// value.
    pub panel_budget_cols: usize,
    /// Panel-kernel numerics: [`NumericsMode::Exact`] (default, bitwise
    /// per-entry dot discipline) or the explicitly opt-in
    /// [`NumericsMode::Fast`] (f32-accumulated `Aᵀb`/diagonal under a
    /// measured error budget — see `fast_tol`).
    pub numerics: NumericsMode,
    /// Fast-mode error tolerance, relative to the largest sampled exact
    /// Gram entry: the driver measures max |Δ| between the fast and f64
    /// panel stats on a sampled sub-block and fails the fit if it
    /// exceeds `fast_tol · max(1, max|exact|)`.  Ignored in exact mode.
    pub fast_tol: f64,
    /// Working-store backing: [`StoreMode::Memory`] (default) or
    /// [`StoreMode::Spill`] — evaluation columns in checksummed on-disk
    /// segments under an LRU resident-byte budget.  Exact-mode results
    /// are bitwise identical either way for any fixed shard count.
    pub store: StoreMode,
}

impl OaviConfig {
    fn base(psi: f64, solver: SolverKind, ihb: IhbMode, constrained: bool) -> Self {
        OaviConfig {
            psi,
            tau: 1000.0,
            solver,
            ihb,
            constrained,
            eps_factor: 0.01,
            max_solver_iters: 10_000,
            max_degree: 12,
            max_o_terms: 5_000,
            panel_budget_cols: 512,
            numerics: NumericsMode::Exact,
            fast_tol: 1e-3,
            store: StoreMode::Memory,
        }
    }

    /// CGAVI-IHB — the paper's fastest variant (§4.4, Figure 4).
    pub fn cgavi_ihb(psi: f64) -> Self {
        Self::base(psi, SolverKind::Cg, IhbMode::Ihb, true)
    }

    /// AGDAVI-IHB — IHB with the unconstrained AGD oracle.
    pub fn agdavi_ihb(psi: f64) -> Self {
        Self::base(psi, SolverKind::Agd, IhbMode::Ihb, false)
    }

    /// BPCGAVI-WIHB — sparse generators at IHB-class speed (§4.4.3).
    pub fn bpcgavi_wihb(psi: f64) -> Self {
        Self::base(psi, SolverKind::Bpcg, IhbMode::Wihb, true)
    }

    /// BPCGAVI — pure BPCG from cold start (Figures 2–3 baseline).
    pub fn bpcgavi(psi: f64) -> Self {
        Self::base(psi, SolverKind::Bpcg, IhbMode::None, true)
    }

    /// PCGAVI — pure PCG from cold start (Figure 2 baseline).
    pub fn pcgavi(psi: f64) -> Self {
        Self::base(psi, SolverKind::Pcg, IhbMode::None, true)
    }

    /// CGAVI — vanilla Frank–Wolfe, cold start.
    pub fn cgavi(psi: f64) -> Self {
        Self::base(psi, SolverKind::Cg, IhbMode::None, true)
    }

    /// AGDAVI — unconstrained AGD, cold start.
    pub fn agdavi(psi: f64) -> Self {
        Self::base(psi, SolverKind::Agd, IhbMode::None, false)
    }

    /// Display name matching the paper's nomenclature.
    pub fn name(&self) -> String {
        let base = format!("{}AVI", self.solver.name());
        match self.ihb {
            IhbMode::None => base,
            IhbMode::Ihb => format!("{base}-IHB"),
            IhbMode::Wihb => format!("{base}-WIHB"),
        }
    }

    /// (CCOP) ball radius τ−1.
    pub fn radius(&self) -> f64 {
        self.tau - 1.0
    }

    /// Theorem 4.3 termination degree D = ⌈−log ψ / log 4⌉.
    pub fn theorem_degree(&self) -> u32 {
        if self.psi >= 1.0 {
            return 1;
        }
        ((-self.psi.ln()) / 4f64.ln()).ceil() as u32
    }

    /// Theorem 4.3 size bound C(D+n, D) on |G|+|O|.
    pub fn size_bound(&self, n_features: usize) -> f64 {
        let d = self.theorem_degree() as u64;
        crate::util::binomial_f64(d + n_features as u64, d)
    }

    /// Validate invariants.
    pub fn validate(&self) -> Result<()> {
        if self.psi < 0.0 || !self.psi.is_finite() {
            return Err(AviError::Config(format!("psi must be ≥ 0, got {}", self.psi)));
        }
        if self.constrained && self.tau < 2.0 {
            return Err(AviError::Config(format!("tau must be ≥ 2, got {}", self.tau)));
        }
        if self.ihb == IhbMode::Wihb && self.solver != SolverKind::Bpcg {
            return Err(AviError::Config(
                "WIHB re-solves with BPCG; configure solver = Bpcg".into(),
            ));
        }
        if self.constrained && self.solver == SolverKind::Agd {
            return Err(AviError::Config("AGD solves the unconstrained problem".into()));
        }
        if self.fast_tol <= 0.0 || !self.fast_tol.is_finite() {
            return Err(AviError::Config(format!(
                "fast_tol must be > 0 and finite, got {}",
                self.fast_tol
            )));
        }
        validate_store_mode(self.store)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_paper() {
        assert_eq!(OaviConfig::cgavi_ihb(0.01).name(), "CGAVI-IHB");
        assert_eq!(OaviConfig::agdavi_ihb(0.01).name(), "AGDAVI-IHB");
        assert_eq!(OaviConfig::bpcgavi_wihb(0.01).name(), "BPCGAVI-WIHB");
        assert_eq!(OaviConfig::bpcgavi(0.01).name(), "BPCGAVI");
        assert_eq!(OaviConfig::pcgavi(0.01).name(), "PCGAVI");
    }

    #[test]
    fn theorem_degree_examples() {
        // ψ = 0.005 ⇒ D = ⌈5.298/1.386⌉ = ⌈3.82⌉ = 4
        assert_eq!(OaviConfig::cgavi_ihb(0.005).theorem_degree(), 4);
        // ψ = 0.25 ⇒ D = ⌈1.386/1.386⌉ = 1
        assert_eq!(OaviConfig::cgavi_ihb(0.25).theorem_degree(), 1);
        assert_eq!(OaviConfig::cgavi_ihb(1.5).theorem_degree(), 1);
    }

    #[test]
    fn size_bound_matches_binomial() {
        let cfg = OaviConfig::cgavi_ihb(0.005); // D = 4
        assert_eq!(cfg.size_bound(3), 35.0); // C(7,4)
    }

    #[test]
    fn validation_catches_bad_configs() {
        assert!(OaviConfig::cgavi_ihb(-1.0).validate().is_err());
        let mut cfg = OaviConfig::cgavi_ihb(0.01);
        cfg.tau = 1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = OaviConfig::bpcgavi_wihb(0.01);
        cfg.solver = SolverKind::Cg;
        assert!(cfg.validate().is_err());
        let mut cfg = OaviConfig::cgavi_ihb(0.01);
        cfg.numerics = NumericsMode::Fast;
        cfg.fast_tol = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = OaviConfig::cgavi_ihb(0.01);
        cfg.store = StoreMode::Spill { budget_bytes: 0 };
        assert!(cfg.validate().is_err());
        cfg.store = StoreMode::spill_mb(64);
        assert!(cfg.validate().is_ok());
        assert!(OaviConfig::cgavi_ihb(0.01).validate().is_ok());
    }
}

//! OAVI driver — Algorithm 1 with the §4 scalability machinery, on a
//! **degree-batched candidate panel** data flow.
//!
//! Per degree d, the driver fills one [`crate::backend::CandidatePanel`]
//! with every `∂_d O` border column (evaluated from the parent columns
//! in one pass), makes **one** [`crate::backend::ComputeBackend::gram_panel`]
//! call per panel chunk (the ℓ×k store block + the k×k cross-Gram upper
//! triangle — one pool dispatch per chunk on the sharded backend instead
//! of one per candidate), and then walks the candidates in DegLex order:
//!
//! 1. **stats**: candidate c's `Aᵀb` is its cached panel column plus —
//!    for every earlier candidate of this chunk that joined O — the
//!    cached cross entry `C[i, c]`, appended in O(1) per pair with no
//!    data pass; `bᵀb` is the cross diagonal.
//! 2. **oracle**: with IHB, the closed form `c = −(AᵀA)^{-1}Aᵀb` plus
//!    residual decides vanishing in O(ℓ²); otherwise the configured
//!    Frank–Wolfe/AGD solver runs (with ψ-certificates for early exit;
//!    the unconstrained AGD path warm-starts from the previous oracle
//!    solution).
//! 3. **accept** → generator with LTC = 1 (WIHB: re-solve with BPCG from
//!    a vertex for sparsity); **reject** → u joins O: the inverse Gram
//!    is appended via Theorem 4.9 **consuming the same cached cross
//!    entries**, and the panel column is copied into the store
//!    shard-to-shard.
//!
//! Panels are chunked under `panel_budget_cols` (plus a ~256MB memory
//! cap) so `m × |∂d|` never blows up at m ≫ 1e5.  The pre-panel flow —
//! one `gram_stats` pass per border term — is kept as
//! [`Oavi::fit_with_backend_per_candidate`]: because every Gram entry in
//! both flows shares the per-entry dot discipline of
//! `backend/store.rs`, the two paths produce **bitwise identical**
//! models (pinned in `tests/runtime_parity.rs`).
//!
//! The (INF) guard (§4.4.3): if the closed-form solution leaves the
//! ℓ1-ball, IHB is disabled for the remainder of the fit (the paper's
//! "approach 2", which preserves the generalization bounds).

use crate::backend::{
    CandidatePanel, ColumnStore, ComputeBackend, CrossMode, NativeBackend, NumericsMode,
    PanelRecipe, PanelStats,
};
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::linalg::dot;
use crate::linalg::gram::GramState;
use crate::linalg::norm1;
use crate::oavi::config::{IhbMode, OaviConfig};
use crate::poly::border::{compute_border, BorderTerm};
use crate::poly::eval::TermSet;
use crate::poly::poly::{Generator, GeneratorSet};
use crate::solvers::{GramProblem, SolverKind, SolverParams, Termination};

/// Diagnostics accumulated over one fit.
///
/// Wall-clock lives in [`crate::estimator::FitReport`], which wraps these
/// counters and is measured uniformly for every estimator.
#[derive(Clone, Debug, Default)]
pub struct FitStats {
    /// Convex-oracle calls (= border terms processed = |G| + |O| − 1).
    pub oracle_calls: usize,
    /// Oracle calls answered by the IHB closed form alone.
    pub ihb_solves: usize,
    /// Full solver runs (cold or warm).
    pub solver_runs: usize,
    /// Total solver iterations.
    pub solver_iters: usize,
    /// Solver runs warm-started from the previous oracle solution
    /// (unconstrained AGD path — the paper's IHB idea applied to the
    /// post-(INF)/no-inverse regime).
    pub warm_starts: usize,
    /// WIHB sparse re-solves.
    pub wihb_resolves: usize,
    /// Theorem 4.9 appends that failed the Schur guard and fell back to a
    /// Cholesky rebuild.
    pub gram_rebuilds: usize,
    /// Whether (INF) disabled IHB mid-fit.
    pub inf_disabled_ihb: bool,
    /// Final border degree processed.
    pub degree_reached: u32,
    /// `gram_panel` passes (one per (degree, panel chunk); 0 on the
    /// legacy per-candidate path).
    pub panel_passes: usize,
    /// Candidate columns evaluated through panels (Σ chunk widths).
    pub panel_cols: usize,
    /// `Aᵀb` entries served from the cached panel cross-Gram instead of
    /// a data pass (one per (accepted, later-candidate) pair per chunk).
    pub cross_cache_hits: usize,
    /// Panel-kernel numerics this fit ran with.
    pub numerics: NumericsMode,
    /// Fast mode only: measured max |Δ| between the fast panel stats and
    /// the exact f64 reference on the sampled Gram sub-block (0 in exact
    /// mode).
    pub fast_max_abs_err: f64,
    /// Fast mode only: the error budget `fast_tol · max(1, max|exact|)`
    /// the measurement was asserted against (0 in exact mode).
    pub fast_err_budget: f64,
    /// Did the working store spill to disk ([`crate::backend::StoreMode::Spill`])?
    pub store_spilled: bool,
    /// Spill mode only: shard-block loads from segments (0 in memory mode).
    pub store_loads: u64,
    /// Spill mode only: loads of previously-resident blocks (evicted or
    /// invalidated by append, then needed again).
    pub store_reloads: u64,
    /// Spill mode only: LRU evictions under the resident-byte budget.
    pub store_evictions: u64,
    /// Spill mode only: high-water mark of resident shard bytes.
    pub store_peak_resident_bytes: u64,
}

/// Fitted OAVI output `(G, O)` plus diagnostics.
#[derive(Clone, Debug)]
pub struct OaviModel {
    pub generators: Vec<Generator>,
    pub o_terms: TermSet,
    pub config: OaviConfig,
    pub stats: FitStats,
    /// Final maintained Gram state `(B, N)` over the O columns — exposed
    /// so the panel parity suite can pin the inverse bitwise; `N` is the
    /// stale 1×1 seed when the config ran without inverse tracking.
    pub final_gram: GramState,
}

impl OaviModel {
    /// View as a [`GeneratorSet`] (evaluation/statistics API).
    pub fn generator_set(&self) -> GeneratorSet {
        GeneratorSet { o_terms: self.o_terms.clone(), generators: self.generators.clone() }
    }

    /// |G| + |O|.
    pub fn total_size(&self) -> usize {
        self.generators.len() + self.o_terms.len()
    }
}

/// Measured fast-mode error sample: recompute a sampled sub-block of the
/// first fast panel's Gram stats with the exact f64 kernels (same
/// shard-order accumulation as `gram_panel_seq`) and return
/// `(max |Δ|, max |exact|)`.  Sample = first `min(k, 4)` candidates ×
/// first `min(ℓ, 8)` store columns plus the panel diagonal — the entries
/// the oracle actually consumes.
fn fast_error_sample(
    cols: &ColumnStore,
    panel: &CandidatePanel,
    pstats: &PanelStats,
) -> (f64, f64) {
    let kk = panel.len().min(4);
    let jj = cols.len().min(8);
    let mut max_err = 0.0f64;
    let mut scale = 0.0f64;
    for c in 0..kk {
        for j in 0..jj {
            let mut exact = 0.0f64;
            for s in 0..cols.n_shards() {
                // lease per shard: works for spilled stores too (the
                // sample is tiny, so re-acquisition cost is noise)
                let lease = cols.lease(s);
                exact += dot(lease.col(j), panel.col_shard(c, s));
            }
            scale = scale.max(exact.abs());
            max_err = max_err.max((pstats.atb_col(c)[j] - exact).abs());
        }
        let mut exact_d = 0.0f64;
        for s in 0..panel.n_shards() {
            let bs = panel.col_shard(c, s);
            exact_d += dot(bs, bs);
        }
        scale = scale.max(exact_d.abs());
        max_err = max_err.max((pstats.btb(c) - exact_d).abs());
    }
    (max_err, scale)
}

/// The OAVI algorithm, generic over the streaming compute backend.
pub struct Oavi {
    config: OaviConfig,
}

impl Oavi {
    pub fn new(config: OaviConfig) -> Self {
        Oavi { config }
    }

    pub fn config(&self) -> &OaviConfig {
        &self.config
    }

    /// Fit on `x` (m×n, expected in [0,1]) with the native backend.
    pub fn fit(&self, x: &Matrix) -> Result<OaviModel> {
        self.fit_with_backend(x, &NativeBackend)
    }

    /// Fit with an explicit backend (native, sharded, or PJRT) through
    /// the degree-batched candidate-panel path — the default.
    pub fn fit_with_backend(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> Result<OaviModel> {
        self.fit_impl(x, backend, true)
    }

    /// Legacy correctness reference: one `gram_stats` pass per border
    /// term (the pre-panel data flow).  Bitwise identical to
    /// [`Oavi::fit_with_backend`] — the contract `tests/runtime_parity.rs`
    /// pins and `benches/micro_gram_panel.rs` measures against.
    pub fn fit_with_backend_per_candidate(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> Result<OaviModel> {
        self.fit_impl(x, backend, false)
    }

    fn fit_impl(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
        panels: bool,
    ) -> Result<OaviModel> {
        let cfg = self.config;
        cfg.validate()?;
        let m = x.rows();
        let n = x.cols();
        if m == 0 || n == 0 {
            return Err(AviError::Data("fit: empty data".into()));
        }

        let mut o = TermSet::with_one(n);
        // the store's shard count is the backend's intra-fit parallelism
        // knob; results are deterministic for a fixed shard count, and
        // (exact mode) bitwise identical across backing modes
        let mut cols =
            ColumnStore::with_ones_backed(m, backend.preferred_shards(m), cfg.store)?;
        let mut gram = if cfg.ihb == IhbMode::None {
            GramState::new_ones_b_only(m)
        } else {
            GramState::new_ones(m)
        };
        let mut generators: Vec<Generator> = Vec::new();
        let mut stats = FitStats { numerics: cfg.numerics, ..FitStats::default() };
        let mut ihb_active = cfg.ihb != IhbMode::None;
        let radius = cfg.radius();
        let solver_params = SolverParams {
            eps: cfg.eps_factor * cfg.psi.max(1e-12),
            max_iters: cfg.max_solver_iters,
            radius,
            psi: Some(cfg.psi),
        };
        // previous oracle solution for the unconstrained AGD warm start
        let mut agd_warm: Option<Vec<f64>> = None;

        if panels {
            let budget = CandidatePanel::budget_cols(cfg.panel_budget_cols, m);
            // one reused Aᵀb buffer: panel block prefix + cached cross tail
            let mut atb_buf: Vec<f64> = Vec::new();
            'degrees: for d in 1..=cfg.max_degree {
                let border = compute_border(&o, d);
                if border.is_empty() {
                    break;
                }
                stats.degree_reached = d;
                let mut start = 0usize;
                while start < border.len() {
                    let end = (start + budget).min(border.len());
                    let chunk = &border[start..end];
                    // evaluate the whole chunk from its parent columns in
                    // one pass, then ONE panel-Gram call for the chunk
                    let recipes: Vec<PanelRecipe> = chunk
                        .iter()
                        .map(|bt| PanelRecipe { parent: bt.parent, var: bt.var })
                        .collect();
                    let panel = CandidatePanel::from_recipes(&cols, x, &recipes);
                    // lazy cross: the O(k²) triangle is never computed up
                    // front — accepted candidates materialize their row on
                    // demand below, so ψ-regimes where most candidates
                    // vanish skip the triangle entirely (bitwise identical
                    // to the eager pass when rows ARE read)
                    let mut pstats =
                        backend.gram_panel(&cols, &panel, CrossMode::Lazy, cfg.numerics);
                    stats.panel_passes += 1;
                    stats.panel_cols += chunk.len();
                    if cfg.numerics == NumericsMode::Fast && stats.panel_passes == 1 {
                        // measured error budget (opt-in fast contract):
                        // recompute a sampled Gram sub-block with the exact
                        // f64 kernels and assert the deviation fits
                        let (max_err, scale) = fast_error_sample(&cols, &panel, &pstats);
                        let budget = cfg.fast_tol * scale.max(1.0);
                        stats.fast_max_abs_err = max_err;
                        stats.fast_err_budget = budget;
                        if max_err > budget {
                            return Err(AviError::Linalg(format!(
                                "fast numerics error budget exceeded: \
                                 max|Δ| = {max_err:.3e} > {budget:.3e} (fast_tol {})",
                                cfg.fast_tol
                            )));
                        }
                    }
                    // panel indices (in this chunk) that joined O, in
                    // acceptance order = store column order
                    let mut accepted: Vec<usize> = Vec::new();
                    for (ci, bt) in chunk.iter().enumerate() {
                        // within-degree dependence resolved incrementally:
                        // the store block is cached, each accepted earlier
                        // candidate contributes its cross-Gram entry in O(1)
                        atb_buf.clear();
                        atb_buf.extend_from_slice(pstats.atb_col(ci));
                        for &ai in &accepted {
                            atb_buf.push(pstats.cross_at(ai, ci));
                        }
                        stats.cross_cache_hits += accepted.len();
                        let btb = pstats.btb(ci);
                        stats.oracle_calls += 1;
                        let outcome = self.candidate_step(
                            bt,
                            &atb_buf,
                            btb,
                            &|| panel.col(ci),
                            &cols,
                            &mut gram,
                            &mut ihb_active,
                            &solver_params,
                            &mut stats,
                            &mut agd_warm,
                        )?;
                        match outcome {
                            Some(generator) => generators.push(generator),
                            None => {
                                cols.push_col_from_panel(&panel, ci);
                                o.push_product(bt.parent, bt.var)?;
                                // materialize this candidate's cross row
                                // (sequential, no pool dispatch): every
                                // later candidate of the chunk reads it,
                                // so no lazy work is ever wasted
                                pstats.ensure_cross_row(&panel, ci);
                                accepted.push(ci);
                                if o.len() >= cfg.max_o_terms {
                                    break 'degrees;
                                }
                            }
                        }
                    }
                    start = end;
                }
            }
        } else {
            // Perf pass #4, tightened by the ColumnStore refactor: ONE
            // candidate buffer for the whole fit.  Accepting a term into O
            // copies the buffer into the store's shard blocks (amortized
            // append) and reuses it — no allocation on either outcome.
            let mut cand_buf = vec![0.0f64; m];
            'degrees_legacy: for d in 1..=cfg.max_degree {
                let border = compute_border(&o, d);
                if border.is_empty() {
                    break;
                }
                stats.degree_reached = d;
                for bt in &border {
                    // candidate column b = parent(X) ⊙ x_var  — O(m)
                    cols.fill_product(bt.parent, x, bt.var, &mut cand_buf);
                    // streaming stats — O(mℓ) per candidate (the cost the
                    // panel path batches away)
                    let (atb, btb) = backend.gram_stats(&cols, &cand_buf);
                    stats.oracle_calls += 1;
                    let outcome = self.candidate_step(
                        bt,
                        &atb,
                        btb,
                        &|| cand_buf.clone(),
                        &cols,
                        &mut gram,
                        &mut ihb_active,
                        &solver_params,
                        &mut stats,
                        &mut agd_warm,
                    )?;
                    match outcome {
                        Some(generator) => generators.push(generator),
                        None => {
                            cols.push_col(&cand_buf);
                            o.push_product(bt.parent, bt.var)?;
                            if o.len() >= cfg.max_o_terms {
                                break 'degrees_legacy;
                            }
                        }
                    }
                }
            }
        }

        stats.store_spilled = cols.is_spilled();
        if let Some(c) = cols.backing_counters() {
            stats.store_loads = c.loads;
            stats.store_reloads = c.reloads;
            stats.store_evictions = c.evictions;
            stats.store_peak_resident_bytes = c.peak_resident_bytes;
        }
        Ok(OaviModel { generators, o_terms: o, config: cfg, stats, final_gram: gram })
    }

    /// One candidate: oracle → `Some(generator)` (vanishing) or `None`
    /// (the term belongs in O; `gram` has been extended via Theorem 4.9,
    /// consuming the caller's cached `Aᵀb`/`bᵀb`).  `cand` lazily
    /// materializes the full candidate column — touched only on the rare
    /// Schur-guard rebuild, so the panel path never pays a per-candidate
    /// O(m) copy.
    #[allow(clippy::too_many_arguments)]
    fn candidate_step(
        &self,
        bt: &BorderTerm,
        atb: &[f64],
        btb: f64,
        cand: &dyn Fn() -> Vec<f64>,
        cols: &ColumnStore,
        gram: &mut GramState,
        ihb_active: &mut bool,
        params: &SolverParams,
        stats: &mut FitStats,
        agd_warm: &mut Option<Vec<f64>>,
    ) -> Result<Option<Generator>> {
        let cfg = &self.config;
        let m = gram.samples();
        let (coeffs, mse) =
            self.oracle(gram, atb, btb, m, ihb_active, params, stats, agd_warm);
        if mse <= cfg.psi {
            // (ψ,1)-approximately vanishing generator found
            let coeffs = if cfg.ihb == IhbMode::Wihb {
                self.wihb_resolve(gram, atb, btb, m, params, coeffs, stats)
            } else {
                coeffs
            };
            Ok(Some(Generator {
                coeffs,
                leading: bt.term.clone(),
                leading_parent: bt.parent,
                leading_var: bt.var,
                mse,
            }))
        } else {
            // u joins O: Theorem 4.9 inverse append from the cached stats
            match gram.append(atb, btb) {
                Ok(()) => {}
                Err(AviError::SchurNotPositive(_)) => {
                    // numerically dependent column: rebuild from scratch
                    // with jitter (keeps OAVI running on adversarial /
                    // duplicated data)
                    stats.gram_rebuilds += 1;
                    let cand_col = cand();
                    *gram = GramState::from_store_with_candidate(cols, &cand_col)?;
                }
                Err(e) => return Err(e),
            }
            Ok(None)
        }
    }

    /// One oracle call: returns `(coeffs, MSE)` for the candidate term.
    #[allow(clippy::too_many_arguments)]
    fn oracle(
        &self,
        gram: &mut GramState,
        atb: &[f64],
        btb: f64,
        m: usize,
        ihb_active: &mut bool,
        params: &SolverParams,
        stats: &mut FitStats,
        agd_warm: &mut Option<Vec<f64>>,
    ) -> (Vec<f64>, f64) {
        let cfg = &self.config;
        if *ihb_active {
            let (c, resid) = gram.solve_closed_form(atb, btb);
            let mse = resid / m as f64;
            // (INF) guard for the constrained problem: the closed-form
            // optimum must lie inside the ℓ1-ball for IHB to stay sound.
            if cfg.constrained && norm1(&c) > params.radius {
                *ihb_active = false;
                stats.inf_disabled_ihb = true;
                // fall through to the solver below
            } else {
                stats.ihb_solves += 1;
                return (c, mse);
            }
        }
        let p = GramProblem { b: gram.b(), atb, btb, m };
        // Warm start (ISSUE 5 satellite): the paper's IHB is "hand the
        // oracle a strong starting point".  The unconstrained AGD path
        // has no feasibility requirement on y0, so the previous oracle
        // solution (zero-padded to the grown dimension) is always a
        // legal warm start; the constrained FW variants keep the cold
        // start — after (INF) the last point may lie outside the ℓ1
        // ball, which is exactly why IHB was disabled.
        let warm_agd = cfg.solver == SolverKind::Agd && !cfg.constrained;
        let res = match (warm_agd, agd_warm.as_ref()) {
            (true, Some(prev)) => {
                let mut y0 = vec![0.0f64; p.dim()];
                let len = prev.len().min(y0.len());
                y0[..len].copy_from_slice(&prev[..len]);
                stats.warm_starts += 1;
                cfg.solver.solve_warm(&p, params, &y0)
            }
            _ => cfg.solver.solve(&p, params),
        };
        stats.solver_runs += 1;
        stats.solver_iters += res.iters;
        if warm_agd {
            *agd_warm = Some(res.y.clone());
        }
        (res.y, res.f)
    }

    /// WIHB (§4.4.3): IHB already certified that the term vanishes; re-run
    /// BPCG from a vertex to get *sparse* coefficients.  Keeps the sparse
    /// solution only if it still vanishes (paranoia against loose solves).
    #[allow(clippy::too_many_arguments)]
    fn wihb_resolve(
        &self,
        gram: &GramState,
        atb: &[f64],
        btb: f64,
        m: usize,
        params: &SolverParams,
        dense_coeffs: Vec<f64>,
        stats: &mut FitStats,
    ) -> Vec<f64> {
        let p = GramProblem { b: gram.b(), atb, btb, m };
        let res = SolverKind::Bpcg.solve(&p, params);
        stats.wihb_resolves += 1;
        stats.solver_iters += res.iters;
        let sparse_ok = res.f <= self.config.psi
            || matches!(res.termination, Termination::TargetReached);
        if sparse_ok {
            res.y
        } else {
            dense_coeffs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Points on the parabola x1 = x0² (plus the ambient box): OAVI must
    /// find the generator x0² − x1 at degree 2 with ψ = 0.
    fn parabola_data(m: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        for i in 0..m {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, t * t);
        }
        x
    }

    #[test]
    fn finds_parabola_generator_exactly() {
        let x = parabola_data(100, 1);
        for cfg in [
            OaviConfig::cgavi_ihb(1e-8),
            OaviConfig::agdavi_ihb(1e-8),
            OaviConfig::bpcgavi(1e-8),
        ] {
            let model = Oavi::new(cfg).fit(&x).unwrap();
            // the relation x0² = x1 must be captured by some generator of
            // degree ≤ 2 with near-zero training MSE
            assert!(
                !model.generators.is_empty(),
                "{}: no generators found",
                cfg.name()
            );
            let best = model
                .generators
                .iter()
                .map(|g| g.mse)
                .fold(f64::INFINITY, f64::min);
            assert!(best <= 1e-8, "{}: best MSE {best}", cfg.name());
            // generators must vanish on fresh data from the same variety
            let x_test = parabola_data(50, 2);
            let gs = model.generator_set();
            for mse in gs.mse_on(&x_test) {
                assert!(mse <= 1e-6, "{}: out-sample MSE {mse}", cfg.name());
            }
        }
    }

    #[test]
    fn psi_zero_on_random_data_keeps_growing_until_cap_or_termination() {
        // random data has no exact structure: with ψ = tiny, O grows; with
        // ψ large, everything vanishes immediately.
        let mut rng = Rng::new(3);
        let mut x = Matrix::zeros(60, 2);
        for i in 0..60 {
            for j in 0..2 {
                x.set(i, j, rng.uniform());
            }
        }
        let loose = Oavi::new(OaviConfig::cgavi_ihb(0.9)).fit(&x).unwrap();
        // ψ close to 1: degree-1 terms already vanish (x ∈ [0,1] ⇒ MSE ≤ 1)
        assert!(loose.o_terms.len() <= 3);
        let tight = Oavi::new(OaviConfig::cgavi_ihb(1e-4)).fit(&x).unwrap();
        assert!(tight.total_size() > loose.total_size());
    }

    #[test]
    fn theorem_4_3_bounds_hold_on_random_data() {
        crate::util::proptest::property(8, |rng| {
            let n = 1 + rng.below(3);
            let m = 40 + rng.below(60);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let psi = [0.3, 0.1, 0.05][rng.below(3)];
            let cfg = OaviConfig::cgavi_ihb(psi);
            let model = Oavi::new(cfg).fit(&x).map_err(|e| e.to_string())?;
            let d_bound = cfg.theorem_degree();
            if model.stats.degree_reached > d_bound {
                return Err(format!(
                    "degree {} exceeds Theorem 4.3 bound {d_bound} (psi={psi})",
                    model.stats.degree_reached
                ));
            }
            let size_bound = cfg.size_bound(n);
            if (model.total_size() as f64) > size_bound {
                return Err(format!(
                    "|G|+|O| = {} exceeds bound {size_bound}",
                    model.total_size()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn generators_vanish_on_training_data() {
        crate::util::proptest::property(8, |rng| {
            let n = 1 + rng.below(3);
            let m = 30 + rng.below(40);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let psi = 0.05;
            let model = Oavi::new(OaviConfig::cgavi_ihb(psi))
                .fit(&x)
                .map_err(|e| e.to_string())?;
            let gs = model.generator_set();
            for (gi, mse) in gs.mse_on(&x).iter().enumerate() {
                // recomputed from scratch, must match the ψ certificate
                if *mse > psi * (1.0 + 1e-6) + 1e-10 {
                    return Err(format!("generator {gi} has training MSE {mse} > ψ"));
                }
            }
            // oracle calls = |G| + |O| − 1
            if model.stats.oracle_calls != model.total_size() - 1 {
                return Err(format!(
                    "oracle calls {} != |G|+|O|−1 = {}",
                    model.stats.oracle_calls,
                    model.total_size() - 1
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn wihb_produces_sparser_generators_than_ihb() {
        // structured data with redundancy: several vanishing directions
        let x = {
            let mut rng = Rng::new(7);
            let mut x = Matrix::zeros(200, 3);
            for i in 0..200 {
                let t = rng.uniform();
                x.set(i, 0, t);
                x.set(i, 1, (t * 1.1).min(1.0));
                x.set(i, 2, t * t);
            }
            x
        };
        let ihb = Oavi::new(OaviConfig::cgavi_ihb(0.001)).fit(&x).unwrap();
        let wihb = Oavi::new(OaviConfig::bpcgavi_wihb(0.001)).fit(&x).unwrap();
        let spar_ihb = ihb.generator_set().sparsity();
        let spar_wihb = wihb.generator_set().sparsity();
        assert!(
            spar_wihb >= spar_ihb,
            "WIHB sparsity {spar_wihb} < IHB sparsity {spar_ihb}"
        );
        assert!(wihb.stats.wihb_resolves == wihb.generators.len());
    }

    #[test]
    fn identical_output_cgavi_ihb_vs_agdavi_ihb() {
        // Paper §6.2: with coefficients inside the ball, CGAVI-IHB and
        // AGDAVI-IHB produce identical outputs (both return the closed form).
        let x = parabola_data(150, 11);
        let a = Oavi::new(OaviConfig::cgavi_ihb(0.005)).fit(&x).unwrap();
        let b = Oavi::new(OaviConfig::agdavi_ihb(0.005)).fit(&x).unwrap();
        assert_eq!(a.generators.len(), b.generators.len());
        assert_eq!(a.o_terms.len(), b.o_terms.len());
        for (ga, gb) in a.generators.iter().zip(b.generators.iter()) {
            assert_eq!(ga.leading, gb.leading);
            for (ca, cb) in ga.coeffs.iter().zip(gb.coeffs.iter()) {
                assert!((ca - cb).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn empty_data_errors() {
        let x = Matrix::zeros(0, 3);
        assert!(Oavi::new(OaviConfig::cgavi_ihb(0.01)).fit(&x).is_err());
    }

    #[test]
    fn panel_counters_attribute_the_default_path() {
        let x = parabola_data(120, 17);
        let model = Oavi::new(OaviConfig::cgavi_ihb(0.005)).fit(&x).unwrap();
        // every oracle call went through a panel, one pass per (degree, chunk)
        assert!(model.stats.panel_passes > 0);
        assert_eq!(model.stats.panel_cols, model.stats.oracle_calls);
        assert!(model.stats.panel_passes >= model.stats.degree_reached as usize);
        // the legacy reference path reports zero panel work
        let legacy = Oavi::new(OaviConfig::cgavi_ihb(0.005))
            .fit_with_backend_per_candidate(&x, &NativeBackend)
            .unwrap();
        assert_eq!(legacy.stats.panel_passes, 0);
        assert_eq!(legacy.stats.panel_cols, 0);
        assert_eq!(legacy.stats.cross_cache_hits, 0);
        assert_eq!(legacy.generators.len(), model.generators.len());
    }

    #[test]
    fn tiny_panel_budget_is_bitwise_equal_to_default() {
        let x = parabola_data(90, 19);
        let mut tiny = OaviConfig::cgavi_ihb(0.01);
        tiny.panel_budget_cols = 1; // every chunk is a single candidate
        let a = Oavi::new(OaviConfig::cgavi_ihb(0.01)).fit(&x).unwrap();
        let b = Oavi::new(tiny).fit(&x).unwrap();
        assert_eq!(a.o_terms.len(), b.o_terms.len());
        assert_eq!(a.generators.len(), b.generators.len());
        for (ga, gb) in a.generators.iter().zip(b.generators.iter()) {
            assert_eq!(ga.mse.to_bits(), gb.mse.to_bits());
            for (ca, cb) in ga.coeffs.iter().zip(gb.coeffs.iter()) {
                assert_eq!(ca.to_bits(), cb.to_bits());
            }
        }
        // single-candidate chunks never cache-hit; multi-candidate may
        assert_eq!(b.stats.cross_cache_hits, 0);
        assert!(b.stats.panel_passes >= a.stats.panel_passes);
    }

    #[test]
    fn unconstrained_agd_warm_starts_from_previous_solution() {
        let mut rng = Rng::new(29);
        let mut x = Matrix::zeros(80, 2);
        for i in 0..80 {
            for j in 0..2 {
                x.set(i, j, rng.uniform());
            }
        }
        let model = Oavi::new(OaviConfig::agdavi(0.01)).fit(&x).unwrap();
        assert!(model.stats.solver_runs > 1, "need several AGD runs");
        // every run after the first is warm-started
        assert_eq!(model.stats.warm_starts, model.stats.solver_runs - 1);
        // generators must still vanish on the training data
        for (gi, mse) in model.generator_set().mse_on(&x).iter().enumerate() {
            assert!(*mse <= 0.01 * (1.0 + 1e-6) + 1e-10, "generator {gi}: {mse}");
        }
        // constrained variants keep the cold start
        let cg = Oavi::new(OaviConfig::cgavi(0.01)).fit(&x).unwrap();
        assert_eq!(cg.stats.warm_starts, 0);
    }

    #[test]
    fn fast_numerics_is_opt_in_and_reports_a_held_error_budget() {
        let x = parabola_data(400, 23);
        // exact fit: no budget machinery engaged
        let exact = Oavi::new(OaviConfig::cgavi_ihb(0.005)).fit(&x).unwrap();
        assert_eq!(exact.stats.numerics, NumericsMode::Exact);
        assert_eq!(exact.stats.fast_err_budget, 0.0);
        assert_eq!(exact.stats.fast_max_abs_err, 0.0);
        // fast fit on benign [0,1] data: budget measured, held, reported
        let mut cfg = OaviConfig::cgavi_ihb(0.005);
        cfg.numerics = NumericsMode::Fast;
        let fast = Oavi::new(cfg).fit(&x).unwrap();
        assert_eq!(fast.stats.numerics, NumericsMode::Fast);
        assert!(fast.stats.fast_err_budget > 0.0, "budget must be measured");
        assert!(
            fast.stats.fast_max_abs_err <= fast.stats.fast_err_budget,
            "measured error {} exceeds budget {}",
            fast.stats.fast_max_abs_err,
            fast.stats.fast_err_budget
        );
        // an absurdly tight tolerance must fail the fit loudly, not
        // silently degrade
        let mut tight = OaviConfig::cgavi_ihb(0.005);
        tight.numerics = NumericsMode::Fast;
        tight.fast_tol = 1e-300;
        match Oavi::new(tight).fit(&x) {
            Err(AviError::Linalg(msg)) => {
                assert!(msg.contains("error budget"), "unexpected message: {msg}")
            }
            other => panic!("expected budget violation, got {other:?}"),
        }
    }

    #[test]
    fn spilled_store_fit_is_bitwise_equal_to_memory() {
        use crate::backend::StoreMode;
        let x = parabola_data(120, 31);
        let mem = Oavi::new(OaviConfig::cgavi_ihb(0.005)).fit(&x).unwrap();
        let mut cfg = OaviConfig::cgavi_ihb(0.005);
        // tiny budget: every lease reloads, exercising evict/reload paths
        cfg.store = StoreMode::Spill { budget_bytes: 4096 };
        let spill = Oavi::new(cfg).fit(&x).unwrap();
        assert!(spill.stats.store_spilled);
        assert!(!mem.stats.store_spilled);
        assert!(spill.stats.store_loads > 0);
        assert_eq!(mem.o_terms.len(), spill.o_terms.len());
        assert_eq!(mem.generators.len(), spill.generators.len());
        for (ga, gb) in mem.generators.iter().zip(&spill.generators) {
            assert_eq!(ga.leading, gb.leading);
            assert_eq!(ga.mse.to_bits(), gb.mse.to_bits());
            for (ca, cb) in ga.coeffs.iter().zip(&gb.coeffs) {
                assert_eq!(ca.to_bits(), cb.to_bits());
            }
        }
    }

    #[test]
    fn coefficient_l1_stays_bounded_by_tau() {
        let x = parabola_data(100, 13);
        let cfg = OaviConfig::cgavi_ihb(0.005);
        let model = Oavi::new(cfg).fit(&x).unwrap();
        assert!(model.generator_set().max_coeff_l1() <= cfg.tau);
    }

    #[test]
    fn duplicated_feature_triggers_rebuild_not_crash() {
        // x1 == x0 exactly ⇒ the column for x1 is dependent after x0 joins
        // O... actually x0−x1 vanishes, so it becomes a generator. Make ψ
        // tiny and duplicate a *product* structure instead to stress the
        // Schur guard with noise-free duplicates.
        let mut x = Matrix::zeros(50, 2);
        for i in 0..50 {
            let t = i as f64 / 49.0;
            x.set(i, 0, t);
            x.set(i, 1, t); // exact duplicate feature
        }
        let model = Oavi::new(OaviConfig::cgavi_ihb(1e-10)).fit(&x).unwrap();
        // x0 − x1 must be discovered as a degree-1 generator
        assert!(model.generators.iter().any(|g| g.degree() == 1));
    }
}

//! OAVI driver — Algorithm 1 with the §4 scalability machinery.
//!
//! Per border term u (DegLex order within each degree-d border):
//!
//! 1. **stats** (O(mℓ), streaming backend): `b = u(X)` from the parent
//!    column, then `(Aᵀb, bᵀb)`.
//! 2. **oracle**: with IHB, the closed form `c = −(AᵀA)^{-1}Aᵀb` plus
//!    residual decides vanishing in O(ℓ²); otherwise the configured
//!    Frank–Wolfe/AGD solver runs (with ψ-certificates for early exit).
//! 3. **accept** → generator with LTC = 1 (WIHB: re-solve with BPCG from
//!    a vertex for sparsity); **reject** → u joins O and the inverse Gram
//!    is appended via Theorem 4.9.
//!
//! The (INF) guard (§4.4.3): if the closed-form solution leaves the
//! ℓ1-ball, IHB is disabled for the remainder of the fit (the paper's
//! "approach 2", which preserves the generalization bounds).

use crate::backend::{ColumnStore, ComputeBackend, NativeBackend};
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::linalg::gram::GramState;
use crate::linalg::norm1;
use crate::oavi::config::{IhbMode, OaviConfig};
use crate::poly::border::compute_border;
use crate::poly::eval::TermSet;
use crate::poly::poly::{Generator, GeneratorSet};
use crate::solvers::{GramProblem, SolverKind, SolverParams, Termination};

/// Diagnostics accumulated over one fit.
///
/// Wall-clock lives in [`crate::estimator::FitReport`], which wraps these
/// counters and is measured uniformly for every estimator.
#[derive(Clone, Debug, Default)]
pub struct FitStats {
    /// Convex-oracle calls (= border terms processed = |G| + |O| − 1).
    pub oracle_calls: usize,
    /// Oracle calls answered by the IHB closed form alone.
    pub ihb_solves: usize,
    /// Full solver runs (cold or warm).
    pub solver_runs: usize,
    /// Total solver iterations.
    pub solver_iters: usize,
    /// WIHB sparse re-solves.
    pub wihb_resolves: usize,
    /// Theorem 4.9 appends that failed the Schur guard and fell back to a
    /// Cholesky rebuild.
    pub gram_rebuilds: usize,
    /// Whether (INF) disabled IHB mid-fit.
    pub inf_disabled_ihb: bool,
    /// Final border degree processed.
    pub degree_reached: u32,
}

/// Fitted OAVI output `(G, O)` plus diagnostics.
#[derive(Clone, Debug)]
pub struct OaviModel {
    pub generators: Vec<Generator>,
    pub o_terms: TermSet,
    pub config: OaviConfig,
    pub stats: FitStats,
}

impl OaviModel {
    /// View as a [`GeneratorSet`] (evaluation/statistics API).
    pub fn generator_set(&self) -> GeneratorSet {
        GeneratorSet { o_terms: self.o_terms.clone(), generators: self.generators.clone() }
    }

    /// |G| + |O|.
    pub fn total_size(&self) -> usize {
        self.generators.len() + self.o_terms.len()
    }
}

/// The OAVI algorithm, generic over the streaming compute backend.
pub struct Oavi {
    config: OaviConfig,
}

impl Oavi {
    pub fn new(config: OaviConfig) -> Self {
        Oavi { config }
    }

    pub fn config(&self) -> &OaviConfig {
        &self.config
    }

    /// Fit on `x` (m×n, expected in [0,1]) with the native backend.
    pub fn fit(&self, x: &Matrix) -> Result<OaviModel> {
        self.fit_with_backend(x, &NativeBackend)
    }

    /// Fit with an explicit backend (native or PJRT).
    pub fn fit_with_backend(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> Result<OaviModel> {
        let cfg = self.config;
        cfg.validate()?;
        let m = x.rows();
        let n = x.cols();
        if m == 0 || n == 0 {
            return Err(AviError::Data("fit: empty data".into()));
        }

        let mut o = TermSet::with_one(n);
        // the store's shard count is the backend's intra-fit parallelism
        // knob; results are deterministic for a fixed shard count
        let mut cols = ColumnStore::with_ones(m, backend.preferred_shards(m));
        let mut gram = if cfg.ihb == IhbMode::None {
            GramState::new_ones_b_only(m)
        } else {
            GramState::new_ones(m)
        };
        let mut generators: Vec<Generator> = Vec::new();
        let mut stats = FitStats::default();
        let mut ihb_active = cfg.ihb != IhbMode::None;
        let radius = cfg.radius();
        let solver_params = SolverParams {
            eps: cfg.eps_factor * cfg.psi.max(1e-12),
            max_iters: cfg.max_solver_iters,
            radius,
            psi: Some(cfg.psi),
        };

        // Perf pass #4, tightened by the ColumnStore refactor: ONE
        // candidate buffer for the whole fit.  Accepting a term into O
        // copies the buffer into the store's shard blocks (amortized
        // append) and reuses it — no allocation on either oracle outcome.
        let mut cand_buf = vec![0.0f64; m];
        'degrees: for d in 1..=cfg.max_degree {
            let border = compute_border(&o, d);
            if border.is_empty() {
                break;
            }
            stats.degree_reached = d;
            for bt in border {
                // candidate column b = parent(X) ⊙ x_var  — O(m)
                cols.fill_product(bt.parent, x, bt.var, &mut cand_buf);
                // streaming stats — O(mℓ), the training hot spot
                let (atb, btb) = backend.gram_stats(&cols, &cand_buf);
                stats.oracle_calls += 1;

                let (coeffs, mse) = self.oracle(
                    &mut gram,
                    &atb,
                    btb,
                    m,
                    &mut ihb_active,
                    &solver_params,
                    &mut stats,
                );

                if mse <= cfg.psi {
                    // (ψ,1)-approximately vanishing generator found
                    let coeffs = if cfg.ihb == IhbMode::Wihb {
                        self.wihb_resolve(&gram, &atb, btb, m, &solver_params, coeffs, &mut stats)
                    } else {
                        coeffs
                    };
                    generators.push(Generator {
                        coeffs,
                        leading: bt.term,
                        leading_parent: bt.parent,
                        leading_var: bt.var,
                        mse,
                    });
                } else {
                    // u joins O: append column + Theorem 4.9 inverse update
                    match gram.append(&atb, btb) {
                        Ok(()) => {}
                        Err(AviError::SchurNotPositive(_)) => {
                            // numerically dependent column: rebuild from
                            // scratch with jitter (keeps OAVI running on
                            // adversarial/duplicated data)
                            stats.gram_rebuilds += 1;
                            gram = GramState::from_store_with_candidate(&cols, &cand_buf)?;
                        }
                        Err(e) => return Err(e),
                    }
                    cols.push_col(&cand_buf);
                    o.push_product(bt.parent, bt.var)?;
                    if o.len() >= cfg.max_o_terms {
                        break 'degrees;
                    }
                }
            }
        }

        Ok(OaviModel { generators, o_terms: o, config: cfg, stats })
    }

    /// One oracle call: returns `(coeffs, MSE)` for the candidate term.
    #[allow(clippy::too_many_arguments)]
    fn oracle(
        &self,
        gram: &mut GramState,
        atb: &[f64],
        btb: f64,
        m: usize,
        ihb_active: &mut bool,
        params: &SolverParams,
        stats: &mut FitStats,
    ) -> (Vec<f64>, f64) {
        let cfg = &self.config;
        if *ihb_active {
            let (c, resid) = gram.solve_closed_form(atb, btb);
            let mse = resid / m as f64;
            // (INF) guard for the constrained problem: the closed-form
            // optimum must lie inside the ℓ1-ball for IHB to stay sound.
            if cfg.constrained && norm1(&c) > params.radius {
                *ihb_active = false;
                stats.inf_disabled_ihb = true;
                // fall through to the solver below
            } else {
                stats.ihb_solves += 1;
                return (c, mse);
            }
        }
        // full solver run (cold start)
        let p = GramProblem { b: gram.b(), atb, btb, m };
        let res = cfg.solver.solve(&p, params);
        stats.solver_runs += 1;
        stats.solver_iters += res.iters;
        (res.y, res.f)
    }

    /// WIHB (§4.4.3): IHB already certified that the term vanishes; re-run
    /// BPCG from a vertex to get *sparse* coefficients.  Keeps the sparse
    /// solution only if it still vanishes (paranoia against loose solves).
    #[allow(clippy::too_many_arguments)]
    fn wihb_resolve(
        &self,
        gram: &GramState,
        atb: &[f64],
        btb: f64,
        m: usize,
        params: &SolverParams,
        dense_coeffs: Vec<f64>,
        stats: &mut FitStats,
    ) -> Vec<f64> {
        let p = GramProblem { b: gram.b(), atb, btb, m };
        let res = SolverKind::Bpcg.solve(&p, params);
        stats.wihb_resolves += 1;
        stats.solver_iters += res.iters;
        let sparse_ok = res.f <= self.config.psi
            || matches!(res.termination, Termination::TargetReached);
        if sparse_ok {
            res.y
        } else {
            dense_coeffs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Points on the parabola x1 = x0² (plus the ambient box): OAVI must
    /// find the generator x0² − x1 at degree 2 with ψ = 0.
    fn parabola_data(m: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        for i in 0..m {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, t * t);
        }
        x
    }

    #[test]
    fn finds_parabola_generator_exactly() {
        let x = parabola_data(100, 1);
        for cfg in [
            OaviConfig::cgavi_ihb(1e-8),
            OaviConfig::agdavi_ihb(1e-8),
            OaviConfig::bpcgavi(1e-8),
        ] {
            let model = Oavi::new(cfg).fit(&x).unwrap();
            // the relation x0² = x1 must be captured by some generator of
            // degree ≤ 2 with near-zero training MSE
            assert!(
                !model.generators.is_empty(),
                "{}: no generators found",
                cfg.name()
            );
            let best = model
                .generators
                .iter()
                .map(|g| g.mse)
                .fold(f64::INFINITY, f64::min);
            assert!(best <= 1e-8, "{}: best MSE {best}", cfg.name());
            // generators must vanish on fresh data from the same variety
            let x_test = parabola_data(50, 2);
            let gs = model.generator_set();
            for mse in gs.mse_on(&x_test) {
                assert!(mse <= 1e-6, "{}: out-sample MSE {mse}", cfg.name());
            }
        }
    }

    #[test]
    fn psi_zero_on_random_data_keeps_growing_until_cap_or_termination() {
        // random data has no exact structure: with ψ = tiny, O grows; with
        // ψ large, everything vanishes immediately.
        let mut rng = Rng::new(3);
        let mut x = Matrix::zeros(60, 2);
        for i in 0..60 {
            for j in 0..2 {
                x.set(i, j, rng.uniform());
            }
        }
        let loose = Oavi::new(OaviConfig::cgavi_ihb(0.9)).fit(&x).unwrap();
        // ψ close to 1: degree-1 terms already vanish (x ∈ [0,1] ⇒ MSE ≤ 1)
        assert!(loose.o_terms.len() <= 3);
        let tight = Oavi::new(OaviConfig::cgavi_ihb(1e-4)).fit(&x).unwrap();
        assert!(tight.total_size() > loose.total_size());
    }

    #[test]
    fn theorem_4_3_bounds_hold_on_random_data() {
        crate::util::proptest::property(8, |rng| {
            let n = 1 + rng.below(3);
            let m = 40 + rng.below(60);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let psi = [0.3, 0.1, 0.05][rng.below(3)];
            let cfg = OaviConfig::cgavi_ihb(psi);
            let model = Oavi::new(cfg).fit(&x).map_err(|e| e.to_string())?;
            let d_bound = cfg.theorem_degree();
            if model.stats.degree_reached > d_bound {
                return Err(format!(
                    "degree {} exceeds Theorem 4.3 bound {d_bound} (psi={psi})",
                    model.stats.degree_reached
                ));
            }
            let size_bound = cfg.size_bound(n);
            if (model.total_size() as f64) > size_bound {
                return Err(format!(
                    "|G|+|O| = {} exceeds bound {size_bound}",
                    model.total_size()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn generators_vanish_on_training_data() {
        crate::util::proptest::property(8, |rng| {
            let n = 1 + rng.below(3);
            let m = 30 + rng.below(40);
            let mut x = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let psi = 0.05;
            let model = Oavi::new(OaviConfig::cgavi_ihb(psi))
                .fit(&x)
                .map_err(|e| e.to_string())?;
            let gs = model.generator_set();
            for (gi, mse) in gs.mse_on(&x).iter().enumerate() {
                // recomputed from scratch, must match the ψ certificate
                if *mse > psi * (1.0 + 1e-6) + 1e-10 {
                    return Err(format!("generator {gi} has training MSE {mse} > ψ"));
                }
            }
            // oracle calls = |G| + |O| − 1
            if model.stats.oracle_calls != model.total_size() - 1 {
                return Err(format!(
                    "oracle calls {} != |G|+|O|−1 = {}",
                    model.stats.oracle_calls,
                    model.total_size() - 1
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn wihb_produces_sparser_generators_than_ihb() {
        // structured data with redundancy: several vanishing directions
        let x = {
            let mut rng = Rng::new(7);
            let mut x = Matrix::zeros(200, 3);
            for i in 0..200 {
                let t = rng.uniform();
                x.set(i, 0, t);
                x.set(i, 1, (t * 1.1).min(1.0));
                x.set(i, 2, t * t);
            }
            x
        };
        let ihb = Oavi::new(OaviConfig::cgavi_ihb(0.001)).fit(&x).unwrap();
        let wihb = Oavi::new(OaviConfig::bpcgavi_wihb(0.001)).fit(&x).unwrap();
        let spar_ihb = ihb.generator_set().sparsity();
        let spar_wihb = wihb.generator_set().sparsity();
        assert!(
            spar_wihb >= spar_ihb,
            "WIHB sparsity {spar_wihb} < IHB sparsity {spar_ihb}"
        );
        assert!(wihb.stats.wihb_resolves == wihb.generators.len());
    }

    #[test]
    fn identical_output_cgavi_ihb_vs_agdavi_ihb() {
        // Paper §6.2: with coefficients inside the ball, CGAVI-IHB and
        // AGDAVI-IHB produce identical outputs (both return the closed form).
        let x = parabola_data(150, 11);
        let a = Oavi::new(OaviConfig::cgavi_ihb(0.005)).fit(&x).unwrap();
        let b = Oavi::new(OaviConfig::agdavi_ihb(0.005)).fit(&x).unwrap();
        assert_eq!(a.generators.len(), b.generators.len());
        assert_eq!(a.o_terms.len(), b.o_terms.len());
        for (ga, gb) in a.generators.iter().zip(b.generators.iter()) {
            assert_eq!(ga.leading, gb.leading);
            for (ca, cb) in ga.coeffs.iter().zip(gb.coeffs.iter()) {
                assert!((ca - cb).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn empty_data_errors() {
        let x = Matrix::zeros(0, 3);
        assert!(Oavi::new(OaviConfig::cgavi_ihb(0.01)).fit(&x).is_err());
    }

    #[test]
    fn coefficient_l1_stays_bounded_by_tau() {
        let x = parabola_data(100, 13);
        let cfg = OaviConfig::cgavi_ihb(0.005);
        let model = Oavi::new(cfg).fit(&x).unwrap();
        assert!(model.generator_set().max_coeff_l1() <= cfg.tau);
    }

    #[test]
    fn duplicated_feature_triggers_rebuild_not_crash() {
        // x1 == x0 exactly ⇒ the column for x1 is dependent after x0 joins
        // O... actually x0−x1 vanishes, so it becomes a generator. Make ψ
        // tiny and duplicate a *product* structure instead to stress the
        // Schur guard with noise-free duplicates.
        let mut x = Matrix::zeros(50, 2);
        for i in 0..50 {
            let t = i as f64 / 49.0;
            x.set(i, 0, t);
            x.set(i, 1, t); // exact duplicate feature
        }
        let model = Oavi::new(OaviConfig::cgavi_ihb(1e-10)).fit(&x).unwrap();
        // x0 − x1 must be discovered as a degree-1 generator
        assert!(model.generators.iter().any(|g| g.degree() == 1));
    }
}

//! The **binary model codec**: a hand-rolled, versioned, length-prefixed
//! encoding of the persistence envelope in [`crate::estimator::persist`]
//! — same payloads, same version gate, a fraction of the bytes.
//!
//! ## Container layout
//!
//! Every artifact starts with a fixed 8-byte header:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "AVIB"
//! 4       1     codec version (currently 1)
//! 5       1     format (1 model, 2 pipeline) — selects the body codec
//! 6       2     reserved (zero)
//! ```
//!
//! The body is a flat sequence of primitive cells, postcard-style:
//!
//! * integers — `u32` little-endian (indices, counts, tags);
//! * floats — raw little-endian `f64` bit patterns (the same
//!   [`crate::storage::segment::f64s_to_le`] convention as shard
//!   segments), so every float round-trips **bitwise**, NaN included;
//! * strings — `u32` byte length + UTF-8 bytes;
//! * arrays — `u32` element count + the elements;
//! * nested envelopes (a pipeline's per-class models) — `u32` byte
//!   length + a complete model artifact, decodable standalone.
//!
//! ## Adversarial inputs
//!
//! Every declared length and count is validated against the bytes
//! actually remaining *before* any allocation — the same discipline as
//! [`crate::coordinator::wire::read_frame`] — so a truncated buffer, a
//! flipped header byte, or a length field claiming `u32::MAX` elements
//! is a typed [`AviError::Artifact`], never a panic and never a
//! memory-exhaustion vector.  Structural indices (recipe parents, DAG
//! node ids) re-run the same range validation the JSON path performs, so
//! both codecs accept exactly the same payloads.

use crate::baselines::vca::{VcaModel, VcaNode};
use crate::error::{AviError, Result};
use crate::estimator::persist;
use crate::estimator::{FittedGeneratorSet, FittedModel, FittedVca};
use crate::pipeline::{FittedTransformer, PipelineModel};
use crate::poly::eval::{Recipe, TermSet};
use crate::poly::poly::{Generator, GeneratorSet};
use crate::svm::linear::{LinearSvm, LinearSvmConfig};

/// Artifact magic: every binary envelope starts with these four bytes
/// (the JSON envelope starts with `{`, so one byte tells them apart).
pub const MAGIC: [u8; 4] = *b"AVIB";

/// Current binary codec version; any other is rejected loudly.
pub const CODEC_VERSION: u8 = 1;

/// Header `format` byte: a single fitted model (mirror of
/// [`persist::FORMAT_MODEL`]).
pub const FORMAT_MODEL: u8 = 1;

/// Header `format` byte: a whole fitted pipeline (mirror of
/// [`persist::FORMAT_PIPELINE`]).
pub const FORMAT_PIPELINE: u8 = 2;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 8;

/// Payload kind tag: monomial-aware generator set.
const KIND_GENERATOR_SET: u8 = 1;
/// Payload kind tag: VCA polynomial op-DAG.
const KIND_VCA_DAG: u8 = 2;

/// Sentinel `(parent, var)` pair encoding the constant-1 recipe (the
/// JSON path writes `[-1,-1]`).
const RECIPE_ONE: u32 = u32::MAX;

/// Does `bytes` start like a binary artifact?  (The version gate: JSON
/// and binary payloads are interchangeable wherever this is consulted.)
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.len() >= 4 && bytes[..4] == MAGIC
}

fn err(m: impl Into<String>) -> AviError {
    AviError::Artifact(m.into())
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn with_header(format: u8) -> Writer {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(&MAGIC);
        buf.push(CODEC_VERSION);
        buf.push(format);
        buf.extend_from_slice(&[0, 0]);
        Writer { buf }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn idx(&mut self, v: usize) -> Result<()> {
        let v = u32::try_from(v)
            .map_err(|_| err(format!("index {v} exceeds the u32 wire range")))?;
        self.u32(v);
        Ok(())
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) -> Result<()> {
        self.idx(s.len())?;
        self.buf.extend_from_slice(s.as_bytes());
        Ok(())
    }

    fn f64s(&mut self, vals: &[f64]) -> Result<()> {
        self.idx(vals.len())?;
        for &v in vals {
            self.f64(v);
        }
        Ok(())
    }

    fn block(&mut self, bytes: &[u8]) -> Result<()> {
        self.idx(bytes.len())?;
        self.buf.extend_from_slice(bytes);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Reader (every length validated before allocation)
// ---------------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(err(format!(
                "truncated artifact: {what} wants {n} bytes, {} remain",
                self.remaining()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        let b = self.take(8, what)?;
        let mut arr = [0u8; 8];
        arr.copy_from_slice(b);
        Ok(f64::from_le_bytes(arr))
    }

    /// A declared element count, validated against the bytes remaining
    /// (`elem_bytes` per element) **before** the caller allocates.
    fn count(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let n = self.u32(what)? as usize;
        let need = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| err(format!("{what}: count {n} overflows")))?;
        if need > self.remaining() {
            return Err(err(format!(
                "oversized declared length: {what} claims {n} elements \
                 ({need} bytes), {} remain",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let n = self.count(1, what)?;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| err(format!("{what} is not UTF-8")))
    }

    fn f64s(&mut self, what: &str) -> Result<Vec<f64>> {
        let n = self.count(8, what)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64(what)?);
        }
        Ok(out)
    }

    fn block(&mut self, what: &str) -> Result<&'a [u8]> {
        let n = self.count(1, what)?;
        self.take(n, what)
    }

    fn done(&self, what: &str) -> Result<()> {
        if self.remaining() != 0 {
            return Err(err(format!(
                "{what}: {} trailing bytes after the envelope",
                self.remaining()
            )));
        }
        Ok(())
    }
}

fn check_header(r: &mut Reader<'_>, expected_format: u8) -> Result<()> {
    let magic = r.take(4, "artifact magic")?;
    if magic != MAGIC {
        return Err(err(format!("bad artifact magic {magic:02x?} (want {MAGIC:02x?})")));
    }
    let version = r.u8("codec version")?;
    if version != CODEC_VERSION {
        return Err(err(format!(
            "unsupported artifact codec version {version} (supported: {CODEC_VERSION})"
        )));
    }
    let format = r.u8("format byte")?;
    if format != expected_format {
        return Err(err(format!(
            "artifact format {format}, expected {expected_format} \
             (1 model, 2 pipeline)"
        )));
    }
    r.take(2, "reserved header bytes")?;
    Ok(())
}

// ---------------------------------------------------------------------
// Payload codecs
// ---------------------------------------------------------------------

fn encode_generator_set(w: &mut Writer, gs: &GeneratorSet) -> Result<()> {
    w.idx(gs.o_terms.n_vars())?;
    w.idx(gs.o_terms.len())?;
    for i in 0..gs.o_terms.len() {
        match gs.o_terms.recipe(i) {
            Recipe::One => {
                w.u32(RECIPE_ONE);
                w.u32(RECIPE_ONE);
            }
            Recipe::Product { parent, var } => {
                w.idx(parent)?;
                w.idx(var)?;
            }
        }
    }
    w.idx(gs.generators.len())?;
    for g in &gs.generators {
        w.idx(g.leading_parent)?;
        w.idx(g.leading_var)?;
        w.f64(g.mse);
        w.f64s(&g.coeffs)?;
    }
    Ok(())
}

fn decode_generator_set(r: &mut Reader<'_>) -> Result<GeneratorSet> {
    let n_vars = r.u32("n_vars")? as usize;
    let n_terms = r.count(8, "o_recipes")?;
    let mut o = TermSet::with_one(n_vars);
    for i in 0..n_terms {
        let parent = r.u32("recipe parent")?;
        let var = r.u32("recipe var")?;
        match (parent, var) {
            (RECIPE_ONE, RECIPE_ONE) => {
                if i != 0 {
                    return Err(err("One recipe not first"));
                }
            }
            _ if i == 0 => return Err(err("first recipe must be the One term")),
            (p, v) => {
                if v as usize >= n_vars {
                    return Err(err(format!("recipe var {v} out of range (n_vars {n_vars})")));
                }
                o.push_product(p as usize, v as usize)
                    .map_err(|e| err(format!("bad recipe: {e}")))?;
            }
        }
    }
    let n_gens = r.count(24, "generators")?;
    let mut generators = Vec::with_capacity(n_gens);
    for _ in 0..n_gens {
        let parent = r.u32("generator parent")? as usize;
        let var = r.u32("generator var")? as usize;
        let mse = r.f64("generator mse")?;
        let coeffs = r.f64s("generator coeffs")?;
        if parent >= o.len() || var >= n_vars {
            return Err(err("leading recipe out of range"));
        }
        let leading = o.terms()[parent].times_var(var);
        generators.push(Generator {
            coeffs,
            leading,
            leading_parent: parent,
            leading_var: var,
            mse,
        });
    }
    Ok(GeneratorSet { o_terms: o, generators })
}

fn encode_vca(w: &mut Writer, model: &VcaModel) -> Result<()> {
    w.idx(model.n_vars())?;
    w.idx(model.nodes().len())?;
    for node in model.nodes() {
        match node {
            VcaNode::One => w.u8(0),
            VcaNode::Feature(j) => {
                w.u8(1);
                w.idx(*j)?;
            }
            VcaNode::Product(a, b) => {
                w.u8(2);
                w.idx(*a)?;
                w.idx(*b)?;
            }
            VcaNode::LinComb(terms) => {
                w.u8(3);
                w.idx(terms.len())?;
                for (weight, id) in terms {
                    w.f64(*weight);
                    w.idx(*id)?;
                }
            }
        }
    }
    w.idx(model.degrees().len())?;
    for &d in model.degrees() {
        w.u32(d);
    }
    w.idx(model.vanishing.len())?;
    for &v in &model.vanishing {
        w.idx(v)?;
    }
    w.idx(model.f_sets.len())?;
    for f in &model.f_sets {
        w.idx(f.len())?;
        for &id in f {
            w.idx(id)?;
        }
    }
    Ok(())
}

fn decode_vca(r: &mut Reader<'_>) -> Result<VcaModel> {
    let n_vars = r.u32("n_vars")? as usize;
    let n_nodes = r.count(1, "nodes")?;
    let mut nodes = Vec::with_capacity(n_nodes);
    for _ in 0..n_nodes {
        let node = match r.u8("node tag")? {
            0 => VcaNode::One,
            1 => VcaNode::Feature(r.u32("feature index")? as usize),
            2 => {
                let a = r.u32("product lhs")? as usize;
                let b = r.u32("product rhs")? as usize;
                VcaNode::Product(a, b)
            }
            3 => {
                let n_terms = r.count(12, "lincomb terms")?;
                let mut terms = Vec::with_capacity(n_terms);
                for _ in 0..n_terms {
                    let weight = r.f64("lincomb weight")?;
                    let id = r.u32("lincomb id")? as usize;
                    terms.push((weight, id));
                }
                VcaNode::LinComb(terms)
            }
            other => return Err(err(format!("unknown VCA node tag {other}"))),
        };
        nodes.push(node);
    }
    let n_degrees = r.count(4, "degrees")?;
    let mut degrees = Vec::with_capacity(n_degrees);
    for _ in 0..n_degrees {
        degrees.push(r.u32("degree")?);
    }
    let n_van = r.count(4, "vanishing")?;
    let mut vanishing = Vec::with_capacity(n_van);
    for _ in 0..n_van {
        vanishing.push(r.u32("vanishing id")? as usize);
    }
    let n_f = r.count(4, "f_sets")?;
    let mut f_sets = Vec::with_capacity(n_f);
    for _ in 0..n_f {
        let n_ids = r.count(4, "f_set ids")?;
        let mut ids = Vec::with_capacity(n_ids);
        for _ in 0..n_ids {
            ids.push(r.u32("f_set id")? as usize);
        }
        f_sets.push(ids);
    }
    // from_parts re-validates the DAG (forward references, feature
    // bounds) exactly like the JSON path, so corrupt payloads fail the
    // load instead of mutating the model
    VcaModel::from_parts(nodes, vanishing, f_sets, degrees, n_vars)
        .map_err(|e| err(format!("VCA DAG rejected: {e}")))
}

// ---------------------------------------------------------------------
// Model envelope
// ---------------------------------------------------------------------

/// Encode one fitted model as a binary artifact (payload-compatible with
/// [`persist::model_to_json`]).
pub fn encode_model(model: &dyn FittedModel) -> Result<Vec<u8>> {
    let mut w = Writer::with_header(FORMAT_MODEL);
    w.str(model.report().name())?;
    if let Some(gs) = model.as_any().downcast_ref::<FittedGeneratorSet>() {
        w.u8(KIND_GENERATOR_SET);
        encode_generator_set(&mut w, &gs.set)?;
    } else if let Some(vca) = model.as_any().downcast_ref::<FittedVca>() {
        w.u8(KIND_VCA_DAG);
        encode_vca(&mut w, &vca.model)?;
    } else {
        return Err(err(format!(
            "estimator '{}' (kind '{}') has no binary payload codec",
            model.report().name(),
            model.payload_kind()
        )));
    }
    Ok(w.buf)
}

/// Decode a binary model artifact back into a fitted model — the exact
/// structures [`persist::model_from_json`] produces.
pub fn decode_model(bytes: &[u8]) -> Result<Box<dyn FittedModel>> {
    let mut r = Reader::new(bytes);
    check_header(&mut r, FORMAT_MODEL)?;
    let model = decode_model_body(&mut r)?;
    r.done("model artifact")?;
    Ok(model)
}

fn decode_model_body(r: &mut Reader<'_>) -> Result<Box<dyn FittedModel>> {
    let estimator = r.str("estimator name")?;
    match r.u8("payload kind")? {
        KIND_GENERATOR_SET => {
            let set = decode_generator_set(r)?;
            let report =
                persist::loaded_report(&estimator, set.generators.len(), set.o_terms.len());
            Ok(Box::new(FittedGeneratorSet { set, report }))
        }
        KIND_VCA_DAG => {
            let model = decode_vca(r)?;
            let n_f: usize = model.f_sets.iter().map(|f| f.len()).sum();
            let report = persist::loaded_report(&estimator, model.n_generators(), n_f);
            Ok(Box::new(FittedVca { model, report }))
        }
        other => Err(err(format!(
            "unknown payload kind {other} (known: {KIND_GENERATOR_SET} generator-set, \
             {KIND_VCA_DAG} vca-dag)"
        ))),
    }
}

// ---------------------------------------------------------------------
// Pipeline envelope
// ---------------------------------------------------------------------

/// Encode a whole fitted pipeline as a binary artifact
/// (payload-compatible with [`persist::pipeline_to_json`]).
pub fn encode_pipeline(model: &PipelineModel) -> Result<Vec<u8>> {
    let mut w = Writer::with_header(FORMAT_PIPELINE);
    w.str(&model.transformer.method_name)?;
    w.idx(model.perm.len())?;
    for &p in &model.perm {
        w.idx(p)?;
    }
    w.idx(model.n_classes)?;
    w.idx(model.transformer.per_class.len())?;
    for cm in &model.transformer.per_class {
        let nested = encode_model(cm.as_ref())?;
        w.block(&nested)?;
    }
    w.f64(model.svm.config.lambda);
    w.idx(model.svm.weights.len())?;
    for (weights, bias) in &model.svm.weights {
        w.f64(*bias);
        w.f64s(weights)?;
    }
    Ok(w.buf)
}

/// Decode a binary pipeline artifact — the exact structures
/// [`persist::pipeline_from_json`] produces.
pub fn decode_pipeline(bytes: &[u8]) -> Result<PipelineModel> {
    let mut r = Reader::new(bytes);
    check_header(&mut r, FORMAT_PIPELINE)?;
    let method_name = r.str("method name")?;
    let n_perm = r.count(4, "perm")?;
    let mut perm = Vec::with_capacity(n_perm);
    for _ in 0..n_perm {
        perm.push(r.u32("perm entry")? as usize);
    }
    let n_classes = r.u32("n_classes")? as usize;
    let n_models = r.count(4, "classes")?;
    let mut per_class: Vec<Box<dyn FittedModel>> = Vec::with_capacity(n_models);
    for _ in 0..n_models {
        let nested = r.block("class model envelope")?;
        let mut nr = Reader::new(nested);
        check_header(&mut nr, FORMAT_MODEL)?;
        let model = decode_model_body(&mut nr)?;
        nr.done("class model envelope")?;
        per_class.push(model);
    }
    if per_class.len() != n_classes {
        return Err(err(format!(
            "{} classes decoded, expected {n_classes}",
            per_class.len()
        )));
    }
    let lambda = r.f64("svm lambda")?;
    let n_heads = r.count(12, "svm heads")?;
    let mut weights = Vec::with_capacity(n_heads);
    for _ in 0..n_heads {
        let bias = r.f64("head bias")?;
        let w = r.f64s("head weights")?;
        weights.push((w, bias));
    }
    if weights.is_empty() {
        return Err(err("no svm heads"));
    }
    r.done("pipeline artifact")?;
    let svm = LinearSvm {
        weights,
        n_classes,
        config: LinearSvmConfig { lambda, ..Default::default() },
        iters: vec![],
    };
    Ok(PipelineModel {
        perm,
        transformer: FittedTransformer { method_name, per_class },
        svm,
        n_classes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synthetic::synthetic_dataset;
    use crate::estimator::EstimatorConfig;
    use crate::linalg::dense::Matrix;
    use crate::oavi::OaviConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, PipelineConfig};
    use crate::svm::linear::LinearSvmConfig;
    use crate::util::rng::Rng;

    fn parabola(m: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        for i in 0..m {
            let t = rng.uniform();
            x.set(i, 0, t);
            x.set(i, 1, t * t);
        }
        x
    }

    fn pipeline(psi: f64, seed: u64) -> PipelineModel {
        let ds = synthetic_dataset(200, seed);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(psi)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        train_pipeline(&cfg, &ds).unwrap()
    }

    fn bits(m: &Matrix) -> Vec<u64> {
        m.data().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn model_artifact_roundtrips_every_estimator_bitwise() {
        let x = parabola(120, 5);
        let z = parabola(40, 6);
        for cfg in EstimatorConfig::battery(0.001) {
            let model = cfg.fit(&x, &NativeBackend).unwrap();
            let bin = encode_model(model.as_ref()).unwrap();
            assert!(is_binary(&bin));
            let back = decode_model(&bin).unwrap_or_else(|e| panic!("{}: {e}", cfg.name()));
            assert_eq!(back.report().name(), model.report().name());
            assert_eq!(back.n_generators(), model.n_generators());
            assert_eq!(back.total_size(), model.total_size());
            let a = model.transform_with(&z, &NativeBackend);
            let b = back.transform_with(&z, &NativeBackend);
            assert_eq!(bits(&a), bits(&b), "{}: transform not bitwise equal", cfg.name());
            // and the binary form beats the JSON form on size
            let json = persist::model_to_json(model.as_ref());
            assert!(
                bin.len() < json.len(),
                "{}: binary {}B >= JSON {}B",
                cfg.name(),
                bin.len(),
                json.len()
            );
        }
    }

    #[test]
    fn pipeline_artifact_roundtrips_bitwise_and_is_smaller_than_json() {
        let model = pipeline(0.01, 9);
        let bin = encode_pipeline(&model).unwrap();
        let back = decode_pipeline(&bin).unwrap();
        assert_eq!(back.n_classes, model.n_classes);
        assert_eq!(back.perm, model.perm);
        assert_eq!(back.transformer.method_name, model.transformer.method_name);
        assert_eq!(
            back.svm.config.lambda.to_bits(),
            model.svm.config.lambda.to_bits()
        );
        for ((wa, ba), (wb, bb)) in model.svm.weights.iter().zip(&back.svm.weights) {
            assert_eq!(ba.to_bits(), bb.to_bits());
            for (a, b) in wa.iter().zip(wb) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let ds = synthetic_dataset(32, 10);
        let (la, sa) = model.predict_scores_with_backend(&ds.x, &NativeBackend);
        let (lb, sb) = back.predict_scores_with_backend(&ds.x, &NativeBackend);
        assert_eq!(la, lb);
        for (ra, rb) in sa.iter().zip(&sb) {
            for (a, b) in ra.iter().zip(rb) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let json = persist::pipeline_to_json(&model);
        assert!(
            bin.len() < json.len(),
            "binary {}B >= JSON {}B",
            bin.len(),
            json.len()
        );
    }

    #[test]
    fn truncation_at_every_boundary_is_a_typed_error_never_a_panic() {
        let model = pipeline(0.05, 11);
        let bin = encode_pipeline(&model).unwrap();
        for cut in (0..bin.len()).step_by(7) {
            let e = decode_pipeline(&bin[..cut]).unwrap_err();
            assert!(matches!(e, AviError::Artifact(_)), "cut {cut}: {e}");
        }
        // and one byte short of complete
        let e = decode_pipeline(&bin[..bin.len() - 1]).unwrap_err();
        assert!(matches!(e, AviError::Artifact(_)), "{e}");
    }

    #[test]
    fn flipped_bytes_never_panic() {
        let model = pipeline(0.05, 12);
        let bin = encode_pipeline(&model).unwrap();
        // structural corruption must surface as a typed error or decode
        // to different-but-valid floats (the checksum layer catches
        // those); it must never panic or hang
        for pos in 0..bin.len().min(512) {
            let mut bad = bin.clone();
            bad[pos] ^= 0xA5;
            let _ = decode_pipeline(&bad);
        }
        // header flips specifically are typed rejections
        for pos in 0..HEADER_LEN - 2 {
            let mut bad = bin.clone();
            bad[pos] ^= 0xFF;
            let e = decode_pipeline(&bad).unwrap_err();
            assert!(matches!(e, AviError::Artifact(_)), "pos {pos}: {e}");
        }
    }

    #[test]
    fn oversized_declared_lengths_reject_before_allocating() {
        // a pipeline header followed by a string length claiming u32::MAX
        // with 4 bytes behind it must fail on the count check, not OOM
        let mut bad = vec![];
        bad.extend_from_slice(&MAGIC);
        bad.push(CODEC_VERSION);
        bad.push(FORMAT_PIPELINE);
        bad.extend_from_slice(&[0, 0]);
        bad.extend_from_slice(&u32::MAX.to_le_bytes());
        bad.extend_from_slice(b"abcd");
        let e = decode_pipeline(&bad).unwrap_err();
        assert!(matches!(e, AviError::Artifact(_)), "{e}");
        assert!(e.to_string().contains("oversized"), "{e}");
        // same for a model envelope's coefficient blob
        let mut bad = vec![];
        bad.extend_from_slice(&MAGIC);
        bad.push(CODEC_VERSION);
        bad.push(FORMAT_MODEL);
        bad.extend_from_slice(&[0, 0]);
        bad.extend_from_slice(&2u32.to_le_bytes());
        bad.extend_from_slice(b"gg");
        bad.push(KIND_GENERATOR_SET);
        bad.extend_from_slice(&2u32.to_le_bytes()); // n_vars
        bad.extend_from_slice(&0x0FFF_FFFFu32.to_le_bytes()); // recipe count
        let e = decode_model(&bad).unwrap_err();
        assert!(matches!(e, AviError::Artifact(_)), "{e}");
    }

    #[test]
    fn wrong_format_version_and_kind_are_typed() {
        let model = pipeline(0.05, 13);
        let bin = encode_pipeline(&model).unwrap();
        // a pipeline artifact is not a model artifact (and vice versa)
        let e = decode_model(&bin).unwrap_err();
        assert!(e.to_string().contains("format"), "{e}");
        let cm = encode_model(model.transformer.per_class[0].as_ref()).unwrap();
        let e = decode_pipeline(&cm).unwrap_err();
        assert!(e.to_string().contains("format"), "{e}");
        // future codec version
        let mut v9 = bin.clone();
        v9[4] = 9;
        let e = decode_pipeline(&v9).unwrap_err();
        assert!(e.to_string().contains("version 9"), "{e}");
        // unknown payload kind inside a model envelope
        let mut badkind = cm.clone();
        // kind byte sits right after the header and the name string
        let name_len =
            u32::from_le_bytes([cm[8], cm[9], cm[10], cm[11]]) as usize;
        badkind[HEADER_LEN + 4 + name_len] = 77;
        let e = decode_model(&badkind).unwrap_err();
        assert!(e.to_string().contains("payload kind"), "{e}");
        // trailing garbage is rejected
        let mut long = bin.clone();
        long.extend_from_slice(b"xx");
        let e = decode_pipeline(&long).unwrap_err();
        assert!(e.to_string().contains("trailing"), "{e}");
        // empty and magic-less buffers
        assert!(decode_pipeline(b"").is_err());
        assert!(decode_pipeline(b"{\"format\": \"avi-scale-pipeline\"}").is_err());
        assert!(!is_binary(b"{}"));
    }
}

//! Checksummed **artifact store**: a directory of binary (or JSON)
//! model artifacts plus a signed-length manifest, so a corrupt or
//! truncated artifact is refused with a typed [`AviError::Artifact`]
//! before it can ever route traffic.
//!
//! ## Directory layout
//!
//! ```text
//! <root>/
//!   manifest.json          index: key@version → file, byte length, FNV-1a-64
//!   a<fnv64(key@version)>.avib   one file per artifact, opaque bytes
//! ```
//!
//! The manifest records, per artifact, the **exact byte length** and the
//! FNV-1a-64 checksum of the file — the same digest
//! [`crate::storage::segment::checksum_file`] uses for shard segments.
//! [`ArtifactStore::open`] re-verifies every entry (existence, length,
//! digest) and [`ArtifactStore::get`] re-verifies the one entry it
//! returns, so a flipped byte, a truncated write, or a hand-edited
//! manifest surfaces as `AviError::Artifact`, never as a wrong model.
//!
//! Writes are crash-safe the same way segment/manifest writes are
//! elsewhere in the crate: bytes land in a `*.tmp` sibling first and are
//! `rename`d into place, and the manifest is rewritten last.
//!
//! The store is deliberately dumb about *semantics*: it will happily
//! overwrite `key@version` with different bytes.  Conflict refusal is
//! the registry's job ([`crate::coordinator::registry::ModelRegistry`]
//! checks fingerprints before the store is touched).

use std::collections::BTreeMap;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::error::{AviError, Result};
use crate::estimator::persist::{extract_array, extract_f64, extract_str, split_objects};
use crate::storage::segment::{checksum_file, Fnv64};
use crate::util::json_escape;

/// Manifest self-description; anything else is refused.
const MANIFEST_FORMAT: &str = "avi-scale-artifacts";
/// Manifest schema version.
const MANIFEST_VERSION: u64 = 1;
/// Manifest file name inside the store root.
const MANIFEST_FILE: &str = "manifest.json";

/// FNV-1a-64 of `bytes` — the digest recorded in manifests and declared
/// in `PushModel` headers.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

/// One manifest row.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Registry key (tenant-namespaced where applicable).
    pub key: String,
    /// Version label.
    pub version: String,
    /// File name inside the store root.
    pub file: String,
    /// Exact byte length — enforced, not advisory.
    pub bytes: u64,
    /// FNV-1a-64 of the file contents.
    pub checksum: u64,
}

/// A verified directory of model artifacts.  See the module docs for
/// the layout and the verification contract.
#[derive(Debug)]
pub struct ArtifactStore {
    root: PathBuf,
    entries: BTreeMap<(String, String), ArtifactEntry>,
}

fn err(m: impl Into<String>) -> AviError {
    AviError::Artifact(m.into())
}

fn file_name(key: &str, version: &str) -> String {
    format!("a{:016x}.avib", fnv64(format!("{key}@{version}").as_bytes()))
}

impl ArtifactStore {
    /// Open (creating if absent) the store at `root`, verifying every
    /// manifest entry: the file must exist, match its recorded length,
    /// and match its recorded checksum.
    pub fn open(root: impl Into<PathBuf>) -> Result<ArtifactStore> {
        let root = root.into();
        fs::create_dir_all(&root)?;
        let manifest = root.join(MANIFEST_FILE);
        let mut store = ArtifactStore { root, entries: BTreeMap::new() };
        if !manifest.exists() {
            return Ok(store);
        }
        let text = fs::read_to_string(&manifest)?;
        let format = extract_str(&text, "\"format\":")
            .map_err(|_| err("artifact manifest missing format header"))?;
        if format != MANIFEST_FORMAT {
            return Err(err(format!(
                "artifact manifest format '{format}', expected '{MANIFEST_FORMAT}'"
            )));
        }
        let version = extract_f64(&text, "\"version\":")
            .map_err(|_| err("artifact manifest missing version"))? as u64;
        if version != MANIFEST_VERSION {
            return Err(err(format!(
                "unsupported artifact manifest version {version} \
                 (supported: {MANIFEST_VERSION})"
            )));
        }
        let body = extract_array(&text, "\"artifacts\":")
            .map_err(|_| err("artifact manifest missing artifacts array"))?;
        for obj in split_objects(&body) {
            let entry = ArtifactEntry {
                key: extract_str(obj, "\"key\":")
                    .map_err(|e| err(format!("manifest entry: {e}")))?,
                version: extract_str(obj, "\"version\":")
                    .map_err(|e| err(format!("manifest entry: {e}")))?,
                file: extract_str(obj, "\"file\":")
                    .map_err(|e| err(format!("manifest entry: {e}")))?,
                bytes: extract_f64(obj, "\"bytes\":")
                    .map_err(|e| err(format!("manifest entry: {e}")))?
                    as u64,
                checksum: parse_hex64(
                    &extract_str(obj, "\"checksum\":")
                        .map_err(|e| err(format!("manifest entry: {e}")))?,
                )?,
            };
            store.verify_entry(&entry)?;
            store
                .entries
                .insert((entry.key.clone(), entry.version.clone()), entry);
        }
        Ok(store)
    }

    /// Store root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Is `key@version` present?
    pub fn contains(&self, key: &str, version: &str) -> bool {
        self.entries
            .contains_key(&(key.to_string(), version.to_string()))
    }

    /// All entries, sorted by `(key, version)`.
    pub fn list(&self) -> Vec<&ArtifactEntry> {
        self.entries.values().collect()
    }

    /// The recorded checksum of `key@version`, if present.
    pub fn checksum(&self, key: &str, version: &str) -> Option<u64> {
        self.entries
            .get(&(key.to_string(), version.to_string()))
            .map(|e| e.checksum)
    }

    /// Write `artifact` as `key@version`: tmp-file + rename, re-read
    /// checksum verification (catching torn writes), manifest rewrite.
    /// Overwrites an existing entry — semantic conflicts are gated
    /// upstream by the registry.
    pub fn put(&mut self, key: &str, version: &str, artifact: &[u8]) -> Result<()> {
        let digest = fnv64(artifact);
        let file = file_name(key, version);
        let path = self.root.join(&file);
        let tmp = self.root.join(format!("{file}.tmp"));
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(artifact)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        let on_disk = checksum_file(&path)?;
        if on_disk != digest {
            return Err(err(format!(
                "torn write: {file} digest {on_disk:016x} != {digest:016x} just written"
            )));
        }
        self.entries.insert(
            (key.to_string(), version.to_string()),
            ArtifactEntry {
                key: key.to_string(),
                version: version.to_string(),
                file,
                bytes: artifact.len() as u64,
                checksum: digest,
            },
        );
        self.write_manifest()
    }

    /// Read back `key@version`, re-verifying length and checksum before
    /// a single byte is handed to a decoder.
    pub fn get(&self, key: &str, version: &str) -> Result<Vec<u8>> {
        let entry = self
            .entries
            .get(&(key.to_string(), version.to_string()))
            .ok_or_else(|| err(format!("unknown artifact {key}@{version}")))?;
        self.verify_entry(entry)?;
        let bytes = fs::read(self.root.join(&entry.file))?;
        // verify_entry checked the file; check the bytes we actually read
        if bytes.len() as u64 != entry.bytes || fnv64(&bytes) != entry.checksum {
            return Err(err(format!(
                "artifact {key}@{version} changed between verify and read"
            )));
        }
        Ok(bytes)
    }

    /// Latest version label for `key` (lexicographically greatest,
    /// matching registry rollback ordering), if any exist.
    pub fn latest_version(&self, key: &str) -> Option<String> {
        self.entries
            .values()
            .filter(|e| e.key == key)
            .map(|e| e.version.clone())
            .max()
    }

    /// Drop `key@version` from disk and manifest.  Unknown entries are
    /// a no-op so eviction sweeps are idempotent.
    pub fn remove(&mut self, key: &str, version: &str) -> Result<()> {
        if let Some(entry) = self
            .entries
            .remove(&(key.to_string(), version.to_string()))
        {
            let path = self.root.join(&entry.file);
            if path.exists() {
                fs::remove_file(&path)?;
            }
            self.write_manifest()?;
        }
        Ok(())
    }

    fn verify_entry(&self, entry: &ArtifactEntry) -> Result<()> {
        let path = self.root.join(&entry.file);
        let meta = fs::metadata(&path).map_err(|_| {
            err(format!(
                "manifest names missing artifact file '{}' ({}@{})",
                entry.file, entry.key, entry.version
            ))
        })?;
        if meta.len() != entry.bytes {
            return Err(err(format!(
                "truncated artifact '{}': {} bytes on disk, manifest signs {}",
                entry.file,
                meta.len(),
                entry.bytes
            )));
        }
        let digest = checksum_file(&path)?;
        if digest != entry.checksum {
            return Err(err(format!(
                "artifact '{}' checksum mismatch: {digest:016x} on disk, \
                 manifest signs {:016x}",
                entry.file, entry.checksum
            )));
        }
        Ok(())
    }

    fn write_manifest(&self) -> Result<()> {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"format\": \"{MANIFEST_FORMAT}\",\n"));
        out.push_str(&format!("  \"version\": {MANIFEST_VERSION},\n"));
        out.push_str("  \"artifacts\": [\n");
        let rows: Vec<String> = self
            .entries
            .values()
            .map(|e| {
                format!(
                    "    {{\"key\": \"{}\", \"version\": \"{}\", \"file\": \"{}\", \
                     \"bytes\": {}, \"checksum\": \"{:016x}\"}}",
                    json_escape(&e.key),
                    json_escape(&e.version),
                    json_escape(&e.file),
                    e.bytes,
                    e.checksum
                )
            })
            .collect();
        out.push_str(&rows.join(",\n"));
        out.push_str("\n  ]\n}\n");
        let tmp = self.root.join(format!("{MANIFEST_FILE}.tmp"));
        fs::write(&tmp, &out)?;
        fs::rename(&tmp, self.root.join(MANIFEST_FILE))?;
        Ok(())
    }
}

/// Parse a 64-bit checksum written as lowercase hex (manifests and wire
/// headers carry digests as strings — u64 exceeds the integer range a
/// JSON `f64` number can hold exactly).
pub fn parse_hex64(s: &str) -> Result<u64> {
    u64::from_str_radix(s.trim(), 16)
        .map_err(|_| err(format!("bad checksum literal '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs::OpenOptions;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "avi_artifact_store_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn put_get_roundtrip_and_reopen() {
        let dir = tmpdir("roundtrip");
        let mut store = ArtifactStore::open(&dir).unwrap();
        let blob = vec![7u8; 1000];
        store.put("acme/m", "v1", &blob).unwrap();
        store.put("acme/m", "v2", b"hello").unwrap();
        assert_eq!(store.get("acme/m", "v1").unwrap(), blob);
        assert_eq!(store.latest_version("acme/m").as_deref(), Some("v2"));
        assert!(store.contains("acme/m", "v2"));
        assert!(!store.contains("acme/m", "v9"));
        // a fresh open re-verifies and sees both entries
        let reopened = ArtifactStore::open(&dir).unwrap();
        assert_eq!(reopened.list().len(), 2);
        assert_eq!(reopened.get("acme/m", "v2").unwrap(), b"hello");
        assert_eq!(
            reopened.checksum("acme/m", "v1"),
            Some(fnv64(&blob))
        );
        let e = reopened.get("acme/m", "v9").unwrap_err();
        assert!(matches!(e, AviError::Artifact(_)), "{e}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn flipped_byte_is_refused_on_open_and_on_get() {
        let dir = tmpdir("flip");
        let mut store = ArtifactStore::open(&dir).unwrap();
        store.put("m", "v1", &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let file = dir.join(&store.list()[0].file.clone());
        let mut bytes = fs::read(&file).unwrap();
        bytes[3] ^= 0xFF;
        fs::write(&file, &bytes).unwrap();
        // the open-handle still knows the old checksum: get refuses
        let e = store.get("m", "v1").unwrap_err();
        assert!(e.to_string().contains("checksum mismatch"), "{e}");
        // and a fresh open refuses outright
        let e = ArtifactStore::open(&dir).unwrap_err();
        assert!(matches!(e, AviError::Artifact(_)), "{e}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncation_and_missing_file_are_typed() {
        let dir = tmpdir("trunc");
        let mut store = ArtifactStore::open(&dir).unwrap();
        store.put("m", "v1", &[9u8; 64]).unwrap();
        store.put("m", "v2", &[8u8; 64]).unwrap();
        let file = dir.join(store.list()[0].file.clone());
        OpenOptions::new()
            .write(true)
            .open(&file)
            .unwrap()
            .set_len(10)
            .unwrap();
        let e = ArtifactStore::open(&dir).unwrap_err();
        assert!(e.to_string().contains("truncated artifact"), "{e}");
        fs::remove_file(&file).unwrap();
        let e = ArtifactStore::open(&dir).unwrap_err();
        assert!(e.to_string().contains("missing artifact file"), "{e}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_tampering_is_typed() {
        let dir = tmpdir("tamper");
        let mut store = ArtifactStore::open(&dir).unwrap();
        store.put("m", "v1", b"payload-bytes").unwrap();
        let manifest = dir.join(MANIFEST_FILE);
        // lie about the checksum
        let text = fs::read_to_string(&manifest).unwrap();
        let idx = text.find("\"checksum\": \"").unwrap() + "\"checksum\": \"".len();
        let mut bad = text.clone();
        bad.replace_range(idx..idx + 1, if &text[idx..idx + 1] == "0" { "1" } else { "0" });
        fs::write(&manifest, &bad).unwrap();
        let e = ArtifactStore::open(&dir).unwrap_err();
        assert!(e.to_string().contains("checksum mismatch"), "{e}");
        // unparseable manifest
        fs::write(&manifest, "not json at all").unwrap();
        let e = ArtifactStore::open(&dir).unwrap_err();
        assert!(matches!(e, AviError::Artifact(_)), "{e}");
        // wrong format header
        fs::write(
            &manifest,
            "{\"format\": \"something-else\", \"version\": 1, \"artifacts\": []}",
        )
        .unwrap();
        let e = ArtifactStore::open(&dir).unwrap_err();
        assert!(e.to_string().contains("format"), "{e}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn remove_is_idempotent_and_overwrite_is_allowed() {
        let dir = tmpdir("remove");
        let mut store = ArtifactStore::open(&dir).unwrap();
        store.put("m", "v1", b"first").unwrap();
        store.put("m", "v1", b"second").unwrap(); // overwrite: store is not the conflict gate
        assert_eq!(store.get("m", "v1").unwrap(), b"second");
        store.remove("m", "v1").unwrap();
        store.remove("m", "v1").unwrap(); // idempotent
        assert!(store.list().is_empty());
        assert!(ArtifactStore::open(&dir).unwrap().list().is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hex_checksums_roundtrip() {
        assert_eq!(parse_hex64("00000000000000ff").unwrap(), 255);
        assert_eq!(parse_hex64(&format!("{:016x}", u64::MAX)).unwrap(), u64::MAX);
        assert!(parse_hex64("zz").is_err());
        assert!(parse_hex64("").is_err());
    }
}

//! **Model artifacts**: the layer between persistence and serving.
//!
//! A fitted model leaves [`crate::estimator::persist`] as an *envelope*
//! — historically JSON, now alternatively the compact binary codec in
//! [`codec`] (same version gate, bitwise-identical floats, a fraction of
//! the bytes).  This module gives those envelopes a durable, verifiable
//! home and a name:
//!
//! * [`codec`] — the hand-rolled `AVIB` binary format: versioned,
//!   length-prefixed, no serde, every length validated before any
//!   allocation.  Interchangeable with the JSON envelope through
//!   [`crate::estimator::persist::pipeline_from_bytes`], which sniffs
//!   the magic and routes to the right decoder.
//! * [`store`] — [`ArtifactStore`]: a directory of artifacts indexed by
//!   `key@version`, each entry signed with its byte length and an
//!   FNV-1a-64 checksum in a manifest.  Corruption is a typed
//!   [`crate::error::AviError::Artifact`] at open/get time, never a
//!   silently wrong model.
//!
//! The serving control plane builds on both: `PushModel` /` PullModel` /
//! `ActivateModel` wire frames (see [`crate::coordinator::wire`]) move
//! artifacts into and out of a live server's store, and activation
//! decodes + hot-swaps through [`crate::coordinator::router`] without a
//! restart.

pub mod codec;
pub mod store;

pub use codec::{decode_model, decode_pipeline, encode_model, encode_pipeline};
pub use store::{fnv64, parse_hex64, ArtifactEntry, ArtifactStore};

use crate::pipeline::PipelineModel;

/// Deterministic fingerprint of a pipeline's *contents* (not its
/// encoding): the FNV-1a-64 of the canonical JSON envelope.  Two models
/// fingerprint equal iff their payloads are identical, whichever codec
/// carried them — the registry uses this to refuse re-registering a
/// `key@version` with different bytes.
pub fn model_fingerprint(model: &PipelineModel) -> u64 {
    fnv64(crate::estimator::persist::pipeline_to_json(model).as_bytes())
}

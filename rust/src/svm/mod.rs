//! SVM substrate: ℓ1-regularized squared-hinge linear SVM (the paper's
//! downstream classifier, §3.2 Line 10) and the polynomial-kernel SVM
//! baseline (§6.1).

pub mod kernel;
pub mod linear;
pub mod metrics;

pub use kernel::PolyKernelSvm;
pub use linear::{LinearSvm, LinearSvmConfig};
pub use metrics::error_rate;

//! ℓ1-regularized squared-hinge linear SVM, one-vs-rest.
//!
//! Mirrors the paper's scikit-learn setup (§6.1): squared hinge loss with
//! ℓ1 penalty ("to keep the number of used features as small as
//! possible"), tolerance 1e-4, iteration cap 10,000.  Optimizer: FISTA
//! (proximal accelerated gradient) with soft-threshold prox and
//! function-value restarts — deterministic and solver-free.
//!
//! Objective (binary, y ∈ {−1,+1}):
//! `F(w, b) = (1/m) Σ_i max(0, 1 − y_i(wᵀx_i + b))² + λ‖w‖₁`.

use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::linalg::dot;

/// Hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct LinearSvmConfig {
    /// ℓ1 penalty λ.
    pub lambda: f64,
    /// stop when the objective improves less than `tol` (rel.) — paper 1e-4.
    pub tol: f64,
    /// iteration cap — paper 10,000.
    pub max_iters: usize,
}

impl Default for LinearSvmConfig {
    fn default() -> Self {
        LinearSvmConfig { lambda: 1e-3, tol: 1e-4, max_iters: 10_000 }
    }
}

/// Trained one-vs-rest linear SVM.
#[derive(Clone, Debug)]
pub struct LinearSvm {
    /// per-class (w, b); binary problems store a single entry for class 1
    /// vs class 0.
    pub weights: Vec<(Vec<f64>, f64)>,
    pub n_classes: usize,
    pub config: LinearSvmConfig,
    /// iterations used per class head (diagnostics).
    pub iters: Vec<usize>,
}

impl LinearSvm {
    /// Train on features `x` (m×p) and labels `y` in {0, …, k−1}.
    pub fn fit(x: &Matrix, y: &[usize], n_classes: usize, config: LinearSvmConfig) -> Result<Self> {
        if x.rows() != y.len() {
            return Err(AviError::Data("LinearSvm::fit: rows != labels".into()));
        }
        if n_classes < 2 {
            return Err(AviError::Config("need ≥ 2 classes".into()));
        }
        let heads = if n_classes == 2 { 1 } else { n_classes };
        let mut weights = Vec::with_capacity(heads);
        let mut iters = Vec::with_capacity(heads);
        let l_smooth = lipschitz(x);
        for class in 0..heads {
            let target = if n_classes == 2 { 1 } else { class };
            let signs: Vec<f64> =
                y.iter().map(|&c| if c == target { 1.0 } else { -1.0 }).collect();
            let (w, b, it) = fista_binary(x, &signs, l_smooth, &config);
            weights.push((w, b));
            iters.push(it);
        }
        Ok(LinearSvm { weights, n_classes, config, iters })
    }

    /// Decision value(s) for one feature row.
    pub fn decision_row(&self, row: &[f64]) -> Vec<f64> {
        self.weights
            .iter()
            .map(|(w, b)| dot(w, row) + b)
            .collect()
    }

    /// Decision values for all rows (m × heads) — the per-class scores
    /// the serving protocol exposes alongside labels.
    pub fn decision(&self, x: &Matrix) -> Vec<Vec<f64>> {
        (0..x.rows()).map(|i| self.decision_row(x.row(i))).collect()
    }

    /// Label implied by a decision vector — the one argmax/threshold rule
    /// shared by the offline predict path and the serving protocol, so
    /// scores and labels can never disagree.  `total_cmp` keeps the
    /// argmax panic-free even if a NaN score slips through (the serving
    /// path rejects non-finite rows before they reach this, but a served
    /// worker must never be one comparison away from a crash).
    pub fn label_from_decision(&self, d: &[f64]) -> usize {
        if self.n_classes == 2 {
            usize::from(d[0] >= 0.0)
        } else {
            d.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        }
    }

    /// Predicted class for one row.
    pub fn predict_row(&self, row: &[f64]) -> usize {
        self.label_from_decision(&self.decision_row(row))
    }

    /// Predict all rows.
    pub fn predict(&self, x: &Matrix) -> Vec<usize> {
        (0..x.rows()).map(|i| self.predict_row(x.row(i))).collect()
    }

    /// Fraction of nonzero weights (ℓ1 sparsity diagnostic).
    pub fn weight_density(&self) -> f64 {
        let (nz, total) = self.weights.iter().fold((0usize, 0usize), |(nz, t), (w, _)| {
            (nz + w.iter().filter(|v| v.abs() > 1e-12).count(), t + w.len())
        });
        if total == 0 {
            0.0
        } else {
            nz as f64 / total as f64
        }
    }
}

/// Smoothness constant of the squared-hinge part: L ≤ 2·λmax([X 1]ᵀ[X 1])/m,
/// estimated by power iteration on the augmented data matrix.
fn lipschitz(x: &Matrix) -> f64 {
    let m = x.rows();
    let p = x.cols();
    let mut v = vec![1.0; p + 1];
    let mut lam = 1.0;
    for _ in 0..25 {
        // u = [X 1] v;  v' = [X 1]ᵀ u
        let mut u = vec![0.0; m];
        for i in 0..m {
            u[i] = dot(x.row(i), &v[..p]) + v[p];
        }
        let mut v_new = vec![0.0; p + 1];
        for i in 0..m {
            let ui = u[i];
            if ui == 0.0 {
                continue;
            }
            for (j, xj) in x.row(i).iter().enumerate() {
                v_new[j] += ui * xj;
            }
            v_new[p] += ui;
        }
        let norm = crate::linalg::norm2(&v_new);
        if norm <= 1e-300 {
            return 2.0 / m as f64;
        }
        lam = norm;
        for (vi, ni) in v.iter_mut().zip(v_new.iter()) {
            *vi = ni / norm;
        }
    }
    2.0 * lam / m as f64
}

/// FISTA on one binary head.  Returns (w, b, iterations).
fn fista_binary(
    x: &Matrix,
    signs: &[f64],
    l_smooth: f64,
    cfg: &LinearSvmConfig,
) -> (Vec<f64>, f64, usize) {
    let m = x.rows();
    let p = x.cols();
    let step = 1.0 / l_smooth.max(1e-12);
    let mut w = vec![0.0; p];
    let mut b = 0.0f64;
    let mut wz = w.clone(); // extrapolated point
    let mut bz = 0.0f64;
    let mut t_k = 1.0f64;
    let mut f_prev = f64::INFINITY;
    let mut used = 0;

    for it in 0..cfg.max_iters {
        used = it + 1;
        // gradient of the smooth part at (wz, bz)
        let mut gw = vec![0.0; p];
        let mut gb = 0.0f64;
        let mut loss = 0.0f64;
        for i in 0..m {
            let margin = signs[i] * (dot(x.row(i), &wz) + bz);
            let viol = 1.0 - margin;
            if viol > 0.0 {
                loss += viol * viol;
                let coef = -2.0 * viol * signs[i] / m as f64;
                for (gj, xj) in gw.iter_mut().zip(x.row(i).iter()) {
                    *gj += coef * xj;
                }
                gb += coef;
            }
        }
        loss /= m as f64;

        // proximal step: soft threshold on w, plain step on b
        let thresh = cfg.lambda * step;
        let mut w_new = vec![0.0; p];
        for j in 0..p {
            let v = wz[j] - step * gw[j];
            w_new[j] = soft_threshold(v, thresh);
        }
        let b_new = bz - step * gb;

        // objective at the new point (for restart/stop tests)
        let f_new = objective(x, signs, &w_new, b_new, cfg.lambda);
        if f_new > f_prev {
            // restart momentum
            t_k = 1.0;
            wz = w.clone();
            bz = b;
            continue;
        }
        let rel_impr = (f_prev - f_new) / f_prev.max(1e-12);
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_k * t_k).sqrt());
        let beta = (t_k - 1.0) / t_next;
        for j in 0..p {
            wz[j] = w_new[j] + beta * (w_new[j] - w[j]);
        }
        bz = b_new + beta * (b_new - b);
        w = w_new;
        b = b_new;
        t_k = t_next;
        let _ = loss;
        if rel_impr < cfg.tol && it > 3 {
            break;
        }
        f_prev = f_new;
    }
    (w, b, used)
}

#[inline]
fn soft_threshold(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

fn objective(x: &Matrix, signs: &[f64], w: &[f64], b: f64, lambda: f64) -> f64 {
    let m = x.rows();
    let mut loss = 0.0;
    for i in 0..m {
        let viol = 1.0 - signs[i] * (dot(x.row(i), w) + b);
        if viol > 0.0 {
            loss += viol * viol;
        }
    }
    loss / m as f64 + lambda * crate::linalg::norm1(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn separable(m: usize, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        let mut y = Vec::with_capacity(m);
        for i in 0..m {
            let c = i % 2;
            let base = if c == 0 { 0.2 } else { 0.8 };
            x.set(i, 0, base + 0.1 * rng.normal());
            x.set(i, 1, rng.uniform());
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn separates_linearly_separable_data() {
        let (x, y) = separable(200, 1);
        let svm = LinearSvm::fit(&x, &y, 2, LinearSvmConfig::default()).unwrap();
        let pred = svm.predict(&x);
        let err = crate::svm::metrics::error_rate(&pred, &y);
        assert!(err < 0.02, "training error {err}");
    }

    #[test]
    fn l1_zeroes_irrelevant_features() {
        // feature 1 is pure noise; with a strong ℓ1 penalty its weight → 0
        let (x, y) = separable(400, 2);
        let cfg = LinearSvmConfig { lambda: 5e-2, ..Default::default() };
        let svm = LinearSvm::fit(&x, &y, 2, cfg).unwrap();
        let (w, _) = &svm.weights[0];
        assert!(w[0].abs() > 1e-6, "informative weight vanished: {w:?}");
        assert!(w[1].abs() < 1e-6, "noise weight survived: {w:?}");
        assert!(svm.weight_density() <= 0.5);
    }

    #[test]
    fn multiclass_one_vs_rest() {
        // three clusters on a line
        let mut rng = Rng::new(3);
        let m = 300;
        let mut x = Matrix::zeros(m, 1);
        let mut y = Vec::new();
        for i in 0..m {
            let c = i % 3;
            x.set(i, 0, 0.15 + 0.35 * c as f64 + 0.03 * rng.normal());
            y.push(c);
        }
        let svm = LinearSvm::fit(&x, &y, 3, LinearSvmConfig::default()).unwrap();
        let err = crate::svm::metrics::error_rate(&svm.predict(&x), &y);
        assert!(err < 0.05, "error {err}");
        assert_eq!(svm.weights.len(), 3);
    }

    #[test]
    fn objective_decreases() {
        let (x, y) = separable(100, 4);
        let signs: Vec<f64> = y.iter().map(|&c| if c == 1 { 1.0 } else { -1.0 }).collect();
        let l = lipschitz(&x);
        let cfg = LinearSvmConfig::default();
        let (w, b, _) = fista_binary(&x, &signs, l, &cfg);
        let f_trained = objective(&x, &signs, &w, b, cfg.lambda);
        let f_zero = objective(&x, &signs, &vec![0.0; 2], 0.0, cfg.lambda);
        assert!(f_trained < f_zero, "{f_trained} !< {f_zero}");
    }

    #[test]
    fn fit_validates_input() {
        let x = Matrix::zeros(3, 2);
        assert!(LinearSvm::fit(&x, &[0, 1], 2, LinearSvmConfig::default()).is_err());
        assert!(LinearSvm::fit(&x, &[0, 0, 0], 1, LinearSvmConfig::default()).is_err());
    }
}

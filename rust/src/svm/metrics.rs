//! Classification metrics.

/// Fraction of mismatches (the paper's "test set error in percent" / 100).
pub fn error_rate(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let wrong = pred.iter().zip(truth.iter()).filter(|(p, t)| p != t).count();
    wrong as f64 / pred.len() as f64
}

/// Accuracy = 1 − error.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    1.0 - error_rate(pred, truth)
}

/// k×k confusion matrix (rows = truth, cols = prediction).
pub fn confusion(pred: &[usize], truth: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; k]; k];
    for (&p, &t) in pred.iter().zip(truth.iter()) {
        m[t][p] += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        let pred = [0, 1, 1, 0];
        let truth = [0, 1, 0, 0];
        assert!((error_rate(&pred, &truth) - 0.25).abs() < 1e-15);
        assert!((accuracy(&pred, &truth) - 0.75).abs() < 1e-15);
        assert_eq!(error_rate(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_counts() {
        let pred = [0, 1, 1, 0];
        let truth = [0, 1, 0, 0];
        let c = confusion(&pred, &truth, 2);
        assert_eq!(c[0][0], 2);
        assert_eq!(c[0][1], 1);
        assert_eq!(c[1][1], 1);
        assert_eq!(c[1][0], 0);
    }
}

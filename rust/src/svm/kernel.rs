//! Polynomial-kernel SVM baseline (paper §6.1).
//!
//! Kernelized Pegasos (Shalev-Shwartz et al.) on the hinge loss with ℓ2
//! regularization and kernel `K(x, z) = (γ·xᵀz + 1)^degree`.  The paper
//! caps the baseline at 10,000 iterations — which is precisely why the
//! poly-kernel SVM falls apart on the 245k-sample skin dataset (Table 3);
//! we reproduce that behaviour by keeping the same cap, and the kernel
//! prediction cost O(#SV · q) reproduces its slow test times.

use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::linalg::dot;
use crate::util::rng::Rng;

/// Hyperparameters for the poly-kernel baseline.
#[derive(Clone, Copy, Debug)]
pub struct PolyKernelConfig {
    pub degree: u32,
    /// ℓ2 regularization λ (Pegasos's 1/(λT) step scale).
    pub lambda: f64,
    /// kernel scale γ.
    pub gamma: f64,
    /// iteration cap — paper: 10,000.
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for PolyKernelConfig {
    fn default() -> Self {
        PolyKernelConfig { degree: 3, lambda: 1e-3, gamma: 1.0, max_iters: 10_000, seed: 0 }
    }
}

/// One-vs-rest polynomial-kernel SVM.
pub struct PolyKernelSvm {
    config: PolyKernelConfig,
    n_classes: usize,
    /// support vectors (rows) shared across heads.
    support: Matrix,
    /// per-head α_i·y_i coefficients over the support rows.
    alphas: Vec<Vec<f64>>,
}

impl PolyKernelSvm {
    pub fn fit(
        x: &Matrix,
        y: &[usize],
        n_classes: usize,
        config: PolyKernelConfig,
    ) -> Result<Self> {
        if x.rows() != y.len() {
            return Err(AviError::Data("PolyKernelSvm::fit: rows != labels".into()));
        }
        let m = x.rows();
        let heads = if n_classes == 2 { 1 } else { n_classes };
        // Pegasos visits at most max_iters random samples; only visited
        // samples can become support vectors.  Collect per-head α over a
        // shared index set for memory sanity.
        let mut alphas_by_index: Vec<std::collections::HashMap<usize, f64>> =
            vec![std::collections::HashMap::new(); heads];
        let t_cap = config.max_iters;
        for (head, alpha) in alphas_by_index.iter_mut().enumerate() {
            let target = if n_classes == 2 { 1 } else { head };
            let mut rng = Rng::new(config.seed ^ (head as u64).wrapping_mul(0x9E37));
            for t in 1..=t_cap {
                let i = rng.below(m);
                let yi = if y[i] == target { 1.0 } else { -1.0 };
                // f(x_i) = 1/(λ t) Σ_j α_j y_j K(x_j, x_i)
                let mut f = 0.0;
                for (&j, &aj) in alpha.iter() {
                    f += aj * poly_kernel(x.row(j), x.row(i), &config);
                }
                f /= config.lambda * t as f64;
                if yi * f < 1.0 {
                    *alpha.entry(i).or_insert(0.0) += yi;
                }
            }
        }
        // union of support indices
        let mut support_idx: Vec<usize> = alphas_by_index
            .iter()
            .flat_map(|a| a.keys().copied())
            .collect();
        support_idx.sort_unstable();
        support_idx.dedup();
        let support_rows: Vec<Vec<f64>> =
            support_idx.iter().map(|&i| x.row(i).to_vec()).collect();
        let support = if support_rows.is_empty() {
            Matrix::zeros(0, x.cols())
        } else {
            Matrix::from_rows(&support_rows)?
        };
        let scale = 1.0 / (config.lambda * t_cap as f64);
        let alphas: Vec<Vec<f64>> = alphas_by_index
            .iter()
            .map(|a| {
                support_idx
                    .iter()
                    .map(|i| a.get(i).copied().unwrap_or(0.0) * scale)
                    .collect()
            })
            .collect();
        Ok(PolyKernelSvm { config, n_classes, support, alphas })
    }

    /// Number of support vectors (test-time cost driver).
    pub fn n_support(&self) -> usize {
        self.support.rows()
    }

    pub fn decision_row(&self, row: &[f64]) -> Vec<f64> {
        self.alphas
            .iter()
            .map(|alpha| {
                let mut f = 0.0;
                for (j, aj) in alpha.iter().enumerate() {
                    if *aj != 0.0 {
                        f += aj * poly_kernel(self.support.row(j), row, &self.config);
                    }
                }
                f
            })
            .collect()
    }

    pub fn predict_row(&self, row: &[f64]) -> usize {
        let d = self.decision_row(row);
        if self.n_classes == 2 {
            usize::from(d[0] >= 0.0)
        } else {
            // total_cmp: NaN-safe argmax (see LinearSvm::label_from_decision)
            d.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        }
    }

    pub fn predict(&self, x: &Matrix) -> Vec<usize> {
        (0..x.rows()).map(|i| self.predict_row(x.row(i))).collect()
    }
}

#[inline]
fn poly_kernel(a: &[f64], b: &[f64], cfg: &PolyKernelConfig) -> f64 {
    (cfg.gamma * dot(a, b) + 1.0).powi(cfg.degree as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// XOR-ish data (centered so sign(a·b) is the label): not linearly
    /// separable, poly kernel (deg ≥ 2) solves it.
    fn xor_data(m: usize, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(m, 2);
        let mut y = Vec::with_capacity(m);
        for i in 0..m {
            let a = rng.uniform() - 0.5;
            let b = rng.uniform() - 0.5;
            x.set(i, 0, a);
            x.set(i, 1, b);
            y.push(usize::from(a * b > 0.0));
        }
        (x, y)
    }

    #[test]
    fn solves_xor_with_degree_2() {
        let (x, y) = xor_data(300, 1);
        let cfg = PolyKernelConfig {
            degree: 2,
            lambda: 1e-5,
            gamma: 4.0,
            max_iters: 10_000,
            ..Default::default()
        };
        let svm = PolyKernelSvm::fit(&x, &y, 2, cfg).unwrap();
        let err = crate::svm::metrics::error_rate(&svm.predict(&x), &y);
        assert!(err < 0.05, "training error {err}");
        assert!(svm.n_support() > 0);
    }

    #[test]
    fn iteration_cap_limits_quality_on_large_data() {
        // With a tiny iteration budget relative to m, accuracy degrades —
        // the paper's skin phenomenon in miniature.
        let (x, y) = xor_data(5000, 2);
        let starved = PolyKernelConfig {
            degree: 2,
            lambda: 1e-5,
            gamma: 4.0,
            max_iters: 60,
            ..Default::default()
        };
        let svm = PolyKernelSvm::fit(&x, &y, 2, starved).unwrap();
        let err_starved = crate::svm::metrics::error_rate(&svm.predict(&x), &y);
        let ample = PolyKernelConfig {
            degree: 2,
            lambda: 1e-5,
            gamma: 4.0,
            max_iters: 8000,
            ..Default::default()
        };
        let svm2 = PolyKernelSvm::fit(&x, &y, 2, ample).unwrap();
        let err_ample = crate::svm::metrics::error_rate(&svm2.predict(&x), &y);
        assert!(
            err_starved > err_ample,
            "starved {err_starved} vs ample {err_ample}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = xor_data(200, 3);
        let cfg = PolyKernelConfig { max_iters: 500, ..Default::default() };
        let a = PolyKernelSvm::fit(&x, &y, 2, cfg).unwrap();
        let b = PolyKernelSvm::fit(&x, &y, 2, cfg).unwrap();
        assert_eq!(a.predict(&x), b.predict(&x));
    }

    #[test]
    fn validates_shapes() {
        let x = Matrix::zeros(3, 2);
        assert!(PolyKernelSvm::fit(&x, &[0, 1], 2, PolyKernelConfig::default()).is_err());
    }
}

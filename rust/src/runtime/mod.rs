//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, lowered
//! from the JAX/Pallas L2/L1 stack by `make artifacts`), compile them once
//! on the CPU PJRT client, and serve them to the L3 hot paths.
//!
//! Interchange is HLO **text** — xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md §6).
//!
//! [`XlaBackend`] adapts the fixed-shape artifacts to arbitrary problem
//! sizes: rows are streamed in `M_TILE`-row tiles with partial-sum
//! accumulation (this is what makes OAVI linear in m end-to-end), live
//! dimensions are zero-padded to the next artifact width, and any shape
//! beyond the largest artifact falls back to the native backend (bit-for-
//! bit the same math in f64, covered by parity tests).

pub mod backend;

pub use backend::XlaBackend;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{AviError, Result};

/// Artifact names understood by the runtime (shapes encoded in the name).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `gram_update_{M}x{L}` — (A:(M,L), b:(M)) → (Aᵀb:(L), bᵀb:())
    GramUpdate { m_tile: usize, l_pad: usize },
    /// `oracle_solve_{L}` — (N, Atb, btb, mask) → (c, m·MSE)
    OracleSolve { l_pad: usize },
    /// `ihb_update_{L}` — (N, Atb, btb, mask, k_onehot) → N'
    IhbUpdate { l_pad: usize },
    /// `transform_{M}x{L}x{G}` — (A, C, U) → |A·C + U|
    Transform { m_tile: usize, l_pad: usize, g_pad: usize },
}

fn parse_artifact_name(stem: &str) -> Option<ArtifactKind> {
    let nums = |s: &str| -> Option<Vec<usize>> {
        s.split('x').map(|p| p.parse::<usize>().ok()).collect()
    };
    if let Some(rest) = stem.strip_prefix("gram_update_") {
        let d = nums(rest)?;
        if d.len() == 2 {
            return Some(ArtifactKind::GramUpdate { m_tile: d[0], l_pad: d[1] });
        }
    } else if let Some(rest) = stem.strip_prefix("oracle_solve_") {
        return Some(ArtifactKind::OracleSolve { l_pad: rest.parse().ok()? });
    } else if let Some(rest) = stem.strip_prefix("ihb_update_") {
        return Some(ArtifactKind::IhbUpdate { l_pad: rest.parse().ok()? });
    } else if let Some(rest) = stem.strip_prefix("transform_") {
        let d = nums(rest)?;
        if d.len() == 3 {
            return Some(ArtifactKind::Transform { m_tile: d[0], l_pad: d[1], g_pad: d[2] });
        }
    }
    None
}

/// A compiled-artifact registry over one PJRT CPU client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    /// lazily compiled executables (compile once, reuse forever).
    exes: Mutex<HashMap<ArtifactKind, xla::PjRtLoadedExecutable>>,
    available: Vec<(ArtifactKind, PathBuf)>,
}

impl PjrtRuntime {
    /// Discover artifacts in `dir` and connect the PJRT CPU client.
    /// Compilation is lazy (first use per artifact).
    pub fn load(dir: &Path) -> Result<Self> {
        if !dir.is_dir() {
            return Err(AviError::Runtime(format!(
                "artifact dir {} missing — run `make artifacts`",
                dir.display()
            )));
        }
        let mut available = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if let Some(stem) = name.strip_suffix(".hlo.txt") {
                if let Some(kind) = parse_artifact_name(stem) {
                    available.push((kind, path.clone()));
                }
            }
        }
        if available.is_empty() {
            return Err(AviError::Runtime(format!(
                "no artifacts in {} — run `make artifacts`",
                dir.display()
            )));
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| AviError::Runtime(format!("PJRT client: {e}")))?;
        Ok(PjrtRuntime { client, exes: Mutex::new(HashMap::new()), available })
    }

    /// Default artifact location (repo-root `artifacts/`).
    pub fn load_default() -> Result<Self> {
        Self::load(Path::new("artifacts"))
    }

    /// All discovered artifact kinds.
    pub fn artifacts(&self) -> Vec<ArtifactKind> {
        self.available.iter().map(|(k, _)| k.clone()).collect()
    }

    /// Smallest gram-update artifact with `l_pad ≥ ell`, if any.
    pub fn gram_artifact_for(&self, ell: usize) -> Option<(usize, usize)> {
        self.available
            .iter()
            .filter_map(|(k, _)| match k {
                ArtifactKind::GramUpdate { m_tile, l_pad } if *l_pad >= ell => {
                    Some((*m_tile, *l_pad))
                }
                _ => None,
            })
            .min_by_key(|(_, l)| *l)
    }

    /// Smallest transform artifact with `l_pad ≥ ell` and `g_pad ≥ g`.
    pub fn transform_artifact_for(&self, ell: usize, g: usize) -> Option<(usize, usize, usize)> {
        self.available
            .iter()
            .filter_map(|(k, _)| match k {
                ArtifactKind::Transform { m_tile, l_pad, g_pad }
                    if *l_pad >= ell && *g_pad >= g =>
                {
                    Some((*m_tile, *l_pad, *g_pad))
                }
                _ => None,
            })
            .min_by_key(|(_, l, g)| (*l, *g))
    }

    /// Execute an artifact on literals, compiling (and caching) on first use.
    pub fn execute(&self, kind: &ArtifactKind, args: &[xla::Literal]) -> Result<xla::Literal> {
        {
            let exes = self.exes.lock().expect("exes poisoned");
            if let Some(exe) = exes.get(kind) {
                return run_exe(exe, args);
            }
        }
        // compile outside the lock (slow), then insert
        let path = self
            .available
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, p)| p.clone())
            .ok_or_else(|| AviError::Runtime(format!("artifact {kind:?} not available")))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| AviError::Runtime("non-utf8 path".into()))?,
        )
        .map_err(|e| AviError::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| AviError::Runtime(format!("compile {}: {e}", path.display())))?;
        let out = run_exe(&exe, args)?;
        self.exes.lock().expect("exes poisoned").insert(kind.clone(), exe);
        Ok(out)
    }

    /// `(Aᵀb, bᵀb)` over one padded row tile through the gram artifact.
    /// `a_tile` is row-major (m_tile × l_pad) f32, `b_tile` is (m_tile) f32.
    pub fn gram_update_tile(
        &self,
        m_tile: usize,
        l_pad: usize,
        a_tile: &[f32],
        b_tile: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        debug_assert_eq!(a_tile.len(), m_tile * l_pad);
        debug_assert_eq!(b_tile.len(), m_tile);
        let kind = ArtifactKind::GramUpdate { m_tile, l_pad };
        let a = xla::Literal::vec1(a_tile)
            .reshape(&[m_tile as i64, l_pad as i64])
            .map_err(|e| AviError::Runtime(format!("reshape A: {e}")))?;
        let b = xla::Literal::vec1(b_tile);
        let out = self.execute(&kind, &[a, b])?;
        let (atb, btb) = out
            .to_tuple2()
            .map_err(|e| AviError::Runtime(format!("tuple2: {e}")))?;
        let atb_v = atb
            .to_vec::<f32>()
            .map_err(|e| AviError::Runtime(format!("atb to_vec: {e}")))?;
        let btb_v = btb
            .to_vec::<f32>()
            .map_err(|e| AviError::Runtime(format!("btb to_vec: {e}")))?;
        Ok((atb_v, btb_v[0]))
    }

    /// `|A·C + U|` over one padded row tile through the transform artifact.
    pub fn transform_tile(
        &self,
        m_tile: usize,
        l_pad: usize,
        g_pad: usize,
        a_tile: &[f32],
        c: &[f32],
        u_tile: &[f32],
    ) -> Result<Vec<f32>> {
        let kind = ArtifactKind::Transform { m_tile, l_pad, g_pad };
        let a = xla::Literal::vec1(a_tile)
            .reshape(&[m_tile as i64, l_pad as i64])
            .map_err(|e| AviError::Runtime(format!("reshape A: {e}")))?;
        let cm = xla::Literal::vec1(c)
            .reshape(&[l_pad as i64, g_pad as i64])
            .map_err(|e| AviError::Runtime(format!("reshape C: {e}")))?;
        let u = xla::Literal::vec1(u_tile)
            .reshape(&[m_tile as i64, g_pad as i64])
            .map_err(|e| AviError::Runtime(format!("reshape U: {e}")))?;
        let out = self.execute(&kind, &[a, cm, u])?;
        let t = out
            .to_tuple1()
            .map_err(|e| AviError::Runtime(format!("tuple1: {e}")))?;
        t.to_vec::<f32>()
            .map_err(|e| AviError::Runtime(format!("transform to_vec: {e}")))
    }
}

fn run_exe(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
    let bufs = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| AviError::Runtime(format!("execute: {e}")))?;
    bufs[0][0]
        .to_literal_sync()
        .map_err(|e| AviError::Runtime(format!("to_literal: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(
            parse_artifact_name("gram_update_4096x256"),
            Some(ArtifactKind::GramUpdate { m_tile: 4096, l_pad: 256 })
        );
        assert_eq!(
            parse_artifact_name("oracle_solve_64"),
            Some(ArtifactKind::OracleSolve { l_pad: 64 })
        );
        assert_eq!(
            parse_artifact_name("ihb_update_256"),
            Some(ArtifactKind::IhbUpdate { l_pad: 256 })
        );
        assert_eq!(
            parse_artifact_name("transform_4096x64x256"),
            Some(ArtifactKind::Transform { m_tile: 4096, l_pad: 64, g_pad: 256 })
        );
        assert_eq!(parse_artifact_name("bogus_3"), None);
        assert_eq!(parse_artifact_name("gram_update_4096"), None);
    }

    #[test]
    fn load_errors_without_artifacts() {
        let dir = std::env::temp_dir().join("avi_scale_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(PjrtRuntime::load(&dir).is_err());
        assert!(PjrtRuntime::load(Path::new("/definitely/not/here")).is_err());
    }

    // Execution tests live in rust/tests/runtime_parity.rs (they need the
    // artifacts built by `make artifacts`).
}

//! [`XlaBackend`]: the [`crate::backend::ComputeBackend`] implementation
//! that routes the streaming hot paths through the AOT PJRT artifacts.
//!
//! Tiling contract (DESIGN.md §6): each [`ColumnStore`] shard is
//! processed independently in `M_TILE`-row chunks (partial tiles —
//! including shard boundaries — are zero-padded; zero rows contribute
//! nothing to either `Aᵀb` or `bᵀb`, and transform rows beyond the shard
//! are discarded); the live column count ℓ is padded to the smallest
//! artifact `L_PAD ≥ ℓ`.  Shapes beyond every artifact fall back to the
//! native backend so the system never refuses work.

use std::sync::Arc;

use crate::backend::store::{
    gram_panel_fast_seq, gram_panel_seq, panel_cross_partial, panel_diag_partial,
};
use crate::backend::{
    CandidatePanel, ColumnStore, ComputeBackend, CrossMode, NativeBackend, NumericsMode,
    PanelStats,
};
use crate::linalg::dense::Matrix;
use crate::runtime::PjrtRuntime;

/// PJRT-backed compute backend with native fallback.
pub struct XlaBackend {
    rt: Arc<PjrtRuntime>,
    fallback: NativeBackend,
}

impl XlaBackend {
    pub fn new(rt: Arc<PjrtRuntime>) -> Self {
        XlaBackend { rt, fallback: NativeBackend }
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.rt
    }
}

impl ComputeBackend for XlaBackend {
    fn gram_stats(&self, cols: &ColumnStore, b_col: &[f64]) -> (Vec<f64>, f64) {
        let ell = cols.len();
        let Some((m_tile, l_pad)) = self.rt.gram_artifact_for(ell) else {
            return self.fallback.gram_stats(cols, b_col);
        };
        let mut atb = vec![0.0f64; ell];
        let mut btb = 0.0f64;
        let mut a_tile = vec![0.0f32; m_tile * l_pad];
        let mut b_tile = vec![0.0f32; m_tile];
        for s in 0..cols.n_shards() {
            let range = cols.shard_range(s);
            let rows = range.len();
            // one lease per shard: pins a spilled block across the tiles
            let lease = cols.lease(s);
            let mut row = 0usize;
            while row < rows {
                let take = (rows - row).min(m_tile);
                // pack the row tile (row-major) from the shard's
                // column-major slices
                a_tile.iter_mut().for_each(|v| *v = 0.0);
                b_tile.iter_mut().for_each(|v| *v = 0.0);
                for j in 0..ell {
                    let col = lease.col(j);
                    for i in 0..take {
                        a_tile[i * l_pad + j] = col[row + i] as f32;
                    }
                }
                for i in 0..take {
                    b_tile[i] = b_col[range.start + row + i] as f32;
                }
                match self.rt.gram_update_tile(m_tile, l_pad, &a_tile, &b_tile) {
                    Ok((atb_part, btb_part)) => {
                        for (a, p) in atb.iter_mut().zip(atb_part.iter()) {
                            *a += *p as f64;
                        }
                        btb += btb_part as f64;
                    }
                    Err(_) => return self.fallback.gram_stats(cols, b_col),
                }
                row += take;
            }
        }
        (atb, btb)
    }

    fn gram_panel(
        &self,
        cols: &ColumnStore,
        panel: &CandidatePanel,
        cross: CrossMode,
        numerics: NumericsMode,
    ) -> PanelStats {
        let ell = cols.len();
        let k = panel.len();
        if self.rt.gram_artifact_for(ell).is_none() {
            // beyond every artifact width: native panel path in the
            // requested numerics mode
            return match numerics {
                NumericsMode::Exact => gram_panel_seq(cols, panel, cross),
                NumericsMode::Fast => gram_panel_fast_seq(cols, panel, cross),
            };
        }
        // Store-vs-panel block through the AOT gram artifact, one tiled
        // pass per panel column (gram_stats falls back natively on any
        // tile error).  The artifact path already accumulates in f32, so
        // NumericsMode::Fast adds nothing here and is ignored.  The k×k
        // cross triangle / lazy diagonal stays on the exact f64 native
        // kernel: its entries feed the Theorem 4.9 inverse append, where
        // f32 rounding would accumulate into the maintained N.
        let mut atb = Vec::with_capacity(ell * k);
        for c in 0..k {
            let b = panel.col(c);
            let (a, _btb) = self.gram_stats(cols, &b);
            atb.extend_from_slice(&a);
        }
        match cross {
            CrossMode::Eager => {
                let mut cross_buf = vec![0.0f64; k * (k + 1) / 2];
                for s in 0..panel.n_shards() {
                    let pc = panel_cross_partial(panel, s, 0..k);
                    for (a, p) in cross_buf.iter_mut().zip(pc.iter()) {
                        *a += *p;
                    }
                }
                PanelStats::new(ell, k, atb, cross_buf)
            }
            CrossMode::Lazy => {
                let mut diag = vec![0.0f64; k];
                for s in 0..panel.n_shards() {
                    let pd = panel_diag_partial(panel, s, 0..k);
                    for (a, p) in diag.iter_mut().zip(pd.iter()) {
                        *a += *p;
                    }
                }
                PanelStats::new_lazy(ell, k, atb, diag)
            }
            CrossMode::Skip => PanelStats::new(ell, k, atb, Vec::new()),
        }
    }

    fn transform_abs(&self, cols: &ColumnStore, c: &Matrix, u: &Matrix) -> Matrix {
        let ell = cols.len();
        let m = u.rows();
        let g = u.cols();
        let Some((m_tile, l_pad, g_pad)) = self.rt.transform_artifact_for(ell, g) else {
            return self.fallback.transform_abs(cols, c, u);
        };
        let mut out = Matrix::zeros(m, g);
        // pack C once (ℓ×g live block inside l_pad×g_pad)
        let mut c_pad = vec![0.0f32; l_pad * g_pad];
        for j in 0..ell {
            for k in 0..g {
                c_pad[j * g_pad + k] = c.get(j, k) as f32;
            }
        }
        let mut a_tile = vec![0.0f32; m_tile * l_pad];
        let mut u_tile = vec![0.0f32; m_tile * g_pad];
        for s in 0..cols.n_shards() {
            let range = cols.shard_range(s);
            let rows = range.len();
            // one lease per shard: pins a spilled block across the tiles
            let lease = cols.lease(s);
            let mut row = 0usize;
            while row < rows {
                let take = (rows - row).min(m_tile);
                a_tile.iter_mut().for_each(|v| *v = 0.0);
                u_tile.iter_mut().for_each(|v| *v = 0.0);
                for j in 0..ell {
                    let col = lease.col(j);
                    for i in 0..take {
                        a_tile[i * l_pad + j] = col[row + i] as f32;
                    }
                }
                for i in 0..take {
                    for k in 0..g {
                        u_tile[i * g_pad + k] = u.get(range.start + row + i, k) as f32;
                    }
                }
                match self.rt.transform_tile(m_tile, l_pad, g_pad, &a_tile, &c_pad, &u_tile)
                {
                    Ok(vals) => {
                        for i in 0..take {
                            for k in 0..g {
                                out.set(range.start + row + i, k, vals[i * g_pad + k] as f64);
                            }
                        }
                    }
                    Err(_) => return self.fallback.transform_abs(cols, c, u),
                }
                row += take;
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

// Execution-level tests (need built artifacts) are in
// rust/tests/runtime_parity.rs.

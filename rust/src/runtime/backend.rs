//! [`XlaBackend`]: the [`crate::backend::ComputeBackend`] implementation
//! that routes the streaming hot paths through the AOT PJRT artifacts.
//!
//! Tiling contract (DESIGN.md §6): rows are processed in `M_TILE`-row
//! chunks (the final partial tile is zero-padded — zero rows contribute
//! nothing to either `Aᵀb` or `bᵀb`, and transform rows beyond m are
//! discarded); the live column count ℓ is padded to the smallest artifact
//! `L_PAD ≥ ℓ`.  Shapes beyond every artifact fall back to the native
//! backend so the system never refuses work.

use std::sync::Arc;

use crate::backend::{ComputeBackend, NativeBackend};
use crate::linalg::dense::Matrix;
use crate::runtime::PjrtRuntime;

/// PJRT-backed compute backend with native fallback.
pub struct XlaBackend {
    rt: Arc<PjrtRuntime>,
    fallback: NativeBackend,
}

impl XlaBackend {
    pub fn new(rt: Arc<PjrtRuntime>) -> Self {
        XlaBackend { rt, fallback: NativeBackend }
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.rt
    }
}

impl ComputeBackend for XlaBackend {
    fn gram_stats(&self, cols: &[Vec<f64>], b_col: &[f64]) -> (Vec<f64>, f64) {
        let ell = cols.len();
        let m = b_col.len();
        let Some((m_tile, l_pad)) = self.rt.gram_artifact_for(ell) else {
            return self.fallback.gram_stats(cols, b_col);
        };
        let mut atb = vec![0.0f64; ell];
        let mut btb = 0.0f64;
        let mut a_tile = vec![0.0f32; m_tile * l_pad];
        let mut b_tile = vec![0.0f32; m_tile];
        let mut row = 0usize;
        while row < m {
            let take = (m - row).min(m_tile);
            // pack the row tile (row-major) from the column-major inputs
            a_tile.iter_mut().for_each(|v| *v = 0.0);
            b_tile.iter_mut().for_each(|v| *v = 0.0);
            for (j, col) in cols.iter().enumerate() {
                for i in 0..take {
                    a_tile[i * l_pad + j] = col[row + i] as f32;
                }
            }
            for i in 0..take {
                b_tile[i] = b_col[row + i] as f32;
            }
            match self.rt.gram_update_tile(m_tile, l_pad, &a_tile, &b_tile) {
                Ok((atb_part, btb_part)) => {
                    for j in 0..ell {
                        atb[j] += atb_part[j] as f64;
                    }
                    btb += btb_part as f64;
                }
                Err(_) => return self.fallback.gram_stats(cols, b_col),
            }
            row += take;
        }
        (atb, btb)
    }

    fn transform_abs(&self, cols: &[Vec<f64>], c: &Matrix, u: &Matrix) -> Matrix {
        let ell = cols.len();
        let m = u.rows();
        let g = u.cols();
        let Some((m_tile, l_pad, g_pad)) = self.rt.transform_artifact_for(ell, g) else {
            return self.fallback.transform_abs(cols, c, u);
        };
        let mut out = Matrix::zeros(m, g);
        // pack C once (ℓ×g live block inside l_pad×g_pad)
        let mut c_pad = vec![0.0f32; l_pad * g_pad];
        for j in 0..ell {
            for k in 0..g {
                c_pad[j * g_pad + k] = c.get(j, k) as f32;
            }
        }
        let mut a_tile = vec![0.0f32; m_tile * l_pad];
        let mut u_tile = vec![0.0f32; m_tile * g_pad];
        let mut row = 0usize;
        while row < m {
            let take = (m - row).min(m_tile);
            a_tile.iter_mut().for_each(|v| *v = 0.0);
            u_tile.iter_mut().for_each(|v| *v = 0.0);
            for (j, col) in cols.iter().enumerate() {
                for i in 0..take {
                    a_tile[i * l_pad + j] = col[row + i] as f32;
                }
            }
            for i in 0..take {
                for k in 0..g {
                    u_tile[i * g_pad + k] = u.get(row + i, k) as f32;
                }
            }
            match self.rt.transform_tile(m_tile, l_pad, g_pad, &a_tile, &c_pad, &u_tile) {
                Ok(vals) => {
                    for i in 0..take {
                        for k in 0..g {
                            out.set(row + i, k, vals[i * g_pad + k] as f64);
                        }
                    }
                }
                Err(_) => return self.fallback.transform_abs(cols, c, u),
            }
            row += take;
        }
        out
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

// Execution-level tests (need built artifacts) are in
// rust/tests/runtime_parity.rs.

//! ℓ1-ball linear minimization oracle + active-set state shared by the
//! Frank–Wolfe family.
//!
//! Vertices of the ℓ1-ball of radius r are `±r·e_i`; we encode them as
//! `(coord, sign)`.  The active set keeps the convex-combination weights
//! `λ_v` and maintains both the iterate `y = Σ λ_v v` and the product
//! `By` incrementally — each vertex step touches one column of B, so a
//! solver iteration is O(ℓ), not O(ℓ²).

use std::collections::HashMap;

use crate::linalg::dense::Matrix;
use crate::solvers::GramProblem;

/// A vertex `sign · r · e_coord` of the ℓ1-ball.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Vertex {
    pub coord: usize,
    /// +1.0 or −1.0
    pub sign: i8,
}

impl Vertex {
    #[inline]
    pub fn value(&self, r: f64) -> f64 {
        self.sign as f64 * r
    }

    /// ⟨g, v⟩ for this vertex.
    #[inline]
    pub fn dot_grad(&self, g: &[f64], r: f64) -> f64 {
        self.value(r) * g[self.coord]
    }
}

/// Global LMO: `argmin_{v ∈ vert(P)} ⟨g, v⟩` = `−r·sign(g_i)·e_i` at
/// `i = argmax |g_i|`.
pub fn lmo_l1(g: &[f64], _r: f64) -> Vertex {
    let mut best = 0usize;
    let mut best_abs = -1.0f64;
    for (i, gi) in g.iter().enumerate() {
        let a = gi.abs();
        if a > best_abs {
            best_abs = a;
            best = i;
        }
    }
    let sign = if g[best] > 0.0 { -1 } else { 1 };
    Vertex { coord: best, sign }
}

/// Active-set iterate for FW variants over the ℓ1-ball.
pub struct ActiveSet {
    pub r: f64,
    pub y: Vec<f64>,
    /// Maintained `B·y`.
    pub by: Vec<f64>,
    pub weights: HashMap<Vertex, f64>,
}

/// Weights below this are culled after reweighting steps.
const WEIGHT_EPS: f64 = 1e-15;

impl ActiveSet {
    /// Start at a deterministic vertex (`+r·e_0`) — FW needs a vertex
    /// start for the convex decomposition to be valid.
    pub fn at_vertex(p: &GramProblem, r: f64, v: Vertex) -> Self {
        let ell = p.dim();
        let mut y = vec![0.0; ell];
        y[v.coord] = v.value(r);
        let by = scaled_col(p.b, v.coord, v.value(r));
        let mut weights = HashMap::new();
        weights.insert(v, 1.0);
        ActiveSet { r, y, by, weights }
    }

    /// Start at the origin — a valid point of the ball but *not* a vertex;
    /// FW variants treat it as an empty active set plus pure-FW first step.
    /// (The origin is the midpoint of ±r·e_0 — we seed with that pair at
    /// weight ½ each so the decomposition stays exact.)
    pub fn at_origin(p: &GramProblem, r: f64) -> Self {
        let ell = p.dim();
        let mut weights = HashMap::new();
        weights.insert(Vertex { coord: 0, sign: 1 }, 0.5);
        weights.insert(Vertex { coord: 0, sign: -1 }, 0.5);
        ActiveSet { r, y: vec![0.0; ell], by: vec![0.0; ell], weights }
    }

    /// ⟨∇f, ·⟩-extreme active vertices: (away = max, local-FW = min).
    /// Returns None when the active set is empty.
    pub fn away_and_local(&self, g: &[f64]) -> Option<(Vertex, Vertex)> {
        let mut away: Option<(Vertex, f64)> = None;
        let mut local: Option<(Vertex, f64)> = None;
        for (&v, _) in self.weights.iter() {
            let d = v.dot_grad(g, self.r);
            match away {
                Some((_, best)) if d <= best => {}
                _ => away = Some((v, d)),
            }
            match local {
                Some((_, best)) if d >= best => {}
                _ => local = Some((v, d)),
            }
        }
        match (away, local) {
            (Some((a, _)), Some((s, _))) => Some((a, s)),
            _ => None,
        }
    }

    /// y += γ(v_to − v_from) (pairwise step); updates weights and By.
    pub fn pairwise_step(&mut self, p: &GramProblem, from: Vertex, to: Vertex, gamma: f64) {
        if gamma == 0.0 {
            return;
        }
        let wf = self.weights.get_mut(&from).expect("from must be active");
        *wf -= gamma;
        let drop = *wf <= WEIGHT_EPS;
        if drop {
            self.weights.remove(&from);
        }
        *self.weights.entry(to).or_insert(0.0) += gamma;

        let vf = from.value(self.r);
        let vt = to.value(self.r);
        self.y[from.coord] -= gamma * vf;
        self.y[to.coord] += gamma * vt;
        add_scaled_col(p.b, from.coord, -gamma * vf, &mut self.by);
        add_scaled_col(p.b, to.coord, gamma * vt, &mut self.by);
    }

    /// y ← (1−γ)·y + γ·v (global FW step); rescales all weights.
    pub fn fw_step(&mut self, p: &GramProblem, v: Vertex, gamma: f64) {
        if gamma == 0.0 {
            return;
        }
        for w in self.weights.values_mut() {
            *w *= 1.0 - gamma;
        }
        self.weights.retain(|_, w| *w > WEIGHT_EPS);
        *self.weights.entry(v).or_insert(0.0) += gamma;

        let vv = v.value(self.r);
        for yi in self.y.iter_mut() {
            *yi *= 1.0 - gamma;
        }
        self.y[v.coord] += gamma * vv;
        for byi in self.by.iter_mut() {
            *byi *= 1.0 - gamma;
        }
        add_scaled_col(p.b, v.coord, gamma * vv, &mut self.by);
    }

    /// Weight of a vertex (0 if inactive).
    pub fn weight(&self, v: Vertex) -> f64 {
        self.weights.get(&v).copied().unwrap_or(0.0)
    }

    /// Invariant check (tests): y = Σ λ_v v, Σ λ_v = 1, λ ≥ 0, and the
    /// maintained By matches B·y.
    #[cfg(test)]
    pub fn check_invariants(&self, p: &GramProblem) -> Result<(), String> {
        let mut y = vec![0.0; self.y.len()];
        let mut total = 0.0;
        for (&v, &w) in self.weights.iter() {
            if w < 0.0 {
                return Err(format!("negative weight {w} on {v:?}"));
            }
            y[v.coord] += w * v.value(self.r);
            total += w;
        }
        if (total - 1.0).abs() > 1e-9 {
            return Err(format!("weights sum to {total}"));
        }
        for i in 0..y.len() {
            if (y[i] - self.y[i]).abs() > 1e-8 * self.r.max(1.0) {
                return Err(format!("y[{i}] decomposition mismatch"));
            }
        }
        let by = p.b.matvec(&self.y);
        for i in 0..by.len() {
            if (by[i] - self.by[i]).abs() > 1e-6 * p.b.max_abs().max(1.0) {
                return Err(format!("By[{i}] drift: {} vs {}", self.by[i], by[i]));
            }
        }
        Ok(())
    }
}

/// `alpha · B[:, j]` as a fresh vector.
fn scaled_col(b: &Matrix, j: usize, alpha: f64) -> Vec<f64> {
    (0..b.rows()).map(|i| alpha * b.get(i, j)).collect()
}

/// `out += alpha · B[:, j]` — the O(ℓ) per-step Gram touch.
#[inline]
fn add_scaled_col(b: &Matrix, j: usize, alpha: f64, out: &mut [f64]) {
    if alpha == 0.0 {
        return;
    }
    for (i, o) in out.iter_mut().enumerate() {
        *o += alpha * b.get(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::testutil::random_instance;
    use crate::util::proptest::property;

    #[test]
    fn lmo_picks_largest_gradient_coordinate() {
        let g = vec![0.5, -2.0, 1.0];
        let v = lmo_l1(&g, 3.0);
        assert_eq!(v.coord, 1);
        assert_eq!(v.sign, 1); // g[1] < 0 ⇒ +r e_1 minimizes ⟨g, v⟩
        assert_eq!(v.value(3.0), 3.0);
        assert_eq!(v.dot_grad(&g, 3.0), -6.0);
    }

    #[test]
    fn steps_preserve_invariants() {
        property(24, |rng| {
            let inst = random_instance(rng, 30, 6);
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let r = 2.0;
            let mut act = ActiveSet::at_vertex(&p, r, Vertex { coord: 0, sign: 1 });
            for _ in 0..20 {
                act.check_invariants(&p)?;
                let g = p.grad_with_by(&act.by);
                let w = lmo_l1(&g, r);
                if rng.uniform() < 0.5 {
                    // FW step with a random feasible γ
                    act.fw_step(&p, w, rng.uniform() * 0.9);
                } else if let Some((a, _s)) = act.away_and_local(&g) {
                    let gamma = act.weight(a) * rng.uniform();
                    act.pairwise_step(&p, a, w, gamma);
                }
            }
            act.check_invariants(&p)
        });
    }

    #[test]
    fn origin_start_is_exact_decomposition() {
        let mut rng = crate::util::rng::Rng::new(2);
        let inst = random_instance(&mut rng, 20, 4);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let act = ActiveSet::at_origin(&p, 5.0);
        act.check_invariants(&p).unwrap();
        assert!(act.y.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn pairwise_drop_step_removes_vertex() {
        let mut rng = crate::util::rng::Rng::new(3);
        let inst = random_instance(&mut rng, 20, 4);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let v0 = Vertex { coord: 0, sign: 1 };
        let v1 = Vertex { coord: 1, sign: -1 };
        let mut act = ActiveSet::at_vertex(&p, 1.0, v0);
        act.pairwise_step(&p, v0, v1, 1.0); // full mass shift = drop step
        assert_eq!(act.weight(v0), 0.0);
        assert_eq!(act.weight(v1), 1.0);
        assert!(!act.weights.contains_key(&v0));
        act.check_invariants(&p).unwrap();
    }
}

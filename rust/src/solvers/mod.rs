//! Convex-oracle substrate: the quadratic problems of OAVI Line 7 /
//! (CCOP), solved in *Gram space*.
//!
//! With `B = AᵀA`, `r = Aᵀb`, `β = bᵀb` precomputed (O(mℓ) once, by the
//! streaming backend), the objective
//! `f(y) = ‖Ay + b‖²/m = (yᵀBy + 2yᵀr + β)/m`
//! and its gradient `∇f(y) = 2(By + r)/m` cost O(ℓ²)/O(ℓ) per iteration —
//! never O(mℓ).  This is what makes solver iterations m-independent and
//! the whole of OAVI linear in m (§4.1, Corollary 4.8).
//!
//! Solvers: [`agd`] (unconstrained, Nesterov), and the Frank–Wolfe family
//! on the ℓ1-ball of radius τ−1 — [`fw`] (vanilla CG), [`pcg`] (pairwise),
//! [`bpcg`] (blended pairwise, Algorithm 3 of the paper).

pub mod agd;
pub mod bpcg;
pub mod fw;
pub mod lmo;
pub mod pcg;

use crate::linalg::dense::Matrix;
use crate::linalg::dot;

/// A quadratic problem in Gram space: minimize
/// `f(y) = (yᵀBy + 2yᵀatb + btb)/m` (over the ℓ1-ball of radius
/// `radius` if constrained).
#[derive(Clone, Copy)]
pub struct GramProblem<'a> {
    pub b: &'a Matrix,
    pub atb: &'a [f64],
    pub btb: f64,
    pub m: usize,
}

impl<'a> GramProblem<'a> {
    pub fn dim(&self) -> usize {
        self.atb.len()
    }

    /// f(y), given the maintained product `by = B·y`.
    #[inline]
    pub fn f_with_by(&self, y: &[f64], by: &[f64]) -> f64 {
        ((dot(y, by) + 2.0 * dot(y, self.atb) + self.btb) / self.m as f64).max(0.0)
    }

    /// f(y) from scratch (O(ℓ²)).
    pub fn f(&self, y: &[f64]) -> f64 {
        let by = self.b.matvec(y);
        self.f_with_by(y, &by)
    }

    /// ∇f(y) given `by = B·y`.
    #[inline]
    pub fn grad_with_by(&self, by: &[f64]) -> Vec<f64> {
        let mut g = Vec::with_capacity(self.dim());
        self.grad_with_by_into(by, &mut g);
        g
    }

    /// ∇f(y) given `by = B·y`, written into a caller-owned buffer so the
    /// solver hot loops allocate one gradient per *solve*, not one per
    /// iteration.  Same map as [`Self::grad_with_by`], so results are
    /// bitwise identical.
    #[inline]
    pub fn grad_with_by_into(&self, by: &[f64], out: &mut Vec<f64>) {
        let scale = 2.0 / self.m as f64;
        out.clear();
        out.extend(by.iter().zip(self.atb.iter()).map(|(byi, ri)| scale * (byi + ri)));
    }

    /// Curvature along d: `dᵀBd / m · 2` is the second derivative of
    /// `γ ↦ f(y + γd)`; returns `dᵀBd`.
    pub fn quad_form(&self, d: &[f64]) -> f64 {
        let bd = self.b.matvec(d);
        dot(d, &bd)
    }
}

/// Why the solver stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Termination {
    /// Frank–Wolfe gap ≤ ε (certified ε-accurate).
    GapConverged,
    /// Gradient-based convergence (AGD).
    GradConverged,
    /// f(y) dropped to the ψ target — a vanishing generator exists;
    /// no need to keep optimizing (paper §6.1 early termination).
    TargetReached,
    /// Certified lower bound f(y) − gap > ψ — no vanishing polynomial
    /// with these terms exists; stop early (paper §6.1).
    Hopeless,
    /// Iteration cap hit.
    MaxIters,
    /// Progress stalled below machine-level improvements.
    Stalled,
}

/// Solver configuration shared across the family.
#[derive(Clone, Copy, Debug)]
pub struct SolverParams {
    /// Target accuracy on the objective (paper: ε = 0.01·ψ).
    pub eps: f64,
    /// Iteration cap (paper: 10,000).
    pub max_iters: usize,
    /// ℓ1-ball radius τ−1 for the constrained problem (CCOP).
    pub radius: f64,
    /// Vanishing threshold ψ for the early-exit certificates
    /// (`None` disables them).
    pub psi: Option<f64>,
}

impl SolverParams {
    pub fn for_psi(psi: f64, radius: f64) -> Self {
        SolverParams { eps: 0.01 * psi, max_iters: 10_000, radius, psi: Some(psi) }
    }
}

impl Default for SolverParams {
    fn default() -> Self {
        SolverParams { eps: 1e-8, max_iters: 10_000, radius: 999.0, psi: None }
    }
}

/// Result of a solver run.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub y: Vec<f64>,
    /// f(y) at the returned point.
    pub f: f64,
    pub iters: usize,
    pub termination: Termination,
}

/// Exact line search for quadratics: minimize `f(y + γ d)` over `[0, γmax]`
/// given `gd = ⟨∇f(y), d⟩` and `dbd = dᵀBd`.
#[inline]
pub fn quad_line_search(gd: f64, dbd: f64, m: usize, gamma_max: f64) -> f64 {
    if dbd <= 0.0 {
        // degenerate direction: either descend to the boundary or stay
        return if gd < 0.0 { gamma_max } else { 0.0 };
    }
    let gamma = -gd * m as f64 / (2.0 * dbd);
    gamma.clamp(0.0, gamma_max)
}

/// The solver family used by OAVI (paper naming: CGAVI, PCGAVI, BPCGAVI,
/// AGDAVI).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolverKind {
    /// Vanilla Frank–Wolfe / Conditional Gradients.
    Cg,
    /// Pairwise Conditional Gradients.
    Pcg,
    /// Blended Pairwise Conditional Gradients (Algorithm 3).
    Bpcg,
    /// Accelerated Gradient Descent (unconstrained Line 7).
    Agd,
}

impl SolverKind {
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Cg => "CG",
            SolverKind::Pcg => "PCG",
            SolverKind::Bpcg => "BPCG",
            SolverKind::Agd => "AGD",
        }
    }

    /// Solve the Gram problem with this solver from a cold start.
    pub fn solve(&self, p: &GramProblem, params: &SolverParams) -> SolveResult {
        match self {
            SolverKind::Cg => fw::solve_cg(p, params, None),
            SolverKind::Pcg => pcg::solve_pcg(p, params, None),
            SolverKind::Bpcg => bpcg::solve_bpcg(p, params, None),
            SolverKind::Agd => agd::solve_agd(p, params, None),
        }
    }

    /// Solve with a dense warm start (IHB's `y0`).  For FW variants the
    /// warm start must be inside the ℓ1-ball; callers enforce (INF).
    pub fn solve_warm(
        &self,
        p: &GramProblem,
        params: &SolverParams,
        y0: &[f64],
    ) -> SolveResult {
        match self {
            SolverKind::Cg => fw::solve_cg(p, params, Some(y0)),
            SolverKind::Pcg => pcg::solve_pcg(p, params, Some(y0)),
            SolverKind::Bpcg => bpcg::solve_bpcg(p, params, Some(y0)),
            SolverKind::Agd => agd::solve_agd(p, params, Some(y0)),
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::linalg::gram::GramState;
    use crate::util::rng::Rng;

    /// Random least-squares instance in Gram space + its closed-form
    /// optimum (unconstrained).
    pub struct Instance {
        pub gram: GramState,
        pub atb: Vec<f64>,
        pub btb: f64,
        pub m: usize,
        pub y_opt: Vec<f64>,
        pub f_opt: f64,
    }

    pub fn random_instance(rng: &mut Rng, m: usize, ell: usize) -> Instance {
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let b_col: Vec<f64> = (0..m).map(|_| rng.uniform() - 0.5).collect();
        let gram = GramState::from_columns(&cols).unwrap();
        let atb: Vec<f64> = cols.iter().map(|c| crate::linalg::dot(c, &b_col)).collect();
        let btb = crate::linalg::dot(&b_col, &b_col);
        let (y_opt, resid) = gram.solve_closed_form(&atb, btb);
        let f_opt = resid / m as f64;
        Instance { gram, atb, btb, m, y_opt, f_opt }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn f_and_grad_consistent() {
        let mut rng = Rng::new(3);
        let inst = testutil::random_instance(&mut rng, 40, 5);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let y: Vec<f64> = (0..5).map(|_| rng.normal() * 0.1).collect();
        // finite-difference gradient check
        let by = p.b.matvec(&y);
        let g = p.grad_with_by(&by);
        let f0 = p.f(&y);
        let h = 1e-6;
        for j in 0..5 {
            let mut yh = y.clone();
            yh[j] += h;
            let fd = (p.f(&yh) - f0) / h;
            assert!((fd - g[j]).abs() < 1e-4, "grad[{j}]: {fd} vs {}", g[j]);
        }
    }

    #[test]
    fn f_at_optimum_matches_closed_form() {
        let mut rng = Rng::new(4);
        let inst = testutil::random_instance(&mut rng, 60, 4);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        assert!((p.f(&inst.y_opt) - inst.f_opt).abs() < 1e-9);
    }

    #[test]
    fn line_search_clamps() {
        assert_eq!(quad_line_search(-1.0, 0.0, 10, 1.0), 1.0);
        assert_eq!(quad_line_search(1.0, 0.0, 10, 1.0), 0.0);
        // γ* = -gd·m/(2dbd) = 1·10/(2·10) = 0.5
        assert!((quad_line_search(-1.0, 10.0, 10, 1.0) - 0.5).abs() < 1e-12);
        assert_eq!(quad_line_search(-100.0, 1.0, 10, 0.25), 0.25);
    }

    #[test]
    fn params_for_psi() {
        let p = SolverParams::for_psi(0.005, 999.0);
        assert!((p.eps - 5e-5).abs() < 1e-12);
        assert_eq!(p.max_iters, 10_000);
        assert_eq!(p.psi, Some(0.005));
    }
}

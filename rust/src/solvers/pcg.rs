//! Pairwise Conditional Gradients (Lacoste-Julien & Jaggi 2015).
//!
//! Each step moves weight γ from the away vertex to the global FW vertex.
//! PCG's rate carries the infamous `(3|vert(P)|! + 1)` factor through
//! swap-steps (Theorem 4.6) — the paper's motivation for BPCG; we keep it
//! as the Figure-2 baseline (PCGAVI).

use crate::linalg::dot;
use crate::solvers::fw::{certificates, warm_active_set};
use crate::solvers::lmo::{lmo_l1, ActiveSet, Vertex};
use crate::solvers::{quad_line_search, GramProblem, SolveResult, SolverParams, Termination};

/// PCG with exact line search.
pub fn solve_pcg(p: &GramProblem, params: &SolverParams, warm: Option<&[f64]>) -> SolveResult {
    let r = params.radius;
    let mut act = match warm {
        Some(y0) => warm_active_set(p, r, y0),
        None => ActiveSet::at_vertex(p, r, Vertex { coord: 0, sign: 1 }),
    };
    let mut stall = 0usize;
    let mut f_prev = f64::INFINITY;
    let mut g: Vec<f64> = Vec::with_capacity(p.dim()); // gradient buffer, reused every iteration

    for t in 0..params.max_iters {
        p.grad_with_by_into(&act.by, &mut g);
        let w = lmo_l1(&g, r);
        let f = p.f_with_by(&act.y, &act.by);
        let fw_gap = dot(&g, &act.y) - w.dot_grad(&g, r);
        if let Some(term) = certificates(f, fw_gap, params) {
            return SolveResult { y: act.y, f, iters: t, termination: term };
        }
        let (a, _local) = match act.away_and_local(&g) {
            Some(pair) => pair,
            None => {
                return SolveResult { y: act.y, f, iters: t, termination: Termination::Stalled }
            }
        };
        // pairwise direction d = w − a
        let gd = w.dot_grad(&g, r) - a.dot_grad(&g, r);
        if gd >= 0.0 {
            // no descent in the pairwise direction: numerically converged
            return SolveResult { y: act.y, f, iters: t, termination: Termination::Stalled };
        }
        let dbd = pair_quad(p, w, a, r);
        let gamma_max = act.weight(a);
        let gamma = quad_line_search(gd, dbd, p.m, gamma_max);
        act.pairwise_step(p, a, w, gamma);

        if f_prev - f <= 1e-16 * f.max(1.0) {
            stall += 1;
            if stall >= 50 {
                let f = p.f_with_by(&act.y, &act.by);
                return SolveResult { y: act.y, f, iters: t, termination: Termination::Stalled };
            }
        } else {
            stall = 0;
        }
        f_prev = f;
    }
    let f = p.f_with_by(&act.y, &act.by);
    SolveResult { y: act.y, f, iters: params.max_iters, termination: Termination::MaxIters }
}

/// (w − a)ᵀ B (w − a) for two ℓ1-ball vertices — three Gram entries.
#[inline]
pub(crate) fn pair_quad(p: &GramProblem, w: Vertex, a: Vertex, r: f64) -> f64 {
    let wv = w.value(r);
    let av = a.value(r);
    wv * wv * p.b.get(w.coord, w.coord) + av * av * p.b.get(a.coord, a.coord)
        - 2.0 * wv * av * p.b.get(w.coord, a.coord)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::testutil::random_instance;
    use crate::util::proptest::property;

    #[test]
    fn converges_to_unconstrained_optimum_when_interior() {
        property(16, |rng| {
            let inst = random_instance(rng, 60, 4);
            if crate::linalg::norm1(&inst.y_opt) > 50.0 {
                return Ok(());
            }
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let params = SolverParams { eps: 1e-9, max_iters: 20_000, radius: 100.0, psi: None };
            let res = solve_pcg(&p, &params, None);
            if res.f > inst.f_opt + 1e-6 {
                return Err(format!("f {} vs opt {} ({:?})", res.f, inst.f_opt, res.termination));
            }
            Ok(())
        });
    }

    #[test]
    fn respects_ball_constraint() {
        property(12, |rng| {
            let inst = random_instance(rng, 40, 6);
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let r = 0.5;
            let params = SolverParams { eps: 1e-10, max_iters: 3000, radius: r, psi: None };
            let res = solve_pcg(&p, &params, None);
            if crate::linalg::norm1(&res.y) > r + 1e-9 {
                return Err("left the ball".into());
            }
            Ok(())
        });
    }

    #[test]
    fn pcg_faster_than_cg_on_boundary_solutions() {
        // On problems whose solution sits on the boundary, CG zig-zags;
        // pairwise steps don't.  Check PCG needs no more iterations.
        let mut rng = crate::util::rng::Rng::new(21);
        let mut cg_total = 0usize;
        let mut pcg_total = 0usize;
        for _ in 0..5 {
            let inst = random_instance(&mut rng, 60, 8);
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let params =
                SolverParams { eps: 1e-8, max_iters: 50_000, radius: 0.3, psi: None };
            cg_total += crate::solvers::fw::solve_cg(&p, &params, None).iters;
            pcg_total += solve_pcg(&p, &params, None).iters;
        }
        assert!(
            pcg_total <= cg_total * 2,
            "pcg {pcg_total} vs cg {cg_total} iterations"
        );
    }

    #[test]
    fn pair_quad_matches_dense() {
        let mut rng = crate::util::rng::Rng::new(5);
        let inst = random_instance(&mut rng, 30, 5);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let r = 2.0;
        let w = Vertex { coord: 1, sign: 1 };
        let a = Vertex { coord: 3, sign: -1 };
        let mut d = vec![0.0; 5];
        d[w.coord] += w.value(r);
        d[a.coord] -= a.value(r);
        assert!((pair_quad(&p, w, a, r) - p.quad_form(&d)).abs() < 1e-9);
    }
}

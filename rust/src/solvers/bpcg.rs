//! Blended Pairwise Conditional Gradients (Tsuji, Tanaka & Pokutta 2021)
//! — Algorithm 3 of the paper, the default (CCOP) solver of BPCGAVI.
//!
//! BPCG removes PCG's swap-steps by *blending*: if the local pairwise
//! direction (away → local-FW vertex, both inside the active set) promises
//! at least as much first-order descent as the global FW direction, take
//! the pairwise step (no LMO-vertex added, active set can only shrink);
//! otherwise take a global FW step.  This removes the `(3|vert(P)|!+1)`
//! factor from the rate (Theorem 4.7 vs 4.6) — the paper's "exponential
//! improvement in |G|+|O|".

use crate::linalg::dot;
use crate::solvers::fw::{certificates, warm_active_set};
use crate::solvers::lmo::{lmo_l1, ActiveSet, Vertex};
use crate::solvers::pcg::pair_quad;
use crate::solvers::{quad_line_search, GramProblem, SolveResult, SolverParams, Termination};

/// BPCG (Algorithm 3) with exact line search.
pub fn solve_bpcg(p: &GramProblem, params: &SolverParams, warm: Option<&[f64]>) -> SolveResult {
    let r = params.radius;
    let mut act = match warm {
        Some(y0) => warm_active_set(p, r, y0),
        None => ActiveSet::at_vertex(p, r, Vertex { coord: 0, sign: 1 }),
    };
    let mut stall = 0usize;
    let mut f_prev = f64::INFINITY;
    let mut g: Vec<f64> = Vec::with_capacity(p.dim()); // gradient buffer, reused every iteration

    for t in 0..params.max_iters {
        p.grad_with_by_into(&act.by, &mut g);
        let w = lmo_l1(&g, r); // global FW vertex (Line 6)
        let f = p.f_with_by(&act.y, &act.by);
        let fw_gap = dot(&g, &act.y) - w.dot_grad(&g, r);
        if let Some(term) = certificates(f, fw_gap, params) {
            return SolveResult { y: act.y, f, iters: t, termination: term };
        }
        let (a, s) = match act.away_and_local(&g) {
            Some(pair) => pair, // away (Line 4), local FW (Line 5)
            None => {
                return SolveResult { y: act.y, f, iters: t, termination: Termination::Stalled }
            }
        };

        // Line 7: ⟨g, w − y⟩ ≥ ⟨g, s − a⟩ ⇒ local pairwise step
        let gd_fw = w.dot_grad(&g, r) - dot(&g, &act.y);
        let gd_pair = s.dot_grad(&g, r) - a.dot_grad(&g, r);
        let progressed;
        if gd_fw >= gd_pair {
            // Lines 8–11: pairwise a → s, γ ∈ [0, λ_a]
            let dbd = pair_quad(p, s, a, r);
            let gamma_max = act.weight(a);
            let gamma = quad_line_search(gd_pair, dbd, p.m, gamma_max);
            act.pairwise_step(p, a, s, gamma);
            progressed = gamma > 0.0;
        } else {
            // Lines 13–17: global FW step, γ ∈ [0, 1]
            let wv = w.value(r);
            let dbd = wv * wv * p.b.get(w.coord, w.coord) - 2.0 * wv * act.by[w.coord]
                + dot(&act.y, &act.by);
            let gamma = quad_line_search(gd_fw, dbd, p.m, 1.0);
            act.fw_step(p, w, gamma);
            progressed = gamma > 0.0;
        }

        if !progressed || f_prev - f <= 1e-16 * f.max(1.0) {
            stall += 1;
            if stall >= 50 {
                let f = p.f_with_by(&act.y, &act.by);
                return SolveResult { y: act.y, f, iters: t, termination: Termination::Stalled };
            }
        } else {
            stall = 0;
        }
        f_prev = f;
    }
    let f = p.f_with_by(&act.y, &act.by);
    SolveResult { y: act.y, f, iters: params.max_iters, termination: Termination::MaxIters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::testutil::random_instance;
    use crate::util::proptest::property;

    #[test]
    fn converges_to_unconstrained_optimum_when_interior() {
        property(16, |rng| {
            let inst = random_instance(rng, 60, 4);
            if crate::linalg::norm1(&inst.y_opt) > 50.0 {
                return Ok(());
            }
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let params = SolverParams { eps: 1e-9, max_iters: 20_000, radius: 100.0, psi: None };
            let res = solve_bpcg(&p, &params, None);
            if res.f > inst.f_opt + 1e-6 {
                return Err(format!("f {} vs opt {} ({:?})", res.f, inst.f_opt, res.termination));
            }
            Ok(())
        });
    }

    #[test]
    fn respects_ball_constraint() {
        property(12, |rng| {
            let inst = random_instance(rng, 40, 6);
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let r = 0.5;
            let params = SolverParams { eps: 1e-10, max_iters: 3000, radius: r, psi: None };
            let res = solve_bpcg(&p, &params, None);
            if crate::linalg::norm1(&res.y) > r + 1e-9 {
                return Err("left the ball".into());
            }
            Ok(())
        });
    }

    #[test]
    fn agrees_with_pcg_objective() {
        property(10, |rng| {
            let inst = random_instance(rng, 50, 6);
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let params = SolverParams { eps: 1e-9, max_iters: 30_000, radius: 1.0, psi: None };
            let f_bpcg = solve_bpcg(&p, &params, None).f;
            let f_pcg = crate::solvers::pcg::solve_pcg(&p, &params, None).f;
            crate::util::proptest::close(f_bpcg, f_pcg, 1e-5, "BPCG vs PCG objective")
        });
    }

    #[test]
    fn produces_sparse_solutions_on_boundary_problems() {
        // The sparsity-inducing property the paper exploits for WIHB: with
        // a tight ball, BPCG's active set (= nonzeros) stays small.
        let mut rng = crate::util::rng::Rng::new(31);
        let inst = random_instance(&mut rng, 80, 20);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let params = SolverParams { eps: 1e-9, max_iters: 30_000, radius: 0.2, psi: None };
        let res = solve_bpcg(&p, &params, None);
        let nnz = res.y.iter().filter(|v| v.abs() > 1e-12).count();
        assert!(nnz < 20, "expected sparse solution, got {nnz}/20 nonzeros");
    }

    #[test]
    fn warm_start_at_optimum_is_instant() {
        let mut rng = crate::util::rng::Rng::new(32);
        let inst = random_instance(&mut rng, 50, 5);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let params = SolverParams { eps: 1e-7, max_iters: 10_000, radius: 1000.0, psi: None };
        let res = solve_bpcg(&p, &params, Some(&inst.y_opt));
        assert!(res.iters <= 2, "{} iters", res.iters);
    }
}

//! Accelerated Gradient Descent (Nesterov 1983) for the *unconstrained*
//! Line-7 problem — the AGDAVI solver, and the fallback IHB polisher
//! (Algorithm 4: warm-start AGD at `y0 = −(AᵀA)^{-1}Aᵀb`).
//!
//! The step size uses `L = 2·λ_max(B)/m` from power iteration; momentum is
//! the standard `(t_k − 1)/t_{k+1}` sequence with function-value restarts
//! (quadratics have unknown-but-positive strong convexity here, restarts
//! recover the linear rate without needing μ).

use crate::linalg::eigen::lambda_max;
use crate::linalg::norm_inf;
use crate::solvers::{GramProblem, SolveResult, SolverParams, Termination};

/// AGD with function-value restarts.
pub fn solve_agd(p: &GramProblem, params: &SolverParams, warm: Option<&[f64]>) -> SolveResult {
    let ell = p.dim();
    let m = p.m as f64;
    let lmax = lambda_max(p.b, 100).max(1e-300);
    let l_smooth = 2.0 * lmax / m;
    let step = 1.0 / l_smooth;

    let mut y: Vec<f64> = warm.map(|w| w.to_vec()).unwrap_or_else(|| vec![0.0; ell]);
    let mut x = y.clone(); // extrapolated point
    let mut t_k = 1.0f64;
    let mut f_prev = f64::INFINITY;
    let mut stall = 0usize;
    let mut g: Vec<f64> = Vec::with_capacity(ell); // gradient buffer, reused every iteration
    // gradient scale for the convergence test: ∇f entries are O(‖B‖·y/m)
    let grad_tol = (params.eps / m).sqrt().max(1e-13) * (1.0 + lmax / m);

    for t in 0..params.max_iters {
        let bx = p.b.matvec(&x);
        p.grad_with_by_into(&bx, &mut g);
        // y⁺ = x − (1/L) ∇f(x)
        let y_new: Vec<f64> = x.iter().zip(g.iter()).map(|(xi, gi)| xi - step * gi).collect();
        let f_new = p.f(&y_new);

        // certificates on the new point
        if let Some(psi) = params.psi {
            if f_new <= psi {
                return SolveResult {
                    y: y_new,
                    f: f_new,
                    iters: t + 1,
                    termination: Termination::TargetReached,
                };
            }
        }
        if norm_inf(&g) <= grad_tol {
            return SolveResult {
                y: y_new,
                f: f_new,
                iters: t + 1,
                termination: Termination::GradConverged,
            };
        }

        if f_new > f_prev {
            // function-value restart: drop momentum, retry from y
            t_k = 1.0;
            x = y.clone();
            stall += 1;
            if stall >= 30 {
                let f = p.f(&y);
                return SolveResult { y, f, iters: t + 1, termination: Termination::Stalled };
            }
            continue;
        }
        if f_prev - f_new <= 1e-16 * f_new.max(1.0) {
            stall += 1;
            if stall >= 30 {
                return SolveResult {
                    y: y_new,
                    f: f_new,
                    iters: t + 1,
                    termination: Termination::Stalled,
                };
            }
        } else {
            stall = 0;
        }

        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_k * t_k).sqrt());
        let beta = (t_k - 1.0) / t_next;
        x = y_new
            .iter()
            .zip(y.iter())
            .map(|(yn, yo)| yn + beta * (yn - yo))
            .collect();
        y = y_new;
        t_k = t_next;
        f_prev = f_new;
    }
    let f = p.f(&y);
    SolveResult { y, f, iters: params.max_iters, termination: Termination::MaxIters }
}

/// Closed-form optimal objective for diagnostics: `f* = (β − rᵀ B^{-1} r)/m`
/// via a dense solve (O(ℓ³); tests only).
#[cfg(test)]
pub fn f_star(p: &GramProblem) -> f64 {
    let chol = crate::linalg::chol::Cholesky::new_with_jitter(p.b, 1e-12).unwrap().0;
    let w = chol.solve(p.atb);
    ((p.btb - crate::linalg::dot(p.atb, &w)) / p.m as f64).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::testutil::random_instance;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    #[test]
    fn reaches_unconstrained_optimum() {
        property(16, |rng| {
            let inst = random_instance(rng, 60, 5);
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let params = SolverParams { eps: 1e-12, max_iters: 50_000, radius: 0.0, psi: None };
            let res = solve_agd(&p, &params, None);
            if res.f > inst.f_opt + 1e-5 * (1.0 + inst.f_opt) {
                return Err(format!("f {} vs opt {} ({:?})", res.f, inst.f_opt, res.termination));
            }
            Ok(())
        });
    }

    #[test]
    fn warm_start_at_optimum_is_instant() {
        let mut rng = Rng::new(12);
        let inst = random_instance(&mut rng, 50, 6);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let params = SolverParams { eps: 1e-10, max_iters: 10_000, radius: 0.0, psi: None };
        let res = solve_agd(&p, &params, Some(&inst.y_opt));
        assert!(res.iters <= 3, "{} iters", res.iters);
    }

    #[test]
    fn psi_certificate_stops_early() {
        let mut rng = Rng::new(13);
        let inst = random_instance(&mut rng, 50, 4);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let params = SolverParams { eps: 1e-12, max_iters: 10_000, radius: 0.0, psi: Some(1e9) };
        let res = solve_agd(&p, &params, None);
        assert_eq!(res.termination, Termination::TargetReached);
        assert_eq!(res.iters, 1);
    }

    #[test]
    fn f_star_matches_gram_closed_form() {
        let mut rng = Rng::new(14);
        let inst = random_instance(&mut rng, 70, 5);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        assert!((f_star(&p) - inst.f_opt).abs() < 1e-9);
    }
}

//! Vanilla Frank–Wolfe (CG) over the ℓ1-ball, in Gram space.
//!
//! Used by CGAVI and as the IHB fast path (warm-started at the closed-form
//! optimum, it certifies convergence via the FW gap in one iteration).

use crate::linalg::dot;
use crate::solvers::lmo::{lmo_l1, ActiveSet, Vertex};
use crate::solvers::{quad_line_search, GramProblem, SolveResult, SolverParams, Termination};

/// Decompose a dense feasible point (‖y0‖₁ ≤ r) into a convex combination
/// of ℓ1-ball vertices: weight |y_i|/r on `sign(y_i)·r·e_i`, remaining mass
/// split over the ±r·e_0 pair (which sums to 0).
pub(crate) fn warm_active_set(p: &GramProblem, r: f64, y0: &[f64]) -> ActiveSet {
    let mut act = ActiveSet::at_origin(p, r);
    act.weights.clear();
    let mut used = 0.0;
    for (i, &yi) in y0.iter().enumerate() {
        if yi != 0.0 {
            let w = yi.abs() / r;
            let sign = if yi > 0.0 { 1 } else { -1 };
            *act.weights.entry(Vertex { coord: i, sign }).or_insert(0.0) += w;
            used += w;
        }
    }
    let rest = (1.0 - used).max(0.0);
    if rest > 0.0 {
        *act.weights.entry(Vertex { coord: 0, sign: 1 }).or_insert(0.0) += rest / 2.0;
        *act.weights.entry(Vertex { coord: 0, sign: -1 }).or_insert(0.0) += rest / 2.0;
    }
    act.y = y0.to_vec();
    act.by = p.b.matvec(y0);
    act
}

/// Shared early-exit certificates (paper §6.1): vanishing reached /
/// provably hopeless.
#[inline]
pub(crate) fn certificates(
    f: f64,
    gap: f64,
    params: &SolverParams,
) -> Option<Termination> {
    if let Some(psi) = params.psi {
        if f <= psi {
            return Some(Termination::TargetReached);
        }
        // f* ≥ f − gap: if even the best attainable value exceeds ψ, no
        // approximately vanishing coefficient vector exists in the ball.
        if f - gap > psi {
            return Some(Termination::Hopeless);
        }
    }
    if gap <= params.eps {
        return Some(Termination::GapConverged);
    }
    None
}

/// Vanilla CG with exact line search.
pub fn solve_cg(p: &GramProblem, params: &SolverParams, warm: Option<&[f64]>) -> SolveResult {
    let r = params.radius;
    let mut act = match warm {
        Some(y0) => warm_active_set(p, r, y0),
        None => ActiveSet::at_vertex(p, r, Vertex { coord: 0, sign: 1 }),
    };
    let mut stall = 0usize;
    let mut f_prev = f64::INFINITY;
    let mut g: Vec<f64> = Vec::with_capacity(p.dim()); // gradient buffer, reused every iteration

    for t in 0..params.max_iters {
        p.grad_with_by_into(&act.by, &mut g);
        let w = lmo_l1(&g, r);
        let f = p.f_with_by(&act.y, &act.by);
        let gap = dot(&g, &act.y) - w.dot_grad(&g, r);
        if let Some(term) = certificates(f, gap, params) {
            return SolveResult { y: act.y, f, iters: t, termination: term };
        }
        // d = w − y;  ⟨g, d⟩ = −gap;  dᵀBd via the maintained By
        let wv = w.value(r);
        let dbd = wv * wv * p.b.get(w.coord, w.coord) - 2.0 * wv * act.by[w.coord]
            + dot(&act.y, &act.by);
        let gamma = quad_line_search(-gap, dbd, p.m, 1.0);
        act.fw_step(p, w, gamma);

        if f_prev - f <= 1e-16 * f.max(1.0) {
            stall += 1;
            if stall >= 50 {
                let f = p.f_with_by(&act.y, &act.by);
                return SolveResult { y: act.y, f, iters: t, termination: Termination::Stalled };
            }
        } else {
            stall = 0;
        }
        f_prev = f;
    }
    let f = p.f_with_by(&act.y, &act.by);
    SolveResult { y: act.y, f, iters: params.max_iters, termination: Termination::MaxIters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::testutil::random_instance;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    #[test]
    fn converges_to_unconstrained_optimum_when_interior() {
        property(16, |rng| {
            let inst = random_instance(rng, 60, 4);
            if crate::linalg::norm1(&inst.y_opt) > 50.0 {
                return Ok(()); // optimum outside a generous ball — skip
            }
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let params = SolverParams { eps: 1e-9, max_iters: 20_000, radius: 100.0, psi: None };
            let res = solve_cg(&p, &params, None);
            if res.f > inst.f_opt + 1e-6 {
                return Err(format!("f {} vs opt {}", res.f, inst.f_opt));
            }
            Ok(())
        });
    }

    #[test]
    fn warm_start_at_optimum_terminates_immediately() {
        let mut rng = Rng::new(8);
        let inst = random_instance(&mut rng, 50, 5);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let params = SolverParams { eps: 1e-7, max_iters: 10_000, radius: 1000.0, psi: None };
        let res = solve_cg(&p, &params, Some(&inst.y_opt));
        assert!(res.iters <= 2, "took {} iters", res.iters);
        assert!((res.f - inst.f_opt).abs() < 1e-8);
    }

    #[test]
    fn target_reached_certificate_fires() {
        let mut rng = Rng::new(9);
        let inst = random_instance(&mut rng, 50, 5);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        // psi far above f(y0) ⇒ immediate TargetReached
        let params = SolverParams { eps: 1e-12, max_iters: 100, radius: 10.0, psi: Some(1e6) };
        let res = solve_cg(&p, &params, None);
        assert_eq!(res.termination, Termination::TargetReached);
        assert_eq!(res.iters, 0);
    }

    #[test]
    fn hopeless_certificate_fires() {
        // a problem whose optimum is far above psi: b orthogonal to A and huge
        let mut rng = Rng::new(10);
        let inst = random_instance(&mut rng, 50, 3);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb + 1e6, // inflate ‖b‖² so f* is large
            m: inst.m,
        };
        let params = SolverParams { eps: 1e-12, max_iters: 10_000, radius: 5.0, psi: Some(1e-6) };
        let res = solve_cg(&p, &params, None);
        assert_eq!(res.termination, Termination::Hopeless);
    }

    #[test]
    fn iterate_stays_in_ball() {
        property(12, |rng| {
            let inst = random_instance(rng, 40, 6);
            let p = GramProblem {
                b: inst.gram.b(),
                atb: &inst.atb,
                btb: inst.btb,
                m: inst.m,
            };
            let r = 0.5; // tight ball so the constraint binds
            let params = SolverParams { eps: 1e-10, max_iters: 3000, radius: r, psi: None };
            let res = solve_cg(&p, &params, None);
            if crate::linalg::norm1(&res.y) > r + 1e-9 {
                return Err(format!("left the ball: {}", crate::linalg::norm1(&res.y)));
            }
            Ok(())
        });
    }

    #[test]
    fn warm_decomposition_is_exact() {
        let mut rng = Rng::new(11);
        let inst = random_instance(&mut rng, 30, 5);
        let p = GramProblem {
            b: inst.gram.b(),
            atb: &inst.atb,
            btb: inst.btb,
            m: inst.m,
        };
        let y0 = vec![0.5, -0.25, 0.0, 0.1, 0.0];
        let act = warm_active_set(&p, 2.0, &y0);
        act.check_invariants(&p).unwrap();
        for (i, v) in y0.iter().enumerate() {
            assert!((act.y[i] - v).abs() < 1e-12);
        }
    }
}

//! Border computation (Definition 2.5).
//!
//! For an order ideal `O` the degree-d border is
//! `∂_d O = { u ∈ T_d : every proper divisor of u lies in O }`.
//! Because `O` is divisor-closed it suffices to check the ≤ n *maximal*
//! divisors `u / x_j` (for `x_j | u`): if they are all in `O`, every
//! deeper divisor is too.
//!
//! Candidates are generated as `t · x_j` for `t ∈ O_{d−1}`; each candidate
//! carries the recipe `(parent ∈ O, var)` used for its O(m) evaluation
//! column (`u(X) = t(X) ⊙ x_j`).

use std::collections::HashSet;

use crate::poly::eval::TermSet;
use crate::poly::term::Term;

/// A border term with its evaluation recipe.
#[derive(Clone, Debug)]
pub struct BorderTerm {
    pub term: Term,
    /// Index into the `TermSet` of the parent `term / x_var`.
    pub parent: usize,
    /// Variable index such that `term = parent · x_var`.
    pub var: usize,
}

/// Compute `∂_d O`, DegLex-ascending.
///
/// `o` must be an order ideal containing all accepted terms of degree
/// < d (which OAVI guarantees).  Returns an empty vec when the border is
/// empty — OAVI's termination condition.
pub fn compute_border(o: &TermSet, d: u32) -> Vec<BorderTerm> {
    let n = o.n_vars();
    let mut seen: HashSet<Term> = HashSet::new();
    let mut out: Vec<BorderTerm> = Vec::new();

    for parent_idx in o.degree_indices(d - 1) {
        let parent = &o.terms()[parent_idx];
        for j in 0..n {
            let cand = parent.times_var(j);
            if seen.contains(&cand) {
                continue;
            }
            seen.insert(cand.clone());
            // all maximal divisors must lie in O
            let mut ok = true;
            for k in 0..n {
                if let Some(div) = cand.div_var(k) {
                    if !o.contains(&div) {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                continue;
            }
            // canonical recipe: divide by the smallest variable present, so
            // identical candidates generated via different parents agree
            let var = cand.min_var().expect("degree ≥ 1");
            let canon_parent = cand.div_var(var).expect("positive exponent");
            let parent_pos = o.position(&canon_parent).expect("order ideal");
            out.push(BorderTerm { term: cand, parent: parent_pos, var });
        }
    }
    out.sort_by(|a, b| a.term.cmp(&b.term));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    /// O = {1}: border at degree 1 is all n variables.
    #[test]
    fn degree1_border_is_all_vars() {
        let o = TermSet::with_one(4);
        let border = compute_border(&o, 1);
        assert_eq!(border.len(), 4);
        for (j, bt) in border.iter().enumerate() {
            assert_eq!(bt.term, Term::var(4, j));
            assert_eq!(bt.parent, 0);
            assert_eq!(bt.var, j);
        }
    }

    /// O = {1, x0, x1} over n=2: degree-2 border is {x0², x0x1, x1²}.
    #[test]
    fn full_degree2_border() {
        let mut o = TermSet::with_one(2);
        o.push_product(0, 0).unwrap();
        o.push_product(0, 1).unwrap();
        let border = compute_border(&o, 2);
        let terms: Vec<Term> = border.iter().map(|b| b.term.clone()).collect();
        assert_eq!(
            terms,
            vec![
                Term::from_exps(&[2, 0]),
                Term::from_exps(&[1, 1]),
                Term::from_exps(&[0, 2]),
            ]
        );
    }

    /// If x1 was claimed as a leading term (not in O), any multiple of x1
    /// is excluded from later borders.
    #[test]
    fn missing_divisor_excludes_candidates() {
        let mut o = TermSet::with_one(2);
        o.push_product(0, 0).unwrap(); // only x0 ∈ O; x1 became a generator
        let border = compute_border(&o, 2);
        let terms: Vec<Term> = border.iter().map(|b| b.term.clone()).collect();
        assert_eq!(terms, vec![Term::from_exps(&[2, 0])]); // x0x1, x1² excluded
    }

    /// Empty border when the last degree produced no O terms.
    #[test]
    fn empty_border_terminates() {
        let o = TermSet::with_one(3); // degree-0 only
        assert!(compute_border(&o, 2).is_empty());
    }

    #[test]
    fn property_border_invariants() {
        property(32, |rng| {
            let n = 1 + rng.below(4);
            let mut o = TermSet::with_one(n);
            let mut d = 1u32;
            // simulate a few OAVI degrees with random accept/reject
            for _ in 0..3 {
                let border = compute_border(&o, d);
                // (1) sorted DegLex, no duplicates
                for w in border.windows(2) {
                    if w[0].term >= w[1].term {
                        return Err(format!(
                            "border not strictly ascending: {} then {}",
                            w[0].term, w[1].term
                        ));
                    }
                }
                for bt in &border {
                    // (2) degree is exactly d
                    if bt.term.degree() != d {
                        return Err(format!("border term {} has degree != {d}", bt.term));
                    }
                    // (3) not already in O
                    if o.contains(&bt.term) {
                        return Err(format!("border term {} already in O", bt.term));
                    }
                    // (4) recipe is consistent
                    let parent = &o.terms()[bt.parent];
                    if parent.times_var(bt.var) != bt.term {
                        return Err("recipe mismatch".into());
                    }
                    // (5) all maximal divisors in O
                    for k in 0..n {
                        if let Some(div) = bt.term.div_var(k) {
                            if !o.contains(&div) {
                                return Err(format!(
                                    "divisor {div} of {} missing from O",
                                    bt.term
                                ));
                            }
                        }
                    }
                }
                // randomly accept ~60% of border terms into O (DegLex order
                // is preserved because the border is sorted)
                for bt in &border {
                    if rng.uniform() < 0.6 {
                        o.push_product(bt.parent, bt.var).map_err(|e| e.to_string())?;
                    }
                }
                d += 1;
            }
            Ok(())
        });
    }
}

//! Generator polynomials `g = Σ_j c_j t_j + u` (LTC = 1) and generator
//! sets with the paper's reporting statistics (average degree, SPAR).

use crate::backend::{ColumnStore, ComputeBackend, NativeBackend};
use crate::linalg::dense::Matrix;
use crate::poly::eval::TermSet;
use crate::poly::term::Term;

/// A (ψ,1)-approximately vanishing generator.
///
/// `coeffs[j]` multiplies the j-th term of the `TermSet` snapshot the
/// generator was built against (only the first `coeffs.len()` terms of the
/// final O are referenced — O only *grows* during OAVI, so indices stay
/// valid).
#[derive(Clone, Debug)]
pub struct Generator {
    /// Coefficients over the O-prefix (length = |O| at construction time).
    pub coeffs: Vec<f64>,
    /// Leading term u (coefficient 1).
    pub leading: Term,
    /// Recipe for evaluating u on new data: O-index of `u / x_var`.
    pub leading_parent: usize,
    /// Variable such that `u = O[leading_parent] · x_var`.
    pub leading_var: usize,
    /// Training MSE(g, X) at construction.
    pub mse: f64,
}

impl Generator {
    /// Degree of the generator (= degree of its leading term).
    pub fn degree(&self) -> u32 {
        self.leading.degree()
    }

    /// Number of non-leading coefficients (gₑ in (SPAR)).
    pub fn n_coeffs(&self) -> usize {
        self.coeffs.len()
    }

    /// Number of zero non-leading coefficients (g_z in (SPAR)).
    pub fn n_zero_coeffs(&self) -> usize {
        self.coeffs.iter().filter(|c| **c == 0.0).count()
    }

    /// ℓ1 norm of the full coefficient vector (incl. the leading 1).
    pub fn coeff_l1(&self) -> f64 {
        1.0 + self.coeffs.iter().map(|c| c.abs()).sum::<f64>()
    }
}

/// The output of a generator-constructing run on one class:
/// `(G, O) = OAVI(X, ψ)`.
#[derive(Clone, Debug)]
pub struct GeneratorSet {
    pub o_terms: TermSet,
    pub generators: Vec<Generator>,
}

impl GeneratorSet {
    /// `|G| + |O|` — the paper's central size statistic.
    pub fn total_size(&self) -> usize {
        self.generators.len() + self.o_terms.len()
    }

    /// Average degree of the generators (Table 3 row "Degree").
    pub fn avg_degree(&self) -> f64 {
        if self.generators.is_empty() {
            return 0.0;
        }
        self.generators.iter().map(|g| g.degree() as f64).sum::<f64>()
            / self.generators.len() as f64
    }

    /// (SPAR): Σ g_z / Σ gₑ over all generators; larger = sparser.
    pub fn sparsity(&self) -> f64 {
        let (mut gz, mut ge) = (0usize, 0usize);
        for g in &self.generators {
            gz += g.n_zero_coeffs();
            ge += g.n_coeffs();
        }
        if ge == 0 {
            0.0
        } else {
            gz as f64 / ge as f64
        }
    }

    /// Max ℓ1 norm over generator coefficient vectors (generalization
    /// bound diagnostics; must stay ≤ τ for CGAVI variants).
    pub fn max_coeff_l1(&self) -> f64 {
        self.generators.iter().map(|g| g.coeff_l1()).fold(0.0, f64::max)
    }

    /// Assemble the `(A, C, U)` operands of the (FT) kernel `|A·C + U|`
    /// over `x`: A = the O-term evaluation store, C = the generator
    /// coefficient matrix (zero-padded to the full |O|), U = the leading-
    /// term columns.
    fn transform_operands(&self, x: &Matrix, n_shards: usize) -> (ColumnStore, Matrix, Matrix) {
        let store = self.o_terms.eval_store(x, n_shards);
        let m = x.rows();
        let g = self.generators.len();
        let mut c = Matrix::zeros(store.len(), g);
        let mut u = Matrix::zeros(m, g);
        let mut lead = vec![0.0f64; m];
        for (gi, gen) in self.generators.iter().enumerate() {
            for (j, &cj) in gen.coeffs.iter().enumerate() {
                c.set(j, gi, cj);
            }
            store.fill_product(gen.leading_parent, x, gen.leading_var, &mut lead);
            for (i, &v) in lead.iter().enumerate() {
                u.set(i, gi, v);
            }
        }
        (store, c, u)
    }

    /// Evaluate |g(z)| for every generator over new data — the (FT)
    /// feature block contributed by this class (m × |G|, row-major) —
    /// through an explicit streaming backend (native, sharded, or PJRT).
    pub fn transform_with(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
        let (store, c, u) = self.transform_operands(x, backend.preferred_shards(x.rows()));
        backend.transform_abs(&store, &c, &u)
    }

    /// [`GeneratorSet::transform_with`] written directly into a column
    /// range of the caller's concatenated m×`stride` feature slab (see
    /// [`ComputeBackend::transform_abs_into`]) — the per-class write path
    /// of the pipeline's (FT) concatenation.  Written cells are bitwise
    /// identical to [`GeneratorSet::transform_with`]'s.
    pub fn transform_into(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
        out: &mut [f64],
        stride: usize,
        col_off: usize,
    ) {
        let (store, c, u) = self.transform_operands(x, backend.preferred_shards(x.rows()));
        backend.transform_abs_into(&store, &c, &u, out, stride, col_off);
    }

    /// [`GeneratorSet::transform_with`] on the native reference backend.
    pub fn transform(&self, x: &Matrix) -> Matrix {
        self.transform_with(x, &NativeBackend)
    }

    /// Human-readable polynomial strings — the interpretability payoff of
    /// sparse monomial-aware generators the paper emphasizes (§1).
    /// Coefficients below `tol` are treated as zero.
    pub fn describe(&self, tol: f64) -> Vec<String> {
        self.generators
            .iter()
            .map(|g| {
                let mut s = g.leading.to_string();
                for (j, &c) in g.coeffs.iter().enumerate() {
                    if c.abs() <= tol {
                        continue;
                    }
                    let term = &self.o_terms.terms()[j];
                    let mag = c.abs();
                    let sign = if c >= 0.0 { "+" } else { "-" };
                    if term.degree() == 0 {
                        s.push_str(&format!(" {sign} {mag:.4}"));
                    } else {
                        s.push_str(&format!(" {sign} {mag:.4}*{term}"));
                    }
                }
                s
            })
            .collect()
    }

    /// MSE of every generator over new data (out-sample vanishing check):
    /// column-wise mean square of the (FT) block (|g(z)|² = g(z)²).
    pub fn mse_on(&self, x: &Matrix) -> Vec<f64> {
        let m = x.rows();
        let t = self.transform(x);
        (0..t.cols())
            .map(|gi| {
                (0..m)
                    .map(|i| {
                        let v = t.get(i, gi);
                        v * v
                    })
                    .sum::<f64>()
                    / m as f64
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny hand-checkable generator set over n=1:
    /// O = {1, x0}, generator g = x0² − x0 (vanishes on {0, 1}).
    fn toy() -> GeneratorSet {
        let mut o = TermSet::with_one(1);
        let ix = o.push_product(0, 0).unwrap(); // x0
        let g = Generator {
            coeffs: vec![0.0, -1.0], // 0·1 − 1·x0
            leading: Term::from_exps(&[2]),
            leading_parent: ix,
            leading_var: 0,
            mse: 0.0,
        };
        GeneratorSet { o_terms: o, generators: vec![g] }
    }

    #[test]
    fn stats() {
        let gs = toy();
        assert_eq!(gs.total_size(), 3); // |G|=1, |O|=2
        assert_eq!(gs.avg_degree(), 2.0);
        assert_eq!(gs.sparsity(), 0.5); // one zero of two coefficients
        assert_eq!(gs.max_coeff_l1(), 2.0);
    }

    #[test]
    fn transform_vanishes_on_roots() {
        let gs = toy();
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![0.5]]).unwrap();
        let t = gs.transform(&x);
        assert!(t.get(0, 0).abs() < 1e-15); // g(0) = 0
        assert!(t.get(1, 0).abs() < 1e-15); // g(1) = 0
        assert!((t.get(2, 0) - 0.25).abs() < 1e-15); // |0.25 − 0.5|
    }

    #[test]
    fn mse_on_matches_transform() {
        let gs = toy();
        let x = Matrix::from_rows(&[vec![0.0], vec![0.5], vec![1.0]]).unwrap();
        let mse = gs.mse_on(&x);
        assert_eq!(mse.len(), 1);
        assert!((mse[0] - 0.0625 / 3.0).abs() < 1e-12); // (0 + 0.0625 + 0) / 3
    }

    #[test]
    fn generator_accessors() {
        let gs = toy();
        let g = &gs.generators[0];
        assert_eq!(g.degree(), 2);
        assert_eq!(g.n_coeffs(), 2);
        assert_eq!(g.n_zero_coeffs(), 1);
    }
}

#[cfg(test)]
mod describe_tests {
    use super::*;
    use crate::poly::eval::TermSet;
    use crate::poly::term::Term;

    #[test]
    fn describe_formats_sparse_polynomials() {
        let mut o = TermSet::with_one(2);
        let ix = o.push_product(0, 0).unwrap(); // x0
        let g = Generator {
            coeffs: vec![0.5, -1.0], // 0.5·1 − 1·x0
            leading: Term::from_exps(&[2, 0]),
            leading_parent: ix,
            leading_var: 0,
            mse: 0.0,
        };
        let gs = GeneratorSet { o_terms: o, generators: vec![g] };
        let desc = gs.describe(1e-12);
        // terms appear in O (DegLex) order: constant, then x0
        assert_eq!(desc, vec!["x0^2 + 0.5000 - 1.0000*x0".to_string()]);
        // tol filters small coefficients
        let gs2 = GeneratorSet {
            o_terms: gs.o_terms.clone(),
            generators: vec![Generator { coeffs: vec![1e-15, -1.0], ..gs.generators[0].clone() }],
        };
        assert_eq!(gs2.describe(1e-12), vec!["x0^2 - 1.0000*x0".to_string()]);
    }
}

//! Monomials as exponent vectors with the DegLex total order.

use std::cmp::Ordering;
use std::fmt;

/// A monomial over n variables, stored as an exponent vector.
///
/// The constant-1 monomial is the all-zero vector.  Ordering is
/// degree-lexicographic (DegLex, paper §2.2): lower total degree first;
/// ties broken lexicographically with *earlier variables heavier*, i.e.
/// for degree-2 terms over (t, u, v):
/// `t² < tu < tv < u² < uv < v²`.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Term {
    exps: Box<[u16]>,
    degree: u32,
}

impl Term {
    /// The constant-1 monomial.
    pub fn one(n_vars: usize) -> Self {
        Term { exps: vec![0u16; n_vars].into_boxed_slice(), degree: 0 }
    }

    /// The degree-1 monomial x_j.
    pub fn var(n_vars: usize, j: usize) -> Self {
        let mut exps = vec![0u16; n_vars];
        exps[j] = 1;
        Term { exps: exps.into_boxed_slice(), degree: 1 }
    }

    /// From an explicit exponent vector.
    pub fn from_exps(exps: &[u16]) -> Self {
        let degree = exps.iter().map(|&e| e as u32).sum();
        Term { exps: exps.to_vec().into_boxed_slice(), degree }
    }

    /// Total degree.
    #[inline]
    pub fn degree(&self) -> u32 {
        self.degree
    }

    /// Number of variables.
    #[inline]
    pub fn n_vars(&self) -> usize {
        self.exps.len()
    }

    /// Exponent of variable j.
    #[inline]
    pub fn exp(&self, j: usize) -> u16 {
        self.exps[j]
    }

    /// Exponent vector.
    #[inline]
    pub fn exps(&self) -> &[u16] {
        &self.exps
    }

    /// self * x_j.
    pub fn times_var(&self, j: usize) -> Term {
        let mut exps = self.exps.to_vec();
        exps[j] += 1;
        Term { exps: exps.into_boxed_slice(), degree: self.degree + 1 }
    }

    /// self / x_j, or None if x_j ∤ self.
    pub fn div_var(&self, j: usize) -> Option<Term> {
        if self.exps[j] == 0 {
            return None;
        }
        let mut exps = self.exps.to_vec();
        exps[j] -= 1;
        Some(Term { exps: exps.into_boxed_slice(), degree: self.degree - 1 })
    }

    /// Does `self` divide `other`?
    pub fn divides(&self, other: &Term) -> bool {
        self.exps.iter().zip(other.exps.iter()).all(|(a, b)| a <= b)
    }

    /// Smallest variable index with a positive exponent (None for 𝟙).
    pub fn min_var(&self) -> Option<usize> {
        self.exps.iter().position(|&e| e > 0)
    }

    /// Evaluate at a point.
    pub fn eval(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.exps.len());
        let mut acc = 1.0;
        for (xi, &e) in x.iter().zip(self.exps.iter()) {
            match e {
                0 => {}
                1 => acc *= xi,
                2 => acc *= xi * xi,
                _ => acc *= xi.powi(e as i32),
            }
        }
        acc
    }
}

impl Ord for Term {
    fn cmp(&self, other: &Self) -> Ordering {
        debug_assert_eq!(self.n_vars(), other.n_vars());
        match self.degree.cmp(&other.degree) {
            Ordering::Equal => {}
            ord => return ord,
        }
        // Equal degree: lexicographic with earlier variables heavier —
        // a HIGHER exponent on an earlier variable makes the term SMALLER
        // (t² < tu: (2,0) < (1,1)).
        for (a, b) in self.exps.iter().zip(other.exps.iter()) {
            match b.cmp(a) {
                Ordering::Equal => {}
                ord => return ord,
            }
        }
        Ordering::Equal
    }
}

impl PartialOrd for Term {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl fmt::Debug for Term {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl fmt::Display for Term {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.degree == 0 {
            return write!(f, "1");
        }
        let mut first = true;
        for (j, &e) in self.exps.iter().enumerate() {
            if e == 0 {
                continue;
            }
            if !first {
                write!(f, "*")?;
            }
            first = false;
            if e == 1 {
                write!(f, "x{j}")?;
            } else {
                write!(f, "x{j}^{e}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    fn t(exps: &[u16]) -> Term {
        Term::from_exps(exps)
    }

    #[test]
    fn paper_deglex_example() {
        // 1 < t < u < v < t² < tu < tv < u² < uv < v² < t³ < ...
        let seq = vec![
            t(&[0, 0, 0]),
            t(&[1, 0, 0]),
            t(&[0, 1, 0]),
            t(&[0, 0, 1]),
            t(&[2, 0, 0]),
            t(&[1, 1, 0]),
            t(&[1, 0, 1]),
            t(&[0, 2, 0]),
            t(&[0, 1, 1]),
            t(&[0, 0, 2]),
            t(&[3, 0, 0]),
        ];
        for w in seq.windows(2) {
            assert!(w[0] < w[1], "{} !< {}", w[0], w[1]);
        }
    }

    #[test]
    fn divisibility() {
        let tu = t(&[1, 1, 0]);
        assert!(t(&[1, 0, 0]).divides(&tu));
        assert!(t(&[0, 1, 0]).divides(&tu));
        assert!(!t(&[0, 0, 1]).divides(&tu));
        assert!(t(&[0, 0, 0]).divides(&tu));
        assert_eq!(tu.div_var(0), Some(t(&[0, 1, 0])));
        assert_eq!(tu.div_var(2), None);
    }

    #[test]
    fn times_var_and_min_var() {
        let one = Term::one(3);
        assert_eq!(one.min_var(), None);
        let u = one.times_var(1);
        assert_eq!(u, Term::var(3, 1));
        assert_eq!(u.min_var(), Some(1));
        assert_eq!(u.times_var(1).exp(1), 2);
        assert_eq!(u.times_var(1).degree(), 2);
    }

    #[test]
    fn eval_matches_definition() {
        let term = t(&[2, 0, 1]);
        let x = [0.5, 3.0, 2.0];
        assert!((term.eval(&x) - 0.5f64.powi(2) * 2.0).abs() < 1e-15);
        assert_eq!(Term::one(3).eval(&x), 1.0);
    }

    #[test]
    fn display_formats() {
        assert_eq!(Term::one(2).to_string(), "1");
        assert_eq!(t(&[1, 2]).to_string(), "x0*x1^2");
    }

    #[test]
    fn property_order_is_total_and_multiplicative() {
        property(64, |rng| {
            let n = 1 + rng.below(5);
            let rand_term = |rng: &mut crate::util::rng::Rng| {
                let exps: Vec<u16> = (0..n).map(|_| rng.below(4) as u16).collect();
                Term::from_exps(&exps)
            };
            let a = rand_term(rng);
            let b = rand_term(rng);
            let c_var = rng.below(n);
            // antisymmetry/totality
            use std::cmp::Ordering::*;
            match a.cmp(&b) {
                Less => {
                    if b.cmp(&a) != Greater {
                        return Err("antisymmetry violated".into());
                    }
                    // multiplicative: a < b ⇒ a·x_j < b·x_j
                    if a.times_var(c_var) >= b.times_var(c_var) {
                        return Err(format!("not multiplicative: {a} {b} x{c_var}"));
                    }
                }
                Equal => {
                    if a.exps() != b.exps() {
                        return Err("equal terms with different exps".into());
                    }
                }
                Greater => {}
            }
            // 1 is the global minimum
            if a.degree() > 0 && a <= Term::one(n) {
                return Err(format!("{a} <= 1"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_divisor_is_smaller() {
        property(64, |rng| {
            let n = 1 + rng.below(4);
            let exps: Vec<u16> = (0..n).map(|_| rng.below(4) as u16).collect();
            let term = Term::from_exps(&exps);
            for j in 0..n {
                if let Some(d) = term.div_var(j) {
                    if d >= term {
                        return Err(format!("divisor {d} >= {term}"));
                    }
                    if !d.divides(&term) {
                        return Err(format!("{d} should divide {term}"));
                    }
                }
            }
            Ok(())
        });
    }
}

//! Monomials, term orderings, borders, and generator polynomials.
//!
//! OAVI is *monomial-aware*: it walks terms in degree-lexicographic order
//! (DegLex, paper §2.2), maintains an order ideal `O ⊆ T` of non-leading
//! terms, and constructs generators `g = Σ c_j t_j + u` with `t_j ∈ O`,
//! leading term `u` from the border `∂_d O` (Definition 2.5), and LTC = 1.

pub mod border;
pub mod eval;
pub mod poly;
pub mod term;

pub use border::{compute_border, BorderTerm};
pub use eval::TermSet;
pub use poly::{Generator, GeneratorSet};
pub use term::Term;

//! Order-ideal term sets with one-multiply-per-term evaluation.
//!
//! Every non-constant term OAVI ever touches is `parent · x_j` for a
//! parent already in `O` (O is an order ideal by construction).  Storing
//! that recipe makes evaluating all of `O` over q new points cost one
//! multiply per (term, point) — exactly the O((|G|+|O|)·q) evaluation
//! complexity of Theorem 4.2.

use std::collections::HashMap;

use crate::backend::ColumnStore;
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::poly::term::Term;

/// How a term is produced from earlier ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Recipe {
    /// The constant-1 monomial.
    One,
    /// `terms[parent] * x_var`.
    Product { parent: usize, var: usize },
}

/// An append-only, DegLex-ascending order ideal of terms with recipes.
#[derive(Clone, Debug)]
pub struct TermSet {
    n_vars: usize,
    terms: Vec<Term>,
    recipes: Vec<Recipe>,
    index: HashMap<Term, usize>,
}

impl TermSet {
    /// Start with O = {𝟙} (OAVI Line 2).
    pub fn with_one(n_vars: usize) -> Self {
        let one = Term::one(n_vars);
        let mut index = HashMap::new();
        index.insert(one.clone(), 0);
        TermSet { n_vars, terms: vec![one], recipes: vec![Recipe::One], index }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.terms.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }

    #[inline]
    pub fn n_vars(&self) -> usize {
        self.n_vars
    }

    /// Terms in append (= DegLex) order.
    #[inline]
    pub fn terms(&self) -> &[Term] {
        &self.terms
    }

    #[inline]
    pub fn recipe(&self, i: usize) -> Recipe {
        self.recipes[i]
    }

    /// The full flattened evaluation program, in append (= DegLex) order —
    /// the model-side invariant a compiled transform plan caches once.
    #[inline]
    pub fn recipes(&self) -> &[Recipe] {
        &self.recipes
    }

    /// Index of a term, if present.
    pub fn position(&self, t: &Term) -> Option<usize> {
        self.index.get(t).copied()
    }

    pub fn contains(&self, t: &Term) -> bool {
        self.index.contains_key(t)
    }

    /// Append `parent_idx · x_var`; enforces DegLex-ascending append order
    /// and order-ideal structure (the parent must already be present).
    pub fn push_product(&mut self, parent_idx: usize, var: usize) -> Result<usize> {
        if parent_idx >= self.terms.len() {
            return Err(AviError::Config(format!(
                "push_product: parent {parent_idx} out of range"
            )));
        }
        let term = self.terms[parent_idx].times_var(var);
        if let Some(last) = self.terms.last() {
            if *last >= term {
                return Err(AviError::Config(format!(
                    "push_product: {term} would break DegLex append order (last = {last})"
                )));
            }
        }
        let idx = self.terms.len();
        self.index.insert(term.clone(), idx);
        self.terms.push(term);
        self.recipes.push(Recipe::Product { parent: parent_idx, var });
        Ok(idx)
    }

    /// Evaluate every term over the rows of `x` (m×n) into a row-sharded
    /// [`ColumnStore`] — one column per term, one multiply per (term,
    /// sample), via one reused scratch buffer.  The store is the column
    /// currency every downstream kernel (gram_stats, transform_abs,
    /// Pearson) consumes.
    pub fn eval_store(&self, x: &Matrix, n_shards: usize) -> ColumnStore {
        let m = x.rows();
        let mut store = ColumnStore::new(m, n_shards);
        let mut buf = vec![0.0f64; m];
        for recipe in &self.recipes {
            match *recipe {
                Recipe::One => buf.fill(1.0),
                Recipe::Product { parent, var } => {
                    store.fill_product(parent, x, var, &mut buf);
                }
            }
            store.push_col(&buf);
        }
        store
    }

    /// Evaluate every term at a single point (used by tests/diagnostics).
    pub fn eval_point(&self, x: &[f64]) -> Vec<f64> {
        let mut vals = Vec::with_capacity(self.terms.len());
        for recipe in &self.recipes {
            let v = match *recipe {
                Recipe::One => 1.0,
                Recipe::Product { parent, var } => vals[parent] * x[var],
            };
            vals.push(v);
        }
        vals
    }

    /// Maximum degree currently present.
    pub fn max_degree(&self) -> u32 {
        self.terms.iter().map(|t| t.degree()).max().unwrap_or(0)
    }

    /// Indices of terms with exactly degree d.
    pub fn degree_indices(&self, d: u32) -> Vec<usize> {
        self.terms
            .iter()
            .enumerate()
            .filter(|(_, t)| t.degree() == d)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn sample_x(rng: &mut Rng, m: usize, n: usize) -> Matrix {
        let mut x = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                x.set(i, j, rng.uniform());
            }
        }
        x
    }

    #[test]
    fn with_one_evaluates_to_ones() {
        let ts = TermSet::with_one(3);
        let mut rng = Rng::new(1);
        let x = sample_x(&mut rng, 5, 3);
        let store = ts.eval_store(&x, 2);
        assert_eq!(store.len(), 1);
        assert_eq!(store.col(0), vec![1.0; 5]);
    }

    #[test]
    fn push_product_builds_expected_terms() {
        let mut ts = TermSet::with_one(2);
        let i1 = ts.push_product(0, 0).unwrap(); // x0
        let i2 = ts.push_product(0, 1).unwrap(); // x1
        let i3 = ts.push_product(i1, 0).unwrap(); // x0²
        assert_eq!(ts.terms()[i1], Term::var(2, 0));
        assert_eq!(ts.terms()[i2], Term::var(2, 1));
        assert_eq!(ts.terms()[i3], Term::from_exps(&[2, 0]));
        assert!(ts.contains(&Term::from_exps(&[2, 0])));
        assert_eq!(ts.position(&Term::var(2, 1)), Some(i2));
    }

    #[test]
    fn push_product_rejects_order_violation() {
        let mut ts = TermSet::with_one(2);
        ts.push_product(0, 1).unwrap(); // x1 first
        // now x0 < x1 would break append order
        assert!(ts.push_product(0, 0).is_err());
    }

    #[test]
    fn eval_store_matches_direct_term_eval() {
        property(32, |rng| {
            let n = 1 + rng.below(4);
            let mut ts = TermSet::with_one(n);
            // grow a random order ideal: repeatedly multiply a random
            // existing term by a var, skipping order violations
            for _ in 0..12 {
                let parent = rng.below(ts.len());
                let var = rng.below(n);
                let _ = ts.push_product(parent, var);
            }
            let m = 6;
            let shards = 1 + rng.below(4);
            let x = sample_x(rng, m, n);
            let store = ts.eval_store(&x, shards);
            for (ti, term) in ts.terms().iter().enumerate() {
                let col = store.col(ti);
                for i in 0..m {
                    let direct = term.eval(x.row(i));
                    if (col[i] - direct).abs() > 1e-12 {
                        return Err(format!(
                            "term {term} at row {i}: {} vs {}",
                            col[i], direct
                        ));
                    }
                }
            }
            // eval_point agrees with the store columns
            let point_vals = ts.eval_point(x.row(0));
            for (ti, v) in point_vals.iter().enumerate() {
                if (store.col(ti)[0] - v).abs() > 1e-12 {
                    return Err("eval_point mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn degree_queries() {
        let mut ts = TermSet::with_one(2);
        let i1 = ts.push_product(0, 0).unwrap();
        ts.push_product(0, 1).unwrap();
        ts.push_product(i1, 0).unwrap();
        assert_eq!(ts.max_degree(), 2);
        assert_eq!(ts.degree_indices(1).len(), 2);
        assert_eq!(ts.degree_indices(2).len(), 1);
        assert_eq!(ts.degree_indices(0), vec![0]);
    }
}

//! Cholesky factorization for SPD Gram matrices.
//!
//! Used as (a) the rebuild path when the IHB block-inverse update hits a
//! non-positive Schur complement (numerical rank deficiency), and (b) the
//! ground truth in IHB parity tests.

use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor an SPD matrix. Fails if a pivot is non-positive.
    pub fn new(a: &Matrix) -> Result<Self> {
        if a.rows() != a.cols() {
            return Err(AviError::Linalg("cholesky: non-square".into()));
        }
        let n = a.rows();
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(AviError::Linalg(format!(
                            "cholesky: pivot {s:.3e} <= 0 at {i}"
                        )));
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.get(j, j));
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Factor with diagonal jitter escalation: tries `a + jitter·I` with
    /// jitter ∈ {0, ε, 10ε, …} until the factorization succeeds.
    pub fn new_with_jitter(a: &Matrix, base: f64) -> Result<(Self, f64)> {
        if let Ok(c) = Cholesky::new(a) {
            return Ok((c, 0.0));
        }
        let mut jitter = base.max(1e-12);
        for _ in 0..12 {
            let mut aj = a.clone();
            for i in 0..a.rows() {
                let v = aj.get(i, i);
                aj.set(i, i, v + jitter);
            }
            if let Ok(c) = Cholesky::new(&aj) {
                return Ok((c, jitter));
            }
            jitter *= 10.0;
        }
        Err(AviError::Linalg("cholesky: jitter escalation exhausted".into()))
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        debug_assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.get(i, k) * y[k];
            }
            y[i] = s / self.l.get(i, i);
        }
        // backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l.get(k, i) * x[k];
            }
            x[i] = s / self.l.get(i, i);
        }
        x
    }

    /// A^{-1} via n solves against unit vectors.
    pub fn inverse(&self) -> Matrix {
        let n = self.l.rows();
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let x = self.solve(&e);
            for i in 0..n {
                inv.set(i, j, x[i]);
            }
            e[j] = 0.0;
        }
        inv
    }

    /// log det A = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{all_close, property};
    use crate::util::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Matrix {
        let mut a = Matrix::zeros(n + 2, n);
        for i in 0..n + 2 {
            for j in 0..n {
                a.set(i, j, rng.normal());
            }
        }
        let mut g = a.gram();
        for i in 0..n {
            g.set(i, i, g.get(i, i) + 0.1);
        }
        g
    }

    #[test]
    fn solve_recovers_known_solution() {
        let a = Matrix::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]).unwrap();
        let c = Cholesky::new(&a).unwrap();
        let x = c.solve(&[8.0, 7.0]);
        // A x = b exact: x = [1.25, 1.5]
        assert!((x[0] - 1.25).abs() < 1e-12);
        assert!((x[1] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]).unwrap();
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn jitter_recovers_singular() {
        // rank-1 PSD matrix
        let a = Matrix::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]).unwrap();
        let (c, jitter) = Cholesky::new_with_jitter(&a, 1e-10).unwrap();
        assert!(jitter > 0.0);
        let _ = c.solve(&[1.0, 1.0]);
    }

    #[test]
    fn property_inverse_roundtrip() {
        property(24, |rng| {
            let n = rng.below(8) + 1;
            let g = random_spd(rng, n);
            let c = Cholesky::new(&g).map_err(|e| e.to_string())?;
            let inv = c.inverse();
            let prod = g.matmul(&inv).map_err(|e| e.to_string())?;
            let eye = Matrix::eye(n);
            all_close(prod.data(), eye.data(), 1e-6, "G G^{-1} = I")
        });
    }

    #[test]
    fn property_solve_matches_matvec() {
        property(24, |rng| {
            let n = rng.below(10) + 1;
            let g = random_spd(rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = g.matvec(&x_true);
            let c = Cholesky::new(&g).map_err(|e| e.to_string())?;
            all_close(&c.solve(&b), &x_true, 1e-6, "solve")
        });
    }

    #[test]
    fn log_det_of_identity_is_zero() {
        let c = Cholesky::new(&Matrix::eye(5)).unwrap();
        assert!(c.log_det().abs() < 1e-12);
    }
}

//! Wide-lane dot-product bricks: the SIMD-shaped building blocks of the
//! panel kernel family in `backend/store.rs`.
//!
//! Everything here is organized around one invariant, the **per-entry
//! dot discipline**: every f64 result produced by these bricks is
//! bitwise equal to [`crate::linalg::dot`] of the two slices involved.
//! `dot` fixes a schedule — four lane accumulators over the `n/4`
//! 4-element chunks, lane combine `(s0+s1)+(s2+s3)`, then a sequential
//! `n%4` tail — and each brick reproduces exactly that schedule *per
//! output entry*, no matter how many columns share a pass over the
//! right-hand side ([`dotn`]) or how the row range is tiled
//! ([`lanes_update`]/[`lanes_finish`] with carried lane state).  Width
//! and tiling therefore change wall-clock only, never bits — the
//! property the panel parity suite (`rust/tests/runtime_parity.rs`,
//! `rust/tests/kernel_parity.rs`) pins down.
//!
//! Two degrees of freedom are exposed:
//!
//! * **Column width** — [`dotn`] computes N dots sharing one streaming
//!   pass over `b` (N = 4 and N = 8 are the bricks `store::dots_into`
//!   selects between by shard size).  Each of the N columns keeps its
//!   own `[f64; 4]` lane state, so widening never perturbs a column's
//!   bits; it only amortizes the (cache-missing past the LLC) `b`
//!   traffic across more columns.
//! * **Row tiling** — [`lanes_update`] advances a column's four lanes
//!   over any 4-multiple row tile, and [`lanes_finish`] performs the
//!   lane combine plus the final `< 4`-row sequential tail.  Because
//!   tile boundaries fall on multiples of 4, element `g` lands in lane
//!   `g % 4` in ascending-`g` order exactly as in the single-pass
//!   `dot`, so carrying lanes across L1/L2-sized row blocks (the tiled
//!   panel kernel) is bit-transparent.
//!
//! The opt-in **fast path** ([`dot_fast`]) deliberately breaks the
//! discipline: products are accumulated in f32 within
//! [`FAST_TILE_ROWS`]-row tiles (8 f32 lanes, freely reassociable) and
//! carried across tiles in f64, bounding the accumulation error by
//! O(`FAST_TILE_ROWS` · ε_f32) per tile independent of m.  It is only
//! reachable through `NumericsMode::Fast`, which the driver guards with
//! a measured error budget against the f64 reference.

use std::array;

/// Row-tile length for the f32 fast-path accumulation: error grows with
/// the number of f32 additions per tile, so the tile bounds it at
/// O(`FAST_TILE_ROWS` · ε_f32) regardless of total row count.
pub const FAST_TILE_ROWS: usize = 4096;

/// Advance one column's four dot lanes over a 4-multiple row tile.
///
/// `a.len() == b.len()` and `a.len() % 4 == 0`; element `j` of the tile
/// accumulates into lane `j % 4`, matching [`crate::linalg::dot`]'s
/// chunk loop.  Calling this over consecutive tiles `[0, t1), [t1, t2),
/// …` (each boundary a multiple of 4) leaves `l` bitwise equal to the
/// lane state of one un-tiled pass.
#[inline]
pub fn lanes_update(l: &mut [f64; 4], a: &[f64], b: &[f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % 4, 0, "lane tiles must be 4-multiples");
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        l[0] += a[j] * b[j];
        l[1] += a[j + 1] * b[j + 1];
        l[2] += a[j + 2] * b[j + 2];
        l[3] += a[j + 3] * b[j + 3];
    }
}

/// Combine four carried lanes and fold in the `< 4`-row sequential
/// tail — exactly `dot`'s `(s0+s1)+(s2+s3)` + tail epilogue, so the
/// result is bitwise [`crate::linalg::dot`] of the full (tiles + tail)
/// row range.
#[inline]
pub fn lanes_finish(l: [f64; 4], a_tail: &[f64], b_tail: &[f64]) -> f64 {
    debug_assert_eq!(a_tail.len(), b_tail.len());
    debug_assert!(a_tail.len() < 4, "tail must be the n % 4 remainder");
    let mut s = (l[0] + l[1]) + (l[2] + l[3]);
    for (x, y) in a_tail.iter().zip(b_tail.iter()) {
        s += x * y;
    }
    s
}

/// Advance N columns' lane states over one 4-multiple row tile sharing
/// a single pass over `b` — the generic wide brick behind `dot4`/`dot8`.
///
/// `lanes.len() == N`; each column's `[f64; 4]` evolves exactly as a
/// solo [`lanes_update`] would (the width only interleaves independent
/// accumulators), so per-column bits are width-invariant.
#[inline]
pub fn dotn_update<const N: usize>(lanes: &mut [[f64; 4]], cols: &[&[f64]; N], b: &[f64]) {
    debug_assert_eq!(lanes.len(), N);
    debug_assert_eq!(b.len() % 4, 0, "lane tiles must be 4-multiples");
    let chunks = b.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        let (b0, b1, b2, b3) = (b[j], b[j + 1], b[j + 2], b[j + 3]);
        for (l, col) in lanes.iter_mut().zip(cols.iter()) {
            debug_assert_eq!(col.len(), b.len());
            l[0] += col[j] * b0;
            l[1] += col[j + 1] * b1;
            l[2] += col[j + 2] * b2;
            l[3] += col[j + 3] * b3;
        }
    }
}

/// N dots sharing one pass over `b`: `out[w]` is bitwise equal to
/// [`crate::linalg::dot`]`(cols[w], b)` for every width N.
pub fn dotn<const N: usize>(cols: &[&[f64]; N], b: &[f64]) -> [f64; N] {
    let n = b.len();
    let full = n & !3usize;
    let mut lanes = [[0.0f64; 4]; N];
    let heads: [&[f64]; N] = array::from_fn(|w| &cols[w][..full]);
    dotn_update(&mut lanes, &heads, &b[..full]);
    array::from_fn(|w| lanes_finish(lanes[w], &cols[w][full..], &b[full..]))
}

/// One f32-accumulated row tile of the fast path: 8 f32 lanes over the
/// `n/8` chunks, freely combined, sequential f32 tail.  No bitwise
/// contract — callers carry the per-tile sums in f64 ([`dot_fast`]).
#[inline]
fn dot_fast_tile(a: &[f64], b: &[f64]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut l = [0.0f32; 8];
    for i in 0..chunks {
        let j = i * 8;
        for (w, lw) in l.iter_mut().enumerate() {
            *lw += (a[j + w] as f32) * (b[j + w] as f32);
        }
    }
    let mut s = ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]));
    for j in chunks * 8..n {
        s += (a[j] as f32) * (b[j] as f32);
    }
    s
}

/// Mixed-precision dot: f32 accumulation within [`FAST_TILE_ROWS`]-row
/// tiles, f64 carry across tiles.  The `NumericsMode::Fast` kernel
/// brick — approximate by design, guarded at fit time by the driver's
/// measured error budget against the exact f64 reference.
pub fn dot_fast(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = 0.0f64;
    let mut t0 = 0usize;
    while t0 < n {
        let t1 = (t0 + FAST_TILE_ROWS).min(n);
        acc += f64::from(dot_fast_tile(&a[t0..t1], &b[t0..t1]));
        t0 = t1;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;
    use crate::util::rng::Rng;

    fn vecs(rng: &mut Rng, n: usize, count: usize) -> Vec<Vec<f64>> {
        (0..count).map(|_| (0..n).map(|_| rng.normal()).collect()).collect()
    }

    #[test]
    fn dotn_is_bitwise_dot_for_all_widths_and_tails() {
        let mut rng = Rng::new(41);
        // lengths straddling both the 4-chunk and 8-chunk boundaries
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 63, 64, 65, 66, 67, 257] {
            let cols = vecs(&mut rng, n, 8);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let c2: [&[f64]; 2] = [&cols[0], &cols[1]];
            let c4: [&[f64]; 4] = [&cols[0], &cols[1], &cols[2], &cols[3]];
            let c8: [&[f64]; 8] = std::array::from_fn(|w| cols[w].as_slice());
            let d2 = dotn(&c2, &b);
            let d4 = dotn(&c4, &b);
            let d8 = dotn(&c8, &b);
            for (w, col) in cols.iter().enumerate() {
                let want = dot(col, &b).to_bits();
                if w < 2 {
                    assert_eq!(d2[w].to_bits(), want, "dotn::<2> lane {w} at n={n}");
                }
                if w < 4 {
                    assert_eq!(d4[w].to_bits(), want, "dotn::<4> lane {w} at n={n}");
                }
                assert_eq!(d8[w].to_bits(), want, "dotn::<8> lane {w} at n={n}");
            }
        }
    }

    #[test]
    fn carried_lanes_across_tiles_are_bitwise_dot() {
        let mut rng = Rng::new(43);
        for n in [0usize, 3, 4, 11, 12, 37, 64, 101, 130] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let full = n & !3usize;
            // tile the lane region at several 4-multiple granularities,
            // including tiles that don't divide the region evenly
            for tile in [4usize, 8, 12, 20, 64] {
                let mut l = [0.0f64; 4];
                let mut t0 = 0usize;
                while t0 < full {
                    let t1 = (t0 + tile).min(full);
                    lanes_update(&mut l, &a[t0..t1], &b[t0..t1]);
                    t0 = t1;
                }
                let got = lanes_finish(l, &a[full..], &b[full..]);
                assert_eq!(
                    got.to_bits(),
                    dot(&a, &b).to_bits(),
                    "tiled lanes diverge at n={n} tile={tile}"
                );
            }
        }
    }

    #[test]
    fn dot_fast_is_close_on_benign_data() {
        let mut rng = Rng::new(47);
        let n = 3 * FAST_TILE_ROWS + 117; // several tiles + ragged tail
        let a: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let exact = dot(&a, &b);
        let fast = dot_fast(&a, &b);
        // uniform [0,1) products: |exact| ~ n/4; f32 tile accumulation
        // keeps the relative error far below 1e-3
        assert!(
            (fast - exact).abs() <= 1e-3 * exact.abs().max(1.0),
            "fast dot off by {} (exact {exact})",
            (fast - exact).abs()
        );
    }
}
